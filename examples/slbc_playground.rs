//! SLBC playground: inspect the packed-arithmetic machinery (§IV) layer
//! by layer.
//!
//! Shows, for a chosen `(weight-bits, activation-bits)` pair:
//! * the polynomial packing identity on a small 1-D convolution;
//! * the adaptive lane plan (lane size / field stride / MACs-per-multiply);
//! * naive-SLBC vs reordered-SLBC segmentation counts (Theorem IV.1);
//! * the resulting equivalent-ops landscape over the full (w,a) grid.
//!
//! Run with `cargo run --release --example slbc_playground -- --wbits 4 --abits 4`.

use mcu_mixq::simd::adaptive::{best_plan, cmixnn_equivalent_ops, slbc_equivalent_ops};
use mcu_mixq::simd::poly::{conv1d_full_direct, conv1d_full_packed};
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::cli::Args;
use mcu_mixq::util::prng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let wbits = args.usize_or("wbits", 4) as u32;
    let abits = args.usize_or("abits", 4) as u32;
    let k_taps = args.usize_or("taps", 3) as u32;

    // --- 1. the packing identity --------------------------------------
    let mut rng = Rng::new(args.u64_or("seed", 1));
    let x: Vec<u64> = (0..12).map(|_| rng.below(1 << abits)).collect();
    let k: Vec<u64> = (0..k_taps as usize).map(|_| rng.below(1 << wbits)).collect();
    let direct = conv1d_full_direct(&x, &k);
    let packed = conv1d_full_packed(&x, &k, abits, wbits);
    println!("x = {x:?}");
    println!("k = {k:?}");
    println!("conv (direct) = {direct:?}");
    println!("conv (packed) = {packed:?}");
    assert_eq!(direct, packed, "Eq. 3–7 identity violated!");
    println!("✓ one wide multiply reproduced the whole convolution\n");

    // --- 2. the adaptive lane plan -------------------------------------
    let plan = best_plan(abits, wbits, k_taps).expect("plan exists for 2..=8 bits");
    println!("adaptive lane plan for a={abits}b w={wbits}b k={k_taps}:");
    println!(
        "  register {}b, lanes of {}b ({} lanes), field stride {}b",
        plan.cfg.register_bits,
        plan.cfg.lane_bits,
        plan.cfg.lanes(),
        plan.field
    );
    println!(
        "  {} MACs per multiply, accumulation depth {}, cost/MAC {:.3}",
        plan.macs_per_instr, plan.accum_depth, plan.cost_per_mac
    );
    if let Some(rp) = &plan.reordered {
        println!(
            "  segmentation: naive {} ops/instr → reordered {} ops/instr ({:.0}% kept)",
            plan.conv.seg_ops_per_instr(),
            rp.seg_ops_per_instr(),
            rp.seg_reduction_vs_naive() * 100.0
        );
    } else {
        println!("  (geometry admits no reordered plan at this width)");
    }

    // --- 3. the (w,a) equivalent-ops landscape (Fig. 6's raw data) -----
    println!("\nequivalent ops per instruction slot (SLBC / CMix-NN):");
    let mut t = Table::new(
        std::iter::once("w\\a".to_string())
            .chain((2..=8).map(|a| format!("{a}b")))
            .collect::<Vec<_>>(),
    );
    for w in 2..=8u32 {
        let mut row = vec![format!("{w}b")];
        for a in 2..=8u32 {
            let s = slbc_equivalent_ops(w, a, k_taps);
            let c = cmixnn_equivalent_ops(w, a);
            row.push(format!("{s:.1}/{c:.1}"));
        }
        t.row(row);
    }
    t.print();
    println!("(larger is better; SLBC ≥ CMix-NN everywhere, biggest at low bits)");
}
