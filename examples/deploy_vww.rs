//! End-to-end driver (the EXPERIMENTS.md §End-to-end run).
//!
//! The full MCU-MixQ workflow on MobileNet-Tiny × synth-VWW (Table I
//! row 2 pairing):
//!
//! 1. differentiable hardware-aware quantization search (a few hundred
//!    PJRT supernet steps, loss curve logged);
//! 2. argmax sub-net selection;
//! 3. quantization-aware training of the selected config (loss curve
//!    logged);
//! 4. deployment on the simulated STM32F746 through the TinyEngine-like
//!    engine, against the CMix-NN / WPC&DDD / TinyEngine baselines;
//! 5. the Table I comparison row plus headline speedups.
//!
//! All three layers compose here: the Pallas fake-quant kernels inside the
//! JAX-lowered HLO programs (L1/L2), PJRT execution + NAS + deployment in
//! Rust (L3). Run with
//! `cargo run --release --example deploy_vww -- --search-steps 200 --qat-steps 300`.

use mcu_mixq::coordinator::{self, PipelineCfg};
use mcu_mixq::runtime::{ArtifactStore, Runtime};
use mcu_mixq::util::cli::Args;

fn main() -> mcu_mixq::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let store = ArtifactStore::open(args.str_or("artifacts", "artifacts"))?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    let backbone = args.str_or("backbone", "mobilenet_tiny");
    let mut cfg = PipelineCfg::new(&backbone);
    cfg.search.steps = args.usize_or("search-steps", 200);
    cfg.qat.steps = args.usize_or("qat-steps", 300);
    cfg.search.seed = args.u64_or("seed", cfg.search.seed);

    println!(
        "== MCU-MixQ pipeline: {} ({} search + {} QAT steps) ==",
        backbone, cfg.search.steps, cfg.qat.steps
    );
    let t0 = std::time::Instant::now();
    let report = coordinator::run_pipeline(&rt, &store, &cfg)?;

    println!("\n-- supernet search loss curve --");
    for log in &report.search_history {
        println!(
            "  step {:>4}  loss {:.4}  ce {:.4}  comp {:.4}  acc {:.3}",
            log.step, log.loss, log.ce, log.comp, log.acc
        );
    }
    println!(
        "selected config: w={:?} a={:?} (branch entropy {:.2})",
        report.searched_wbits, report.searched_abits, report.final_entropy
    );

    println!("\n-- QAT loss curve --");
    for log in &report.qat_history {
        println!(
            "  step {:>4}  loss {:.4}  acc {:.3}",
            log.step, log.loss, log.acc
        );
    }
    println!("QAT eval accuracy: {:.1}%", report.qat_eval_acc * 100.0);

    println!("\n-- deployment comparison (Table I) --");
    println!(
        "{}",
        coordinator::deploy::render_rows(&backbone, &report.rows)
    );
    for (m, s) in &report.speedups {
        println!("MCU-MixQ speedup over {m}: {s:.2}x");
    }
    println!("\npipeline wall-clock: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
