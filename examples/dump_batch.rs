//! Debug helper: dump synthetic batches to raw f32/i32 files.
use mcu_mixq::datasets::{generate, Task};
use std::io::Write;

fn main() {
    let n = 512;
    let b = generate(Task::SynthCifar, n, 16, 4321);
    let mut f = std::fs::File::create("/tmp/cifar_x.bin").unwrap();
    for v in &b.images { f.write_all(&v.to_le_bytes()).unwrap(); }
    let mut f = std::fs::File::create("/tmp/cifar_y.bin").unwrap();
    for v in &b.labels { f.write_all(&v.to_le_bytes()).unwrap(); }
    println!("dumped {} images", n);
}
