//! Hardware-aware quantization search (Fig. 8 reproduction, single run).
//!
//! Runs the differentiable supernet search twice on the same backbone —
//! once with the EdMIPS MAC-count proxy, once with the SIMD-aware Eq. 12
//! model — and prints the two searched bitwidth profiles side by side,
//! plus their predicted MCU latency. This is the experiment behind the
//! paper's claim that an implementation-aware cost signal quantizes
//! *lower* where packing is cheap without giving up accuracy.
//!
//! Run with
//! `cargo run --release --example nas_search -- --backbone vgg_tiny --steps 120`.

use mcu_mixq::coordinator::{SearchCfg, SupernetSearch};
use mcu_mixq::mcu::CycleModel;
use mcu_mixq::nas::CostProxy;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::PerfModel;
use mcu_mixq::runtime::{ArtifactStore, Runtime};
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::cli::Args;

fn main() -> mcu_mixq::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let store = ArtifactStore::open(args.str_or("artifacts", "artifacts"))?;
    let rt = Runtime::cpu()?;
    let arts = store.backbone(&args.str_or("backbone", "vgg_tiny"))?;

    let mut cfg = SearchCfg::default();
    cfg.steps = args.usize_or("steps", 120);
    cfg.lam = args.f32_or("lam", cfg.lam);
    cfg.seed = args.u64_or("seed", cfg.seed);

    let pm = PerfModel::cortex_m7();
    let proxies = [
        CostProxy::EdMipsMacs,
        CostProxy::SimdAware(pm, Method::RpSlbc),
    ];
    let mut outcomes = Vec::new();
    for proxy in proxies {
        println!("=== searching with {} ===", proxy.name());
        let search = SupernetSearch::new(&rt, &arts, proxy, cfg.seed)?;
        let out = search.run(&cfg)?;
        for log in &out.history {
            println!(
                "  step {:>4}  loss {:.4}  ce {:.4}  comp {:.4}  acc {:.3}",
                log.step, log.loss, log.ce, log.comp, log.acc
            );
        }
        outcomes.push(out);
    }

    // Side-by-side per-layer profile (the Fig. 8 bars).
    println!("\n=== searched quantization profiles ({}) ===", arts.model.name);
    let mut t = Table::new(vec![
        "layer", "EdMIPS w", "EdMIPS a", "SIMD-aware w", "SIMD-aware a",
    ]);
    for (i, l) in arts.model.layers.iter().enumerate() {
        t.row(vec![
            l.name.clone(),
            format!("{}", outcomes[0].config.wbits[i]),
            format!("{}", outcomes[0].config.abits[i]),
            format!("{}", outcomes[1].config.wbits[i]),
            format!("{}", outcomes[1].config.abits[i]),
        ]);
    }
    t.print();

    let cm = CycleModel::cortex_m7();
    let pm = PerfModel::from_cycles(&cm);
    for (name, out) in ["EdMIPS", "SIMD-aware"].iter().zip(&outcomes) {
        let cost = pm.model_complexity(&arts.model, Method::RpSlbc, &out.config);
        println!(
            "{name:<11} avg bits w={:.2} a={:.2}  predicted SLBC complexity {:.3e}  entropy {:.2}",
            out.config.avg_wbits(),
            out.config.avg_abits(),
            cost,
            out.final_entropy
        );
    }
    Ok(())
}
