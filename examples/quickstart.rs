//! Quickstart: the MCU-MixQ public API in ~60 lines.
//!
//! 1. Pick a backbone and a mixed-precision bit configuration.
//! 2. Predict its MCU cost with the Eq. 12 performance model.
//! 3. Deploy it on the simulated STM32F746 through the engine and compare
//!    the prediction with the measured cycle count.
//!
//! Run with `cargo run --release --example quickstart`.

use mcu_mixq::engine;
use mcu_mixq::models;
use mcu_mixq::ops::Method;
use mcu_mixq::perf::PerfModel;
use mcu_mixq::quant::BitConfig;
use mcu_mixq::util::prng::Rng;

fn main() -> mcu_mixq::Result<()> {
    // A VGG-style compact backbone (Table I row 1 geometry).
    let model = models::vgg_tiny(10, 16);
    println!(
        "backbone: {} ({} layers, {} params, {} MACs)",
        model.name,
        model.num_layers(),
        model.param_count,
        model.total_macs()
    );

    // A mixed 2–8-bit configuration (what the NAS would emit).
    let cfg = BitConfig {
        wbits: vec![4, 3, 4, 3, 2, 8],
        abits: vec![8, 4, 4, 4, 4, 8],
    };
    println!(
        "config: w={:?} a={:?} (avg {:.2}/{:.2} bits)",
        cfg.wbits,
        cfg.abits,
        cfg.avg_wbits(),
        cfg.avg_abits()
    );

    // Predict the deployment cost analytically (Eq. 12)...
    let pm = PerfModel::cortex_m7();
    let predicted = pm.model_complexity(&model, Method::RpSlbc, &cfg);
    println!("Eq.12 predicted complexity: {predicted:.0} SISD-equivalents");

    // ...then actually deploy on the simulated MCU and measure.
    let mut rng = Rng::new(42);
    let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
    let image: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.f32()).collect();
    let report = engine::deploy(&model, &params, &cfg, Method::RpSlbc, &image)?;
    println!(
        "deployed via {}: {} cycles = {:.2} ms @216MHz, peak SRAM {:.1} KB, flash {:.1} KB",
        report.method.name(),
        report.cycles,
        report.latency_ms,
        report.peak_sram as f64 / 1024.0,
        report.flash_bytes as f64 / 1024.0
    );

    // And the same model as int8 TinyEngine for contrast.
    let cfg8 = BitConfig::uniform(model.num_layers(), 8);
    let tiny = engine::deploy(&model, &params, &cfg8, Method::TinyEngine, &image)?;
    println!(
        "int8 TinyEngine baseline: {} cycles = {:.2} ms  →  MCU-MixQ speedup {:.2}x",
        tiny.cycles,
        tiny.latency_ms,
        tiny.cycles as f64 / report.cycles as f64
    );
    Ok(())
}
