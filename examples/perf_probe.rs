use mcu_mixq::engine;
use mcu_mixq::mcu::CycleModel;
use mcu_mixq::models::vgg_tiny;
use mcu_mixq::ops::Method;
use mcu_mixq::quant::{quantize_model, BitConfig};
use mcu_mixq::util::prng::Rng;
use std::time::Instant;

fn main() {
    let m = vgg_tiny(10, 16);
    let mut rng = Rng::new(1);
    let flat: Vec<f32> = (0..m.param_count).map(|_| rng.normal() * 0.1).collect();
    let img: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.f32()).collect();
    let cm = CycleModel::cortex_m7();
    for method in [Method::RpSlbc, Method::TinyEngine, Method::Naive] {
        for bits in [4u8, 8] {
            if !method.supports(bits, bits) { continue; }
            let cfg = BitConfig::uniform(m.num_layers(), bits);
            let q = quantize_model(&m, &flat, &cfg);
            // warmup
            engine::infer(&m, &q, &cfg, method, &img, &cm).unwrap();
            let iters = 20;
            let t0 = Instant::now();
            for _ in 0..iters {
                engine::infer(&m, &q, &cfg, method, &img, &cm).unwrap();
            }
            let dt = t0.elapsed().as_secs_f64() / iters as f64;
            let macs_s = m.total_macs() as f64 / dt;
            println!("{:<11} {}bit: {:>8.2} ms/infer, {:.2e} simulated MACs/s", method.name(), bits, dt*1e3, macs_s);
        }
    }
}
