//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The workspace builds without network access, so instead of the
//! crates.io `anyhow` this vendored shim provides exactly the surface the
//! repository uses: [`Error`] with a context chain, [`Result`], the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!`,
//! `bail!` and `ensure!` macros. `{:#}` formatting renders the whole
//! chain (`outer: inner: root`), matching anyhow's alternate Display.

use std::error::Error as StdError;
use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: every std error converts into `Error`, capturing its
// source chain. (`Error` itself intentionally does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_render_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("opening config")
            .unwrap_err()
            .context("starting up");
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: opening config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{:#}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{:#}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("val {}", 7);
        assert_eq!(format!("{e}"), "val 7");
    }

    #[test]
    fn question_mark_converts() {
        fn g() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?;
            Ok(s)
        }
        assert!(g().is_err());
    }
}
