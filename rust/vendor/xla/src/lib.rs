//! Offline stub of the `xla-rs` surface used by this repository.
//!
//! The real dependency wraps XLA's PJRT C API and needs the
//! `xla_extension` shared library, which is not available in offline
//! builds. This stub keeps the whole crate compiling and the pure parts
//! testable:
//!
//! * [`Literal`] is implemented honestly (typed host tensors with shape
//!   bookkeeping), so every `runtime::lit` helper and its tests behave
//!   exactly as with the real crate;
//! * [`PjRtClient::cpu`] returns an error explaining that PJRT is
//!   unavailable, so anything that would actually execute HLO fails fast
//!   with a clear message instead of segfaulting on a missing plugin.
//!
//! Swapping in a real `xla-rs` checkout (workspace manifest) restores the
//! full Layer-3 behavior; no call site changes.

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla_rs::Error` closely enough for our call sites.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT unavailable: built with the offline xla stub \
                        (swap rust/vendor/xla for a real xla-rs checkout to \
                        execute HLO artifacts)";

/// Typed storage of a host literal.
#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy + Sized {
    const NAME: &'static str;
    fn wrap(v: Vec<Self>) -> Payload;
    fn unwrap_ref(p: &Payload) -> Option<&[Self]>;
}

macro_rules! native {
    ($t:ty, $variant:ident, $name:literal) => {
        impl NativeType for $t {
            const NAME: &'static str = $name;
            fn wrap(v: Vec<Self>) -> Payload {
                Payload::$variant(v)
            }
            fn unwrap_ref(p: &Payload) -> Option<&[Self]> {
                match p {
                    Payload::$variant(v) => Some(v),
                    _ => None,
                }
            }
        }
    };
}

native!(f32, F32, "f32");
native!(i32, I32, "i32");
native!(i64, I64, "i64");

/// A host tensor literal (array or tuple), shape in row-major dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal {
            payload: T::wrap(v.to_vec()),
            dims: vec![v.len() as i64],
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal {
            payload: T::wrap(vec![x]),
            dims: Vec::new(),
        }
    }

    /// Tuple literal.
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal {
            dims: vec![elems.len() as i64],
            payload: Payload::Tuple(elems),
        }
    }

    /// Element count implied by the dims (empty dims = scalar = 1).
    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<i64>() as usize
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::I64(v) => v.len(),
            Payload::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        };
        if want as usize != have {
            return Err(Error(format!(
                "reshape {:?} wants {want} elements, literal has {have}",
                dims
            )));
        }
        Ok(Literal {
            payload: self.payload.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Copy the elements out as a `Vec<T>` (row-major).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_ref(&self.payload)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error(format!("literal does not hold {}", T::NAME)))
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (text is retained verbatim; nothing interprets it
/// offline).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO text artifact. File I/O is real so missing-artifact
    /// errors stay genuine even under the stub.
    pub fn from_text_file(path: &str) -> Result<Self> {
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { text })
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))
    }
}

/// An XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle. Under the stub, construction always fails with a
/// clear message; the accessors exist only so call sites typecheck.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error(STUB_MSG.into()))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.into()))
    }
}

/// Uninhabited: no executable can exist without a real PJRT client, so
/// the execute path is statically unreachable under the stub.
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// Uninhabited for the same reason as [`PjRtLoadedExecutable`].
pub enum PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i64>().is_err());
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(0.5f32);
        assert_eq!(s.element_count(), 1);
        let t = Literal::tuple(vec![s.clone(), Literal::vec1(&[1i64, 2])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_fails_with_clear_message() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("PJRT unavailable"));
    }
}
