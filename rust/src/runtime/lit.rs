//! Literal construction / extraction helpers around the `xla` crate.
//!
//! The Layer-2 programs exchange only four tensor kinds with Rust: f32
//! arrays (params, images, bitwidths, cost tables, hyper-parameters), i32
//! labels, i64 packed-SLBC carriers, and f32 scalars. These helpers keep
//! shape bookkeeping in one place and out of the coordinator loops.

use anyhow::{Context, Result};

/// f32 vector literal of shape `[len]`.
pub fn f32_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 literal reshaped to `shape` (row-major data).
pub fn f32_tensor(v: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let n: i64 = shape.iter().product();
    anyhow::ensure!(
        n as usize == v.len(),
        "shape {:?} wants {} elements, got {}",
        shape,
        n,
        v.len()
    );
    xla::Literal::vec1(v)
        .reshape(shape)
        .context("reshaping f32 literal")
}

/// i32 vector literal of shape `[len]`.
pub fn i32_vec(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// i64 vector literal of shape `[len]` (SLBC packed carriers).
pub fn i64_vec(v: &[i64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 scalar literal (hyper-parameters: lr, lambda, ...).
pub fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a `Vec<f32>` from a literal.
pub fn to_f32_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().context("literal -> Vec<f32>")
}

/// Extract a `Vec<i64>` from a literal.
pub fn to_i64_vec(l: &xla::Literal) -> Result<Vec<i64>> {
    l.to_vec::<i64>().context("literal -> Vec<i64>")
}

/// Extract the single f32 element of a scalar literal.
pub fn to_f32_scalar(l: &xla::Literal) -> Result<f32> {
    let v = to_f32_vec(l)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = f32_vec(&[1.0, 2.5, -3.0]);
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn tensor_shape_checked() {
        assert!(f32_tensor(&[0.0; 6], &[2, 3]).is_ok());
        assert!(f32_tensor(&[0.0; 5], &[2, 3]).is_err());
    }

    #[test]
    fn scalar_roundtrip() {
        let l = f32_scalar(0.125);
        assert_eq!(to_f32_scalar(&l).unwrap(), 0.125);
    }

    #[test]
    fn i64_roundtrip() {
        let l = i64_vec(&[-1, 0, 1 << 40]);
        assert_eq!(to_i64_vec(&l).unwrap(), vec![-1, 0, 1 << 40]);
    }
}
