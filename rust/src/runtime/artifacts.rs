//! Artifact store: `artifacts/manifest.json` + HLO programs + init params.
//!
//! The manifest is written by `python/compile/aot.py` and is the single
//! source of truth for shapes, flat-parameter offsets and batch sizes; the
//! Rust model zoo ([`crate::models`]) is cross-checked against it in the
//! integration tests so the two layers cannot drift silently.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::models::{self, ModelDesc};
use crate::util::json::Json;

use super::{Program, Runtime};

/// Search-space bitwidth options (must equal `model.OPTIONS` on the JAX
/// side; verified when the manifest is opened).
pub const OPTIONS: [u8; 7] = [2, 3, 4, 5, 6, 7, 8];

/// Parsed manifest + artifact directory handle.
pub struct ArtifactStore {
    pub dir: PathBuf,
    manifest: Json,
    /// Bitwidth options shared with Layer 2.
    pub options: Vec<u8>,
    /// SGD momentum baked into the train-step programs.
    pub momentum: f64,
}

impl ArtifactStore {
    /// Open `dir` (typically `artifacts/`) and parse its manifest.
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let src = fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&src).context("parsing manifest.json")?;
        let options: Vec<u8> = manifest
            .req("options")
            .ok()
            .and_then(|o| o.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_usize()).map(|x| x as u8).collect())
            .unwrap_or_else(|| OPTIONS.to_vec());
        anyhow::ensure!(
            options == OPTIONS,
            "manifest options {:?} differ from the Rust search space {:?}",
            options,
            OPTIONS
        );
        let momentum = manifest
            .get("momentum")
            .and_then(|m| m.as_f64())
            .unwrap_or(0.9);
        Ok(ArtifactStore {
            dir,
            manifest,
            options,
            momentum,
        })
    }

    /// Names of the backbones recorded in the manifest.
    pub fn backbone_names(&self) -> Vec<String> {
        match self.manifest.get("backbones") {
            Some(Json::Obj(map)) => map.iter().map(|(k, _)| k.clone()).collect(),
            _ => vec![],
        }
    }

    /// Load the manifest entry (geometry + artifact paths) of one backbone.
    pub fn backbone(&self, name: &str) -> Result<BackboneArtifacts> {
        let entry = self
            .manifest
            .req("backbones")
            .and_then(|b| b.req(name))
            .with_context(|| format!("backbone {name} not in manifest"))?;
        let model = models::from_manifest(name, entry)
            .with_context(|| format!("parsing geometry of {name}"))?;
        let arts = entry.req("artifacts").context("artifacts entry")?;
        let art = |key: &str| -> Result<PathBuf> {
            let rel = arts
                .req(key)
                .ok()
                .and_then(|a| a.as_str().map(str::to_string))
                .with_context(|| format!("artifact {key} missing for {name}"))?;
            Ok(self.dir.join(rel))
        };
        let init_rel = entry
            .req("init")
            .ok()
            .and_then(|a| a.as_str().map(str::to_string))
            .with_context(|| format!("init missing for {name}"))?;
        let get_batch = |key: &str, default: usize| {
            entry.get(key).and_then(|b| b.as_usize()).unwrap_or(default)
        };
        Ok(BackboneArtifacts {
            model,
            qat_step: art("qat_step")?,
            eval: art("eval")?,
            infer: art("infer")?,
            supernet_step: art("supernet_step")?,
            init: self.dir.join(init_rel),
            train_batch: get_batch("train_batch", 64),
            eval_batch: get_batch("eval_batch", 256),
            infer_batch: get_batch("infer_batch", 1),
        })
    }

    /// Metadata of the standalone Layer-1 SLBC demo kernel.
    pub fn slbc_demo(&self) -> Result<SlbcDemoArtifact> {
        let e = self.manifest.req("slbc_demo").context("slbc_demo entry")?;
        let get = |k: &str| -> Result<usize> {
            e.req(k)
                .ok()
                .and_then(|x| x.as_usize())
                .with_context(|| format!("slbc_demo.{k}"))
        };
        let rel = e
            .req("artifact")
            .ok()
            .and_then(|a| a.as_str().map(str::to_string))
            .context("slbc_demo.artifact")?;
        Ok(SlbcDemoArtifact {
            path: self.dir.join(rel),
            n: get("n")?,
            k: get("k")?,
            sx_bits: get("sx_bits")? as u32,
            sk_bits: get("sk_bits")? as u32,
            group_size: get("group_size")? as u32,
            field_width: get("field_width")? as u32,
        })
    }
}

/// One backbone's artifact bundle (paths + geometry + batch sizes).
pub struct BackboneArtifacts {
    pub model: ModelDesc,
    pub qat_step: PathBuf,
    pub eval: PathBuf,
    pub infer: PathBuf,
    pub supernet_step: PathBuf,
    pub init: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub infer_batch: usize,
}

impl BackboneArtifacts {
    /// Load the He-initialised flat f32 parameter vector (`*_init.bin`).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let bytes = fs::read(&self.init)
            .with_context(|| format!("reading {}", self.init.display()))?;
        anyhow::ensure!(
            bytes.len() == self.model.param_count * 4,
            "{}: expected {} f32 ({} bytes), file has {} bytes",
            self.init.display(),
            self.model.param_count,
            self.model.param_count * 4,
            bytes.len()
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Compile the four programs of this backbone on `rt`.
    pub fn load_programs(&self, rt: &Runtime) -> Result<BackbonePrograms> {
        Ok(BackbonePrograms {
            qat_step: rt.load_program(&self.qat_step)?,
            eval: rt.load_program(&self.eval)?,
            infer: rt.load_program(&self.infer)?,
            supernet_step: rt.load_program(&self.supernet_step)?,
        })
    }
}

/// The compiled programs of one backbone.
pub struct BackbonePrograms {
    pub qat_step: Program,
    pub eval: Program,
    pub infer: Program,
    pub supernet_step: Program,
}

/// Manifest entry for the standalone SLBC kernel artifact.
pub struct SlbcDemoArtifact {
    pub path: PathBuf,
    pub n: usize,
    pub k: usize,
    pub sx_bits: u32,
    pub sk_bits: u32,
    pub group_size: u32,
    pub field_width: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full manifest round-trips are integration tests (need artifacts/);
    // here we only check option invariants.

    #[test]
    fn options_match_quant_range() {
        assert_eq!(OPTIONS.first(), Some(&2));
        assert_eq!(OPTIONS.last(), Some(&8));
        assert!(OPTIONS.windows(2).all(|w| w[1] == w[0] + 1));
    }
}
