//! PJRT runtime: load and execute the AOT artifacts from `artifacts/`.
//!
//! This is the only place the crate touches XLA. The interchange format is
//! HLO **text** (`*.hlo.txt`), not a serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which the bundled
//! xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
//! reassigns ids and round-trips cleanly.
//!
//! All Layer-2 programs were lowered with `return_tuple=True`, so every
//! execution returns ONE tuple literal which [`Program::run`] decomposes
//! into its elements.
//!
//! Python never runs at this layer: once `make artifacts` has produced the
//! HLO text + `manifest.json` + `*_init.bin`, the Rust binary is fully
//! self-contained.

pub mod artifacts;
pub mod lit;

pub use artifacts::{ArtifactStore, BackboneArtifacts, SlbcDemoArtifact};

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

/// A PJRT CPU client plus compile bookkeeping.
///
/// Compilation happens once per program ([`Runtime::load_program`]); the
/// compiled executable is then reused for every step of the search / QAT /
/// eval loops, so nothing on the hot path re-enters the compiler.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a PJRT CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string, e.g. `"cpu"` (useful for logs / sanity checks).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO text file and compile it into an executable [`Program`].
    pub fn load_program<P: AsRef<Path>>(&self, path: P) -> Result<Program> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "program".into());
        Ok(Program {
            exe,
            name,
            path: path.to_path_buf(),
            compile_time_s: t0.elapsed().as_secs_f64(),
        })
    }
}

/// One compiled XLA program (e.g. `vgg_tiny_qat_step`).
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact stem, e.g. `vgg_tiny_qat_step.hlo`.
    pub name: String,
    /// Source artifact path.
    pub path: PathBuf,
    /// Wall-clock seconds spent in `client.compile` (reported by the CLI).
    pub compile_time_s: f64,
}

impl Program {
    /// Execute with literal arguments; decompose the output tuple.
    ///
    /// The lowered programs take/return plain arrays; sending literals keeps
    /// the FFI surface trivial. Buffer copies are negligible next to the
    /// conv math for our shapes (measured in EXPERIMENTS.md §Perf).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<L>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        out.to_tuple()
            .with_context(|| format!("untupling result of {}", self.name))
    }

    /// Execute and return exactly `n` outputs (arity check included).
    pub fn run_n<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
        n: usize,
    ) -> Result<Vec<xla::Literal>> {
        let outs = self.run(args)?;
        anyhow::ensure!(
            outs.len() == n,
            "{}: expected {} outputs, got {}",
            self.name,
            n,
            outs.len()
        );
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests (they need `artifacts/`) live in
    // `rust/tests/runtime_integration.rs`; unit tests here cover only the
    // pure helpers.

    #[test]
    fn program_name_from_stem() {
        let p = std::path::Path::new("/x/y/vgg_tiny_eval.hlo.txt");
        let stem = p.file_stem().unwrap().to_string_lossy();
        assert_eq!(stem, "vgg_tiny_eval.hlo");
    }
}
