//! The hardware-aware quantization explorer loop (paper §III.B).
//!
//! Drives the Layer-2 `supernet_train_step` program: per step it feeds a
//! synthetic batch, the **cost table** (EdMIPS MAC proxy or the SIMD-aware
//! Eq. 12 model — the HW/SW co-design seam), and the current training
//! state; the state cycles through PJRT literals without host round-trips.
//! After `steps` iterations the branch logits are pulled back once and the
//! final sub-net is selected by argmax.

use anyhow::Context;

use crate::datasets::Task;
use crate::nas::{self, CostProxy, CostTable, SearchSpace};
use crate::quant::BitConfig;
use crate::runtime::{lit, BackboneArtifacts, Program, Runtime};
use crate::Result;

use super::{DataStream, StepLog};

/// Search hyper-parameters.
#[derive(Debug, Clone)]
pub struct SearchCfg {
    pub steps: usize,
    pub lr: f32,
    pub lr_alpha: f32,
    /// Complexity-loss weight λ (Eq. 2).
    pub lam: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for SearchCfg {
    fn default() -> Self {
        SearchCfg {
            steps: 200,
            lr: 0.01,
            lr_alpha: 0.25,
            lam: 0.3,
            seed: 1234,
            log_every: 10,
        }
    }
}

/// Search result: the selected configuration plus full training history.
#[derive(Debug)]
pub struct SearchOutcome {
    pub config: BitConfig,
    pub history: Vec<StepLog>,
    pub alpha_w: Vec<f32>,
    pub alpha_a: Vec<f32>,
    /// Final supernet params (flat) — the QAT warm start.
    pub params: Vec<f32>,
    /// Mean per-layer branch entropy at the end (convergence diagnostic).
    pub final_entropy: f64,
    pub proxy_name: &'static str,
}

/// The supernet search driver for one backbone.
pub struct SupernetSearch<'rt> {
    program: Program,
    space: SearchSpace,
    table: CostTable,
    stream: DataStream,
    num_layers: usize,
    init_params: Vec<f32>,
    proxy_name: &'static str,
    _rt: &'rt Runtime,
}

impl<'rt> SupernetSearch<'rt> {
    /// Compile the supernet program and build the cost table under `proxy`.
    pub fn new(
        rt: &'rt Runtime,
        arts: &BackboneArtifacts,
        proxy: CostProxy,
        seed: u64,
    ) -> Result<Self> {
        let program = rt.load_program(&arts.supernet_step)?;
        let space = SearchSpace::default();
        let table = nas::cost_table(&arts.model, &space, proxy);
        let task = Task::for_backbone(&arts.model.name);
        let stream = DataStream::new(task, arts.model.input_hw, arts.train_batch, seed);
        Ok(SupernetSearch {
            program,
            space,
            table,
            stream,
            num_layers: arts.model.num_layers(),
            init_params: arts.load_init_params()?,
            proxy_name: proxy.name(),
            _rt: rt,
        })
    }

    /// Cost table accessor (logged by examples / benches).
    pub fn cost_table(&self) -> &CostTable {
        &self.table
    }

    /// Run the differentiable search loop.
    pub fn run(&self, cfg: &SearchCfg) -> Result<SearchOutcome> {
        let (l, k) = (self.num_layers, self.space.k());

        // Training state as literals; initialized once.
        let mut params = lit::f32_vec(&self.init_params);
        let mut mom = lit::f32_vec(&vec![0.0f32; self.init_params.len()]);
        let mut alpha_w = lit::f32_tensor(&vec![0.0f32; l * k], &[l as i64, k as i64])?;
        let mut alpha_a = lit::f32_tensor(&vec![0.0f32; l * k], &[l as i64, k as i64])?;
        let cost = lit::f32_tensor(&self.table.data, &[l as i64, k as i64, k as i64])?;
        let lr = lit::f32_scalar(cfg.lr);
        let lr_alpha = lit::f32_scalar(cfg.lr_alpha);
        let lam = lit::f32_scalar(cfg.lam);

        let mut history = Vec::new();
        for step in 0..cfg.steps {
            let (x, y) = self.stream.batch_literals(step)?;
            let outs = self
                .program
                .run_n(
                    &[
                        &params, &mom, &alpha_w, &alpha_a, &x, &y, &cost, &lr, &lr_alpha,
                        &lam,
                    ],
                    8,
                )
                .with_context(|| format!("supernet step {step}"))?;
            let mut it = outs.into_iter();
            params = it.next().unwrap();
            mom = it.next().unwrap();
            alpha_w = it.next().unwrap();
            alpha_a = it.next().unwrap();
            let loss = lit::to_f32_scalar(&it.next().unwrap())?;
            let ce = lit::to_f32_scalar(&it.next().unwrap())?;
            let comp = lit::to_f32_scalar(&it.next().unwrap())?;
            let acc = lit::to_f32_scalar(&it.next().unwrap())?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                history.push(StepLog {
                    step,
                    loss,
                    ce,
                    comp,
                    acc,
                });
            }
        }

        let aw = lit::to_f32_vec(&alpha_w)?;
        let aa = lit::to_f32_vec(&alpha_a)?;
        let config = nas::select_config(&self.space, &aw, &aa);
        let final_entropy =
            (nas::mean_entropy(&aw, k) + nas::mean_entropy(&aa, k)) / 2.0;
        Ok(SearchOutcome {
            config,
            history,
            alpha_w: aw,
            alpha_a: aa,
            params: lit::to_f32_vec(&params)?,
            final_entropy,
            proxy_name: self.proxy_name,
        })
    }
}
