//! The end-to-end MCU-MixQ pipeline (paper Fig. 1, left to right).
//!
//! `search → select → QAT → deploy → compare`: this is the driver behind
//! `examples/deploy_vww.rs`, the `mcu-mixq pipeline` CLI command and the
//! Table I bench. All loss curves are captured so EXPERIMENTS.md can plot
//! the training dynamics.

use crate::nas::CostProxy;
use crate::ops::Method;
use crate::perf::PerfModel;
use crate::quant::BitConfig;
use crate::runtime::{ArtifactStore, Runtime};
use crate::target::Target;
use crate::Result;

use super::deploy::{deploy_all_methods, MethodRow};
use super::qat::{QatCfg, QatRunner};
use super::search::{SearchCfg, SupernetSearch};
use super::StepLog;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    pub backbone: String,
    /// Deployment target, resolved by name through the
    /// [`Target`] registry (`stm32f746`/`m7`, `stm32f446`/`m4`). Drives
    /// the search proxy's cycle model and the comparison table's
    /// cycle/latency/energy pricing.
    pub target: String,
    pub search: SearchCfg,
    pub qat: QatCfg,
    /// Methods to deploy for the comparison table.
    pub methods: Vec<Method>,
    /// Use the EdMIPS MAC proxy instead of the Eq. 12 model (Fig. 8
    /// ablation).
    pub use_edmips_proxy: bool,
    /// Skip the supernet search and QAT/deploy this configuration
    /// instead — how a saved config (`--config-file`, written by
    /// `search --native` or `quant::save_config`) re-enters the
    /// pipeline as a reusable artifact.
    pub fixed_config: Option<BitConfig>,
}

impl PipelineCfg {
    pub fn new(backbone: &str) -> Self {
        PipelineCfg {
            backbone: backbone.to_string(),
            target: "stm32f746".to_string(),
            search: SearchCfg::default(),
            qat: QatCfg::default(),
            methods: vec![
                Method::CmixNn,
                Method::WpcDdd,
                Method::TinyEngine,
                Method::RpSlbc,
            ],
            use_edmips_proxy: false,
            fixed_config: None,
        }
    }
}

/// Everything the pipeline produced.
#[derive(Debug)]
pub struct PipelineReport {
    pub backbone: String,
    pub search_history: Vec<StepLog>,
    pub searched_wbits: Vec<u8>,
    pub searched_abits: Vec<u8>,
    pub final_entropy: f64,
    pub qat_history: Vec<StepLog>,
    pub qat_eval_acc: f32,
    pub rows: Vec<MethodRow>,
    /// (method, speedup of MCU-MixQ over it) pairs — the headline claims.
    pub speedups: Vec<(String, f64)>,
}

/// Run the full pipeline on `store`'s artifacts.
pub fn run_pipeline(rt: &Runtime, store: &ArtifactStore, cfg: &PipelineCfg) -> Result<PipelineReport> {
    let arts = store.backbone(&cfg.backbone)?;
    let model = arts.model.clone();
    let target = Target::resolve(&cfg.target)?;

    // 1. Hardware-aware quantization search, priced for the deployment
    // target's core — or the caller's fixed configuration, which skips
    // the supernet entirely (QAT warm-starts from the init params).
    let (config, warm_params, search_history, final_entropy) = match &cfg.fixed_config {
        Some(fixed) => {
            anyhow::ensure!(
                fixed.num_layers() == model.num_layers(),
                "fixed config has {} layers, {} has {}",
                fixed.num_layers(),
                model.name,
                model.num_layers()
            );
            (fixed.clone(), arts.load_init_params()?, Vec::new(), 0.0)
        }
        None => {
            let proxy = if cfg.use_edmips_proxy {
                CostProxy::EdMipsMacs
            } else {
                CostProxy::SimdAware(PerfModel::for_target(target), Method::RpSlbc)
            };
            let search = SupernetSearch::new(rt, &arts, proxy, cfg.search.seed)?;
            let outcome = search.run(&cfg.search)?;
            (
                outcome.config,
                outcome.params,
                outcome.history,
                outcome.final_entropy,
            )
        }
    };

    // 2. QAT of the selected sub-net.
    let runner = QatRunner::new(rt, &arts, cfg.qat.seed)?;
    let qat = runner.run(&warm_params, &config, &cfg.qat)?;

    // 3. Deploy every method and compare.
    let probe = super::DataStream::new(
        crate::datasets::Task::for_backbone(&model.name),
        model.input_hw,
        1,
        cfg.search.seed + 777,
    )
    .raw_batch(0);
    let rows = deploy_all_methods(
        rt,
        &arts,
        &model,
        &config,
        &qat.params,
        &cfg.methods,
        &cfg.qat,
        probe.image(0),
        target,
    )?;

    // 4. Headline speedups (MCU-MixQ row vs each competitor).
    let mixq_clocks = rows
        .iter()
        .find(|r| matches!(r.method, Method::RpSlbc | Method::Slbc))
        .map(|r| r.clocks)
        .unwrap_or(1);
    let speedups = rows
        .iter()
        .filter(|r| !matches!(r.method, Method::RpSlbc | Method::Slbc))
        .map(|r| {
            (
                r.method.name().to_string(),
                r.clocks as f64 / mixq_clocks as f64,
            )
        })
        .collect();

    Ok(PipelineReport {
        backbone: cfg.backbone.clone(),
        search_history,
        searched_wbits: config.wbits.clone(),
        searched_abits: config.abits.clone(),
        final_entropy,
        qat_history: qat.history,
        qat_eval_acc: qat.eval_acc,
        rows,
        speedups,
    })
}
