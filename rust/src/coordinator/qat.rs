//! Quantization-aware training of the selected sub-net (paper Fig. 1,
//! final stage before deployment).
//!
//! Runs the Layer-2 `qat_train_step` program with the *fixed* per-layer
//! bitwidth tensors chosen by the search, then measures loss/accuracy on a
//! held-out batch through the `eval` program. As in [`super::search`],
//! training state stays in PJRT literals across steps.

use anyhow::Context;

use crate::datasets::Task;
use crate::quant::BitConfig;
use crate::runtime::{lit, BackboneArtifacts, Program, Runtime};
use crate::Result;

use super::{DataStream, StepLog};

/// QAT hyper-parameters.
#[derive(Debug, Clone)]
pub struct QatCfg {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for QatCfg {
    fn default() -> Self {
        QatCfg {
            steps: 400,
            lr: 0.01,
            seed: 4321,
            log_every: 10,
        }
    }
}

/// QAT result: trained params + history + final eval metrics.
#[derive(Debug, Clone)]
pub struct QatOutcome {
    pub params: Vec<f32>,
    pub history: Vec<StepLog>,
    pub eval_loss: f32,
    pub eval_acc: f32,
    pub config: BitConfig,
}

/// QAT + eval driver for one backbone.
pub struct QatRunner<'rt> {
    qat: Program,
    eval: Program,
    train_stream: DataStream,
    eval_stream: DataStream,
    _rt: &'rt Runtime,
}

impl<'rt> QatRunner<'rt> {
    pub fn new(rt: &'rt Runtime, arts: &BackboneArtifacts, seed: u64) -> Result<Self> {
        let task = Task::for_backbone(&arts.model.name);
        Ok(QatRunner {
            qat: rt.load_program(&arts.qat_step)?,
            eval: rt.load_program(&arts.eval)?,
            train_stream: DataStream::new(task, arts.model.input_hw, arts.train_batch, seed),
            // Disjoint seed stream for eval data.
            eval_stream: DataStream::new(
                task,
                arts.model.input_hw,
                arts.eval_batch,
                seed ^ 0x5eed_0e7a_1u64,
            ),
            _rt: rt,
        })
    }

    /// Train `init_params` at the fixed `config` for `cfg.steps` steps,
    /// then evaluate once on a large held-out batch.
    pub fn run(
        &self,
        init_params: &[f32],
        config: &BitConfig,
        cfg: &QatCfg,
    ) -> Result<QatOutcome> {
        let wb = lit::f32_vec(&config.wbits_f32());
        let ab = lit::f32_vec(&config.abits_f32());
        let lr = lit::f32_scalar(cfg.lr);
        let mut params = lit::f32_vec(init_params);
        let mut mom = lit::f32_vec(&vec![0.0f32; init_params.len()]);

        let mut history = Vec::new();
        for step in 0..cfg.steps {
            let (x, y) = self.train_stream.batch_literals(step)?;
            let outs = self
                .qat
                .run_n(&[&params, &mom, &x, &y, &wb, &ab, &lr], 4)
                .with_context(|| format!("qat step {step}"))?;
            let mut it = outs.into_iter();
            params = it.next().unwrap();
            mom = it.next().unwrap();
            let loss = lit::to_f32_scalar(&it.next().unwrap())?;
            let acc = lit::to_f32_scalar(&it.next().unwrap())?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                history.push(StepLog {
                    step,
                    loss,
                    ce: loss,
                    comp: 0.0,
                    acc,
                });
            }
        }

        let (eval_loss, eval_acc) = self.evaluate(&params, config)?;
        Ok(QatOutcome {
            params: lit::to_f32_vec(&params)?,
            history,
            eval_loss,
            eval_acc,
            config: config.clone(),
        })
    }

    /// Evaluate literal params at `config` on the held-out batch.
    fn evaluate(&self, params: &xla::Literal, config: &BitConfig) -> Result<(f32, f32)> {
        let wb = lit::f32_vec(&config.wbits_f32());
        let ab = lit::f32_vec(&config.abits_f32());
        let (x, y) = self.eval_stream.batch_literals(0)?;
        let outs = self.eval.run_n(&[params, &x, &y, &wb, &ab], 2)?;
        Ok((lit::to_f32_scalar(&outs[0])?, lit::to_f32_scalar(&outs[1])?))
    }

    /// Evaluate host-side params (used to score *other* methods' effective
    /// bitwidths for Table I without retraining).
    pub fn evaluate_params(&self, params: &[f32], config: &BitConfig) -> Result<(f32, f32)> {
        self.evaluate(&lit::f32_vec(params), config)
    }
}
