//! Table I row generation: deploy one trained backbone under every
//! competitor framework and report peak SRAM / flash / clocks / latency /
//! accuracy.
//!
//! Per method the row uses:
//!
//! * the method's **supported quantization** (MCU-MixQ: the searched 2–8
//!   bit config; CMix-NN / WPC&DDD: the config clamped to {2,4,8};
//!   TinyEngine / plain-SIMD / naive: uniform int8);
//! * the method's **deployment style** (lifetime-planned arena vs
//!   all-buffers-live — [`crate::engine::planner`]);
//! * a short per-method QAT at its effective bitwidths (every framework
//!   fine-tunes its own quantization in the paper), evaluated through the
//!   Layer-2 `eval` program;
//! * a simulated batch-1 inference for the cycle count.

use crate::engine;
use crate::models::ModelDesc;
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::runtime::{BackboneArtifacts, Runtime};
use crate::target::Target;
use crate::Result;

use super::qat::{QatCfg, QatRunner};

/// One Table I row.
#[derive(Debug, Clone)]
pub struct MethodRow {
    pub method: Method,
    pub quantization: String,
    pub config: BitConfig,
    pub peak_sram: usize,
    pub flash_bytes: usize,
    /// Cycles in the deployment target's own cycle table.
    pub clocks: u64,
    /// Milliseconds at the deployment target's clock.
    pub latency_ms: f64,
    /// Joules per inference on the deployment target.
    pub joules: f64,
    pub accuracy: f32,
}

/// The effective configuration a method deploys for a searched `cfg`.
pub fn method_config(method: Method, searched: &BitConfig, num_layers: usize) -> BitConfig {
    match method {
        Method::Slbc | Method::RpSlbc => searched.clone(),
        Method::CmixNn | Method::WpcDdd => searched.to_cmixnn_supported(),
        Method::TinyEngine | Method::Simd | Method::Naive => BitConfig::uniform(num_layers, 8),
    }
}

/// Human label for the quantization column.
fn quant_label(method: Method) -> String {
    match method {
        Method::Slbc | Method::RpSlbc => "Mixed(2-8)".into(),
        Method::CmixNn | Method::WpcDdd => "Mixed(2,4,8)".into(),
        _ => "8-bit".into(),
    }
}

/// Produce Table I rows for `methods` on one backbone.
///
/// `searched` is MCU-MixQ's NAS result; `warm_params` the post-search
/// parameters (QAT warm start). Each method gets `qat_cfg.steps` of QAT at
/// its own effective bitwidths before evaluation — mirroring the paper's
/// "same accuracy constraint" protocol.
#[allow(clippy::too_many_arguments)]
pub fn deploy_all_methods(
    rt: &Runtime,
    arts: &BackboneArtifacts,
    model: &ModelDesc,
    searched: &BitConfig,
    warm_params: &[f32],
    methods: &[Method],
    qat_cfg: &QatCfg,
    probe_image: &[f32],
    target: &Target,
) -> Result<Vec<MethodRow>> {
    let runner = QatRunner::new(rt, arts, qat_cfg.seed)?;
    let mut rows = Vec::with_capacity(methods.len());
    for &method in methods {
        let cfg = method_config(method, searched, model.num_layers());
        // Fine-tune at the method's own quantization — except when the
        // effective config IS the searched one: `warm_params` were already
        // QAT'd there, so deploy them directly (re-training a converged
        // model from a fresh momentum state can destabilize it).
        let (qat_params, qat_acc);
        if cfg == *searched {
            let (_, acc) = runner.evaluate_params(warm_params, &cfg)?;
            qat_params = warm_params.to_vec();
            qat_acc = acc;
        } else {
            let qat = runner.run(warm_params, &cfg, qat_cfg)?;
            qat_params = qat.params;
            qat_acc = qat.eval_acc;
        }

        // Engine-side deployment (memory plan + flash + cycles), built
        // once through the compile path and executed on the artifact.
        // Unbounded: the comparison table reports over-budget methods in
        // its peak-memory column instead of failing the whole table.
        let compiled =
            engine::CompiledModel::compile_unbounded_for(model, &qat_params, &cfg, method, target);
        let infer = compiled.run(probe_image)?;

        rows.push(MethodRow {
            method,
            quantization: quant_label(method),
            config: cfg,
            peak_sram: compiled.peak_sram(),
            flash_bytes: compiled.flash_bytes(),
            clocks: infer.cycles,
            latency_ms: target.seconds(infer.cycles) * 1e3,
            joules: target.joules(&infer.counter),
            accuracy: qat_acc,
        });
    }
    Ok(rows)
}

/// Render rows as the Table I layout (used by the bench and the CLI).
pub fn render_rows(backbone: &str, rows: &[MethodRow]) -> String {
    use crate::util::bench::Table;
    let mut t = Table::new(vec![
        "Backbone",
        "Method",
        "Quantization",
        "Peak Memory",
        "Flash",
        "Clocks",
        "Latency",
        "Energy",
        "Accuracy",
    ]);
    for r in rows {
        t.row(vec![
            backbone.to_string(),
            r.method.name().to_string(),
            r.quantization.clone(),
            format!("{:.2}KB", r.peak_sram as f64 / 1024.0),
            format!("{:.2}KB", r.flash_bytes as f64 / 1024.0),
            format!("{}", r.clocks),
            format!("{:.1}ms", r.latency_ms),
            format!("{:.2}mJ", r.joules * 1e3),
            format!("{:.1}%", r.accuracy * 100.0),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_configs_respect_support() {
        let searched = BitConfig {
            wbits: vec![2, 3, 5, 7, 8, 4],
            abits: vec![3, 4, 5, 6, 7, 8],
        };
        for m in Method::ALL {
            let cfg = method_config(m, &searched, 6);
            for i in 0..6 {
                assert!(
                    m.supports(cfg.wbits[i], cfg.abits[i]),
                    "{} rejects its own config at layer {i}",
                    m.name()
                );
            }
        }
    }

    #[test]
    fn mixq_keeps_searched_bits() {
        let searched = BitConfig {
            wbits: vec![2, 3, 5],
            abits: vec![3, 4, 5],
        };
        assert_eq!(method_config(Method::RpSlbc, &searched, 3), searched);
        let clamped = method_config(Method::CmixNn, &searched, 3);
        assert_eq!(clamped.wbits, vec![2, 4, 8]);
        assert_eq!(clamped.abits, vec![4, 4, 8]);
    }
}
