//! The Layer-3 coordinator: MCU-MixQ's full workflow (paper Fig. 1).
//!
//! ```text
//! pretrained params ─► supernet search (PJRT, cost table from perf/) ─►
//!   argmax BitConfig ─► QAT (PJRT) ─► quantize ─► engine deploy ─►
//!     Table I report
//! ```
//!
//! Everything here runs in Rust; the JAX-authored compute graphs execute
//! as compiled PJRT programs. Training state (params / momentum / branch
//! logits) stays in XLA literals across steps — the hot loop never copies
//! it through host vectors (only per-`log_every` scalars leave the
//! device).
//!
//! * [`search`] — the hardware-aware quantization explorer loop (§III.B);
//! * [`qat`] — quantization-aware training of the selected sub-net;
//! * [`deploy`] — Table I row generation over all competitor methods;
//! * [`pipeline`] — the end-to-end driver used by `examples/deploy_vww.rs`
//!   and the `mcu-mixq pipeline` CLI.

pub mod deploy;
pub mod pipeline;
pub mod qat;
pub mod search;

pub use deploy::{deploy_all_methods, MethodRow};
pub use pipeline::{run_pipeline, PipelineCfg, PipelineReport};
pub use qat::{QatOutcome, QatRunner};
pub use search::{SearchCfg, SearchOutcome, SupernetSearch};

use crate::datasets::{self, Task};
use crate::runtime::lit;
use crate::Result;

/// One logged optimization step (either loop).
#[derive(Debug, Clone)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    /// Cross-entropy part (total loss for QAT).
    pub ce: f32,
    /// λ-scaled complexity part (0 for QAT).
    pub comp: f32,
    pub acc: f32,
}

/// Deterministic synthetic data feeder: a fresh batch per step, seeded so
/// every run is reproducible.
pub struct DataStream {
    task: Task,
    hw: usize,
    batch: usize,
    seed: u64,
}

impl DataStream {
    pub fn new(task: Task, hw: usize, batch: usize, seed: u64) -> Self {
        DataStream {
            task,
            hw,
            batch,
            seed,
        }
    }

    /// Literals `(x [B,H,W,C] f32, y [B] i32)` for step `step`.
    pub fn batch_literals(&self, step: usize) -> Result<(xla::Literal, xla::Literal)> {
        let b = datasets::generate(self.task, self.batch, self.hw, self.seed + step as u64);
        let x = lit::f32_tensor(
            &b.images,
            &[self.batch as i64, self.hw as i64, self.hw as i64, b.c as i64],
        )?;
        let y = lit::i32_vec(&b.labels);
        Ok((x, y))
    }

    /// A raw batch (for engine-side evaluation on the same distribution).
    pub fn raw_batch(&self, step: usize) -> datasets::Batch {
        datasets::generate(self.task, self.batch, self.hw, self.seed + step as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datastream_is_deterministic() {
        let s1 = DataStream::new(Task::SynthCifar, 16, 4, 9);
        let s2 = DataStream::new(Task::SynthCifar, 16, 4, 9);
        let a = s1.raw_batch(3);
        let b = s2.raw_batch(3);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        // Different steps -> different data.
        let c = s1.raw_batch(4);
        assert_ne!(a.labels, c.labels);
    }
}
