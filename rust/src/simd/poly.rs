//! The polynomial-multiplication packing identity (paper Eq. 3–7).
//!
//! For an `sx`-bit sequence `s` and an `sk`-bit kernel `k`:
//!
//! ```text
//! R1 = Σ_i s[i]·2^(i·S)      (Eq. 3, packed signal)
//! R2 = Σ_j k[j]·2^(j·S)      (Eq. 4, packed kernel)
//! P  = R1 × R2 = Σ_n y[n]·2^(n·S)   with   y = conv_full(s, k)   (Eq. 5/7)
//! ```
//!
//! provided each field of width `S` can hold the worst-case partial sum —
//! the *guard-bit* condition `S ≥ sx + sk + ceil(log2(min(G, K)))`.
//! One wide multiply therefore performs `G·K` MACs, which is the whole
//! reason SLBC beats lane-per-operand packing (CMix-NN et al.).
//!
//! This module is the pure-math mirror of the Layer-1 Pallas kernel
//! (`python/compile/kernels/slbc.py`) and the ground truth the MCU
//! operators are property-tested against.

/// Bits usable in the wide carrier. Mirrors the Pallas kernel's int64
/// carrier (one sign bit reserved). The MCU operators use narrower
/// carriers via [`group_size_for_register`].
pub const REGISTER_BITS: u32 = 63;

/// Minimal field stride `S` so packed convolution outputs never carry into
/// the neighbouring field.
pub fn field_width(sx_bits: u32, sk_bits: u32, k_taps: u32) -> u32 {
    assert!(k_taps >= 1, "kernel must have at least one tap");
    let guard = if k_taps > 1 {
        (32 - (k_taps - 1).leading_zeros()).max(1)
    } else {
        0
    };
    sx_bits + sk_bits + guard
}

/// Signal elements packable per `register_bits`-wide multiply, given that
/// the product of a `G`-field and a `K`-field word spans `G + K - 1` fields.
pub fn group_size_for_register(
    sx_bits: u32,
    sk_bits: u32,
    k_taps: u32,
    register_bits: u32,
) -> Option<u32> {
    let s = field_width(sx_bits, sk_bits, k_taps);
    let fields = register_bits / s;
    if fields >= k_taps {
        Some(fields - (k_taps - 1))
    } else {
        None
    }
}

/// [`group_size_for_register`] on the default 63-bit carrier.
pub fn group_size(sx_bits: u32, sk_bits: u32, k_taps: u32) -> Option<u32> {
    group_size_for_register(sx_bits, sk_bits, k_taps, REGISTER_BITS)
}

/// A validated packing configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PackSpec {
    pub sx_bits: u32,
    pub sk_bits: u32,
    pub k_taps: u32,
    /// Field stride S in bits.
    pub field: u32,
    /// Signal elements per multiply (G).
    pub group: u32,
    /// Carrier width this spec was sized for.
    pub register_bits: u32,
}

impl PackSpec {
    /// Build a spec for the given bitwidths/taps, or `None` if the
    /// configuration cannot fit the carrier.
    pub fn new(sx_bits: u32, sk_bits: u32, k_taps: u32, register_bits: u32) -> Option<Self> {
        let group = group_size_for_register(sx_bits, sk_bits, k_taps, register_bits)?;
        Some(PackSpec {
            sx_bits,
            sk_bits,
            k_taps,
            field: field_width(sx_bits, sk_bits, k_taps),
            group,
            register_bits,
        })
    }

    /// Build a spec with an explicit (wider-than-minimal) field stride.
    ///
    /// A wider field donates its slack to *in-register accumulation*: up to
    /// [`PackSpec::accum_depth`] products can be summed in the packed
    /// domain before segmentation, amortizing the extraction cost — the
    /// ULPPACK-inspired trade §IV.C's adaptive search optimizes over.
    pub fn with_field(
        sx_bits: u32,
        sk_bits: u32,
        k_taps: u32,
        field: u32,
        register_bits: u32,
    ) -> Option<Self> {
        if field < field_width(sx_bits, sk_bits, k_taps) {
            return None;
        }
        let fields = register_bits / field;
        if fields < k_taps {
            return None;
        }
        Some(PackSpec {
            sx_bits,
            sk_bits,
            k_taps,
            field,
            group: fields - (k_taps - 1),
            register_bits,
        })
    }

    /// How many packed products can accumulate in-register before any
    /// field can overflow: `floor((2^S - 1) / (K · x_max · k_max))`.
    pub fn accum_depth(&self) -> u32 {
        let per_mul = self.k_taps as u128
            * ((1u128 << self.sx_bits) - 1)
            * ((1u128 << self.sk_bits) - 1);
        if per_mul == 0 {
            return u32::MAX;
        }
        let cap = if self.field >= 64 {
            u64::MAX as u128
        } else {
            (1u128 << self.field) - 1
        };
        (cap / per_mul).min(u32::MAX as u128) as u32
    }

    /// Effective MACs performed by one wide multiply (Fig. 6's quantity).
    pub fn macs_per_multiply(&self) -> u32 {
        self.group * self.k_taps
    }

    /// Pack up to `group` signal values (ascending fields, Eq. 3).
    pub fn pack_signal(&self, vals: &[u64]) -> u64 {
        debug_assert!(vals.len() as u32 <= self.group);
        let mut r = 0u64;
        for (i, &v) in vals.iter().enumerate() {
            debug_assert!(v < (1 << self.sx_bits), "signal out of range");
            r |= v << (i as u32 * self.field);
        }
        r
    }

    /// Pack the kernel taps (ascending fields, Eq. 4).
    pub fn pack_kernel(&self, taps: &[u64]) -> u64 {
        debug_assert_eq!(taps.len() as u32, self.k_taps);
        let mut r = 0u64;
        for (j, &v) in taps.iter().enumerate() {
            debug_assert!(v < (1 << self.sk_bits), "kernel tap out of range");
            r |= v << (j as u32 * self.field);
        }
        r
    }

    /// Extract the `G + K - 1` convolution fields of a product (Eq. 7).
    pub fn segment(&self, product: u64) -> Vec<u64> {
        let n_fields = self.group + self.k_taps - 1;
        let mask = if self.field >= 64 {
            u64::MAX
        } else {
            (1u64 << self.field) - 1
        };
        (0..n_fields)
            .map(|f| (product >> (f * self.field)) & mask)
            .collect()
    }

    /// Allocation-free [`Self::segment`]: calls `f(field_idx, value)` for
    /// every field of the product (the hot-path variant).
    #[inline]
    pub fn segment_each<F: FnMut(usize, u64)>(&self, product: u64, mut f: F) {
        let n_fields = self.group + self.k_taps - 1;
        let mask = if self.field >= 64 {
            u64::MAX
        } else {
            (1u64 << self.field) - 1
        };
        for fi in 0..n_fields {
            f(fi as usize, (product >> (fi * self.field)) & mask);
        }
    }
}

/// Full 1-D convolution of unsigned low-bitwidth sequences via packed
/// multiplication — the reference implementation of the SLBC arithmetic
/// (Alg. 1 without the SIMD-lane dimension).
///
/// Bit-exact with the naïve `y[n] = Σ_m s[n-m]·k[m]`.
pub fn conv1d_full_packed(x: &[u64], k: &[u64], sx_bits: u32, sk_bits: u32) -> Vec<u64> {
    let spec = PackSpec::new(sx_bits, sk_bits, k.len() as u32, REGISTER_BITS)
        .expect("bitwidth/taps combination does not fit the carrier");
    let g = spec.group as usize;
    let out_len = x.len() + k.len() - 1;
    let mut y = vec![0u64; out_len + g]; // slack for the last group's spill
    let r2 = spec.pack_kernel(k);
    let mut i = 0;
    while i < x.len() {
        let hi = (i + g).min(x.len());
        let r1 = spec.pack_signal(&x[i..hi]);
        let p = r1.wrapping_mul(r2);
        // Segmentation with overlap accumulation (Eq. 11): fields beyond
        // this group's span overlap the next group's low outputs.
        for (f, v) in spec.segment(p).into_iter().enumerate() {
            y[i + f] += v;
        }
        i += g;
    }
    y.truncate(out_len);
    y
}

/// Naïve direct convolution (the oracle).
pub fn conv1d_full_direct(x: &[u64], k: &[u64]) -> Vec<u64> {
    let mut y = vec![0u64; x.len() + k.len() - 1];
    for (i, &xv) in x.iter().enumerate() {
        for (j, &kv) in k.iter().enumerate() {
            y[i + j] += xv * kv;
        }
    }
    y
}

/// Packed dot product: both operands packed with one reversed so the middle
/// field of the product accumulates the group's inner product. Used by the
/// dense-layer/im2col paths; `G` here must satisfy the *dot* guard
/// (`ceil(log2 G)` extra bits, every field can accumulate up to G terms).
pub fn dot_packed(a: &[u64], b: &[u64], sa_bits: u32, sb_bits: u32) -> u64 {
    let g = dot_group_size(sa_bits, sb_bits, REGISTER_BITS);
    let s = field_width(sa_bits, sb_bits, g);
    let mask = (1u64 << s) - 1;
    let mut acc = 0u64;
    let mut i = 0usize;
    while i < a.len() {
        let hi = (i + g as usize).min(a.len());
        let mut ra = 0u64;
        let mut rb = 0u64;
        for (l, j) in (i..hi).enumerate() {
            ra |= a[j] << (l as u32 * s);
            rb |= b[j] << ((hi - i - 1 - l) as u32 * s);
        }
        // The top field of the (possibly partial) group holds its dot.
        let mid = (hi - i - 1) as u32 * s;
        acc += (ra.wrapping_mul(rb) >> mid) & mask;
        i = hi;
    }
    acc
}

/// Pack the signal-side operand of [`dot_packed`] into one register per
/// group (ascending fields). The packing depends only on the operand and
/// the bitwidth pair, so the result is reusable across every weight vector
/// dotted against it (one packing per dense layer, not per output neuron).
pub fn dot_pack_a(a: &[u64], sa_bits: u32, sb_bits: u32) -> Vec<u64> {
    let mut regs = Vec::with_capacity(a.len().div_ceil(dot_group_size(
        sa_bits,
        sb_bits,
        REGISTER_BITS,
    ) as usize));
    dot_pack_a_into(a, sa_bits, sb_bits, &mut regs);
    regs
}

/// Allocation-free [`dot_pack_a`]: clears `out` and fills it with the
/// packed signal registers (capacity is retained across calls — the dense
/// hot path's steady state).
pub fn dot_pack_a_into(a: &[u64], sa_bits: u32, sb_bits: u32, out: &mut Vec<u64>) {
    let g = dot_group_size(sa_bits, sb_bits, REGISTER_BITS) as usize;
    let s = field_width(sa_bits, sb_bits, g as u32);
    out.clear();
    let mut i = 0usize;
    while i < a.len() {
        let hi = (i + g).min(a.len());
        let mut ra = 0u64;
        for (l, j) in (i..hi).enumerate() {
            ra |= a[j] << (l as u32 * s);
        }
        out.push(ra);
        i = hi;
    }
}

/// Pack the weight-side operand of [`dot_packed`] into one register per
/// group (descending fields, the reversal that turns the product's middle
/// field into the group's inner product). Deploy-time work: the packed
/// registers are what a real flash image stores, so repeated inference
/// never re-packs them (see the engine's `KernelCache`).
pub fn dot_pack_b(b: &[u64], sa_bits: u32, sb_bits: u32) -> Vec<u64> {
    let g = dot_group_size(sa_bits, sb_bits, REGISTER_BITS) as usize;
    let s = field_width(sa_bits, sb_bits, g as u32);
    let mut regs = Vec::with_capacity(b.len().div_ceil(g));
    let mut i = 0usize;
    while i < b.len() {
        let hi = (i + g).min(b.len());
        let mut rb = 0u64;
        for (l, j) in (i..hi).enumerate() {
            rb |= b[j] << ((hi - i - 1 - l) as u32 * s);
        }
        regs.push(rb);
        i = hi;
    }
    regs
}

/// [`dot_packed`] over operands prepacked by [`dot_pack_a`] /
/// [`dot_pack_b`]; `n` is the original (unpacked) operand length, needed
/// to locate the partial last group's dot field. Bit-identical to
/// [`dot_packed`] (enforced by tests).
pub fn dot_packed_prepacked(
    a_regs: &[u64],
    b_regs: &[u64],
    n: usize,
    sa_bits: u32,
    sb_bits: u32,
) -> u64 {
    let g = dot_group_size(sa_bits, sb_bits, REGISTER_BITS) as usize;
    let s = field_width(sa_bits, sb_bits, g as u32);
    let mask = (1u64 << s) - 1;
    debug_assert_eq!(a_regs.len(), n.div_ceil(g));
    debug_assert_eq!(b_regs.len(), n.div_ceil(g));
    let mut acc = 0u64;
    for (gi, (&ra, &rb)) in a_regs.iter().zip(b_regs).enumerate() {
        // The top field of the (possibly partial) group holds its dot.
        let len = (n - gi * g).min(g);
        let mid = (len - 1) as u32 * s;
        acc += (ra.wrapping_mul(rb) >> mid) & mask;
    }
    acc
}

/// Largest dot-product group size for the given operand widths.
pub fn dot_group_size(sa_bits: u32, sb_bits: u32, register_bits: u32) -> u32 {
    let mut g = 1u32;
    loop {
        let s_next = field_width(sa_bits, sb_bits, g + 1);
        if (2 * (g + 1) - 1) * s_next > register_bits {
            return g;
        }
        g += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    fn rand_vec(rng: &mut Rng, n: usize, bits: u32) -> Vec<u64> {
        (0..n).map(|_| rng.below(1 << bits)).collect()
    }

    #[test]
    fn field_width_matches_paper_example() {
        // 4b × 4b with 5 taps: 4+4+ceil(log2 5) = 11.
        assert_eq!(field_width(4, 4, 5), 11);
        assert_eq!(field_width(3, 2, 1), 5);
    }

    #[test]
    fn group_size_known_values() {
        // 2b×2b, 3 taps: S = 2+2+1 = 5? ceil(log2 3)=2 -> S=6; 63/6=10 fields
        // -> G = 10-2 = 8.
        assert_eq!(field_width(2, 2, 3), 6);
        assert_eq!(group_size(2, 2, 3), Some(8));
        // Oversize config rejected.
        assert_eq!(group_size_for_register(8, 8, 4, 32), None);
    }

    #[test]
    fn packed_conv_matches_direct_exhaustive_small() {
        // Exhaustive over all 2-bit signals of length 4 with a fixed kernel.
        let k = vec![3u64, 1, 2];
        for a in 0..4u64 {
            for b in 0..4u64 {
                for c in 0..4u64 {
                    for d in 0..4u64 {
                        let x = vec![a, b, c, d];
                        assert_eq!(
                            conv1d_full_packed(&x, &k, 2, 2),
                            conv1d_full_direct(&x, &k)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_conv_worst_case_saturation() {
        // All operands at their maxima — the guard-bit condition's edge.
        for (sx, sk, kt) in [(4u32, 4u32, 5usize), (8, 8, 3), (2, 2, 7), (7, 3, 4)] {
            let x = vec![(1u64 << sx) - 1; 40];
            let k = vec![(1u64 << sk) - 1; kt];
            assert_eq!(conv1d_full_packed(&x, &k, sx, sk), conv1d_full_direct(&x, &k));
        }
    }

    #[test]
    fn packed_conv_property_random() {
        check("packed conv == direct conv", 300, |rng| {
            let sx = rng.range(1, 9) as u32;
            let sk = rng.range(1, 9) as u32;
            let kt = rng.range(1, 10);
            if group_size(sx, sk, kt as u32).is_none() {
                return;
            }
            let n = rng.range(1, 70);
            let mut r = rng.fork(1);
            let x = rand_vec(&mut r, n, sx);
            let k = rand_vec(&mut r, kt, sk);
            assert_eq!(conv1d_full_packed(&x, &k, sx, sk), conv1d_full_direct(&x, &k));
        });
    }

    #[test]
    fn dot_packed_property() {
        check("packed dot == direct dot", 300, |rng| {
            let sa = rng.range(1, 9) as u32;
            let sb = rng.range(1, 9) as u32;
            let n = rng.range(1, 100);
            let mut r = rng.fork(2);
            let a = rand_vec(&mut r, n, sa);
            let b = rand_vec(&mut r, n, sb);
            let direct: u64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot_packed(&a, &b, sa, sb), direct);
        });
    }

    #[test]
    fn dot_prepacked_matches_direct() {
        check("prepacked dot == direct dot", 200, |rng| {
            let sa = rng.range(1, 9) as u32;
            let sb = rng.range(1, 9) as u32;
            let n = rng.range(1, 100);
            let mut r = rng.fork(5);
            let a = rand_vec(&mut r, n, sa);
            let b = rand_vec(&mut r, n, sb);
            let direct: u64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let a_regs = dot_pack_a(&a, sa, sb);
            let b_regs = dot_pack_b(&b, sa, sb);
            assert_eq!(dot_packed_prepacked(&a_regs, &b_regs, n, sa, sb), direct);
        });
    }

    #[test]
    fn macs_per_multiply_increases_at_low_bits() {
        let m2 = PackSpec::new(2, 2, 3, 63).unwrap().macs_per_multiply();
        let m8 = PackSpec::new(8, 8, 3, 63).unwrap().macs_per_multiply();
        assert!(m2 > m8, "2-bit packing must beat 8-bit ({m2} vs {m8})");
    }

    #[test]
    fn segment_roundtrip() {
        let spec = PackSpec::new(3, 3, 2, 63).unwrap();
        let x: Vec<u64> = vec![5, 1, 7];
        let r1 = spec.pack_signal(&x);
        let fields = spec.segment(r1);
        assert_eq!(&fields[..3], &x[..]);
    }

    #[test]
    fn impulse_kernel_identity() {
        let x: Vec<u64> = (0..20).map(|i| (i * 7 % 16) as u64).collect();
        let y = conv1d_full_packed(&x, &[1], 4, 1);
        assert_eq!(y, x);
    }
}
