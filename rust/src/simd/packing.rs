//! Lane-granularity SIMD packing (paper Eq. 8–11, Alg. 1).
//!
//! On the Cortex-M7 the "SIMD register" is a 32-bit GPR viewed through the
//! ARMv7E-M DSP extension as `N_l` lanes of `L_b` bits (2×16 or 4×8), and a
//! 64-bit view exists through the `UMULL`/`UMLAL`-class long multiplies.
//! SLBC packs `N_s` sub-byte signal elements *within each lane* and the
//! whole kernel into every lane (Eq. 8/9); one SIMD multiply then yields,
//! per lane, the packed convolution fields (Eq. 10). Segmentation (Eq. 11)
//! must additionally stitch the boundary field of lane `l` to the first
//! field of lane `l+1` — the overhead RP-SLBC's reordering removes.

use super::poly::PackSpec;

/// A SIMD lane configuration of the 32-bit DSP register file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneCfg {
    /// Bits per register (32 for GPR view, 64 for the long-multiply view).
    pub register_bits: u32,
    /// Bits per lane; must divide `register_bits`.
    pub lane_bits: u32,
}

impl LaneCfg {
    pub fn new(register_bits: u32, lane_bits: u32) -> Self {
        assert!(register_bits % lane_bits == 0, "lanes must tile the register");
        LaneCfg {
            register_bits,
            lane_bits,
        }
    }

    /// Number of lanes `N_l`.
    pub fn lanes(&self) -> u32 {
        self.register_bits / self.lane_bits
    }

    /// All configurations the Cortex-M7 DSP view offers (§IV.C's search
    /// space for adaptive packing). A `'static` table: the adaptive-plan
    /// search runs per layer, so the search space must not be re-allocated
    /// per query.
    pub const ALL: [LaneCfg; 4] = [
        LaneCfg { register_bits: 32, lane_bits: 8 },
        LaneCfg { register_bits: 32, lane_bits: 16 },
        LaneCfg { register_bits: 32, lane_bits: 32 },
        // UMULL/UMLAL long-multiply path.
        LaneCfg { register_bits: 64, lane_bits: 64 },
    ];

    pub fn all() -> &'static [LaneCfg] {
        &Self::ALL
    }
}

/// A lane-granularity SLBC convolution plan: how many signal elements fit a
/// lane, how lanes combine, and the bookkeeping for Eq. 11 segmentation.
#[derive(Debug, Clone, Copy)]
pub struct SimdConv {
    pub cfg: LaneCfg,
    /// Per-lane packing of the signal (Ns elements) against the kernel.
    pub spec: PackSpec,
}

impl SimdConv {
    /// Build a plan if the kernel fits a lane at these bitwidths.
    pub fn plan(cfg: LaneCfg, sx_bits: u32, sk_bits: u32, k_taps: u32) -> Option<SimdConv> {
        let spec = PackSpec::new(sx_bits, sk_bits, k_taps, cfg.lane_bits)?;
        if spec.group == 0 {
            return None;
        }
        Some(SimdConv { cfg, spec })
    }

    /// Build a plan with an explicit field stride (see
    /// [`PackSpec::with_field`] for the accumulation-depth trade-off).
    pub fn plan_with_field(
        cfg: LaneCfg,
        sx_bits: u32,
        sk_bits: u32,
        k_taps: u32,
        field: u32,
    ) -> Option<SimdConv> {
        let spec = PackSpec::with_field(sx_bits, sk_bits, k_taps, field, cfg.lane_bits)?;
        if spec.group == 0 {
            return None;
        }
        Some(SimdConv { cfg, spec })
    }

    /// Signal elements consumed per SIMD multiply: `N_l · N_s`.
    pub fn elements_per_instr(&self) -> u32 {
        self.cfg.lanes() * self.spec.group
    }

    /// Effective MACs per SIMD multiply: `N_l · N_s · K` (Fig. 6 quantity).
    pub fn macs_per_instr(&self) -> u32 {
        self.elements_per_instr() * self.spec.k_taps
    }

    /// Pack a signal window into one register (Eq. 8): lane `l` holds
    /// elements `x[l·Ns .. (l+1)·Ns]` in ascending fields.
    pub fn pack_signal(&self, x: &[u64]) -> u64 {
        let ns = self.spec.group as usize;
        let mut reg = 0u64;
        for l in 0..self.cfg.lanes() as usize {
            let base = l * ns;
            if base >= x.len() {
                break;
            }
            let hi = (base + ns).min(x.len());
            let lane = self.spec.pack_signal(&x[base..hi]);
            reg |= lane << (l as u32 * self.cfg.lane_bits);
        }
        reg
    }

    /// Pack the kernel broadcast into every lane (Eq. 9).
    pub fn pack_kernel(&self, k: &[u64]) -> u64 {
        let lane = self.spec.pack_kernel(k);
        let mut reg = 0u64;
        for l in 0..self.cfg.lanes() {
            reg |= lane << (l * self.cfg.lane_bits);
        }
        reg
    }

    /// The SIMD multiplication of Eq. 10: independent per-lane products,
    /// each truncated to the lane width (hardware lane semantics).
    pub fn simd_mul(&self, vs: u64, vk: u64) -> u64 {
        let lanes = self.cfg.lanes();
        let lb = self.cfg.lane_bits;
        let mask = if lb >= 64 { u64::MAX } else { (1u64 << lb) - 1 };
        let mut out = 0u64;
        for l in 0..lanes {
            let a = (vs >> (l * lb)) & mask;
            let b = (vk >> (l * lb)) & mask;
            out |= (a.wrapping_mul(b) & mask) << (l * lb);
        }
        out
    }

    /// Segmentation (Eq. 11): extract the convolution contributions of one
    /// product register and accumulate them into `y` at the window offset.
    ///
    /// Lane `l` covers global outputs `[off + l·Ns, off + l·Ns + Ns+K-1)`;
    /// the top `K-1` fields of lane `l` overlap the first fields of lane
    /// `l+1` — both are accumulated, which is exactly how the boundary
    /// elements "jointly form one complete convolution element".
    pub fn segment_into(&self, product: u64, off: usize, y: &mut [u64]) {
        let lanes = self.cfg.lanes() as usize;
        let lb = self.cfg.lane_bits;
        let ns = self.spec.group as usize;
        let lane_mask = if lb >= 64 { u64::MAX } else { (1u64 << lb) - 1 };
        for l in 0..lanes {
            let lane = (product >> (l as u32 * lb)) & lane_mask;
            for (f, v) in self.spec.segment(lane).into_iter().enumerate() {
                let idx = off + l * ns + f;
                if idx < y.len() {
                    y[idx] += v;
                }
            }
        }
    }

    /// Full 1-D convolution through the lane-packed pipeline (Alg. 1):
    /// pack → SIMD multiply → segment, window by window. Bit-exact with
    /// direct convolution whenever the plan is valid.
    pub fn conv1d_full(&self, x: &[u64], k: &[u64]) -> Vec<u64> {
        assert_eq!(k.len() as u32, self.spec.k_taps);
        let out_len = x.len() + k.len() - 1;
        let mut y = vec![0u64; out_len];
        let vk = self.pack_kernel(k);
        let step = self.elements_per_instr() as usize;
        let mut i = 0usize;
        while i < x.len() {
            let hi = (i + step).min(x.len());
            let vs = self.pack_signal(&x[i..hi]);
            let vp = self.simd_mul(vs, vk);
            self.segment_into(vp, i, &mut y);
            i += step;
        }
        y
    }

    /// Number of window registers [`Self::pack_windows_into`] produces for
    /// an `n`-element row — the per-row stride of the flat packed buffers
    /// the rolling-row conv pipeline holds.
    pub fn n_regs(&self, n: usize) -> usize {
        n.div_ceil(self.elements_per_instr() as usize)
    }

    /// Pre-pack a signal row into its per-window registers.
    ///
    /// Packing depends only on the signal, not the filter, so the result
    /// is reused across all output channels (the `PACK_REUSE`
    /// amortization the cost model assumes). Appends into `out`.
    pub fn pack_windows_into(&self, x: &[u64], out: &mut Vec<u64>) {
        let step = self.elements_per_instr() as usize;
        let mut i = 0usize;
        while i < x.len() {
            let hi = (i + step).min(x.len());
            out.push(self.pack_signal(&x[i..hi]));
            i += step;
        }
    }

    /// Allocation-free [`Self::pack_windows_into`]: writes the
    /// [`Self::n_regs`]`(x.len())` window registers into `out` (a slot of a
    /// flat, strided buffer) instead of appending to a `Vec`.
    #[inline]
    pub fn pack_windows_to(&self, x: &[u64], out: &mut [u64]) {
        let step = self.elements_per_instr() as usize;
        debug_assert_eq!(out.len(), self.n_regs(x.len()));
        let mut i = 0usize;
        let mut r = 0usize;
        while i < x.len() {
            let hi = (i + step).min(x.len());
            out[r] = self.pack_signal(&x[i..hi]);
            r += 1;
            i += step;
        }
    }

    /// Segmentation variant accumulating into a signed buffer (the layer
    /// accumulator) — bit-identical to [`Self::segment_into`].
    #[inline]
    pub fn segment_into_i64(&self, product: u64, off: usize, y: &mut [i64]) {
        let lanes = self.cfg.lanes() as usize;
        let lb = self.cfg.lane_bits;
        let ns = self.spec.group as usize;
        let lane_mask = if lb >= 64 { u64::MAX } else { (1u64 << lb) - 1 };
        for l in 0..lanes {
            let lane = (product >> (l as u32 * lb)) & lane_mask;
            self.spec.segment_each(lane, |f, v| {
                let idx = off + l * ns + f;
                if idx < y.len() {
                    y[idx] += v as i64;
                }
            });
        }
    }

    /// Multiply prepacked windows against a prepacked kernel register and
    /// accumulate the segmented fields into `y` (Alg. 1 with the packing
    /// hoisted out) — the allocation-free hot path of `ops::conv_slbc`.
    #[inline]
    pub fn conv1d_prepacked_into(&self, windows: &[u64], vk: u64, y: &mut [i64]) {
        let step = self.elements_per_instr() as usize;
        for (wi, &vs) in windows.iter().enumerate() {
            let vp = self.simd_mul(vs, vk);
            self.segment_into_i64(vp, wi * step, y);
        }
    }

    /// Count of segmentation bit-operations per SIMD multiply in naïve
    /// SLBC: every field of every lane needs a shift+mask, and lane
    /// boundaries need an extra cross-lane add (Alg. 1's `vshr`/`vand`/
    /// `vget` sequence).
    pub fn seg_ops_per_instr(&self) -> u32 {
        let fields = self.spec.group + self.spec.k_taps - 1;
        // shift + and per field per lane, plus the cross-lane boundary fix.
        self.cfg.lanes() * fields * 2 + (self.cfg.lanes() - 1)
    }

    /// Packing bit-operations per SIMD multiply (shift+or per element).
    pub fn pack_ops_per_instr(&self) -> u32 {
        self.elements_per_instr() * 2
    }
}

/// Check that a lane can hold the full kernel at the given widths — the
/// condition under which SLBC degenerates gracefully (paper assumes
/// `N_k == k`, i.e. whole kernel per lane).
///
/// This is *defined as* "[`SimdConv::plan`] succeeds": the planner and the
/// static analyzer must never disagree on legality, so there is exactly one
/// implementation of the predicate. `field_width(sx,sk,k)·k ≤ lane_bits` is
/// the closed form (pinned equal by `fits_lane_matches_plan` below).
pub fn kernel_fits_lane(cfg: LaneCfg, sx_bits: u32, sk_bits: u32, k_taps: u32) -> bool {
    SimdConv::plan(cfg, sx_bits, sk_bits, k_taps).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::poly::{conv1d_full_direct, field_width};
    use crate::util::prop::check;

    #[test]
    fn lane_cfg_lanes() {
        assert_eq!(LaneCfg::new(32, 8).lanes(), 4);
        assert_eq!(LaneCfg::new(32, 16).lanes(), 2);
        assert_eq!(LaneCfg::new(32, 32).lanes(), 1);
    }

    #[test]
    #[should_panic]
    fn lane_cfg_must_tile() {
        LaneCfg::new(32, 12);
    }

    #[test]
    fn plan_2bit_in_16bit_lanes() {
        // 2b×2b, K=2: S = 2+2+1 = 5; 16-bit lane → 3 fields → Ns = 2.
        let plan = SimdConv::plan(LaneCfg::new(32, 16), 2, 2, 2).unwrap();
        assert_eq!(plan.spec.group, 2);
        assert_eq!(plan.elements_per_instr(), 4);
        assert_eq!(plan.macs_per_instr(), 8);
    }

    #[test]
    fn plan_rejects_oversize_kernel() {
        assert!(SimdConv::plan(LaneCfg::new(32, 8), 4, 4, 3).is_none());
    }

    #[test]
    fn lane_conv_matches_direct_fixed() {
        let plan = SimdConv::plan(LaneCfg::new(32, 16), 2, 2, 2).unwrap();
        let x: Vec<u64> = vec![1, 3, 2, 0, 3, 3, 1, 2, 2, 1, 0, 3];
        let k: Vec<u64> = vec![2, 3];
        assert_eq!(plan.conv1d_full(&x, &k), conv1d_full_direct(&x, &k));
    }

    #[test]
    fn lane_conv_matches_direct_property() {
        check("lane-packed conv == direct", 300, |rng| {
            let cfgs = LaneCfg::all();
            let cfg = cfgs[rng.range(0, cfgs.len())];
            let sx = rng.range(1, 9) as u32;
            let sk = rng.range(1, 9) as u32;
            let kt = rng.range(1, 6) as u32;
            let plan = match SimdConv::plan(cfg, sx, sk, kt) {
                Some(p) => p,
                None => return,
            };
            let n = rng.range(1, 64);
            let mut r = rng.fork(3);
            let x: Vec<u64> = (0..n).map(|_| r.below(1 << sx)).collect();
            let k: Vec<u64> = (0..kt).map(|_| r.below(1 << sk)).collect();
            assert_eq!(plan.conv1d_full(&x, &k), conv1d_full_direct(&x, &k));
        });
    }

    #[test]
    fn simd_mul_truncates_within_lane() {
        let plan = SimdConv::plan(LaneCfg::new(32, 16), 2, 2, 2).unwrap();
        // 0xFFFF * 0xFFFF truncated to 16 bits = 0x0001 per lane.
        let v = plan.simd_mul(0xFFFF_FFFF, 0xFFFF_FFFF);
        assert_eq!(v, 0x0001_0001);
    }

    #[test]
    fn pack_windows_flat_matches_vec_variant() {
        let plan = SimdConv::plan(LaneCfg::new(32, 16), 2, 2, 2).unwrap();
        for n in 1..40usize {
            let x: Vec<u64> = (0..n).map(|i| (i % 4) as u64).collect();
            let mut v = Vec::new();
            plan.pack_windows_into(&x, &mut v);
            assert_eq!(v.len(), plan.n_regs(n), "n={n}");
            let mut flat = vec![0u64; plan.n_regs(n)];
            plan.pack_windows_to(&x, &mut flat);
            assert_eq!(v, flat, "n={n}");
        }
    }

    #[test]
    fn seg_ops_scale_with_lanes_and_fields() {
        let p16 = SimdConv::plan(LaneCfg::new(32, 16), 2, 2, 2).unwrap();
        let p32 = SimdConv::plan(LaneCfg::new(32, 32), 2, 2, 2).unwrap();
        assert!(p16.seg_ops_per_instr() > p32.seg_ops_per_instr());
    }

    #[test]
    fn kernel_fits_lane_check() {
        assert!(kernel_fits_lane(LaneCfg::new(32, 16), 2, 2, 2));
        assert!(!kernel_fits_lane(LaneCfg::new(32, 8), 8, 8, 3));
    }

    /// The legality predicate has one implementation: `kernel_fits_lane`
    /// delegates to `SimdConv::plan`, whose closed form is
    /// `field_width(sx,sk,k)·k ≤ lane_bits`. Pin the three agree over the
    /// whole `LaneCfg::all()` × bitwidth × taps grid so the analyzer and
    /// the planner can never drift apart.
    #[test]
    fn fits_lane_matches_plan() {
        for &cfg in LaneCfg::all() {
            for sx in 1..=8u32 {
                for sk in 1..=8u32 {
                    for kt in 1..=8u32 {
                        let closed = field_width(sx, sk, kt) * kt <= cfg.lane_bits;
                        let planned = SimdConv::plan(cfg, sx, sk, kt).is_some();
                        let fits = kernel_fits_lane(cfg, sx, sk, kt);
                        assert_eq!(
                            fits, planned,
                            "fits_lane vs plan at {cfg:?} sx={sx} sk={sk} k={kt}"
                        );
                        assert_eq!(
                            fits, closed,
                            "fits_lane vs closed form at {cfg:?} sx={sx} sk={sk} k={kt}"
                        );
                    }
                }
            }
        }
    }
}
