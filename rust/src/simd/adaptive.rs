//! Adaptive SIMD packing (paper §IV.C).
//!
//! The packing efficiency of SLBC depends on the SIMD lane size, the
//! operand bitwidths *and* the field stride: a wider-than-minimal field
//! wastes capacity per multiply but buys guard bits for in-register
//! accumulation (extraction amortized over [`accum depth`] multiplies).
//! Since the DSP register file supports several lane views (4×8, 2×16,
//! 1×32, and the 64-bit long-multiply path), MCU-MixQ picks — at compile
//! time, per convolution — the `(lane size, field stride)` pair minimizing
//! amortized instruction cost per MAC.
//!
//! The cost model here is the single source of truth shared by the SLBC
//! operators ([`crate::ops::slbc`]), the Eq. 12 performance model
//! ([`crate::perf`]) and the Fig. 5/6 benches.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use super::packing::{LaneCfg, SimdConv};
use super::reorder::RpConv;

/// How many output-channel filters reuse one packed activation register
/// before it is re-packed (packing cost amortization). Conservative: real
/// layers have 16–64 output channels.
pub const PACK_REUSE: u32 = 4;

/// A fully-resolved lane plan for one convolution's bitwidth pair.
#[derive(Debug, Clone, Copy)]
pub struct LanePlan {
    pub cfg: LaneCfg,
    /// Naïve SLBC plan at the chosen field stride.
    pub conv: SimdConv,
    /// Reordered plan at the same stride, when the geometry admits it.
    pub reordered: Option<RpConv>,
    /// Field stride actually chosen (≥ the guard-bit minimum).
    pub field: u32,
    /// In-register accumulation depth at this stride.
    pub accum_depth: u32,
    /// MACs per SIMD multiply.
    pub macs_per_instr: u32,
    /// Amortized instruction-slots per MAC (multiply + packing/PACK_REUSE
    /// + segmentation/accum_depth); lower is better.
    pub cost_per_mac: f64,
}

impl LanePlan {
    /// Whether RP-SLBC's reordered segmentation actually reduces work for
    /// this plan (compile-time adaptivity, §IV.C): e.g. single-lane
    /// pointwise plans gain nothing from Theorem IV.1 and keep naive
    /// segmentation. The single source of truth for the operator
    /// ([`crate::ops::slbc`]), its charging mirror
    /// ([`crate::perf::predict`]) and codegen's kernel flag.
    pub fn reordering_wins(&self) -> bool {
        self.reordered
            .as_ref()
            .map(|r| r.seg_ops_per_instr() < self.conv.seg_ops_per_instr())
            .unwrap_or(false)
    }

    fn build(cfg: LaneCfg, sx: u32, sk: u32, k_taps: u32, field: u32) -> Option<LanePlan> {
        let conv = SimdConv::plan_with_field(cfg, sx, sk, k_taps, field)?;
        let reordered = RpConv::plan_with_field(cfg, sx, sk, k_taps, field);
        let macs = conv.macs_per_instr();
        let depth = conv.spec.accum_depth().max(1);
        let seg = reordered
            .map(|r| r.seg_ops_per_instr())
            .unwrap_or_else(|| conv.seg_ops_per_instr());
        let cost =
            (1.0 + conv.pack_ops_per_instr() as f64 / PACK_REUSE as f64
                + seg as f64 / depth as f64)
                / macs as f64;
        Some(LanePlan {
            cfg,
            conv,
            reordered,
            field,
            accum_depth: depth,
            macs_per_instr: macs,
            cost_per_mac: cost,
        })
    }
}

/// Memo table for [`best_plan`]: the plan search enumerates every
/// `(lane cfg, field stride)` pair, and it used to run afresh for every
/// layer of every compile *and* every `run_layer` call. The result is a
/// pure function of `(sx, sk, k_taps)` over a tiny domain (bitwidths 2–8,
/// a handful of tap counts), so each triple is resolved exactly once per
/// process.
fn plan_memo() -> &'static Mutex<HashMap<(u32, u32, u32), Option<LanePlan>>> {
    static MEMO: OnceLock<Mutex<HashMap<(u32, u32, u32), Option<LanePlan>>>> = OnceLock::new();
    MEMO.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Pick the best `(lane size, field stride)` for a convolution with
/// `sx`-bit activations, `sk`-bit weights and `k_taps` kernel taps.
/// Returns `None` only when no configuration fits (the operator then falls
/// back to the plain-SIMD int8 path). Memoized per `(sx, sk, k_taps)`.
pub fn best_plan(sx: u32, sk: u32, k_taps: u32) -> Option<LanePlan> {
    let key = (sx, sk, k_taps);
    if let Some(p) = plan_memo().lock().unwrap().get(&key) {
        return *p;
    }
    let p = best_plan_with(LaneCfg::all(), sx, sk, k_taps);
    plan_memo().lock().unwrap().insert(key, p);
    p
}

/// [`best_plan`] restricted to a caller-chosen set of lane configurations.
///
/// Used by the Fig. 6 bench to compare against CMix-NN under the same
/// 32-bit-SIMD-register constraint the paper assumes (excluding the
/// long-multiply 64-bit carrier that adaptive packing would otherwise
/// prefer), and by ablations of the adaptive-lane mechanism itself.
pub fn best_plan_with(
    cfgs: &[LaneCfg],
    sx: u32,
    sk: u32,
    k_taps: u32,
) -> Option<LanePlan> {
    let mut best: Option<LanePlan> = None;
    for &cfg in cfgs {
        let min_field = super::poly::field_width(sx, sk, k_taps);
        for field in min_field..=cfg.lane_bits {
            if let Some(p) = LanePlan::build(cfg, sx, sk, k_taps, field) {
                if best
                    .as_ref()
                    .map(|b| p.cost_per_mac < b.cost_per_mac)
                    .unwrap_or(true)
                {
                    best = Some(p);
                }
            }
        }
    }
    best
}

/// The equivalent-operations ratio of one instruction slot under SLBC for
/// a (weight-bits, activation-bits) pair — the quantity of Fig. 6. Kernel
/// taps default to 3 (the dominant 3×3 convolution rows).
pub fn slbc_equivalent_ops(wbits: u32, abits: u32, k_taps: u32) -> f64 {
    best_plan(abits, wbits, k_taps)
        .map(|p| 1.0 / p.cost_per_mac)
        .unwrap_or(1.0)
}

/// [`slbc_equivalent_ops`] under the paper's 32-bit SIMD register
/// constraint (no long-multiply carrier) — the Fig. 6 comparison uses
/// this so the SLBC-vs-CMix-NN ratio reflects packing strategy, not the
/// wider datapath adaptive packing also exploits.
pub fn slbc_equivalent_ops_simd32(wbits: u32, abits: u32, k_taps: u32) -> f64 {
    let cfgs: Vec<LaneCfg> = LaneCfg::all()
        .iter()
        .copied()
        .filter(|c| c.register_bits == 32)
        .collect();
    best_plan_with(&cfgs, abits, wbits, k_taps)
        .map(|p| 1.0 / p.cost_per_mac)
        .unwrap_or(1.0)
}

/// CMix-NN-style lane-per-operand packing throughput for comparison:
/// operands expand to 16-bit lanes and SMLAD performs 2 MACs per multiply
/// regardless of sub-byte width; sub-byte storage additionally pays
/// mask/shift unpacking (CMix-NN's published kernels):
/// 8-bit ≈ 0.5 aux ops per SMLAD (loads amortized), 4-bit ≈ 1.5,
/// 2-bit ≈ 2.0.
pub fn cmixnn_equivalent_ops(wbits: u32, abits: u32) -> f64 {
    // CMix-NN only supports {2,4,8}; other widths round up to the next
    // supported container.
    let eff = |b: u32| -> u32 {
        if b <= 2 {
            2
        } else if b <= 4 {
            4
        } else {
            8
        }
    };
    let unpack_for = |b: u32| match eff(b) {
        2 => 2.0,
        4 => 1.5,
        _ => 0.5,
    };
    let aux: f64 = unpack_for(wbits) + unpack_for(abits) - 0.5; // weights unpack once-ish
    let macs_per_mul = 2.0;
    macs_per_mul / (1.0 + aux)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_plan_exists_for_all_paper_bitwidths() {
        for w in 2..=8u32 {
            for a in 2..=8u32 {
                assert!(best_plan(a, w, 3).is_some(), "w={w} a={a}");
            }
        }
    }

    #[test]
    fn memoized_plan_is_stable_and_matches_search() {
        // The memo must return exactly what the underlying search returns,
        // call after call (the conv pipeline builds kernel caches from it).
        for (a, w, k) in [(2u32, 2u32, 3u32), (4, 4, 3), (8, 8, 3), (3, 5, 1)] {
            let fresh = best_plan_with(LaneCfg::all(), a, w, k).unwrap();
            for _ in 0..3 {
                let memo = best_plan(a, w, k).unwrap();
                assert_eq!(memo.cfg, fresh.cfg, "a={a} w={w} k={k}");
                assert_eq!(memo.field, fresh.field);
                assert_eq!(memo.accum_depth, fresh.accum_depth);
                assert_eq!(memo.macs_per_instr, fresh.macs_per_instr);
                assert!((memo.cost_per_mac - fresh.cost_per_mac).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn low_bits_pack_more_macs() {
        let p2 = best_plan(2, 2, 3).unwrap();
        let p8 = best_plan(8, 8, 3).unwrap();
        assert!(
            p2.macs_per_instr > p8.macs_per_instr,
            "2-bit should pack more MACs/instr ({} vs {})",
            p2.macs_per_instr,
            p8.macs_per_instr
        );
    }

    #[test]
    fn cost_per_mac_monotone_in_bits() {
        let c2 = best_plan(2, 2, 3).unwrap().cost_per_mac;
        let c4 = best_plan(4, 4, 3).unwrap().cost_per_mac;
        let c8 = best_plan(8, 8, 3).unwrap().cost_per_mac;
        assert!(c2 <= c4 && c4 <= c8, "c2={c2} c4={c4} c8={c8}");
    }

    #[test]
    fn guard_slack_buys_accumulation() {
        // The chosen plan at low bitwidths should have accumulation depth
        // greater than one — that's the point of widening the field.
        let p = best_plan(2, 2, 3).unwrap();
        assert!(p.accum_depth >= 2, "depth={}", p.accum_depth);
    }

    #[test]
    fn slbc_beats_cmixnn_at_low_bits() {
        // Fig. 6's headline: SLBC wins on most sub-byte combinations.
        let s = slbc_equivalent_ops(2, 2, 3);
        let c = cmixnn_equivalent_ops(2, 2);
        assert!(s > c, "slbc {s} vs cmixnn {c}");
        let s4 = slbc_equivalent_ops(4, 4, 3);
        let c4 = cmixnn_equivalent_ops(4, 4);
        assert!(s4 > c4, "slbc {s4} vs cmixnn {c4}");
    }

    #[test]
    fn equivalent_ops_decrease_with_bits() {
        let e2 = slbc_equivalent_ops(2, 2, 3);
        let e8 = slbc_equivalent_ops(8, 8, 3);
        assert!(e2 > e8);
    }
}
