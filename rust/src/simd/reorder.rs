//! RP-SLBC — reordered packing with local accumulation (paper §IV.B,
//! Theorem IV.1, Alg. 2).
//!
//! Naïve SLBC packs consecutive element chunks into *adjacent lanes of the
//! same register*, so the overlapping boundary terms of adjacent chunks sit
//! in neighbouring lanes and each product register needs a full
//! segmentation pass (shift+mask per field) plus scalar cross-lane fixes.
//!
//! RP-SLBC reorders the element stream so consecutive chunks go to
//! *corresponding lanes of adjacent registers* (chunk `c` → register
//! `c mod L`, lane `c div L`). Then, between the multiplies of one
//! L-register round, a single parallel lane-shift aligns the previous
//! accumulator with the new product and one SIMD add merges them — the
//! overlap resolves itself inside the accumulator, only the `Ns` freshly
//! completed fields are extracted per multiply, and the cross-lane scalar
//! stitching happens once per round instead of once per multiply. For
//! registers with `L` lanes holding `N` elements each this removes `L`
//! segmentation passes per `N·L·L` elements — the `1/(N·L)` reduction the
//! paper claims.

use super::packing::{LaneCfg, SimdConv};

/// Reordered-packing SLBC convolution plan.
#[derive(Debug, Clone, Copy)]
pub struct RpConv {
    pub inner: SimdConv,
}

impl RpConv {
    /// Build a reordered plan. Requires the kernel spill to fit within one
    /// chunk (`K - 1 <= Ns`) so the low `Ns` fields complete after every
    /// accumulate — the condition under which Alg. 2's local accumulation
    /// is exact.
    pub fn plan(cfg: LaneCfg, sx_bits: u32, sk_bits: u32, k_taps: u32) -> Option<RpConv> {
        Self::from_inner(SimdConv::plan(cfg, sx_bits, sk_bits, k_taps)?)
    }

    /// Like [`RpConv::plan`] with an explicit field stride (for adaptive
    /// guard-bit/accumulation trade-offs, §IV.C).
    pub fn plan_with_field(
        cfg: LaneCfg,
        sx_bits: u32,
        sk_bits: u32,
        k_taps: u32,
        field: u32,
    ) -> Option<RpConv> {
        Self::from_inner(SimdConv::plan_with_field(cfg, sx_bits, sk_bits, k_taps, field)?)
    }

    fn from_inner(inner: SimdConv) -> Option<RpConv> {
        if inner.spec.k_taps > inner.spec.group + 1 {
            return None;
        }
        Some(RpConv { inner })
    }

    /// The reordering of Theorem IV.1: chunk index → (register, lane).
    pub fn chunk_position(&self, chunk: usize) -> (usize, usize) {
        let l = self.inner.cfg.lanes() as usize;
        (chunk % l, chunk / l)
    }

    /// Gather the reordered signal group layout: for a round of
    /// `L` registers, returns `layout[register][lane]` = start element
    /// index of the chunk packed there (or `None` past the signal's end).
    pub fn round_layout(&self, round: usize, x_len: usize) -> Vec<Vec<Option<usize>>> {
        let l = self.inner.cfg.lanes() as usize;
        let ns = self.inner.spec.group as usize;
        let chunks_per_round = l * l;
        let base_chunk = round * chunks_per_round;
        (0..l)
            .map(|reg| {
                (0..l)
                    .map(|lane| {
                        let chunk = base_chunk + lane * l + reg;
                        let start = chunk * ns;
                        (start < x_len).then_some(start)
                    })
                    .collect()
            })
            .collect()
    }

    /// Bit-exact full 1-D convolution through the reordered pipeline:
    /// for each round, L packed multiplies with a lane-parallel
    /// shift-and-accumulate between them; `Ns` completed fields extracted
    /// per multiply; round leftovers stitched once at the end.
    pub fn conv1d_full(&self, x: &[u64], k: &[u64]) -> Vec<u64> {
        let sc = &self.inner;
        assert_eq!(k.len() as u32, sc.spec.k_taps);
        let l = sc.cfg.lanes() as usize;
        let ns = sc.spec.group as usize;
        let s = sc.spec.field;
        let lb = sc.cfg.lane_bits;
        let lane_mask = if lb >= 64 { u64::MAX } else { (1u64 << lb) - 1 };
        let field_mask = (1u64 << s) - 1;
        let out_len = x.len() + k.len() - 1;
        let mut y = vec![0u64; out_len];
        let vk = sc.pack_kernel(k);

        let n_chunks = x.len().div_ceil(ns);
        let rounds = n_chunks.div_ceil(l * l);

        // Lane-parallel right shift by `fields` fields.
        let lane_shr = |reg: u64, fields: usize| -> u64 {
            let sh = fields as u32 * s;
            if sh >= 64 {
                return 0;
            }
            let mut out = 0u64;
            for lane in 0..l {
                let v = (reg >> (lane as u32 * lb)) & lane_mask;
                out |= (v >> sh) << (lane as u32 * lb);
            }
            out
        };
        // Lane-parallel add (fields are guard-protected, no carries cross).
        let lane_add = |a: u64, b: u64| -> u64 {
            let mut out = 0u64;
            for lane in 0..l {
                let va = (a >> (lane as u32 * lb)) & lane_mask;
                let vb = (b >> (lane as u32 * lb)) & lane_mask;
                out |= ((va + vb) & lane_mask) << (lane as u32 * lb);
            }
            out
        };

        for round in 0..rounds {
            let layout = self.round_layout(round, x.len());
            let mut acc = 0u64;
            for reg in 0..l {
                // Pack this register: lane `lane` holds its chunk.
                let mut vs = 0u64;
                for lane in 0..l {
                    if let Some(start) = layout[reg][lane] {
                        let hi = (start + ns).min(x.len());
                        let packed = sc.spec.pack_signal(&x[start..hi]);
                        vs |= packed << (lane as u32 * lb);
                    }
                }
                let vp = sc.simd_mul(vs, vk);
                // Local accumulation: align previous leftovers and merge.
                acc = lane_add(if reg == 0 { 0 } else { lane_shr(acc, ns) }, vp);
                // Extract the Ns now-complete low fields of every lane.
                // Extraction is keyed off the chunk *arithmetic* (not the
                // layout option) because a lane may still carry the spill
                // of its previous register's chunk even when this
                // register's chunk is past the signal's end.
                for lane in 0..l {
                    let start = (round * l * l + lane * l + reg) * ns;
                    if start < x.len() + ns {
                        let lane_v = (acc >> (lane as u32 * lb)) & lane_mask;
                        for f in 0..ns {
                            let idx = start + f;
                            if idx < y.len() {
                                y[idx] += (lane_v >> (f as u32 * s)) & field_mask;
                            }
                        }
                    }
                }
            }
            // Round epilogue: the K-1 leftover fields per lane belong to the
            // chunk after the lane's last chunk of this round (register L-1).
            let kt = sc.spec.k_taps as usize;
            for lane in 0..l {
                if let Some(start) = layout[l - 1][lane] {
                    let lane_v = (lane_shr(acc, ns) >> (lane as u32 * lb)) & lane_mask;
                    for f in 0..kt.saturating_sub(1) {
                        let idx = start + ns + f;
                        if idx < y.len() {
                            y[idx] += (lane_v >> (f as u32 * s)) & field_mask;
                        }
                    }
                }
            }
        }
        y
    }

    /// Number of chunks [`Self::prepack_chunks`] produces for an
    /// `n`-element row — the per-row stride of the flat packed buffers the
    /// rolling-row conv pipeline holds.
    pub fn n_chunks(&self, n: usize) -> usize {
        n.div_ceil(self.inner.spec.group as usize)
    }

    /// Pre-pack the signal's chunks once (filter-independent): chunk `c`
    /// covers `x[c*Ns .. c*Ns+Ns]`; its packed lane value is reused by
    /// every output channel.
    pub fn prepack_chunks(&self, x: &[u64], out: &mut Vec<u64>) {
        let ns = self.inner.spec.group as usize;
        let mut start = 0usize;
        while start < x.len() {
            let hi = (start + ns).min(x.len());
            out.push(self.inner.spec.pack_signal(&x[start..hi]));
            start += ns;
        }
    }

    /// Allocation-free [`Self::prepack_chunks`]: writes the
    /// [`Self::n_chunks`]`(x.len())` packed chunks into `out` (a slot of a
    /// flat, strided buffer) instead of appending to a `Vec`.
    #[inline]
    pub fn prepack_chunks_to(&self, x: &[u64], out: &mut [u64]) {
        let ns = self.inner.spec.group as usize;
        debug_assert_eq!(out.len(), self.n_chunks(x.len()));
        let mut start = 0usize;
        let mut c = 0usize;
        while start < x.len() {
            let hi = (start + ns).min(x.len());
            out[c] = self.inner.spec.pack_signal(&x[start..hi]);
            c += 1;
            start += ns;
        }
    }

    /// Allocation-free reordered convolution over prepacked chunks,
    /// accumulating into a signed layer buffer — bit-identical to
    /// [`Self::conv1d_full`] (enforced by tests), used by the operator
    /// hot path.
    pub fn conv_prepacked_into(&self, chunks: &[u64], x_len: usize, vk: u64, y: &mut [i64]) {
        let sc = &self.inner;
        let l = sc.cfg.lanes() as usize;
        let ns = sc.spec.group as usize;
        let s = sc.spec.field;
        let lb = sc.cfg.lane_bits;
        let lane_mask = if lb >= 64 { u64::MAX } else { (1u64 << lb) - 1 };
        let field_mask = (1u64 << s) - 1;

        let n_chunks = x_len.div_ceil(ns);
        let rounds = n_chunks.div_ceil(l * l);
        let kt = sc.spec.k_taps as usize;

        let lane_shr = |reg: u64, fields: usize| -> u64 {
            let sh = fields as u32 * s;
            if sh >= 64 {
                return 0;
            }
            let mut out = 0u64;
            for lane in 0..l {
                let v = (reg >> (lane as u32 * lb)) & lane_mask;
                out |= (v >> sh) << (lane as u32 * lb);
            }
            out
        };
        let lane_add = |a: u64, b: u64| -> u64 {
            let mut out = 0u64;
            for lane in 0..l {
                let va = (a >> (lane as u32 * lb)) & lane_mask;
                let vb = (b >> (lane as u32 * lb)) & lane_mask;
                out |= ((va + vb) & lane_mask) << (lane as u32 * lb);
            }
            out
        };

        for round in 0..rounds {
            let base_chunk = round * l * l;
            let mut acc = 0u64;
            for reg in 0..l {
                let mut vs = 0u64;
                for lane in 0..l {
                    let chunk = base_chunk + lane * l + reg;
                    if chunk * ns < x_len {
                        vs |= chunks[chunk] << (lane as u32 * lb);
                    }
                }
                let vp = sc.simd_mul(vs, vk);
                acc = lane_add(if reg == 0 { 0 } else { lane_shr(acc, ns) }, vp);
                for lane in 0..l {
                    let start = (base_chunk + lane * l + reg) * ns;
                    if start < x_len + ns {
                        let lane_v = (acc >> (lane as u32 * lb)) & lane_mask;
                        for f in 0..ns {
                            let idx = start + f;
                            if idx < y.len() {
                                y[idx] += ((lane_v >> (f as u32 * s)) & field_mask) as i64;
                            }
                        }
                    }
                }
            }
            // Round epilogue: K-1 leftover fields per lane.
            for lane in 0..l {
                let chunk = base_chunk + lane * l + (l - 1);
                if chunk * ns < x_len {
                    let start = chunk * ns;
                    let lane_v = (lane_shr(acc, ns) >> (lane as u32 * lb)) & lane_mask;
                    for f in 0..kt.saturating_sub(1) {
                        let idx = start + ns + f;
                        if idx < y.len() {
                            y[idx] += ((lane_v >> (f as u32 * s)) & field_mask) as i64;
                        }
                    }
                }
            }
        }
    }

    /// Segmentation bit-ops per SIMD multiply under reordered packing:
    /// one lane-shift + one lane-add for the accumulation, then shift+mask
    /// per *completed* field only (Ns of them, not Ns+K-1), and no per-
    /// multiply cross-lane scalar fixes.
    pub fn seg_ops_per_instr(&self) -> u32 {
        2 + self.inner.spec.group * 2
    }

    /// The paper's headline ratio: segmentation overhead relative to naïve
    /// SLBC (→ `1/(N·L)` asymptotically for the boundary work).
    pub fn seg_reduction_vs_naive(&self) -> f64 {
        self.seg_ops_per_instr() as f64 / self.inner.seg_ops_per_instr() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::poly::conv1d_full_direct;
    use crate::util::prop::check;

    fn cfg16() -> LaneCfg {
        LaneCfg::new(32, 16)
    }

    #[test]
    fn chunk_positions_interleave_registers_first() {
        let rp = RpConv::plan(cfg16(), 2, 2, 2).unwrap();
        // L = 2 lanes: chunks 0,1 -> registers 0,1 lane 0; chunks 2,3 ->
        // registers 0,1 lane 1.
        assert_eq!(rp.chunk_position(0), (0, 0));
        assert_eq!(rp.chunk_position(1), (1, 0));
        assert_eq!(rp.chunk_position(2), (0, 1));
        assert_eq!(rp.chunk_position(3), (1, 1));
    }

    #[test]
    fn reordered_conv_matches_direct_fixed() {
        let rp = RpConv::plan(cfg16(), 2, 2, 2).unwrap();
        let x: Vec<u64> = vec![1, 3, 2, 0, 3, 3, 1, 2, 2, 1, 0, 3, 1, 1, 2, 3];
        let k: Vec<u64> = vec![2, 3];
        assert_eq!(rp.conv1d_full(&x, &k), conv1d_full_direct(&x, &k));
    }

    #[test]
    fn reordered_conv_partial_rounds() {
        // Lengths that do not fill a round (N*L*L elements) still work.
        let rp = RpConv::plan(cfg16(), 2, 2, 2).unwrap();
        for n in 1..20 {
            let x: Vec<u64> = (0..n).map(|i| (i % 4) as u64).collect();
            let k: Vec<u64> = vec![1, 2];
            assert_eq!(rp.conv1d_full(&x, &k), conv1d_full_direct(&x, &k), "n={n}");
        }
    }

    #[test]
    fn reordered_conv_property() {
        check("reordered conv == direct", 300, |rng| {
            let cfgs = LaneCfg::all();
            let cfg = cfgs[rng.range(0, cfgs.len())];
            let sx = rng.range(1, 9) as u32;
            let sk = rng.range(1, 9) as u32;
            let kt = rng.range(1, 6) as u32;
            let rp = match RpConv::plan(cfg, sx, sk, kt) {
                Some(p) => p,
                None => return,
            };
            let n = rng.range(1, 80);
            let mut r = rng.fork(4);
            let x: Vec<u64> = (0..n).map(|_| r.below(1 << sx)).collect();
            let k: Vec<u64> = (0..kt).map(|_| r.below(1 << sk)).collect();
            assert_eq!(rp.conv1d_full(&x, &k), conv1d_full_direct(&x, &k));
        });
    }

    #[test]
    fn prepack_chunks_flat_matches_vec_variant() {
        let rp = RpConv::plan(cfg16(), 2, 2, 2).unwrap();
        for n in 1..40usize {
            let x: Vec<u64> = (0..n).map(|i| ((i * 3) % 4) as u64).collect();
            let mut v = Vec::new();
            rp.prepack_chunks(&x, &mut v);
            assert_eq!(v.len(), rp.n_chunks(n), "n={n}");
            let mut flat = vec![0u64; rp.n_chunks(n)];
            rp.prepack_chunks_to(&x, &mut flat);
            assert_eq!(v, flat, "n={n}");
        }
    }

    #[test]
    fn rp_plan_rejects_wide_kernels() {
        // K > Ns + 1 breaks the local-accumulation completeness condition.
        // 8b x 8b in a 32-bit lane: S = 17 with 2 taps -> Ns = 0/invalid.
        assert!(RpConv::plan(LaneCfg::new(32, 8), 4, 4, 3).is_none());
    }

    #[test]
    fn seg_ops_strictly_fewer_than_naive() {
        for (sx, sk, kt) in [(2u32, 2u32, 2u32), (2, 4, 2), (3, 3, 2)] {
            for &cfg in LaneCfg::all() {
                if let Some(rp) = RpConv::plan(cfg, sx, sk, kt) {
                    // Strict win whenever there is more than one lane (the
                    // cross-lane stitching disappears); equality is the
                    // best possible for single-lane views, where RP's gain
                    // comes from accumulation-depth amortization instead.
                    if cfg.lanes() > 1 {
                        assert!(
                            rp.seg_ops_per_instr() < rp.inner.seg_ops_per_instr(),
                            "cfg={cfg:?} sx={sx} sk={sk} kt={kt}"
                        );
                    } else {
                        assert!(rp.seg_ops_per_instr() <= rp.inner.seg_ops_per_instr());
                    }
                }
            }
        }
    }

    #[test]
    fn seg_reduction_below_one() {
        let rp = RpConv::plan(cfg16(), 2, 2, 2).unwrap();
        let r = rp.seg_reduction_vs_naive();
        assert!(r < 1.0 && r > 0.0);
    }
}
