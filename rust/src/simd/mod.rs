//! SLBC packing mathematics (paper §IV).
//!
//! * [`poly`]     — the polynomial-multiplication identity (Eq. 3–7): pack,
//!   wide-multiply, segment; bit-exact convolution on a u64 carrier.
//! * [`packing`]  — SIMD-lane-granularity packing (Eq. 8–11): registers with
//!   configurable lane sizes, per-lane products, cross-lane boundary
//!   combination — the scheme the MCU operators replay.
//! * [`reorder`]  — RP-SLBC (Thm. IV.1): the reordered element layout that
//!   moves overlap from *adjacent lanes* to *corresponding lanes of adjacent
//!   registers*, enabling local accumulation and cutting segmentation ops to
//!   `1/(N·L)` of naïve SLBC.
//! * [`adaptive`] — adaptive lane sizing (§IV.C): choose the lane
//!   configuration maximizing effective MACs per instruction for each
//!   convolution's bitwidth pair at compile time.

pub mod adaptive;
pub mod packing;
pub mod poly;
pub mod reorder;

pub use adaptive::{best_plan, LanePlan};
pub use packing::{LaneCfg, SimdConv};
pub use poly::{conv1d_full_packed, field_width, group_size, PackSpec};
