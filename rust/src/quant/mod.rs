//! Quantization machinery: per-layer bit configurations, symmetric weight
//! quantization and unsigned activation quantization — the integer twin of
//! the Layer-1 `fake_quant` kernels (same max-abs dynamic scaling), used
//! when deploying a trained flat parameter vector onto the MCU engine.

use crate::models::ModelDesc;
use crate::util::json::{Json, JsonError};
use std::collections::BTreeMap;

/// Per-layer weight/activation bitwidths, the NAS search result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitConfig {
    pub wbits: Vec<u8>,
    pub abits: Vec<u8>,
}

impl BitConfig {
    /// Uniform configuration (e.g. the TinyEngine int8 baseline).
    pub fn uniform(num_layers: usize, bits: u8) -> Self {
        BitConfig {
            wbits: vec![bits; num_layers],
            abits: vec![bits; num_layers],
        }
    }

    /// Clamp every layer into CMix-NN's supported set {2,4,8} (rounding
    /// up), for baseline comparisons.
    pub fn to_cmixnn_supported(&self) -> BitConfig {
        let up = |b: u8| -> u8 {
            if b <= 2 {
                2
            } else if b <= 4 {
                4
            } else {
                8
            }
        };
        BitConfig {
            wbits: self.wbits.iter().map(|&b| up(b)).collect(),
            abits: self.abits.iter().map(|&b| up(b)).collect(),
        }
    }

    pub fn num_layers(&self) -> usize {
        self.wbits.len()
    }

    /// Mean weight bitwidth (Fig. 8's y-axis).
    pub fn avg_wbits(&self) -> f64 {
        self.wbits.iter().map(|&b| b as f64).sum::<f64>() / self.wbits.len() as f64
    }

    pub fn avg_abits(&self) -> f64 {
        self.abits.iter().map(|&b| b as f64).sum::<f64>() / self.abits.len() as f64
    }

    /// Bits as f32 tensors for the HLO programs.
    pub fn wbits_f32(&self) -> Vec<f32> {
        self.wbits.iter().map(|&b| b as f32).collect()
    }

    pub fn abits_f32(&self) -> Vec<f32> {
        self.abits.iter().map(|&b| b as f32).collect()
    }

    /// JSON form: `{"wbits": [...], "abits": [...]}`.
    pub fn to_json(&self) -> Json {
        let bits = |v: &[u8]| Json::Arr(v.iter().map(|&b| Json::Num(b as f64)).collect());
        let mut o = BTreeMap::new();
        o.insert("wbits".into(), bits(&self.wbits));
        o.insert("abits".into(), bits(&self.abits));
        Json::Obj(o)
    }

    /// Parse the [`to_json`](BitConfig::to_json) form back (also accepts
    /// the saved-config envelope, which carries the same two keys).
    pub fn from_json(j: &Json) -> Result<BitConfig, JsonError> {
        let bits = |key: &str| -> Result<Vec<u8>, JsonError> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| JsonError(format!("{key} not an array")))?
                .iter()
                .map(|v| {
                    v.as_usize()
                        .filter(|&b| (1..=32).contains(&b))
                        .map(|b| b as u8)
                        .ok_or_else(|| JsonError(format!("bad bitwidth in {key}")))
                })
                .collect()
        };
        let cfg = BitConfig {
            wbits: bits("wbits")?,
            abits: bits("abits")?,
        };
        if cfg.wbits.is_empty() || cfg.wbits.len() != cfg.abits.len() {
            return Err(JsonError(format!(
                "wbits/abits length mismatch ({} vs {})",
                cfg.wbits.len(),
                cfg.abits.len()
            )));
        }
        Ok(cfg)
    }
}

/// Save a searched configuration as a reusable artifact:
/// `{"backbone": "...", "wbits": [...], "abits": [...]}` — the file
/// `deploy`/`pipeline` `--config-file` and serve's `cfg@FILE` mix entries
/// consume.
pub fn save_config(path: &str, backbone: &str, cfg: &BitConfig) -> crate::Result<()> {
    let mut o = match cfg.to_json() {
        Json::Obj(o) => o,
        _ => unreachable!(),
    };
    o.insert("backbone".into(), Json::Str(backbone.into()));
    std::fs::write(path, format!("{}\n", Json::Obj(o).to_string_compact()))?;
    Ok(())
}

/// Load a saved configuration: `(backbone, config)`.
pub fn load_config(path: &str) -> crate::Result<(String, BitConfig)> {
    let src = std::fs::read_to_string(path)?;
    let j = Json::parse(&src).map_err(|e| anyhow::anyhow!("{path}: {}", e.0))?;
    let backbone = j
        .req("backbone")
        .ok()
        .and_then(|b| b.as_str())
        .ok_or_else(|| anyhow::anyhow!("{path}: missing \"backbone\""))?
        .to_string();
    let cfg = BitConfig::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {}", e.0))?;
    Ok((backbone, cfg))
}

/// A quantized weight tensor: integer values in `[-2^(b-1)+1, 2^(b-1)-1]`
/// with a per-tensor scale (symmetric, zero-point-free).
#[derive(Debug, Clone)]
pub struct QWeights {
    pub data: Vec<i32>,
    pub bits: u8,
    pub scale: f32,
}

/// Largest representable magnitude of a symmetric `bits`-wide weight:
/// `2^(bits-1) - 1` — the clamp bound of [`quantize_weights`] and the
/// range the static analyzer's quant lint re-proves per layer.
pub fn weight_limit(bits: u8) -> i32 {
    (1i32 << (bits - 1)) - 1
}

impl QWeights {
    /// Every value inside the symmetric representable range.
    /// [`quantize_weights`] guarantees this by clamping; a violation
    /// means the tensor was mutated or decoded from a corrupt image.
    pub fn in_range(&self) -> bool {
        let lim = weight_limit(self.bits);
        self.data.iter().all(|&v| (-lim..=lim).contains(&v))
    }
}

/// A quantized activation tensor: unsigned `[0, 2^b - 1]` with scale.
#[derive(Debug, Clone)]
pub struct QActs {
    pub data: Vec<u32>,
    pub bits: u8,
    pub scale: f32,
}

/// Symmetric signed quantization with dynamic max-abs scale (mirror of
/// `kernels/quant.py::fake_quant_signed`).
pub fn quantize_weights(w: &[f32], bits: u8) -> QWeights {
    let n = ((1i64 << (bits - 1)) - 1) as f32;
    let amax = w.iter().fold(1e-8f32, |m, &v| m.max(v.abs()));
    let scale = amax / n;
    let data = w
        .iter()
        .map(|&v| (v / scale).round().clamp(-n, n) as i32)
        .collect();
    QWeights { data, bits, scale }
}

/// Unsigned activation quantization (mirror of `fake_quant_unsigned`).
pub fn quantize_acts(x: &[f32], bits: u8) -> QActs {
    let n = ((1u64 << bits) - 1) as f32;
    let amax = x.iter().fold(1e-8f32, |m, &v| m.max(v.max(0.0)));
    let scale = amax / n;
    let data = x
        .iter()
        .map(|&v| (v.max(0.0) / scale).round().clamp(0.0, n) as u32)
        .collect();
    QActs { data, bits, scale }
}

/// Dequantize helper (tests / debugging).
pub fn dequantize_weights(q: &QWeights) -> Vec<f32> {
    q.data.iter().map(|&v| v as f32 * q.scale).collect()
}

/// Extract and quantize every layer's weights from the flat f32 parameter
/// vector (the QAT training state) according to a [`BitConfig`].
pub fn quantize_model(
    model: &ModelDesc,
    flat: &[f32],
    cfg: &BitConfig,
) -> Vec<(QWeights, Vec<f32>)> {
    assert_eq!(cfg.num_layers(), model.layers.len());
    model
        .layers
        .iter()
        .zip(&cfg.wbits)
        .map(|(l, &b)| {
            let w = &flat[l.w_offset..l.w_offset + l.w_size];
            let bias = flat[l.b_offset..l.b_offset + l.b_size].to_vec();
            (quantize_weights(w, b), bias)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn uniform_config() {
        let c = BitConfig::uniform(4, 8);
        assert_eq!(c.wbits, vec![8, 8, 8, 8]);
        assert_eq!(c.avg_wbits(), 8.0);
    }

    #[test]
    fn cmixnn_rounding() {
        let c = BitConfig {
            wbits: vec![2, 3, 5, 8],
            abits: vec![4, 6, 7, 2],
        };
        let r = c.to_cmixnn_supported();
        assert_eq!(r.wbits, vec![2, 4, 8, 8]);
        assert_eq!(r.abits, vec![4, 8, 8, 2]);
    }

    #[test]
    fn weight_quant_range() {
        check("weights quantize within signed range", 100, |rng| {
            let bits = rng.range(2, 9) as u8;
            let n = rng.range(1, 200);
            let mut r = rng.fork(5);
            let w: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let q = quantize_weights(&w, bits);
            let lim = (1i32 << (bits - 1)) - 1;
            assert!(q.data.iter().all(|&v| v >= -lim && v <= lim));
        });
    }

    #[test]
    fn act_quant_unsigned_range() {
        check("acts quantize within unsigned range", 100, |rng| {
            let bits = rng.range(2, 9) as u8;
            let n = rng.range(1, 200);
            let mut r = rng.fork(6);
            let x: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let q = quantize_acts(&x, bits);
            let lim = (1u64 << bits) - 1;
            assert!(q.data.iter().all(|&v| (v as u64) <= lim));
        });
    }

    #[test]
    fn quantization_error_bounded() {
        let mut r = Rng::new(3);
        let w: Vec<f32> = (0..512).map(|_| r.normal()).collect();
        let q = quantize_weights(&w, 8);
        let back = dequantize_weights(&q);
        for (a, b) in w.iter().zip(&back) {
            assert!((a - b).abs() <= q.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn more_bits_smaller_error() {
        let mut r = Rng::new(4);
        let w: Vec<f32> = (0..2048).map(|_| r.normal()).collect();
        let mut errs = Vec::new();
        for b in [2u8, 4, 8] {
            let q = quantize_weights(&w, b);
            let back = dequantize_weights(&q);
            let mse: f32 = w
                .iter()
                .zip(&back)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / w.len() as f32;
            errs.push(mse);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2]);
    }
}
