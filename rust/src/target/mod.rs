//! The unified device-description layer: one [`Target`] type that owns
//! everything the rest of the framework needs to know about an MCU —
//! ISA/cycle table, memory capacities, clock, device class and an
//! [`EnergyModel`] — plus the named-target registry every string→device
//! resolution goes through.
//!
//! Before this module existed the repo described "what device am I
//! compiling/pricing/serving for?" four different ways (`Machine`
//! constructors, `Memory` constructors, per-device `CycleModel`s and the
//! serving layer's `DeviceCfg`), each carrying its own copy of the same
//! clock/SRAM/flash literals. Those constants now live **here and only
//! here**; every other site is a one-line delegation:
//!
//! * [`crate::mcu::Machine::stm32f746`] → [`Target::lookup`] + the
//!   target's memory map and cycle table;
//! * [`crate::mcu::Memory::stm32f746`] → [`Memory::for_target`];
//! * `serve::DeviceCfg` is a type alias of [`Target`] (the fleet prices
//!   batches with `target.cycle_model` and `target.energy_model`);
//! * [`crate::engine::CompiledModel::compile_for`] gates the memory plan
//!   against `target.sram_bytes` and prices inference with
//!   `target.cycle_model`;
//! * [`crate::perf`] predictions price to cycles *and joules* against a
//!   `&Target` ([`crate::perf::PredictedCost::cycles_on`] /
//!   [`joules_on`](crate::perf::PredictedCost::joules_on)).
//!
//! # Energy
//!
//! The [`EnergyModel`] mirrors the [`CycleModel`] shape: a per-
//! [`InstrClass`] dynamic energy (picojoules per executed instruction)
//! plus a static/leakage power term, so any instruction [`Counter`]
//! histogram prices to joules exactly the way it already prices to
//! cycles. The M4-class part spends fewer joules than the M7 on every
//! instruction class (smaller core, lower clock/voltage) even where it
//! spends more cycles — which is what makes energy-aware placement
//! ([`crate::serve::sched::EnergyAware`]) a real trade-off instead of a
//! latency re-ranking.

use crate::mcu::counter::Counter;
use crate::mcu::cycles::{CycleModel, InstrClass, ALL_CLASSES};
use crate::Result;

/// STM32F746 (the paper's evaluation platform) clock frequency in Hz.
pub const STM32F746_CLOCK_HZ: u64 = 216_000_000;

/// STM32F746 SRAM capacity in bytes (320 KB).
pub const STM32F746_SRAM_BYTES: usize = 320 * 1024;

/// STM32F746 flash capacity in bytes (1 MB).
pub const STM32F746_FLASH_BYTES: usize = 1024 * 1024;

/// STM32F446 (Cortex-M4 class, the heterogeneous-fleet companion part)
/// clock frequency in Hz.
pub const STM32F446_CLOCK_HZ: u64 = 180_000_000;

/// STM32F446 SRAM capacity in bytes (128 KB).
pub const STM32F446_SRAM_BYTES: usize = 128 * 1024;

/// STM32F446 flash capacity in bytes (512 KB).
pub const STM32F446_FLASH_BYTES: usize = 512 * 1024;

/// Device class label (reporting + fleet-spec parsing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// Cortex-M7 class (STM32F746 profile).
    M7,
    /// Cortex-M4 class (STM32F446 profile).
    M4,
}

impl DeviceClass {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceClass::M7 => "m7",
            DeviceClass::M4 => "m4",
        }
    }
}

/// Per-instruction-class dynamic energy (picojoules per executed
/// instruction) plus static power — the energy twin of [`CycleModel`].
///
/// Folding a [`Counter`] through the table yields dynamic energy; the
/// static term charges leakage/always-on power over the execution time
/// implied by the paired cycle model and clock. Absolute values are
/// datasheet-order estimates (run-mode current × supply voltage,
/// apportioned by instruction latency); what the framework relies on is
/// the *relative* structure: joules grow monotonically with work, and
/// the smaller M4 core spends less energy per instruction than the M7
/// on every class — including the 4-cycle long multiplies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    pub alu_pj: f64,
    pub bit_pj: f64,
    pub mul_pj: f64,
    pub simd_pj: f64,
    pub mul_long_pj: f64,
    pub load_pj: f64,
    pub store_pj: f64,
    pub branch_taken_pj: f64,
    pub branch_not_taken_pj: f64,
    pub sat_pj: f64,
    /// Static/leakage power in milliwatts, charged over busy time.
    pub static_mw: f64,
}

impl EnergyModel {
    /// Cortex-M7 @ STM32F746: ~1.5 nJ per single-cycle instruction at
    /// 216 MHz run mode, loads/branches pro-rated by their cycle cost.
    pub const fn cortex_m7() -> Self {
        EnergyModel {
            alu_pj: 1500.0,
            bit_pj: 1500.0,
            mul_pj: 1700.0,
            simd_pj: 1900.0,
            mul_long_pj: 2100.0,
            load_pj: 3100.0,
            store_pj: 1700.0,
            branch_taken_pj: 3900.0,
            branch_not_taken_pj: 1500.0,
            sat_pj: 1600.0,
            static_mw: 40.0,
        }
    }

    /// Cortex-M4 @ STM32F446: the smaller core burns roughly half the
    /// charge per instruction; even the 4-cycle long multiply lands
    /// below the M7's single-cycle one in total energy.
    pub const fn cortex_m4() -> Self {
        EnergyModel {
            alu_pj: 700.0,
            bit_pj: 700.0,
            mul_pj: 800.0,
            simd_pj: 900.0,
            mul_long_pj: 1900.0,
            load_pj: 1450.0,
            store_pj: 800.0,
            branch_taken_pj: 1850.0,
            branch_not_taken_pj: 700.0,
            sat_pj: 750.0,
            static_mw: 16.0,
        }
    }

    /// Dynamic energy of one instruction of a class, in picojoules.
    pub fn instr_pj(&self, class: InstrClass) -> f64 {
        match class {
            InstrClass::Alu => self.alu_pj,
            InstrClass::Bit => self.bit_pj,
            InstrClass::Mul => self.mul_pj,
            InstrClass::Simd => self.simd_pj,
            InstrClass::MulLong => self.mul_long_pj,
            InstrClass::Load => self.load_pj,
            InstrClass::Store => self.store_pj,
            InstrClass::BranchTaken => self.branch_taken_pj,
            InstrClass::BranchNotTaken => self.branch_not_taken_pj,
            InstrClass::Sat => self.sat_pj,
        }
    }

    /// Dynamic energy of a whole instruction histogram, in joules.
    pub fn dynamic_joules(&self, ctr: &Counter) -> f64 {
        ALL_CLASSES
            .iter()
            .map(|&c| ctr.get(c) as f64 * self.instr_pj(c))
            .sum::<f64>()
            * 1e-12
    }

    /// Static power in watts.
    pub fn static_watts(&self) -> f64 {
        self.static_mw * 1e-3
    }

    /// Total energy of executing `ctr` on a core with `cycles` table at
    /// `clock_hz`: dynamic per-instruction energy plus static power over
    /// the implied execution time.
    pub fn joules(&self, ctr: &Counter, cycles: &CycleModel, clock_hz: u64) -> f64 {
        self.dynamic_joules(ctr)
            + self.static_watts() * (ctr.cycles(cycles) as f64 / clock_hz as f64)
    }
}

/// One MCU deployment/pricing/serving target: the single source of truth
/// for a named device's capabilities and costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Target {
    /// Registry name (`stm32f746`, `stm32f446`).
    pub name: &'static str,
    /// Coarse device class (`m7`, `m4`) — the fleet-spec shorthand.
    pub class: DeviceClass,
    pub clock_hz: u64,
    pub sram_bytes: usize,
    pub flash_bytes: usize,
    /// Per-instruction-class cycle costs of this core.
    pub cycle_model: CycleModel,
    /// Per-instruction-class energy costs + static power of this core.
    pub energy_model: EnergyModel,
}

/// Every registered target, in registry order. [`Target::lookup`]
/// resolves names and class aliases against this table.
pub static REGISTRY: [Target; 2] = [Target::stm32f746(), Target::stm32f446()];

impl Target {
    /// The paper's evaluation platform: Cortex-M7, 320 KB SRAM, 1 MB
    /// flash, 216 MHz.
    pub const fn stm32f746() -> Target {
        Target {
            name: "stm32f746",
            class: DeviceClass::M7,
            clock_hz: STM32F746_CLOCK_HZ,
            sram_bytes: STM32F746_SRAM_BYTES,
            flash_bytes: STM32F746_FLASH_BYTES,
            cycle_model: CycleModel::cortex_m7(),
            energy_model: EnergyModel::cortex_m7(),
        }
    }

    /// The M4-class companion part: Cortex-M4, 128 KB SRAM, 512 KB
    /// flash, 180 MHz, 4-cycle long multiplies — the "just enough data
    /// width" end of a heterogeneous extreme-edge fleet.
    pub const fn stm32f446() -> Target {
        Target {
            name: "stm32f446",
            class: DeviceClass::M4,
            clock_hz: STM32F446_CLOCK_HZ,
            sram_bytes: STM32F446_SRAM_BYTES,
            flash_bytes: STM32F446_FLASH_BYTES,
            cycle_model: CycleModel::cortex_m4(),
            energy_model: EnergyModel::cortex_m4(),
        }
    }

    /// Resolve a target by registry name or class alias (`stm32f746` /
    /// `m7`, `stm32f446` / `m4`), case-insensitively.
    pub fn lookup(name: &str) -> Option<&'static Target> {
        let n = name.trim().to_ascii_lowercase();
        REGISTRY
            .iter()
            .find(|t| t.name == n || t.class.name() == n)
    }

    /// Human-readable list of every accepted spelling, for error
    /// messages and CLI help.
    pub fn known_names() -> String {
        REGISTRY
            .iter()
            .map(|t| format!("{}|{}", t.class.name(), t.name))
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// [`lookup`](Target::lookup) with the registry's canonical error:
    /// the offending name plus every accepted spelling. The single
    /// resolution path for `--target`-style CLI/config arguments.
    pub fn resolve(name: &str) -> Result<&'static Target> {
        Target::lookup(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown target `{name}` (known targets: {})",
                Target::known_names()
            )
        })
    }

    /// Parse a fleet spec — comma-separated `target[@MHZmhz][:count]`
    /// entries, e.g. `m7:2,m4:2`, `stm32f746:4` or `m4@84mhz:2` — into
    /// one [`Target`] per device. The optional `@NNmhz` suffix overrides
    /// the registry clock, making throttled (DVFS) operating points
    /// constructible straight from the CLI; the override rescales
    /// timeline and energy pricing exactly like a runtime
    /// `Throttle{clock}` fleet event. Unknown tokens report the
    /// offending entry and the list of registered target names.
    pub fn parse_fleet(spec: &str) -> Result<Vec<Target>> {
        let mut fleet = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (name, count) = match entry.split_once(':') {
                Some((c, n)) => (
                    c,
                    n.trim().parse::<usize>().map_err(|_| {
                        anyhow::anyhow!(
                            "bad device count `{n}` in fleet entry `{entry}` (want target[:count])"
                        )
                    })?,
                ),
                None => (entry, 1),
            };
            // Clock override: `m4@84mhz` — split before registry lookup
            // so the base name still gets the canonical unknown-target
            // error.
            let (name, clock_override) = match name.split_once('@') {
                Some((base, clk)) => {
                    let clk = clk.trim().to_ascii_lowercase();
                    let mhz = clk
                        .strip_suffix("mhz")
                        .and_then(|m| m.trim().parse::<u64>().ok())
                        .filter(|m| *m >= 1)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "bad clock override `{clk}` in fleet entry `{entry}` \
                                 (want target@NNmhz[:count], e.g. m4@84mhz:2)"
                            )
                        })?;
                    (base, Some(mhz * 1_000_000))
                }
                None => (name, None),
            };
            let target = Target::lookup(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown target `{name}` in fleet spec `{spec}` (known targets: {})",
                    Target::known_names()
                )
            })?;
            anyhow::ensure!(count >= 1, "device count must be >= 1 in `{entry}`");
            let mut target = *target;
            if let Some(clock_hz) = clock_override {
                target.clock_hz = clock_hz;
            }
            fleet.extend(std::iter::repeat(target).take(count));
        }
        anyhow::ensure!(!fleet.is_empty(), "fleet spec `{spec}` names no devices");
        Ok(fleet)
    }

    /// Render a fleet back to its canonical spec (`m7:2,m4:2`):
    /// consecutive identical devices collapse to `label:count`, where
    /// the label is the class shorthand when that alias resolves to
    /// this exact target in the registry and the full part name
    /// otherwise — so the rendering stays unambiguous even once a
    /// class has more than one registered part.
    ///
    /// Round-trip contract: for fleets built from unmodified registry
    /// targets, `parse_fleet(fleet_spec(f)) == f`. The spec grammar can
    /// only name registry entries, so a hand-customized target (say, a
    /// registry part with its `sram_bytes` overridden) renders as its
    /// part name and re-parses to the registry's values — use a richer
    /// serialization if custom hardware must survive a round-trip.
    pub fn fleet_spec(fleet: &[Target]) -> String {
        let mut parts: Vec<String> = Vec::new();
        let mut i = 0;
        while i < fleet.len() {
            let t = &fleet[i];
            let mut n = 1;
            while i + n < fleet.len() && fleet[i + n] == *t {
                n += 1;
            }
            // A pure clock override of a registry part (the DVFS case
            // the spec grammar can express) renders as `label@NNmhz`;
            // any other customization falls back to the part name.
            let mut probe = *t;
            let mut suffix = String::new();
            if let Some(reg) = Target::lookup(t.name) {
                if reg.clock_hz != t.clock_hz && t.clock_hz % 1_000_000 == 0 {
                    probe.clock_hz = reg.clock_hz;
                    if probe == *reg {
                        suffix = format!("@{}mhz", t.clock_hz / 1_000_000);
                    } else {
                        probe = *t;
                    }
                }
            }
            let label = match Target::lookup(t.class.name()) {
                Some(reg) if *reg == probe => format!("{}{suffix}", t.class.name()),
                _ => format!("{}{suffix}", t.name),
            };
            if n == 1 {
                parts.push(label);
            } else {
                parts.push(format!("{label}:{n}"));
            }
            i += n;
        }
        parts.join(",")
    }

    /// Price an instruction histogram in this target's cycles.
    pub fn cycles(&self, ctr: &Counter) -> u64 {
        ctr.cycles(&self.cycle_model)
    }

    /// Wall-clock seconds of `device_cycles` at this target's clock.
    pub fn seconds(&self, device_cycles: u64) -> f64 {
        device_cycles as f64 / self.clock_hz as f64
    }

    /// Price an instruction histogram in joules on this target: dynamic
    /// per-instruction energy plus static power over the execution time.
    pub fn joules(&self, ctr: &Counter) -> f64 {
        self.energy_model
            .joules(ctr, &self.cycle_model, self.clock_hz)
    }
}

impl Default for Target {
    fn default() -> Self {
        Target::stm32f746()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::InstrClass;

    fn conv_like_counter() -> Counter {
        // A histogram shaped like real SLBC conv work: multiplies +
        // long-multiply carriers + packing bit-ops + row loads.
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 4000);
        c.charge(InstrClass::Bit, 2500);
        c.charge(InstrClass::Simd, 1200);
        c.charge(InstrClass::MulLong, 900);
        c.charge(InstrClass::Load, 1500);
        c.charge(InstrClass::Store, 300);
        c.charge(InstrClass::Sat, 200);
        c
    }

    #[test]
    fn registry_lookup_accepts_names_and_class_aliases() {
        assert_eq!(Target::lookup("stm32f746").unwrap().class, DeviceClass::M7);
        assert_eq!(Target::lookup("m7").unwrap().name, "stm32f746");
        assert_eq!(Target::lookup("STM32F446").unwrap().class, DeviceClass::M4);
        assert_eq!(Target::lookup(" m4 ").unwrap().name, "stm32f446");
        assert!(Target::lookup("m33").is_none());
        assert_eq!(Target::resolve("m7").unwrap().name, "stm32f746");
        let msg = format!("{:#}", Target::resolve("m33").unwrap_err());
        assert!(msg.contains("m33") && msg.contains("stm32f446"), "{msg}");
    }

    #[test]
    fn registry_is_the_single_constant_source() {
        let m7 = Target::lookup("m7").unwrap();
        assert_eq!(m7.clock_hz, STM32F746_CLOCK_HZ);
        assert_eq!(m7.sram_bytes, STM32F746_SRAM_BYTES);
        assert_eq!(m7.flash_bytes, STM32F746_FLASH_BYTES);
        let m4 = Target::lookup("m4").unwrap();
        assert_eq!(m4.clock_hz, STM32F446_CLOCK_HZ);
        assert_eq!(m4.sram_bytes, STM32F446_SRAM_BYTES);
        assert_eq!(m4.flash_bytes, STM32F446_FLASH_BYTES);
        assert!(m4.sram_bytes < m7.sram_bytes);
        assert!(m4.clock_hz < m7.clock_hz);
    }

    #[test]
    fn fleet_spec_round_trips() {
        for spec in ["m7:2,m4:2", "m7", "m4:3", "m7,m4,m7"] {
            let fleet = Target::parse_fleet(spec).unwrap();
            assert_eq!(Target::fleet_spec(&fleet), spec, "spec `{spec}`");
            let again = Target::parse_fleet(&Target::fleet_spec(&fleet)).unwrap();
            assert_eq!(fleet, again);
        }
        // Full part names parse to the same fleet as the class aliases.
        assert_eq!(
            Target::parse_fleet("stm32f746:2,stm32f446:2").unwrap(),
            Target::parse_fleet("m7:2,m4:2").unwrap()
        );
        // A device that no longer matches its registry entry renders by
        // full part name, not the (now ambiguous) class shorthand. This
        // is a best-effort label: the spec grammar can only express
        // registry entries, so custom hardware does not round-trip (see
        // the fleet_spec contract).
        let mut custom = Target::stm32f746();
        custom.sram_bytes = 1024;
        assert_eq!(Target::fleet_spec(&[custom]), "stm32f746");
        // Mixed identical/custom runs do not collapse together.
        assert_eq!(
            Target::fleet_spec(&[Target::stm32f746(), custom]),
            "m7,stm32f746"
        );
    }

    #[test]
    fn fleet_clock_override_parses_renders_and_round_trips() {
        let fleet = Target::parse_fleet("m4@84mhz:2").unwrap();
        assert_eq!(fleet.len(), 2);
        for d in &fleet {
            assert_eq!(d.name, "stm32f446");
            assert_eq!(d.clock_hz, 84_000_000);
            // Everything except the clock stays the registry profile.
            assert_eq!(d.sram_bytes, STM32F446_SRAM_BYTES);
            assert_eq!(d.cycle_model, CycleModel::cortex_m4());
        }
        // The override renders back and round-trips through the spec
        // grammar, mixed freely with unmodified entries.
        for spec in ["m4@84mhz:2", "m7:2,m4@84mhz:2", "m7@108mhz,m7"] {
            let fleet = Target::parse_fleet(spec).unwrap();
            assert_eq!(Target::fleet_spec(&fleet), spec, "spec `{spec}`");
            assert_eq!(Target::parse_fleet(&Target::fleet_spec(&fleet)).unwrap(), fleet);
        }
        // Case-insensitive suffix, full part names accepted too.
        assert_eq!(
            Target::parse_fleet("stm32f746@108MHz").unwrap()[0].clock_hz,
            108_000_000
        );

        // Bad overrides name the offending token; the base-name error
        // message is untouched by the new suffix.
        for bad in ["m4@84", "m4@fastmhz:2", "m4@0mhz", "m4@:2"] {
            let msg = format!("{:#}", Target::parse_fleet(bad).unwrap_err());
            assert!(msg.contains("clock override"), "`{bad}`: {msg}");
        }
        let msg = format!("{:#}", Target::parse_fleet("m33@84mhz:2").unwrap_err());
        assert!(msg.contains("m33") && msg.contains("stm32f746"), "{msg}");
    }

    #[test]
    fn fleet_parse_errors_name_the_token_and_known_targets() {
        let err = Target::parse_fleet("m7:2,m33:1").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("m33"), "offending token missing: {msg}");
        assert!(msg.contains("stm32f746"), "known names missing: {msg}");
        assert!(msg.contains("stm32f446"), "known names missing: {msg}");

        let err = Target::parse_fleet("m7:zero").unwrap_err();
        assert!(format!("{err:#}").contains("zero"));
        assert!(Target::parse_fleet("").is_err());
        assert!(Target::parse_fleet("m7:0").is_err());
    }

    #[test]
    fn joules_monotonic_in_cycle_count_at_fixed_clock() {
        let t = Target::stm32f746();
        let base = conv_like_counter();
        let e0 = t.joules(&base);
        // Strictly more work of any class means strictly more joules.
        for class in crate::mcu::cycles::ALL_CLASSES {
            let mut more = base.clone();
            more.charge(class, 1000);
            assert!(
                t.joules(&more) > e0,
                "joules must grow with {class:?} work"
            );
        }
        // And scaling the whole histogram scales energy up.
        let mut double = base.clone();
        double.merge(&base);
        assert!(t.joules(&double) > e0);
    }

    #[test]
    fn m4_spends_fewer_joules_than_m7_on_identical_conv_work() {
        let m7 = Target::stm32f746();
        let m4 = Target::stm32f446();
        let ctr = conv_like_counter();
        assert!(
            m4.joules(&ctr) < m7.joules(&ctr),
            "m4 {} J vs m7 {} J",
            m4.joules(&ctr),
            m7.joules(&ctr)
        );
        // Per-class dominance: the M4 wins on every instruction class,
        // so the inequality holds for any histogram, not just this one.
        for class in ALL_CLASSES {
            assert!(
                m4.energy_model.instr_pj(class) < m7.energy_model.instr_pj(class),
                "{class:?}"
            );
        }
        // ... including total (dynamic + static) on a pure long-multiply
        // histogram, where the M4 pays 4 cycles per instruction.
        let mut longs = Counter::new();
        longs.charge(InstrClass::MulLong, 1_000_000);
        assert!(m4.joules(&longs) < m7.joules(&longs));
    }

    #[test]
    fn energy_static_term_scales_with_time() {
        let t = Target::stm32f746();
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 1_000_000);
        let dynamic = t.energy_model.dynamic_joules(&c);
        let total = t.joules(&c);
        let static_j = total - dynamic;
        let want = t.energy_model.static_watts() * t.seconds(t.cycles(&c));
        assert!((static_j - want).abs() < 1e-12);
        assert!(static_j > 0.0);
    }
}
