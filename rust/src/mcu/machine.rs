//! The ARMv7E-M interpreter: registers, flags, memory, cycle accounting.
//!
//! Micro-kernels (dot products, packed multiplies, requantization loops)
//! are written as [`Instr`] programs and executed bit-exactly; the per-
//! instruction cycle charges use the same [`CycleModel`] as the fast
//! counters, which is what makes the two tiers cross-checkable.

use super::counter::Counter;
use super::cycles::{CycleModel, InstrClass};
use super::isa::{Cond, Instr, Op2, Reg};
use super::memory::Memory;

/// Execution fault.
#[derive(Debug, thiserror::Error)]
pub enum Fault {
    #[error("memory fault: {0}")]
    Mem(#[from] super::memory::MemError),
    #[error("undefined label {0}")]
    UndefinedLabel(usize),
    #[error("executed {0} instructions without Halt (runaway?)")]
    Runaway(u64),
}

/// Machine state.
pub struct Machine {
    pub regs: [u32; 16],
    pub flag_n: bool,
    pub flag_z: bool,
    pub mem: Memory,
    pub counter: Counter,
    pub model: CycleModel,
    program: Vec<Instr>,
    labels: Vec<Option<usize>>,
}

impl Machine {
    /// Machine configured for a [`Target`](crate::target::Target): the
    /// target's memory map plus its cycle table.
    pub fn for_target(t: &crate::target::Target) -> Self {
        Machine::new(Memory::for_target(t), t.cycle_model)
    }

    /// Machine for the `stm32f746` registry target (M7 profile).
    pub fn stm32f746() -> Self {
        Machine::for_target(&crate::target::Target::stm32f746())
    }

    /// Machine for the `stm32f446` registry target — the slower, smaller
    /// device class of heterogeneous fleet simulations (same ISA subset;
    /// long multiplies cost more, and the part runs a slower clock with
    /// less SRAM).
    pub fn stm32f446() -> Self {
        Machine::for_target(&crate::target::Target::stm32f446())
    }

    pub fn new(mem: Memory, model: CycleModel) -> Self {
        Machine {
            regs: [0; 16],
            flag_n: false,
            flag_z: false,
            mem,
            counter: Counter::new(),
            model,
            program: Vec::new(),
            labels: Vec::new(),
        }
    }

    pub fn set(&mut self, r: Reg, v: u32) {
        self.regs[r.0 as usize] = v;
    }

    pub fn get(&self, r: Reg) -> u32 {
        self.regs[r.0 as usize]
    }

    /// Load a program, resolving labels.
    pub fn load_program(&mut self, program: Vec<Instr>) {
        let max_label = program
            .iter()
            .filter_map(|i| match i {
                Instr::Label(l) | Instr::B(_, l) => Some(*l),
                _ => None,
            })
            .max()
            .map(|m| m + 1)
            .unwrap_or(0);
        let mut labels = vec![None; max_label];
        for (pc, i) in program.iter().enumerate() {
            if let Instr::Label(l) = i {
                labels[*l] = Some(pc);
            }
        }
        self.program = program;
        self.labels = labels;
    }

    /// Total cycles charged so far.
    pub fn cycles(&self) -> u64 {
        self.counter.cycles(&self.model)
    }

    fn op2(&self, o: Op2) -> u32 {
        match o {
            Op2::Imm(v) => v,
            Op2::Reg(r) => self.get(r),
        }
    }

    fn set_nz(&mut self, v: u32) {
        self.flag_n = (v as i32) < 0;
        self.flag_z = v == 0;
    }

    /// Run until `Halt` or the step budget is exhausted.
    pub fn run(&mut self, max_steps: u64) -> Result<(), Fault> {
        let mut pc = 0usize;
        let mut steps = 0u64;
        while pc < self.program.len() {
            steps += 1;
            if steps > max_steps {
                return Err(Fault::Runaway(max_steps));
            }
            let instr = self.program[pc];
            pc += 1;
            match instr {
                Instr::Label(_) => {} // free
                Instr::Nop => self.counter.charge(InstrClass::Alu, 1),
                Instr::Halt => return Ok(()),

                Instr::Mov(rd, o) => {
                    let v = self.op2(o);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Movt(rd, hi) => {
                    let v = (self.get(rd) & 0xFFFF) | (hi << 16);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Add(rd, rn, o) => {
                    let v = self.get(rn).wrapping_add(self.op2(o));
                    self.set(rd, v);
                    self.set_nz(v);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Sub(rd, rn, o) => {
                    let v = self.get(rn).wrapping_sub(self.op2(o));
                    self.set(rd, v);
                    self.set_nz(v);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Rsb(rd, rn, o) => {
                    let v = self.op2(o).wrapping_sub(self.get(rn));
                    self.set(rd, v);
                    self.set_nz(v);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::And(rd, rn, o) => {
                    let v = self.get(rn) & self.op2(o);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Orr(rd, rn, o) => {
                    let v = self.get(rn) | self.op2(o);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Eor(rd, rn, o) => {
                    let v = self.get(rn) ^ self.op2(o);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Bic(rd, rn, o) => {
                    let v = self.get(rn) & !self.op2(o);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Lsl(rd, rn, o) => {
                    let sh = self.op2(o) & 0xFF;
                    let v = if sh >= 32 { 0 } else { self.get(rn) << sh };
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Lsr(rd, rn, o) => {
                    let sh = self.op2(o) & 0xFF;
                    let v = if sh >= 32 { 0 } else { self.get(rn) >> sh };
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Asr(rd, rn, o) => {
                    let sh = (self.op2(o) & 0xFF).min(31);
                    let v = ((self.get(rn) as i32) >> sh) as u32;
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Ubfx(rd, rn, lsb, width) => {
                    let mask = if width >= 32 {
                        u32::MAX
                    } else {
                        (1u32 << width) - 1
                    };
                    self.set(rd, (self.get(rn) >> lsb) & mask);
                    self.counter.charge(InstrClass::Bit, 1);
                }
                Instr::Ssat(rd, bits, rn) => {
                    let max = (1i32 << (bits - 1)) - 1;
                    let min = -(1i32 << (bits - 1));
                    let v = (self.get(rn) as i32).clamp(min, max);
                    self.set(rd, v as u32);
                    self.counter.charge(InstrClass::Sat, 1);
                }
                Instr::Usat(rd, bits, rn) => {
                    let max = (1i32 << bits) - 1;
                    let v = (self.get(rn) as i32).clamp(0, max);
                    self.set(rd, v as u32);
                    self.counter.charge(InstrClass::Sat, 1);
                }
                Instr::Sxtb(rd, rn) => {
                    self.set(rd, self.get(rn) as u8 as i8 as i32 as u32);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Uxtb(rd, rn) => {
                    self.set(rd, self.get(rn) & 0xFF);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Sxth(rd, rn) => {
                    self.set(rd, self.get(rn) as u16 as i16 as i32 as u32);
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::Uxth(rd, rn) => {
                    self.set(rd, self.get(rn) & 0xFFFF);
                    self.counter.charge(InstrClass::Alu, 1);
                }

                Instr::Mul(rd, rn, rm) => {
                    let v = self.get(rn).wrapping_mul(self.get(rm));
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Mul, 1);
                }
                Instr::Mla(rd, rn, rm, ra) => {
                    let v = self
                        .get(ra)
                        .wrapping_add(self.get(rn).wrapping_mul(self.get(rm)));
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Mul, 1);
                }
                Instr::Mls(rd, rn, rm, ra) => {
                    let v = self
                        .get(ra)
                        .wrapping_sub(self.get(rn).wrapping_mul(self.get(rm)));
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Mul, 1);
                }
                Instr::Umull(rdlo, rdhi, rn, rm) => {
                    let p = self.get(rn) as u64 * self.get(rm) as u64;
                    self.set(rdlo, p as u32);
                    self.set(rdhi, (p >> 32) as u32);
                    self.counter.charge(InstrClass::MulLong, 1);
                }
                Instr::Umlal(rdlo, rdhi, rn, rm) => {
                    let acc = ((self.get(rdhi) as u64) << 32) | self.get(rdlo) as u64;
                    let p = acc.wrapping_add(self.get(rn) as u64 * self.get(rm) as u64);
                    self.set(rdlo, p as u32);
                    self.set(rdhi, (p >> 32) as u32);
                    self.counter.charge(InstrClass::MulLong, 1);
                }
                Instr::Smull(rdlo, rdhi, rn, rm) => {
                    let p = (self.get(rn) as i32 as i64) * (self.get(rm) as i32 as i64);
                    self.set(rdlo, p as u32);
                    self.set(rdhi, ((p as u64) >> 32) as u32);
                    self.counter.charge(InstrClass::MulLong, 1);
                }

                Instr::Smlad(rd, rn, rm, ra) => {
                    let n = self.get(rn);
                    let m = self.get(rm);
                    let p1 = (n as u16 as i16 as i32) * (m as u16 as i16 as i32);
                    let p2 = ((n >> 16) as u16 as i16 as i32)
                        * ((m >> 16) as u16 as i16 as i32);
                    let v = (self.get(ra) as i32)
                        .wrapping_add(p1)
                        .wrapping_add(p2) as u32;
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Smuad(rd, rn, rm) => {
                    let n = self.get(rn);
                    let m = self.get(rm);
                    let p1 = (n as u16 as i16 as i32) * (m as u16 as i16 as i32);
                    let p2 = ((n >> 16) as u16 as i16 as i32)
                        * ((m >> 16) as u16 as i16 as i32);
                    self.set(rd, p1.wrapping_add(p2) as u32);
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Smlabb(rd, rn, rm, ra) => {
                    let p = (self.get(rn) as u16 as i16 as i32)
                        * (self.get(rm) as u16 as i16 as i32);
                    self.set(rd, (self.get(ra) as i32).wrapping_add(p) as u32);
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Smlatt(rd, rn, rm, ra) => {
                    let p = ((self.get(rn) >> 16) as u16 as i16 as i32)
                        * ((self.get(rm) >> 16) as u16 as i16 as i32);
                    self.set(rd, (self.get(ra) as i32).wrapping_add(p) as u32);
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Uadd8(rd, rn, rm) => {
                    let n = self.get(rn).to_le_bytes();
                    let m = self.get(rm).to_le_bytes();
                    let mut out = [0u8; 4];
                    for i in 0..4 {
                        out[i] = n[i].wrapping_add(m[i]);
                    }
                    self.set(rd, u32::from_le_bytes(out));
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Uadd16(rd, rn, rm) => {
                    let n = self.get(rn);
                    let m = self.get(rm);
                    let lo = (n as u16).wrapping_add(m as u16) as u32;
                    let hi = ((n >> 16) as u16).wrapping_add((m >> 16) as u16) as u32;
                    self.set(rd, (hi << 16) | lo);
                    self.counter.charge(InstrClass::Simd, 1);
                }
                Instr::Pkhbt(rd, rn, rm) => {
                    let v = (self.get(rn) & 0xFFFF) | (self.get(rm) << 16);
                    self.set(rd, v);
                    self.counter.charge(InstrClass::Bit, 1);
                }

                Instr::Ldr(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    let v = self.mem.read_u32(addr)?;
                    self.set(rt, v);
                    self.counter.charge(InstrClass::Load, 1);
                }
                Instr::Ldrb(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    let v = self.mem.read_u8(addr)? as u32;
                    self.set(rt, v);
                    self.counter.charge(InstrClass::Load, 1);
                }
                Instr::Ldrh(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    let v = self.mem.read_u16(addr)? as u32;
                    self.set(rt, v);
                    self.counter.charge(InstrClass::Load, 1);
                }
                Instr::Ldrsb(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    let v = self.mem.read_u8(addr)? as i8 as i32 as u32;
                    self.set(rt, v);
                    self.counter.charge(InstrClass::Load, 1);
                }
                Instr::Ldrsh(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    let v = self.mem.read_u16(addr)? as i16 as i32 as u32;
                    self.set(rt, v);
                    self.counter.charge(InstrClass::Load, 1);
                }
                Instr::Str(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    self.mem.write_u32(addr, self.get(rt))?;
                    self.counter.charge(InstrClass::Store, 1);
                }
                Instr::Strb(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    self.mem.write_u8(addr, self.get(rt) as u8)?;
                    self.counter.charge(InstrClass::Store, 1);
                }
                Instr::Strh(rt, rn, off) => {
                    let addr = self.get(rn).wrapping_add(off as u32);
                    self.mem.write_u16(addr, self.get(rt) as u16)?;
                    self.counter.charge(InstrClass::Store, 1);
                }

                Instr::Cmp(rn, o) => {
                    let v = self.get(rn).wrapping_sub(self.op2(o));
                    // Signed comparison flags via subtraction result.
                    let a = self.get(rn) as i64;
                    let b = self.op2(o) as i64;
                    self.flag_n = (a as i32 as i64) < (b as i32 as i64);
                    self.flag_z = v == 0;
                    self.counter.charge(InstrClass::Alu, 1);
                }
                Instr::B(cond, label) => {
                    let taken = match cond {
                        Cond::Al => true,
                        Cond::Eq => self.flag_z,
                        Cond::Ne => !self.flag_z,
                        Cond::Lt => self.flag_n,
                        Cond::Le => self.flag_n || self.flag_z,
                        Cond::Gt => !self.flag_n && !self.flag_z,
                        Cond::Ge => !self.flag_n || self.flag_z,
                    };
                    if taken {
                        pc = self
                            .labels
                            .get(label)
                            .copied()
                            .flatten()
                            .ok_or(Fault::UndefinedLabel(label))?;
                        self.counter.charge(InstrClass::BranchTaken, 1);
                    } else {
                        self.counter.charge(InstrClass::BranchNotTaken, 1);
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::isa::*;
    use crate::mcu::memory::SRAM_BASE;

    fn machine() -> Machine {
        Machine::new(Memory::with_sizes(4096, 4096), CycleModel::cortex_m7())
    }

    #[test]
    fn mov_add_loop() {
        // Sum 1..=10 with a countdown loop.
        let mut m = machine();
        m.load_program(vec![
            Instr::Mov(R0, Op2::Imm(0)),  // acc
            Instr::Mov(R1, Op2::Imm(10)), // i
            Instr::Label(0),
            Instr::Add(R0, R0, Op2::Reg(R1)),
            Instr::Sub(R1, R1, Op2::Imm(1)),
            Instr::Cmp(R1, Op2::Imm(0)),
            Instr::B(Cond::Gt, 0),
            Instr::Halt,
        ]);
        m.run(10_000).unwrap();
        assert_eq!(m.get(R0), 55);
        assert!(m.cycles() > 0);
    }

    #[test]
    fn smlad_dual_mac() {
        let mut m = machine();
        // rn = (3, -2) halfwords, rm = (5, 7): 3*5 + (-2)*7 = 1.
        let rn = ((-2i16 as u16 as u32) << 16) | 3;
        let rm = (7u32 << 16) | 5;
        m.set(R1, rn);
        m.set(R2, rm);
        m.set(R3, 100);
        m.load_program(vec![Instr::Smlad(R0, R1, R2, R3), Instr::Halt]);
        m.run(10).unwrap();
        assert_eq!(m.get(R0), 101);
        assert_eq!(m.counter.simd, 1);
    }

    #[test]
    fn umull_umlal_64bit() {
        let mut m = machine();
        m.set(R1, 0xFFFF_FFFF);
        m.set(R2, 2);
        m.load_program(vec![
            Instr::Umull(R0, R3, R1, R2), // 0x1_FFFF_FFFE
            Instr::Umlal(R0, R3, R1, R2), // doubled
            Instr::Halt,
        ]);
        m.run(10).unwrap();
        let v = ((m.get(R3) as u64) << 32) | m.get(R0) as u64;
        assert_eq!(v, 0xFFFF_FFFFu64 * 2 * 2);
    }

    #[test]
    fn m4_machine_is_bit_exact_but_slower_on_long_multiplies() {
        let prog = vec![
            Instr::Mov(R1, Op2::Imm(7)),
            Instr::Mov(R2, Op2::Imm(9)),
            Instr::Umull(R0, R3, R1, R2),
            Instr::Halt,
        ];
        let mut m7 = Machine::stm32f746();
        m7.load_program(prog.clone());
        m7.run(10).unwrap();
        let mut m4 = Machine::stm32f446();
        m4.load_program(prog);
        m4.run(10).unwrap();
        assert_eq!(m7.get(R0), 63);
        assert_eq!(m4.get(R0), 63, "device classes stay bit-exact");
        assert!(m4.cycles() > m7.cycles(), "M4 long multiplies cost more");
        assert!(m4.mem.sram_len() < m7.mem.sram_len(), "M4 part has less SRAM");
    }

    #[test]
    fn memory_load_store() {
        let mut m = machine();
        m.set(R1, SRAM_BASE);
        m.set(R2, 0x1234_5678);
        m.load_program(vec![
            Instr::Str(R2, R1, 8),
            Instr::Ldr(R0, R1, 8),
            Instr::Ldrb(R3, R1, 8),
            Instr::Halt,
        ]);
        m.run(10).unwrap();
        assert_eq!(m.get(R0), 0x1234_5678);
        assert_eq!(m.get(R3), 0x78);
    }

    #[test]
    fn ubfx_extracts_field() {
        let mut m = machine();
        m.set(R1, 0b1101_0110_0000);
        m.load_program(vec![Instr::Ubfx(R0, R1, 5, 4), Instr::Halt]);
        m.run(10).unwrap();
        assert_eq!(m.get(R0), 0b1011);
    }

    #[test]
    fn usat_clamps() {
        let mut m = machine();
        m.set(R1, 300);
        m.set(R2, (-5i32) as u32);
        m.load_program(vec![
            Instr::Usat(R0, 8, R1),
            Instr::Usat(R3, 8, R2),
            Instr::Halt,
        ]);
        m.run(10).unwrap();
        assert_eq!(m.get(R0), 255);
        assert_eq!(m.get(R3), 0);
    }

    #[test]
    fn ssat_signed_clamp() {
        let mut m = machine();
        m.set(R1, 300);
        m.set(R2, (-300i32) as u32);
        m.load_program(vec![
            Instr::Ssat(R0, 8, R1),
            Instr::Ssat(R3, 8, R2),
            Instr::Halt,
        ]);
        m.run(10).unwrap();
        assert_eq!(m.get(R0) as i32, 127);
        assert_eq!(m.get(R3) as i32, -128);
    }

    #[test]
    fn runaway_detection() {
        let mut m = machine();
        m.load_program(vec![Instr::Label(0), Instr::B(Cond::Al, 0)]);
        assert!(matches!(m.run(100), Err(Fault::Runaway(_))));
    }

    #[test]
    fn signed_compare_branches() {
        let mut m = machine();
        m.set(R1, (-3i32) as u32);
        m.load_program(vec![
            Instr::Cmp(R1, Op2::Imm(2)),
            Instr::B(Cond::Lt, 1),
            Instr::Mov(R0, Op2::Imm(111)), // skipped
            Instr::Label(1),
            Instr::Mov(R2, Op2::Imm(7)),
            Instr::Halt,
        ]);
        m.run(100).unwrap();
        assert_eq!(m.get(R0), 0);
        assert_eq!(m.get(R2), 7);
    }

    #[test]
    fn flash_write_faults() {
        let mut m = machine();
        m.set(R1, crate::mcu::memory::FLASH_BASE);
        m.load_program(vec![Instr::Str(R1, R1, 0), Instr::Halt]);
        assert!(m.run(10).is_err());
    }
}
