//! STM32F746-like memory map: 1 MB flash at `0x0800_0000` (read-only at
//! run time — weights and constants) and 320 KB SRAM at `0x2000_0000`
//! (activations, im2col buffers, stack).

/// Base address of flash.
pub const FLASH_BASE: u32 = 0x0800_0000;
/// Base address of SRAM.
pub const SRAM_BASE: u32 = 0x2000_0000;

/// Byte-addressable memory with the two STM32F746 regions.
#[derive(Debug, Clone)]
pub struct Memory {
    flash: Vec<u8>,
    sram: Vec<u8>,
}

/// Errors surfaced by the memory system (turned into panics by the
/// machine — an MCU would hard-fault).
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum MemError {
    #[error("address {0:#010x} is outside flash and SRAM")]
    Unmapped(u32),
    #[error("write to read-only flash at {0:#010x}")]
    FlashWrite(u32),
}

impl Memory {
    /// Memory sized for a [`Target`](crate::target::Target)'s flash and
    /// SRAM capacities.
    pub fn for_target(t: &crate::target::Target) -> Self {
        Memory::with_sizes(t.flash_bytes, t.sram_bytes)
    }

    /// Memory with the `stm32f746` registry target's sizes (the paper
    /// platform: 1 MB flash, 320 KB SRAM).
    pub fn stm32f746() -> Self {
        Memory::for_target(&crate::target::Target::stm32f746())
    }

    /// Memory with the `stm32f446` registry target's sizes (the M4-class
    /// companion part) used by heterogeneous-fleet simulation.
    pub fn stm32f446() -> Self {
        Memory::for_target(&crate::target::Target::stm32f446())
    }

    pub fn with_sizes(flash_bytes: usize, sram_bytes: usize) -> Self {
        Memory {
            flash: vec![0; flash_bytes],
            sram: vec![0; sram_bytes],
        }
    }

    pub fn flash_len(&self) -> usize {
        self.flash.len()
    }

    pub fn sram_len(&self) -> usize {
        self.sram.len()
    }

    fn resolve(&self, addr: u32) -> Result<(bool, usize), MemError> {
        if addr >= FLASH_BASE && (addr - FLASH_BASE) < self.flash.len() as u32 {
            Ok((true, (addr - FLASH_BASE) as usize))
        } else if addr >= SRAM_BASE && (addr - SRAM_BASE) < self.sram.len() as u32 {
            Ok((false, (addr - SRAM_BASE) as usize))
        } else {
            Err(MemError::Unmapped(addr))
        }
    }

    pub fn read_u8(&self, addr: u32) -> Result<u8, MemError> {
        let (is_flash, off) = self.resolve(addr)?;
        Ok(if is_flash {
            self.flash[off]
        } else {
            self.sram[off]
        })
    }

    pub fn read_u16(&self, addr: u32) -> Result<u16, MemError> {
        Ok(u16::from_le_bytes([
            self.read_u8(addr)?,
            self.read_u8(addr.wrapping_add(1))?,
        ]))
    }

    pub fn read_u32(&self, addr: u32) -> Result<u32, MemError> {
        Ok(u32::from_le_bytes([
            self.read_u8(addr)?,
            self.read_u8(addr.wrapping_add(1))?,
            self.read_u8(addr.wrapping_add(2))?,
            self.read_u8(addr.wrapping_add(3))?,
        ]))
    }

    pub fn write_u8(&mut self, addr: u32, v: u8) -> Result<(), MemError> {
        let (is_flash, off) = self.resolve(addr)?;
        if is_flash {
            return Err(MemError::FlashWrite(addr));
        }
        self.sram[off] = v;
        Ok(())
    }

    pub fn write_u16(&mut self, addr: u32, v: u16) -> Result<(), MemError> {
        let b = v.to_le_bytes();
        self.write_u8(addr, b[0])?;
        self.write_u8(addr.wrapping_add(1), b[1])
    }

    pub fn write_u32(&mut self, addr: u32, v: u32) -> Result<(), MemError> {
        let b = v.to_le_bytes();
        for (i, &byte) in b.iter().enumerate() {
            self.write_u8(addr.wrapping_add(i as u32), byte)?;
        }
        Ok(())
    }

    /// Program flash contents at build/load time (e.g. weights) — this is
    /// the flashing tool's path, not a run-time store.
    pub fn program_flash(&mut self, offset: usize, bytes: &[u8]) {
        self.flash[offset..offset + bytes.len()].copy_from_slice(bytes);
    }

    /// Bulk-load SRAM (e.g. the input image before inference).
    pub fn load_sram(&mut self, offset: usize, bytes: &[u8]) {
        self.sram[offset..offset + bytes.len()].copy_from_slice(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sram_roundtrip() {
        let mut m = Memory::with_sizes(1024, 1024);
        m.write_u32(SRAM_BASE + 16, 0xDEAD_BEEF).unwrap();
        assert_eq!(m.read_u32(SRAM_BASE + 16).unwrap(), 0xDEAD_BEEF);
        assert_eq!(m.read_u8(SRAM_BASE + 16).unwrap(), 0xEF); // little-endian
    }

    #[test]
    fn flash_is_read_only() {
        let mut m = Memory::with_sizes(1024, 1024);
        assert_eq!(
            m.write_u8(FLASH_BASE, 1),
            Err(MemError::FlashWrite(FLASH_BASE))
        );
        m.program_flash(0, &[7, 8]);
        assert_eq!(m.read_u8(FLASH_BASE).unwrap(), 7);
        assert_eq!(m.read_u8(FLASH_BASE + 1).unwrap(), 8);
    }

    #[test]
    fn unmapped_faults() {
        let m = Memory::with_sizes(16, 16);
        assert!(m.read_u8(0).is_err());
        assert!(m.read_u8(SRAM_BASE + 16).is_err());
    }

    #[test]
    fn stm32f746_sizes() {
        let m = Memory::stm32f746();
        assert_eq!(m.flash_len(), 1024 * 1024);
        assert_eq!(m.sram_len(), 320 * 1024);
    }
}
