//! Instruction-class cycle model for the Cortex-M7.
//!
//! The M7 is a dual-issue in-order core; exact timing depends on pairing,
//! but per-class base costs from the TRM (and ST's AN4667) are accurate
//! enough for the paper's comparisons, which hinge on instruction *mix*.
//! The same table prices both the interpreter and the fast counters, so
//! every operator comparison is internally consistent.

/// Coarse instruction classes, the granularity at which the paper's Eq. 12
/// performance model reasons (`C = C_SISD + α·C_SIMD + β·C_bit`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InstrClass {
    /// Single-cycle ALU: ADD/SUB/MOV/CMP and friends.
    Alu,
    /// Bit manipulation: shifts, AND/ORR/EOR/BIC, bit-field extract.
    Bit,
    /// 32×32→32 multiply / multiply-accumulate (MUL/MLA).
    Mul,
    /// DSP/SIMD: SMLAD/SMUAD/SSUB8/SEL..., the "SIMD" class of Eq. 12.
    Simd,
    /// Long multiplies: UMULL/UMLAL/SMULL/SMLAL (the 64-bit carrier path).
    MulLong,
    /// Memory load (word/half/byte).
    Load,
    /// Memory store.
    Store,
    /// Taken branch (includes pipeline refill).
    BranchTaken,
    /// Not-taken branch / fall-through compare-branch.
    BranchNotTaken,
    /// Saturation ops (SSAT/USAT) used by requantization.
    Sat,
}

/// All classes, for iteration/reporting.
pub const ALL_CLASSES: [InstrClass; 10] = [
    InstrClass::Alu,
    InstrClass::Bit,
    InstrClass::Mul,
    InstrClass::Simd,
    InstrClass::MulLong,
    InstrClass::Load,
    InstrClass::Store,
    InstrClass::BranchTaken,
    InstrClass::BranchNotTaken,
    InstrClass::Sat,
];

/// A per-class cycle table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CycleModel {
    pub alu: u64,
    pub bit: u64,
    pub mul: u64,
    pub simd: u64,
    pub mul_long: u64,
    pub load: u64,
    pub store: u64,
    pub branch_taken: u64,
    pub branch_not_taken: u64,
    pub sat: u64,
}

impl CycleModel {
    /// Cortex-M7 @ STM32F746: single-cycle ALU/MUL/DSP, 1-cycle long
    /// multiply, ~2-cycle loads from DTCM/SRAM (no cache miss modelling —
    /// the evaluation working sets fit SRAM), 1-cycle stores (write
    /// buffer), taken branches cost the ~2-cycle refill on top.
    pub const fn cortex_m7() -> Self {
        CycleModel {
            alu: 1,
            bit: 1,
            mul: 1,
            simd: 1,
            mul_long: 1,
            load: 2,
            store: 1,
            branch_taken: 3,
            branch_not_taken: 1,
            sat: 1,
        }
    }

    /// Cortex-M4 (for sensitivity studies): 1-cycle ALU, 1-cycle DSP,
    /// 3–5 cycle long multiplies, 2-cycle loads.
    pub const fn cortex_m4() -> Self {
        CycleModel {
            alu: 1,
            bit: 1,
            mul: 1,
            simd: 1,
            mul_long: 4,
            load: 2,
            store: 1,
            branch_taken: 3,
            branch_not_taken: 1,
            sat: 1,
        }
    }

    /// Cost of one instruction of a class.
    pub fn cost(&self, class: InstrClass) -> u64 {
        match class {
            InstrClass::Alu => self.alu,
            InstrClass::Bit => self.bit,
            InstrClass::Mul => self.mul,
            InstrClass::Simd => self.simd,
            InstrClass::MulLong => self.mul_long,
            InstrClass::Load => self.load,
            InstrClass::Store => self.store,
            InstrClass::BranchTaken => self.branch_taken,
            InstrClass::BranchNotTaken => self.branch_not_taken,
            InstrClass::Sat => self.sat,
        }
    }

    /// Eq. 12 proportionality coefficients derived from the table:
    /// α = cost(SIMD)/cost(ALU), β = cost(Bit)/cost(ALU). On the M7 both
    /// are 1 in the base table; calibration against the interpreter
    /// (which sees loads, branches and loop overhead) yields the effective
    /// values the NAS cost model uses.
    pub fn alpha_beta(&self) -> (f64, f64) {
        (
            self.simd as f64 / self.alu as f64,
            self.bit as f64 / self.alu as f64,
        )
    }
}

impl Default for CycleModel {
    fn default() -> Self {
        CycleModel::cortex_m7()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m7_single_cycle_mac() {
        let m = CycleModel::cortex_m7();
        assert_eq!(m.cost(InstrClass::Mul), 1);
        assert_eq!(m.cost(InstrClass::Simd), 1);
    }

    #[test]
    fn m4_long_multiply_slower() {
        assert!(CycleModel::cortex_m4().mul_long > CycleModel::cortex_m7().mul_long);
    }

    #[test]
    fn all_classes_priced() {
        let m = CycleModel::default();
        for c in ALL_CLASSES {
            assert!(m.cost(c) >= 1);
        }
    }
}
