//! Fast instruction-class accounting for whole-network simulation.
//!
//! The bit-exact operator implementations in [`crate::ops`] compute with
//! native Rust arithmetic but *charge* every MCU instruction they would
//! execute to a [`Counter`]. Folding the histogram through the shared
//! [`CycleModel`](super::cycles::CycleModel) yields the same cycle totals
//! the interpreter would produce for the equivalent program (validated by
//! the cross-check tests in `rust/tests/`), at orders of magnitude higher
//! simulation speed.

use super::cycles::{CycleModel, InstrClass, ALL_CLASSES};

/// Instruction-class histogram.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Counter {
    pub alu: u64,
    pub bit: u64,
    pub mul: u64,
    pub simd: u64,
    pub mul_long: u64,
    pub load: u64,
    pub store: u64,
    pub branch_taken: u64,
    pub branch_not_taken: u64,
    pub sat: u64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge `n` instructions of `class`.
    #[inline]
    pub fn charge(&mut self, class: InstrClass, n: u64) {
        match class {
            InstrClass::Alu => self.alu += n,
            InstrClass::Bit => self.bit += n,
            InstrClass::Mul => self.mul += n,
            InstrClass::Simd => self.simd += n,
            InstrClass::MulLong => self.mul_long += n,
            InstrClass::Load => self.load += n,
            InstrClass::Store => self.store += n,
            InstrClass::BranchTaken => self.branch_taken += n,
            InstrClass::BranchNotTaken => self.branch_not_taken += n,
            InstrClass::Sat => self.sat += n,
        }
    }

    pub fn get(&self, class: InstrClass) -> u64 {
        match class {
            InstrClass::Alu => self.alu,
            InstrClass::Bit => self.bit,
            InstrClass::Mul => self.mul,
            InstrClass::Simd => self.simd,
            InstrClass::MulLong => self.mul_long,
            InstrClass::Load => self.load,
            InstrClass::Store => self.store,
            InstrClass::BranchTaken => self.branch_taken,
            InstrClass::BranchNotTaken => self.branch_not_taken,
            InstrClass::Sat => self.sat,
        }
    }

    /// Total instruction count.
    pub fn instructions(&self) -> u64 {
        ALL_CLASSES.iter().map(|&c| self.get(c)).sum()
    }

    /// Total cycles under a cycle model.
    pub fn cycles(&self, model: &CycleModel) -> u64 {
        ALL_CLASSES
            .iter()
            .map(|&c| self.get(c) * model.cost(c))
            .sum()
    }

    /// The Eq. 12 decomposition: (C_SISD, C_SIMD, C_bit) — SISD covers
    /// ALU/MUL/load/store/branch scalar work, SIMD covers the DSP and
    /// long-multiply classes, bit covers shifts/masks.
    pub fn eq12_components(&self) -> (u64, u64, u64) {
        let sisd = self.alu
            + self.mul
            + self.load
            + self.store
            + self.branch_taken
            + self.branch_not_taken
            + self.sat;
        let simd = self.simd + self.mul_long;
        let bit = self.bit;
        (sisd, simd, bit)
    }

    /// Merge another counter into this one.
    pub fn merge(&mut self, other: &Counter) {
        for c in ALL_CLASSES {
            self.charge(c, other.get(c));
        }
    }

    /// Class-wise difference against an earlier snapshot of the same
    /// monotonically-growing counter. Panics in debug builds if
    /// `earlier` is not a prefix (some class would go negative).
    pub fn diff(&self, earlier: &Counter) -> Counter {
        let mut out = Counter::new();
        for c in ALL_CLASSES {
            debug_assert!(
                self.get(c) >= earlier.get(c),
                "diff against a non-prefix counter ({c:?})"
            );
            out.charge(c, self.get(c) - earlier.get(c));
        }
        out
    }
}

impl std::ops::AddAssign<&Counter> for Counter {
    fn add_assign(&mut self, rhs: &Counter) {
        self.merge(rhs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_cycles() {
        let mut c = Counter::new();
        c.charge(InstrClass::Mul, 10);
        c.charge(InstrClass::Load, 5);
        let m = CycleModel::cortex_m7();
        assert_eq!(c.cycles(&m), 10 * m.mul + 5 * m.load);
        assert_eq!(c.instructions(), 15);
    }

    #[test]
    fn eq12_split() {
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 3);
        c.charge(InstrClass::Simd, 7);
        c.charge(InstrClass::Bit, 11);
        c.charge(InstrClass::MulLong, 2);
        let (sisd, simd, bit) = c.eq12_components();
        assert_eq!((sisd, simd, bit), (3, 9, 11));
    }

    #[test]
    fn merge_sums_classwise() {
        let mut a = Counter::new();
        a.charge(InstrClass::Store, 4);
        let mut b = Counter::new();
        b.charge(InstrClass::Store, 6);
        b.charge(InstrClass::Sat, 1);
        a += &b;
        assert_eq!(a.store, 10);
        assert_eq!(a.sat, 1);
    }
}
