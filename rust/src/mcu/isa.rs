//! ARMv7E-M (Thumb-2 + DSP extension) instruction subset.
//!
//! Enough of the ISA to express the neural-network micro-kernels the paper
//! relies on: scalar ALU/MAC, the DSP dual-MAC family (`SMLAD`/`SMUAD`),
//! long multiplies (the 64-bit packing carrier), bit-field manipulation
//! (packing/segmentation), and load/store/branch for loop structure.
//! Programs are assembled from `Vec<Instr>` with symbolic labels.

/// A core register (r0–r12, sp=13, lr=14, pc=15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Reg(pub u8);

pub const R0: Reg = Reg(0);
pub const R1: Reg = Reg(1);
pub const R2: Reg = Reg(2);
pub const R3: Reg = Reg(3);
pub const R4: Reg = Reg(4);
pub const R5: Reg = Reg(5);
pub const R6: Reg = Reg(6);
pub const R7: Reg = Reg(7);
pub const R8: Reg = Reg(8);
pub const R9: Reg = Reg(9);
pub const R10: Reg = Reg(10);
pub const R11: Reg = Reg(11);
pub const R12: Reg = Reg(12);

/// Flexible second operand: immediate or register.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op2 {
    Imm(u32),
    Reg(Reg),
}

impl From<u32> for Op2 {
    fn from(v: u32) -> Self {
        Op2::Imm(v)
    }
}

impl From<Reg> for Op2 {
    fn from(r: Reg) -> Self {
        Op2::Reg(r)
    }
}

/// Branch conditions (subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Al,
}

/// The instruction subset. Semantics follow the ARMv7-M ARM; all
/// arithmetic is 32-bit two's complement unless noted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    // --- data processing -------------------------------------------------
    Mov(Reg, Op2),
    /// MOVT-style: set high 16 bits, keep low.
    Movt(Reg, u32),
    Add(Reg, Reg, Op2),
    Sub(Reg, Reg, Op2),
    Rsb(Reg, Reg, Op2),
    And(Reg, Reg, Op2),
    Orr(Reg, Reg, Op2),
    Eor(Reg, Reg, Op2),
    Bic(Reg, Reg, Op2),
    Lsl(Reg, Reg, Op2),
    Lsr(Reg, Reg, Op2),
    Asr(Reg, Reg, Op2),
    /// Unsigned bit-field extract: rd = (rn >> lsb) & ((1<<width)-1).
    Ubfx(Reg, Reg, u32, u32),
    /// Signed saturate to `bits`.
    Ssat(Reg, u32, Reg),
    /// Unsigned saturate to `bits`.
    Usat(Reg, u32, Reg),
    Sxtb(Reg, Reg),
    Uxtb(Reg, Reg),
    Sxth(Reg, Reg),
    Uxth(Reg, Reg),

    // --- multiply family --------------------------------------------------
    /// rd = rn * rm (low 32 bits).
    Mul(Reg, Reg, Reg),
    /// rd = ra + rn * rm.
    Mla(Reg, Reg, Reg, Reg),
    /// rd = ra - rn * rm.
    Mls(Reg, Reg, Reg, Reg),
    /// (rdhi:rdlo) = rn * rm (unsigned 64).
    Umull(Reg, Reg, Reg, Reg),
    /// (rdhi:rdlo) += rn * rm (unsigned 64).
    Umlal(Reg, Reg, Reg, Reg),
    /// (rdhi:rdlo) = rn * rm (signed 64).
    Smull(Reg, Reg, Reg, Reg),

    // --- DSP / SIMD extension ----------------------------------------------
    /// rd = ra + rn[15:0]*rm[15:0] + rn[31:16]*rm[31:16] (dual 16×16 MAC).
    Smlad(Reg, Reg, Reg, Reg),
    /// rd = rn[15:0]*rm[15:0] + rn[31:16]*rm[31:16].
    Smuad(Reg, Reg, Reg),
    /// rd = ra + rn[15:0]*rm[15:0].
    Smlabb(Reg, Reg, Reg, Reg),
    /// rd = ra + rn[31:16]*rm[31:16].
    Smlatt(Reg, Reg, Reg, Reg),
    /// Per-byte unsigned add (no carry across lanes).
    Uadd8(Reg, Reg, Reg),
    /// Per-halfword unsigned add.
    Uadd16(Reg, Reg, Reg),
    /// Pack halfwords: rd = (rm[15:0] << 16) | rn[15:0].
    Pkhbt(Reg, Reg, Reg),

    // --- memory -----------------------------------------------------------
    /// rt = mem32[rn + off].
    Ldr(Reg, Reg, i32),
    Ldrb(Reg, Reg, i32),
    Ldrh(Reg, Reg, i32),
    Ldrsb(Reg, Reg, i32),
    Ldrsh(Reg, Reg, i32),
    Str(Reg, Reg, i32),
    Strb(Reg, Reg, i32),
    Strh(Reg, Reg, i32),

    // --- control ----------------------------------------------------------
    Cmp(Reg, Op2),
    /// Conditional branch to a label id.
    B(Cond, usize),
    /// Pseudo-instruction: label definition (free).
    Label(usize),
    Nop,
    /// Stop execution.
    Halt,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op2_conversions() {
        assert_eq!(Op2::from(5u32), Op2::Imm(5));
        assert_eq!(Op2::from(R3), Op2::Reg(R3));
    }

    #[test]
    fn reg_constants() {
        assert_eq!(R0, Reg(0));
        assert_eq!(R12, Reg(12));
    }
}
