//! Cortex-M7 (ARMv7E-M + DSP extension) substrate simulator.
//!
//! The paper evaluates on an STM32F746 (Cortex-M7, 320 KB SRAM, 1 MB flash,
//! 216 MHz). That hardware is not available here, so this module builds the
//! closest synthetic equivalent (DESIGN.md §3): a register-level executor
//! for a realistic ARMv7E-M instruction subset with a per-class cycle model
//! taken from the Cortex-M7 TRM, plus an SRAM/flash memory map.
//!
//! Two usage tiers:
//!
//! * [`machine::Machine`] — an actual interpreter: micro-kernels are written
//!   as instruction programs and executed bit-exactly with cycle
//!   accounting. Used to validate the cost tables and for the calibration
//!   of Eq. 12's α/β coefficients ([`crate::perf::calibrate`]).
//! * [`counter::Counter`] — an instruction-class histogram the full
//!   convolution operators charge while computing bit-exactly in Rust.
//!   `cycles()` folds the histogram through the same cycle model, which
//!   keeps whole-network simulation fast (≥10⁸ simulated MACs/s) while
//!   staying consistent with the interpreter (cross-checked in tests).

pub mod counter;
pub mod kernels;
pub mod cycles;
pub mod isa;
pub mod machine;
pub mod memory;

pub use counter::Counter;
pub use cycles::{CycleModel, InstrClass};
pub use isa::{Cond, Instr, Reg};
pub use machine::Machine;
pub use memory::Memory;
