//! Hand-written ISA-level micro-kernels, executed on the [`Machine`]
//! interpreter.
//!
//! These are the ground-truth tier of the two-level simulation strategy
//! (DESIGN.md §3): the fast operators in [`crate::ops`] *charge* abstract
//! instruction counts, while the kernels here actually *execute* the same
//! inner loops instruction by instruction — scalar int8 MACs, SMLAD
//! dual-MACs, and the SLBC packed multiply with UBFX segmentation — so
//! the counter-based accounting can be cross-validated against an
//! interpreted run (`instruction mix × cycle table` must agree).
//!
//! Memory layout convention: operands are preloaded into SRAM
//! (`SRAM_BASE`), results are read back from registers/SRAM after `Halt`.

use super::isa::{Cond, Instr, Op2};
use super::isa::{R0, R1, R2, R3, R4, R5, R6, R7, R8};
use super::machine::{Fault, Machine};
use super::memory::SRAM_BASE;

/// Emitted program plus the I/O contract of a micro-kernel.
pub struct MicroKernel {
    pub program: Vec<Instr>,
    pub name: &'static str,
}

/// Scalar int8 dot product (the `Naive` method's inner loop):
///
/// * in: `r1` = &a (i8), `r2` = &b (i8), `r3` = n
/// * out: `r0` = Σ a[i]·b[i]
pub fn dot_i8() -> MicroKernel {
    MicroKernel {
        name: "dot_i8",
        program: vec![
            Instr::Mov(R0, Op2::Imm(0)),
            Instr::Label(0),
            Instr::Cmp(R3, Op2::Imm(0)),
            Instr::B(Cond::Le, 1),
            Instr::Ldrsb(R4, R1, 0),
            Instr::Ldrsb(R5, R2, 0),
            Instr::Mla(R0, R4, R5, R0),
            Instr::Add(R1, R1, Op2::Imm(1)),
            Instr::Add(R2, R2, Op2::Imm(1)),
            Instr::Sub(R3, R3, Op2::Imm(1)),
            Instr::B(Cond::Al, 0),
            Instr::Label(1),
            Instr::Halt,
        ],
    }
}

/// SMLAD dual-MAC dot product (the CMSIS-NN/`Simd` inner loop): operands
/// pre-expanded to i16 pairs.
///
/// * in: `r1` = &a (i16), `r2` = &b (i16), `r3` = n/2 (pair count)
/// * out: `r0` = Σ a[i]·b[i]
pub fn dot_smlad() -> MicroKernel {
    MicroKernel {
        name: "dot_smlad",
        program: vec![
            Instr::Mov(R0, Op2::Imm(0)),
            Instr::Label(0),
            Instr::Cmp(R3, Op2::Imm(0)),
            Instr::B(Cond::Le, 1),
            Instr::Ldr(R4, R1, 0), // two i16 lanes per word
            Instr::Ldr(R5, R2, 0),
            Instr::Smlad(R0, R4, R5, R0),
            Instr::Add(R1, R1, Op2::Imm(4)),
            Instr::Add(R2, R2, Op2::Imm(4)),
            Instr::Sub(R3, R3, Op2::Imm(1)),
            Instr::B(Cond::Al, 0),
            Instr::Label(1),
            Instr::Halt,
        ],
    }
}

/// The SLBC packed multiply core (Eq. 3–7 at ISA level), one group:
/// packs `g` unsigned sub-byte values against packed kernel taps already
/// living in a register, using one UMULL and UBFX segmentation.
///
/// * in: `r1` = &x (u8, `g` values), `r2` = packed kernel (u32),
///   `r3` = g, `r6` = field stride S (compile-time constant too)
/// * out: SRAM at `r8`: the `g + k_taps - 1` extracted convolution fields
///   (u16 each)
///
/// The packing loop builds `R4 = Σ x[i] << (i·S)` (LSL+ORR — exactly the
/// "elements packing" of Alg. 1), then `UMULL R0:R5 = R4 × R2`, then a
/// UBFX loop slides a 64-bit window extracting one `S`-bit field per
/// step (the shift+mask sequence SLBC charges as 2 bit-ops per field).
pub fn slbc_packed_group(s_bits: u32, out_fields: u32) -> MicroKernel {
    let mut p = vec![
        // ---- packing: R4 = Σ x[i] << (i*S) ----
        Instr::Mov(R4, Op2::Imm(0)),
        Instr::Mov(R5, Op2::Imm(0)), // running shift
        Instr::Mov(R7, Op2::Reg(R3)),
        Instr::Label(0),
        Instr::Cmp(R7, Op2::Imm(0)),
        Instr::B(Cond::Le, 1),
        Instr::Ldrb(R0, R1, 0),
        Instr::Lsl(R0, R0, Op2::Reg(R5)),
        Instr::Orr(R4, R4, Op2::Reg(R0)),
        Instr::Add(R5, R5, Op2::Reg(R6)),
        Instr::Add(R1, R1, Op2::Imm(1)),
        Instr::Sub(R7, R7, Op2::Imm(1)),
        Instr::B(Cond::Al, 0),
        Instr::Label(1),
        // ---- one wide multiply: R0(lo), R5(hi) = R4 * R2 ----
        Instr::Umull(R0, R5, R4, R2),
    ];
    // ---- segmentation: slide the 64-bit product window S bits per field.
    for i in 0..out_fields {
        p.push(Instr::Ubfx(R3, R0, 0, s_bits));
        p.push(Instr::Strh(R3, R8, (i as i32) * 2));
        // lo = (lo >> S) | (hi << (32-S)); hi >>= S.
        p.push(Instr::Lsr(R0, R0, Op2::Imm(s_bits)));
        p.push(Instr::Mov(R7, Op2::Reg(R5)));
        p.push(Instr::Lsl(R7, R7, Op2::Imm(32 - s_bits)));
        p.push(Instr::Orr(R0, R0, Op2::Reg(R7)));
        p.push(Instr::Lsr(R5, R5, Op2::Imm(s_bits)));
    }
    p.push(Instr::Halt);
    MicroKernel {
        name: "slbc_packed_group",
        program: p,
    }
}

/// Requantization loop (multiply + shift + saturate + store):
///
/// * in: `r1` = &acc (i32), `r2` = multiplier, `r3` = n, `r6` = shift,
///   `r8` = &out (u8)
/// * out: out[i] = usat8((acc[i] * m) >> s)
pub fn requant_loop() -> MicroKernel {
    MicroKernel {
        name: "requant_loop",
        program: vec![
            Instr::Label(0),
            Instr::Cmp(R3, Op2::Imm(0)),
            Instr::B(Cond::Le, 1),
            Instr::Ldr(R4, R1, 0),
            Instr::Mul(R4, R4, R2),
            Instr::Asr(R4, R4, Op2::Reg(R6)),
            Instr::Usat(R4, 8, R4),
            Instr::Strb(R4, R8, 0),
            Instr::Add(R1, R1, Op2::Imm(4)),
            Instr::Add(R8, R8, Op2::Imm(1)),
            Instr::Sub(R3, R3, Op2::Imm(1)),
            Instr::B(Cond::Al, 0),
            Instr::Label(1),
            Instr::Halt,
        ],
    }
}

/// Run `dot_i8` on `a`, `b` (preloaded into SRAM) and return
/// `(result, interpreted cycles)`.
pub fn run_dot_i8(a: &[i8], b: &[i8]) -> Result<(i32, u64), Fault> {
    assert_eq!(a.len(), b.len());
    let mut m = Machine::stm32f746();
    let abytes: Vec<u8> = a.iter().map(|&v| v as u8).collect();
    let bbytes: Vec<u8> = b.iter().map(|&v| v as u8).collect();
    m.mem.load_sram(0, &abytes);
    m.mem.load_sram(4096, &bbytes);
    m.set(R1, SRAM_BASE);
    m.set(R2, SRAM_BASE + 4096);
    m.set(R3, a.len() as u32);
    m.load_program(dot_i8().program);
    m.run(1_000_000)?;
    Ok((m.get(R0) as i32, m.cycles()))
}

/// Run `dot_smlad` on i16 operands; `a.len()` must be even.
pub fn run_dot_smlad(a: &[i16], b: &[i16]) -> Result<(i32, u64), Fault> {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len() % 2, 0);
    let mut m = Machine::stm32f746();
    let pack = |v: &[i16]| -> Vec<u8> {
        v.iter().flat_map(|&x| (x as u16).to_le_bytes()).collect()
    };
    m.mem.load_sram(0, &pack(a));
    m.mem.load_sram(4096, &pack(b));
    m.set(R1, SRAM_BASE);
    m.set(R2, SRAM_BASE + 4096);
    m.set(R3, (a.len() / 2) as u32);
    m.load_program(dot_smlad().program);
    m.run(1_000_000)?;
    Ok((m.get(R0) as i32, m.cycles()))
}

/// Run the packed-group kernel: x (unsigned sub-byte values), packed
/// kernel taps, field stride `s_bits`. Returns the extracted fields and
/// interpreted cycles.
pub fn run_slbc_packed_group(
    x: &[u8],
    taps: &[u8],
    s_bits: u32,
) -> Result<(Vec<u16>, u64), Fault> {
    assert!(s_bits <= 16, "kernel assumes field stride <= 16");
    assert!(x.len() as u32 * s_bits <= 32, "one 32-bit packing group");
    let mut m = Machine::stm32f746();
    m.mem.load_sram(0, x);
    let packed_k: u32 = taps
        .iter()
        .enumerate()
        .map(|(i, &t)| (t as u32) << (i as u32 * s_bits))
        .sum();
    let out_fields = (x.len() + taps.len() - 1) as u32;
    m.set(R1, SRAM_BASE);
    m.set(R2, packed_k);
    m.set(R3, x.len() as u32);
    m.set(R6, s_bits);
    m.set(R8, SRAM_BASE + 8192);
    m.load_program(slbc_packed_group(s_bits, out_fields).program);
    m.run(1_000_000)?;
    let mut fields = Vec::with_capacity(out_fields as usize);
    for i in 0..out_fields {
        fields.push(m.mem.read_u16(SRAM_BASE + 8192 + i * 2)?);
    }
    Ok((fields, m.cycles()))
}

/// Run the requantization loop.
pub fn run_requant(acc: &[i32], mult: u32, shift: u32) -> Result<(Vec<u8>, u64), Fault> {
    let mut m = Machine::stm32f746();
    let bytes: Vec<u8> = acc.iter().flat_map(|&v| (v as u32).to_le_bytes()).collect();
    m.mem.load_sram(0, &bytes);
    m.set(R1, SRAM_BASE);
    m.set(R2, mult);
    m.set(R3, acc.len() as u32);
    m.set(R6, shift);
    m.set(R8, SRAM_BASE + 8192);
    m.load_program(requant_loop().program);
    m.run(1_000_000)?;
    let mut out = Vec::with_capacity(acc.len());
    for i in 0..acc.len() as u32 {
        out.push(m.mem.read_u8(SRAM_BASE + 8192 + i)?);
    }
    Ok((out, m.cycles()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::{Counter, CycleModel, InstrClass};
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn dot_i8_bit_exact() {
        check("interpreted dot_i8 == rust dot", 25, |rng| {
            let n = rng.range(1, 64);
            let a: Vec<i8> = (0..n).map(|_| rng.below(256) as u8 as i8).collect();
            let b: Vec<i8> = (0..n).map(|_| rng.below(256) as u8 as i8).collect();
            let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            let (got, cycles) = run_dot_i8(&a, &b).unwrap();
            assert_eq!(got, want, "n={n}");
            assert!(cycles > 0);
        });
    }

    #[test]
    fn dot_smlad_bit_exact_and_faster() {
        let mut rng = Rng::new(4);
        let n = 32;
        let a: Vec<i16> = (0..n).map(|_| rng.below(255) as i16 - 127).collect();
        let b: Vec<i16> = (0..n).map(|_| rng.below(255) as i16 - 127).collect();
        let want: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        let (got, smlad_cycles) = run_dot_smlad(&a, &b).unwrap();
        assert_eq!(got, want);
        let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
        let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
        let (_, scalar_cycles) = run_dot_i8(&a8, &b8).unwrap();
        // Dual-MAC halves the multiply count and quarters the loads, but
        // loop overhead stays: expect ≥1.5× on this inner loop.
        assert!(
            smlad_cycles * 3 < scalar_cycles * 2,
            "SMLAD {smlad_cycles} vs scalar {scalar_cycles}: dual-MAC must win"
        );
    }

    #[test]
    fn packed_group_realizes_polynomial_convolution() {
        // The ISA-level proof of Eq. 3–7: UMULL of packed operands, UBFX
        // segmentation, equals the convolution — with enough guard bits.
        check("packed group == conv1d_full", 25, |rng| {
            let sx = rng.range(2, 5) as u32; // value bits
            let k_taps = rng.range(2, 4);
            let s_bits = 12u32; // generous stride: no field overflow
            let g = (32 / s_bits) as usize; // values per 32-bit packing
            let x: Vec<u8> = (0..g).map(|_| rng.below(1 << sx) as u8).collect();
            let taps: Vec<u8> = (0..k_taps).map(|_| rng.below(1 << sx) as u8).collect();
            let (fields, cycles) = run_slbc_packed_group(&x, &taps, s_bits).unwrap();
            let xu: Vec<u64> = x.iter().map(|&v| v as u64).collect();
            let tu: Vec<u64> = taps.iter().map(|&v| v as u64).collect();
            let want = crate::simd::poly::conv1d_full_direct(&xu, &tu);
            let got: Vec<u64> = fields.iter().map(|&f| f as u64).collect();
            assert_eq!(got, want, "sx={sx} k={k_taps}");
            assert!(cycles > 0);
        });
    }

    #[test]
    fn requant_loop_saturates() {
        let acc = vec![0i32, 100, 1000, -50, 1 << 20];
        let (out, _) = run_requant(&acc, 3, 4).unwrap();
        assert_eq!(out[0], 0);
        assert_eq!(out[1] as u32, (100u32 * 3) >> 4);
        assert_eq!(out[2], 187); // (3000>>4)=187 < 255
        assert_eq!(out[3], 0); // negative saturates to 0
        assert_eq!(out[4], 255); // large saturates to 255
    }

    #[test]
    fn interpreted_cycles_match_counter_model() {
        // The cross-check that justifies the fast counter tier: build the
        // instruction histogram of dot_i8 analytically and compare its
        // cycle total with the interpreter's.
        let n = 24usize;
        let a = vec![3i8; n];
        let b = vec![-2i8; n];
        let (_, interp_cycles) = run_dot_i8(&a, &b).unwrap();
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 1); // acc init
        // per iteration: cmp, 2 loads, mla, 3 adds/subs, back-branch
        c.charge(InstrClass::Alu, n as u64); // cmp
        c.charge(InstrClass::Load, 2 * n as u64);
        c.charge(InstrClass::Mul, n as u64);
        c.charge(InstrClass::Alu, 3 * n as u64);
        c.charge(InstrClass::BranchTaken, n as u64); // loop-back taken
        c.charge(InstrClass::BranchNotTaken, n as u64); // exit test falls through
        // final: cmp + exit-branch taken
        c.charge(InstrClass::Alu, 1);
        c.charge(InstrClass::BranchTaken, 1);
        let model = CycleModel::cortex_m7();
        let predicted = c.cycles(&model);
        let err = (predicted as f64 - interp_cycles as f64).abs() / interp_cycles as f64;
        assert!(
            err < 0.02,
            "counter model {predicted} vs interpreter {interp_cycles} ({:.1}% off)",
            err * 100.0
        );
    }
}
