//! Baseline convolution operators: naïve SISD, CMSIS-NN-style plain SIMD,
//! CMix-NN, WPC&DDD and TinyEngine-int8.
//!
//! All baselines perform standard integer MACs, so their *compute* path is
//! the shared direct convolution; what distinguishes them is the
//! instruction mix they charge — each model follows the published kernel
//! structure of its library (see DESIGN.md §3 for the fidelity argument):
//!
//! * **Naive** — one `LDRSB`+`LDRB`+`MUL`+`ADD` per MAC, scalar loops.
//! * **Simd (CMSIS-NN)** — 4 MACs per 2 `SMLAD` after `SXTB16` unpacking;
//!   no sub-byte support (everything runs as int8).
//! * **CMix-NN** — supports {2,4,8}; sub-byte operands are mask/shift-
//!   expanded into 16-bit lanes before `SMLAD` (extra bit ops, fewer
//!   loads), matching the CMix-NN kernel recipe.
//! * **WPC&DDD** — weight-packed convolution with table-assisted decode:
//!   cheaper unpacking than CMix-NN at 4/2 bits, one extra table load per
//!   8 MACs.
//! * **TinyEngine** — int8 only, CMSIS-style MACs with kernel
//!   specialization: unrolled loops (¼ branch charge) and no generic-path
//!   address arithmetic.

use crate::mcu::{Counter, InstrClass};
use crate::models::{LayerKind, LayerSpec};

use super::common;
use super::Method;

/// Per-4-MACs auxiliary bit-operation count for mask/shift unpacking at an
/// effective bitwidth (both operands), per method.
fn unpack_bit_ops(method: Method, eff_bits: u8) -> u64 {
    match (method, eff_bits) {
        // CMSIS-NN int8: two SXTB16 per operand word.
        (Method::Simd, _) => 4,
        (Method::TinyEngine, _) => 2, // specialization folds one unpack
        (Method::CmixNn, 8) => 4,
        (Method::CmixNn, 4) => 8,
        (Method::CmixNn, 2) => 10,
        (Method::WpcDdd, 8) => 4,
        (Method::WpcDdd, 4) => 6,
        (Method::WpcDdd, 2) => 8,
        _ => 4,
    }
}

/// Loads per 4 MACs: operand bytes fetched word-wise; packed sub-byte
/// storage fetches proportionally fewer words.
fn loads_per_4macs(method: Method, wbits: u8, abits: u8) -> f64 {
    match method {
        Method::Naive => 8.0, // byte loads, one per operand per MAC
        Method::Simd | Method::TinyEngine => 2.0,
        Method::CmixNn | Method::WpcDdd => {
            // ceil-free fractional accounting; 4 operands of each kind.
            (4.0 * wbits as f64 / 32.0) + (4.0 * abits as f64 / 32.0)
        }
        _ => 2.0,
    }
}

/// Charge the instruction mix of `macs` multiply-accumulates plus the
/// per-output loop overhead for a baseline method.
fn charge_conv(
    method: Method,
    macs: u64,
    outputs: u64,
    wbits: u8,
    abits: u8,
    ctr: &mut Counter,
) {
    let (we, ae) = method.effective_bits(wbits, abits);
    match method {
        Method::Naive => {
            ctr.charge(InstrClass::Load, 2 * macs);
            ctr.charge(InstrClass::Mul, macs);
            ctr.charge(InstrClass::Alu, macs); // accumulate
            ctr.charge(InstrClass::Alu, 3 * outputs); // address arithmetic
            ctr.charge(InstrClass::BranchTaken, outputs);
        }
        Method::Simd | Method::TinyEngine | Method::CmixNn | Method::WpcDdd => {
            let groups = macs.div_ceil(4);
            ctr.charge(InstrClass::Simd, 2 * groups); // 2 SMLAD per 4 MACs
            ctr.charge(
                InstrClass::Load,
                (groups as f64 * loads_per_4macs(method, we, ae)).ceil() as u64,
            );
            ctr.charge(InstrClass::Bit, groups * unpack_bit_ops(method, we.max(ae)));
            if method == Method::WpcDdd {
                ctr.charge(InstrClass::Load, macs.div_ceil(8)); // decode table
            }
            // Zero-point/offset correction for the signed-to-unsigned
            // trick the sub-byte libraries use (per output: MUL + ADD).
            if matches!(method, Method::CmixNn | Method::WpcDdd) {
                ctr.charge(InstrClass::Mul, outputs);
                ctr.charge(InstrClass::Alu, outputs);
            }
            // Loop overhead: generic path vs specialized/unrolled.
            let (alu_per_out, branch_per_out) = match method {
                Method::TinyEngine => (2, 1),
                _ => (4, 4),
            };
            ctr.charge(InstrClass::Alu, alu_per_out * outputs);
            ctr.charge(InstrClass::BranchTaken, (branch_per_out * outputs).div_ceil(4));
        }
        _ => unreachable!("SLBC handled in ops::slbc"),
    }
}

/// Bit-exact baseline layer execution with instruction charging.
pub fn run_layer(
    method: Method,
    x: &[u32],
    w: &[i32],
    layer: &LayerSpec,
    wbits: u8,
    abits: u8,
    ctr: &mut Counter,
) -> Vec<i64> {
    // The engine clamps configs to each method's container before
    // dispatch (`Method::effective_bits`); charging below does the same,
    // so out-of-support widths degrade to the container's cost rather
    // than being rejected here.
    let out = common::direct_layer(x, w, layer);
    let outputs = out.len() as u64;
    charge_conv(method, layer.macs, outputs, wbits, abits, ctr);
    if layer.kind == LayerKind::Dense {
        // Dense layers stream weights once; charge the stores of the
        // accumulators (convs fold stores into requant).
        ctr.charge(InstrClass::Store, outputs);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::CycleModel;
    use crate::models::vgg_tiny;
    use crate::util::prng::Rng;

    fn small_layer() -> LayerSpec {
        let mut l = vgg_tiny(10, 16).layers[0].clone();
        l.in_h = 8;
        l.in_w = 8;
        l.out_h = 8;
        l.out_w = 8;
        l.cin = 4;
        l.cout = 8;
        l.macs = l.compute_macs();
        l
    }

    fn rand_inputs(l: &LayerSpec, abits: u8, wbits: u8) -> (Vec<u32>, Vec<i32>) {
        let mut rng = Rng::new(11);
        let x: Vec<u32> = (0..l.in_h * l.in_w * l.cin)
            .map(|_| rng.below(1 << abits) as u32)
            .collect();
        let lim = (1i64 << (wbits - 1)) - 1;
        let w: Vec<i32> = (0..l.k * l.k * l.cin * l.cout)
            .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
            .collect();
        (x, w)
    }

    #[test]
    fn all_baselines_agree_on_result() {
        let l = small_layer();
        let (x, w) = rand_inputs(&l, 8, 8);
        let reference = common::direct_conv2d(&x, &w, &l);
        for m in [
            Method::Naive,
            Method::Simd,
            Method::CmixNn,
            Method::WpcDdd,
            Method::TinyEngine,
        ] {
            let mut ctr = Counter::new();
            let y = run_layer(m, &x, &w, &l, 8, 8, &mut ctr);
            assert_eq!(y, reference, "method {}", m.name());
            assert!(ctr.instructions() > 0);
        }
    }

    #[test]
    fn simd_faster_than_naive() {
        let l = small_layer();
        let (x, w) = rand_inputs(&l, 8, 8);
        let model = CycleModel::cortex_m7();
        let mut c_naive = Counter::new();
        run_layer(Method::Naive, &x, &w, &l, 8, 8, &mut c_naive);
        let mut c_simd = Counter::new();
        run_layer(Method::Simd, &x, &w, &l, 8, 8, &mut c_simd);
        assert!(
            c_simd.cycles(&model) * 2 < c_naive.cycles(&model),
            "simd {} vs naive {}",
            c_simd.cycles(&model),
            c_naive.cycles(&model)
        );
    }

    #[test]
    fn tinyengine_faster_than_plain_simd() {
        let l = small_layer();
        let (x, w) = rand_inputs(&l, 8, 8);
        let model = CycleModel::cortex_m7();
        let mut a = Counter::new();
        run_layer(Method::Simd, &x, &w, &l, 8, 8, &mut a);
        let mut b = Counter::new();
        run_layer(Method::TinyEngine, &x, &w, &l, 8, 8, &mut b);
        assert!(b.cycles(&model) < a.cycles(&model));
    }

    #[test]
    fn cmixnn_subbyte_reduces_loads_but_adds_bitops() {
        let l = small_layer();
        let (x, w) = rand_inputs(&l, 2, 2);
        let mut c8 = Counter::new();
        run_layer(Method::CmixNn, &x, &w, &l, 8, 8, &mut c8);
        let mut c2 = Counter::new();
        run_layer(Method::CmixNn, &x, &w, &l, 2, 2, &mut c2);
        assert!(c2.load < c8.load, "loads {} vs {}", c2.load, c8.load);
        assert!(c2.bit > c8.bit, "bits {} vs {}", c2.bit, c8.bit);
    }

    #[test]
    fn naive_cost_independent_of_bits() {
        // "latency of the conv does not change under 8 bits" (paper §V.B).
        let l = small_layer();
        let (x, w) = rand_inputs(&l, 4, 4);
        let model = CycleModel::cortex_m7();
        let mut c4 = Counter::new();
        run_layer(Method::Naive, &x, &w, &l, 4, 4, &mut c4);
        let mut c8 = Counter::new();
        run_layer(Method::Naive, &x, &w, &l, 8, 8, &mut c8);
        assert_eq!(c4.cycles(&model), c8.cycles(&model));
    }
}
