//! The SLBC / RP-SLBC operators (the paper's contribution, §IV).
//!
//! These operators *compute through the packed representation* — every
//! output is produced by packing sub-byte operands into wide registers,
//! performing one multiply per group and segmenting the product fields
//! (via [`crate::simd`]) — so correctness here is the packed-arithmetic
//! identity itself. Signed weights are handled with the standard offset
//! trick (also used by CMix-NN): `w_u = w + 2^(b-1)` is packed unsigned and
//! the correction `off · Σ window(x)` is subtracted per output; the window
//! sums are filter-independent and computed once, amortized over all
//! output channels.
//!
//! # The rolling-row pipeline
//!
//! The conv hot path is a **rolling-row pipeline** (the row-reuse
//! discipline of CMix-NN-class kernels): consecutive stride-1 output rows
//! share `k-1` of their `k` input rows, so the per-row work — fetch into
//! the padded staging row, window sums, signal packing — runs **once per
//! input row**, not once per output row that consumes it. The packed rows
//! live in a ring buffer keyed by `(iy + pad) mod k`; advancing to the
//! next output row fetches exactly one new row per channel and overwrites
//! the slot of the row that just fell out of the window.
//!
//! All intermediate state lives in a [`ConvScratch`] of *flat, strided*
//! buffers (`rows` / `wsums` / `packs` / `corr` / `row_acc`) reused across
//! calls through a thread-local, so the steady state performs no heap
//! allocation beyond the layer's output vector.
//!
//! Kernel registers are pre-packed once per layer into a [`LayerKernel`]
//! (conv: reversed offset taps broadcast per [`LanePlan`]; dense: the
//! reversed-group weight registers of `dot_pack_b`). The engine's
//! `KernelCache` builds these at compile time, so repeated
//! `CompiledModel::run` calls perform **zero kernel re-packing** — the
//! host-side [`kernel_pack_count`] counter observes this guarantee.
//!
//! # Charging rules
//!
//! Instruction charging follows the adaptive lane plan (§IV.C) and, since
//! the rolling-row refactor, what the pipeline actually executes:
//!
//! * **row work is charged once per fetched row** — `chan · (out_h + k - 1)`
//!   rows per layer, not `chan · k` per output row — covering the packed
//!   row loads, the signal packing and the window sums;
//! * **depthwise rows are charged per channel**: each of the `cout · (out_h
//!   + k - 1)` per-channel rows pays fetch/pack/window-sum exactly once
//!   (the pre-refactor operator charged only a channel-0 prefetch and
//!   never the per-channel re-packing it actually performed), and the
//!   window-sum *reduction* is charged per output channel because each
//!   depthwise channel owns its correction row;
//! * multiplies go to the plan's carrier class, segmentation flushes are
//!   amortized over the in-register accumulation depth, and kernel-register
//!   streaming charges stay per inference — the *modeled* MCU always
//!   streams its packed registers from flash, so cached and uncached host
//!   paths produce identical cycle totals (the compile/run-split
//!   equivalence tests pin this).
//!
//! [`crate::perf::predict`] mirrors these rules term by term; the
//! counter-equivalence tests keep the two from drifting apart.

use std::cell::{Cell, RefCell};

use crate::mcu::{Counter, InstrClass};
use crate::models::{LayerKind, LayerSpec};
use crate::simd::adaptive::{best_plan, LanePlan};
use crate::simd::poly::{dot_group_size, dot_pack_a_into, dot_pack_b, dot_packed_prepacked};
use crate::simd::reorder::RpConv;

use super::common::{pad_of, padded_row_into};

/// Which instruction class the plan's wide multiply uses.
fn mul_class(plan: &LanePlan) -> InstrClass {
    if plan.cfg.register_bits == 64 {
        InstrClass::MulLong
    } else if plan.cfg.lanes() > 1 {
        InstrClass::Simd
    } else {
        InstrClass::Mul
    }
}

thread_local! {
    /// Host-side count of kernel-register packing events (conv
    /// `pack_kernel` registers and dense `dot_pack_b` registers built).
    /// Thread-local so the zero-repack assertions observe exactly the
    /// current thread's work (parallel test threads compile models too).
    static KERNEL_PACKS: Cell<u64> = Cell::new(0);
}

/// Number of kernel registers packed *by the current thread* so far. The
/// engine's compile/run split asserts repeated `CompiledModel::run` calls
/// leave this unchanged (packing is compile-time work).
pub fn kernel_pack_count() -> u64 {
    KERNEL_PACKS.with(|c| c.get())
}

fn note_kernel_packs(n: u64) {
    KERNEL_PACKS.with(|c| c.set(c.get() + n));
}

/// Pre-packed kernel state of one convolution layer: the resolved lane
/// plan plus every output channel's packed kernel registers.
#[derive(Debug, Clone)]
pub struct ConvKernel {
    pub plan: LanePlan,
    /// Whether the reordered (RP-SLBC) segmentation is actually used —
    /// compile-time adaptivity keeps naive segmentation where reordering
    /// does not reduce work (§IV.C).
    pub use_rp: bool,
    /// Signed-weight offset `2^(wbits-1)`.
    pub off: i64,
    pub depthwise: bool,
    pub wbits: u8,
    pub abits: u8,
    /// `vks[(oc·k + ky)·chan_eff + ic]` — packed (reversed, offset)
    /// kernel rows broadcast across lanes.
    pub vks: Vec<u64>,
}

impl ConvKernel {
    pub fn build(
        w: &[i32],
        l: &LayerSpec,
        wbits: u8,
        abits: u8,
        reordered: bool,
        depthwise: bool,
    ) -> ConvKernel {
        let k = l.k;
        let cout = l.cout;
        let chan_eff = if depthwise { 1 } else { l.cin };
        let off = 1i64 << (wbits - 1);
        let plan = best_plan(abits as u32, wbits as u32, k as u32)
            .expect("SLBC plan must exist for 2..=8-bit operands");
        // Reordering is applied only where it actually reduces segmentation
        // work (compile-time adaptivity, §IV.C).
        let use_rp = reordered && plan.reordering_wins();

        // krows[oc][ky][ic] = the k unsigned taps, reversed so the packed
        // polynomial convolution realizes the correlation orientation.
        let kidx = |ky: usize, kx: usize, ic: usize, oc: usize| -> usize {
            if depthwise {
                (ky * k + kx) * cout + oc
            } else {
                ((ky * k + kx) * l.cin + ic) * cout + oc
            }
        };
        let mut taps = vec![0u64; k];
        let mut vks = Vec::with_capacity(cout * k * chan_eff);
        for oc in 0..cout {
            for ky in 0..k {
                for ic in 0..chan_eff {
                    for (ti, kx) in (0..k).rev().enumerate() {
                        taps[ti] = (w[kidx(ky, kx, ic, oc)] as i64 + off) as u64;
                    }
                    vks.push(plan.conv.pack_kernel(&taps));
                }
            }
        }
        note_kernel_packs(vks.len() as u64);
        ConvKernel {
            plan,
            use_rp,
            off,
            depthwise,
            wbits,
            abits,
            vks,
        }
    }
}

/// Pre-packed kernel state of one dense layer: every output neuron's
/// weight vector offset to unsigned and packed into dot-product registers.
#[derive(Debug, Clone)]
pub struct DenseKernel {
    pub off: i64,
    pub wbits: u8,
    pub abits: u8,
    /// `b_regs[oc·regs_per_oc ..][..regs_per_oc]` — `dot_pack_b` registers.
    pub b_regs: Vec<u64>,
    pub regs_per_oc: usize,
}

impl DenseKernel {
    pub fn build(w: &[i32], l: &LayerSpec, wbits: u8, abits: u8) -> DenseKernel {
        let off = 1i64 << (wbits - 1);
        let g = dot_group_size(abits as u32, wbits as u32, 63) as usize;
        let regs_per_oc = l.cin.div_ceil(g);
        let mut b = vec![0u64; l.cin];
        let mut b_regs = Vec::with_capacity(l.cout * regs_per_oc);
        for oc in 0..l.cout {
            for (i, bv) in b.iter_mut().enumerate() {
                *bv = (w[i * l.cout + oc] as i64 + off) as u64;
            }
            b_regs.extend_from_slice(&dot_pack_b(&b, abits as u32, wbits as u32));
        }
        note_kernel_packs(b_regs.len() as u64);
        DenseKernel {
            off,
            wbits,
            abits,
            b_regs,
            regs_per_oc,
        }
    }
}

/// The compile-time product for one SLBC layer: packed kernel registers
/// plus the resolved plan, reusable across arbitrarily many inferences.
#[derive(Debug, Clone)]
pub enum LayerKernel {
    Conv(ConvKernel),
    Dense(DenseKernel),
}

impl LayerKernel {
    /// Build the packed kernel state for `layer` at `(wbits, abits)`.
    pub fn build(w: &[i32], layer: &LayerSpec, wbits: u8, abits: u8, reordered: bool) -> LayerKernel {
        match layer.kind {
            LayerKind::Dense => LayerKernel::Dense(DenseKernel::build(w, layer, wbits, abits)),
            LayerKind::Conv => {
                LayerKernel::Conv(ConvKernel::build(w, layer, wbits, abits, reordered, false))
            }
            LayerKind::DwConv => {
                LayerKernel::Conv(ConvKernel::build(w, layer, wbits, abits, reordered, true))
            }
        }
    }

    /// The `(wbits, abits)` pair this kernel was packed for.
    pub fn bits(&self) -> (u8, u8) {
        match self {
            LayerKernel::Conv(c) => (c.wbits, c.abits),
            LayerKernel::Dense(d) => (d.wbits, d.abits),
        }
    }
}

/// Reusable flat buffers of the rolling-row conv pipeline (plus the dense
/// staging buffers). All buffers are strided views indexed by ring slot;
/// `ensure` resizes them for a layer shape without shedding capacity, so
/// the steady state is allocation-free.
#[derive(Debug, Default)]
pub struct ConvScratch {
    /// `rows[slot·padded_w ..][..padded_w]` — padded staging rows.
    rows: Vec<u64>,
    /// `wsums[slot·out_w ..][..out_w]` — per-row window sums.
    wsums: Vec<i64>,
    /// `packs[slot·regs_per_row ..][..regs_per_row]` — packed row registers.
    packs: Vec<u64>,
    /// Correction row `Σ_rows wsums` for the current window.
    corr: Vec<i64>,
    /// Full-convolution accumulator of one output row.
    row_acc: Vec<i64>,
    /// Dense: activations widened to u64.
    dense_a: Vec<u64>,
    /// Dense: packed activation registers (`dot_pack_a`).
    a_regs: Vec<u64>,
}

impl ConvScratch {
    pub fn new() -> ConvScratch {
        ConvScratch::default()
    }

    fn ensure(&mut self, slots: usize, padded_w: usize, out_w: usize, regs_per_row: usize, acc_len: usize) {
        self.rows.resize(slots * padded_w, 0);
        self.wsums.resize(slots * out_w, 0);
        self.packs.resize(slots * regs_per_row, 0);
        self.corr.resize(out_w, 0);
        self.row_acc.resize(acc_len, 0);
    }
}

thread_local! {
    /// Per-thread scratch: `CompiledModel::run` is `&self` (artifacts are
    /// shared through the serve registry), so the mutable pipeline state
    /// lives thread-locally rather than in the artifact.
    static SCRATCH: RefCell<ConvScratch> = RefCell::new(ConvScratch::new());
}

/// Run one layer through SLBC (or RP-SLBC when `reordered`), packing the
/// kernel registers on the fly. Callers running a layer more than once
/// should build a [`LayerKernel`] and use [`run_layer_cached`] (the
/// engine's `KernelCache` does this automatically).
pub fn run_layer(
    x: &[u32],
    w: &[i32],
    layer: &LayerSpec,
    wbits: u8,
    abits: u8,
    reordered: bool,
    ctr: &mut Counter,
) -> Vec<i64> {
    let kern = LayerKernel::build(w, layer, wbits, abits, reordered);
    run_layer_cached(x, layer, &kern, ctr)
}

/// Run one layer over a pre-packed [`LayerKernel`]: the allocation-free,
/// zero-repacking hot path of repeated inference. Charges exactly what
/// [`run_layer`] charges (the modeled MCU streams packed registers either
/// way); only the *host-side* packing work is skipped.
pub fn run_layer_cached(
    x: &[u32],
    layer: &LayerSpec,
    kern: &LayerKernel,
    ctr: &mut Counter,
) -> Vec<i64> {
    SCRATCH.with(|s| run_layer_with_scratch(x, layer, kern, ctr, &mut s.borrow_mut()))
}

/// [`run_layer_cached`] over a caller-owned [`ConvScratch`] (benches that
/// want scratch reuse without the thread-local indirection).
pub fn run_layer_with_scratch(
    x: &[u32],
    layer: &LayerSpec,
    kern: &LayerKernel,
    ctr: &mut Counter,
    scratch: &mut ConvScratch,
) -> Vec<i64> {
    match (layer.kind, kern) {
        (LayerKind::Dense, LayerKernel::Dense(dk)) => dense_slbc_core(x, layer, dk, ctr, scratch),
        (LayerKind::Conv | LayerKind::DwConv, LayerKernel::Conv(ck)) => {
            conv_slbc_core(x, layer, ck, ctr, scratch)
        }
        _ => panic!("layer kernel kind does not match layer {}", layer.name),
    }
}

/// The rolling-row conv pipeline (see the module docs for the design and
/// the charging rules).
fn conv_slbc_core(
    x: &[u32],
    l: &LayerSpec,
    kern: &ConvKernel,
    ctr: &mut Counter,
    s: &mut ConvScratch,
) -> Vec<i64> {
    let k = l.k;
    let pad = pad_of(k);
    let padded_w = l.in_w + 2 * pad as usize;
    let out_w = l.out_w;
    let depthwise = kern.depthwise;
    // Ring channels: depthwise rows are per output channel, regular convs
    // share every input channel's rows across all output channels.
    let chan = if depthwise { l.cout } else { l.cin };
    let chan_eff = if depthwise { 1 } else { l.cin };
    let cout = l.cout;
    let off = kern.off;
    let plan = &kern.plan;
    let use_rp = kern.use_rp;
    let conv_plan = plan.conv; // Copy — keeps closure captures borrow-free
    let rp_plan: Option<RpConv> = plan.reordered;

    let elems_per_mul = conv_plan.elements_per_instr() as usize;
    let regs_per_row = if use_rp {
        rp_plan.as_ref().unwrap().n_chunks(padded_w)
    } else {
        conv_plan.n_regs(padded_w)
    };
    let acc_len = padded_w + k - 1;
    let slots = k * chan;
    s.ensure(slots, padded_w, out_w, regs_per_row, acc_len);

    let n_mul_per_row = padded_w.div_ceil(elems_per_mul) as u64;
    let seg_ops = if use_rp {
        rp_plan.as_ref().unwrap().seg_ops_per_instr() as u64
    } else {
        conv_plan.seg_ops_per_instr() as u64
    };
    let fields_per_flush = (conv_plan.spec.group * conv_plan.cfg.lanes()) as u64;
    let row_load = ((padded_w * kern.abits as usize).div_ceil(32)) as u64;

    // Kernel-register streaming: 2 bit-ops per tap + a store per register,
    // once per layer invocation (identical for cached and uncached runs —
    // the modeled flash image stores packed registers either way).
    ctr.charge(InstrClass::Bit, (cout * k * chan_eff * k * 2) as u64);
    ctr.charge(InstrClass::Store, (cout * k * chan_eff) as u64);

    // Fetch one padded row into its ring slot: staging copy, window sums,
    // signal packing — charged once, reused by every output row and every
    // filter that consumes it (PACK_REUSE + row reuse).
    let fetch_row = |s: &mut ConvScratch, ctr: &mut Counter, iy: i64, c: usize| {
        let slot = ((iy + pad) as usize % k) * chan + c;
        let row_off = slot * padded_w;
        padded_row_into(x, l, iy, c, pad, &mut s.rows[row_off..row_off + padded_w]);
        {
            let (rows, wsums) = (&s.rows, &mut s.wsums);
            let row = &rows[row_off..row_off + padded_w];
            let ws = &mut wsums[slot * out_w..(slot + 1) * out_w];
            for (ox, wsv) in ws.iter_mut().enumerate() {
                *wsv = row[ox..ox + k].iter().map(|&v| v as i64).sum::<i64>();
            }
        }
        {
            let (rows, packs) = (&s.rows, &mut s.packs);
            let row = &rows[row_off..row_off + padded_w];
            let dst = &mut packs[slot * regs_per_row..(slot + 1) * regs_per_row];
            if use_rp {
                rp_plan.as_ref().unwrap().prepack_chunks_to(row, dst);
            } else {
                conv_plan.pack_windows_to(row, dst);
            }
        }
        ctr.charge(InstrClass::Load, row_load);
        ctr.charge(InstrClass::Bit, 2 * padded_w as u64);
        ctr.charge(InstrClass::Alu, 2 * out_w as u64);
    };

    let mut out = vec![0i64; l.out_h * out_w * cout];
    for oy in 0..l.out_h {
        // Rolling fetch: the first output row fills the ring, every later
        // one replaces exactly the row that left the window.
        let top = oy as i64 - pad;
        let bot = top + k as i64 - 1;
        let fetch_from = if oy == 0 { top } else { bot };
        for iy in fetch_from..=bot {
            for c in 0..chan {
                fetch_row(&mut *s, &mut *ctr, iy, c);
            }
        }

        if !depthwise {
            // Shared correction row: Σ over all k·cin ring rows — identical
            // for every output channel, so computed (and charged) once per
            // output row.
            let (corr, wsums) = (&mut s.corr, &s.wsums);
            corr.fill(0);
            for slot in 0..slots {
                let ws = &wsums[slot * out_w..(slot + 1) * out_w];
                for (cv, &wv) in corr.iter_mut().zip(ws) {
                    *cv += wv;
                }
            }
            ctr.charge(InstrClass::Alu, (out_w * chan * k) as u64);
        }

        for oc in 0..cout {
            if depthwise {
                // Per-channel correction: each depthwise channel owns its
                // k window-sum rows, so the reduction is charged per oc.
                let (corr, wsums) = (&mut s.corr, &s.wsums);
                corr.fill(0);
                for ky in 0..k {
                    let iy = oy as i64 + ky as i64 - pad;
                    let slot = ((iy + pad) as usize % k) * chan + oc;
                    let ws = &wsums[slot * out_w..(slot + 1) * out_w];
                    for (cv, &wv) in corr.iter_mut().zip(ws) {
                        *cv += wv;
                    }
                }
                ctr.charge(InstrClass::Alu, (out_w * k) as u64);
            }

            s.row_acc.fill(0);
            let mut muls_done = 0u64;
            for ky in 0..k {
                let iy = oy as i64 + ky as i64 - pad;
                let slot_y = (iy + pad) as usize % k;
                for ic in 0..chan_eff {
                    let c = if depthwise { oc } else { ic };
                    let slot = slot_y * chan + c;
                    let vk = kern.vks[(oc * k + ky) * chan_eff + ic];
                    // The packed computation itself (bit-exact).
                    if use_rp {
                        rp_plan.as_ref().unwrap().conv_prepacked_into(
                            &s.packs[slot * regs_per_row..(slot + 1) * regs_per_row],
                            padded_w,
                            vk,
                            &mut s.row_acc,
                        );
                    } else {
                        conv_plan.conv1d_prepacked_into(
                            &s.packs[slot * regs_per_row..(slot + 1) * regs_per_row],
                            vk,
                            &mut s.row_acc,
                        );
                    }
                    muls_done += n_mul_per_row;
                }
            }
            // Kernel register reload per row-pair.
            ctr.charge(InstrClass::Load, (k * chan_eff) as u64);
            // Multiply + packed-accumulate charges.
            ctr.charge(mul_class(plan), muls_done);
            ctr.charge(InstrClass::Alu, muls_done);
            // Segmentation flushes, amortized over the accumulation depth.
            let flushes = muls_done.div_ceil(plan.accum_depth as u64);
            ctr.charge(InstrClass::Bit, flushes * seg_ops);
            ctr.charge(InstrClass::Alu, flushes * fields_per_flush);

            // Write outputs with offset correction.
            for ox in 0..out_w {
                let raw = s.row_acc[ox + k - 1];
                out[(oy * out_w + ox) * cout + oc] = raw - off * s.corr[ox];
            }
            // Correction charges: per output 1 MUL + 1 SUB (the window-sum
            // reduction is charged above — shared for regular convs,
            // per-channel for depthwise).
            ctr.charge(InstrClass::Mul, out_w as u64);
            ctr.charge(InstrClass::Alu, out_w as u64);
        }
    }
    out
}

fn dense_slbc_core(
    x: &[u32],
    l: &LayerSpec,
    kern: &DenseKernel,
    ctr: &mut Counter,
    s: &mut ConvScratch,
) -> Vec<i64> {
    let off = kern.off;
    let (wbits, abits) = (kern.wbits, kern.abits);
    s.dense_a.clear();
    s.dense_a.extend(x.iter().take(l.cin).map(|&v| v as u64));
    let sx: i64 = s.dense_a.iter().map(|&v| v as i64).sum();
    // Activation packing once, reused by every output neuron.
    dot_pack_a_into(&s.dense_a, abits as u32, wbits as u32, &mut s.a_regs);

    let g = dot_group_size(abits as u32, wbits as u32, 63);
    let n_groups = (l.cin as u64).div_ceil(g as u64);
    let mut out = vec![0i64; l.cout];

    ctr.charge(InstrClass::Bit, 2 * l.cin as u64);
    ctr.charge(InstrClass::Alu, l.cin as u64); // Σx for the offset fix
    for (oc, o) in out.iter_mut().enumerate() {
        let b_regs = &kern.b_regs[oc * kern.regs_per_oc..(oc + 1) * kern.regs_per_oc];
        let dot =
            dot_packed_prepacked(&s.a_regs, b_regs, l.cin, abits as u32, wbits as u32) as i64;
        *o = dot - off * sx;
        // Pre-packed weights stream from flash; one multiply + one
        // extract (shift+mask) + accumulate per group.
        ctr.charge(
            InstrClass::Load,
            ((l.cin * wbits as usize).div_ceil(32)) as u64,
        );
        ctr.charge(InstrClass::MulLong, n_groups);
        ctr.charge(InstrClass::Bit, 2 * n_groups);
        ctr.charge(InstrClass::Alu, n_groups + 2); // acc + offset fix
        ctr.charge(InstrClass::Store, 1);
    }
    out
}

/// The pre-rolling-pipeline operator, retained verbatim (arithmetic *and*
/// charging) as the perf baseline of the `conv_hotpath` bench and as a
/// second correctness oracle for the new pipeline. Re-fetches and re-packs
/// every input row for every output row, allocates nested `Vec`s in the
/// steady state, and re-packs all kernel registers on every call — exactly
/// what each serve request paid before the rolling-row refactor.
pub mod legacy {
    use super::*;
    use crate::ops::common::padded_row;
    use crate::simd::poly::dot_packed;

    /// Pre-PR `run_layer` (see the module docs of [`self`]).
    pub fn run_layer(
        x: &[u32],
        w: &[i32],
        layer: &LayerSpec,
        wbits: u8,
        abits: u8,
        reordered: bool,
        ctr: &mut Counter,
    ) -> Vec<i64> {
        match layer.kind {
            LayerKind::Dense => dense_slbc(x, w, layer, wbits, abits, ctr),
            LayerKind::Conv => conv_slbc(x, w, layer, wbits, abits, reordered, false, ctr),
            LayerKind::DwConv => conv_slbc(x, w, layer, wbits, abits, reordered, true, ctr),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_slbc(
        x: &[u32],
        w: &[i32],
        l: &LayerSpec,
        wbits: u8,
        abits: u8,
        reordered: bool,
        depthwise: bool,
        ctr: &mut Counter,
    ) -> Vec<i64> {
        let k = l.k;
        let pad = pad_of(k);
        let padded_w = l.in_w + 2 * pad as usize;
        let cin_eff = if depthwise { 1 } else { l.cin };
        let cout = l.cout;
        let off = 1i64 << (wbits - 1);

        let plan = best_plan(abits as u32, wbits as u32, k as u32)
            .expect("SLBC plan must exist for 2..=8-bit operands");
        // Deliberately NOT `LanePlan::reordering_wins`: this module is the
        // frozen pre-PR baseline, inlined predicate and all.
        let use_rp = reordered
            && plan
                .reordered
                .as_ref()
                .map(|r| r.seg_ops_per_instr() < plan.conv.seg_ops_per_instr())
                .unwrap_or(false);

        let kidx = |ky: usize, kx: usize, ic: usize, oc: usize| -> usize {
            if depthwise {
                (ky * k + kx) * cout + oc
            } else {
                ((ky * k + kx) * l.cin + ic) * cout + oc
            }
        };
        let mut krows: Vec<Vec<u64>> = Vec::with_capacity(cout * k * cin_eff);
        for oc in 0..cout {
            for ky in 0..k {
                for ic in 0..cin_eff {
                    let taps: Vec<u64> = (0..k)
                        .rev()
                        .map(|kx| (w[kidx(ky, kx, ic, oc)] as i64 + off) as u64)
                        .collect();
                    krows.push(taps);
                }
            }
        }
        ctr.charge(InstrClass::Bit, (cout * k * cin_eff * k * 2) as u64);
        ctr.charge(InstrClass::Store, (cout * k * cin_eff) as u64);

        let mut out = vec![0i64; l.out_h * l.out_w * cout];
        let elems_per_mul = plan.conv.elements_per_instr() as usize;
        let n_mul_per_row = padded_w.div_ceil(elems_per_mul) as u64;
        let seg_ops = if use_rp {
            plan.reordered.as_ref().unwrap().seg_ops_per_instr() as u64
        } else {
            plan.conv.seg_ops_per_instr() as u64
        };
        let fields_per_flush = (plan.conv.spec.group * plan.conv.cfg.lanes()) as u64;

        let vks: Vec<u64> = krows.iter().map(|taps| plan.conv.pack_kernel(taps)).collect();

        let n_rows = cin_eff * k;
        let mut rows: Vec<Vec<u64>> = vec![Vec::new(); n_rows];
        let mut wsums: Vec<Vec<i64>> = vec![vec![0i64; l.out_w]; n_rows];
        let mut packs: Vec<Vec<u64>> = vec![Vec::new(); n_rows];
        let mut row_acc = vec![0i64; padded_w + k - 1];

        let rp = plan.reordered.as_ref();
        let pack_row = |row: &[u64], dst: &mut Vec<u64>| {
            dst.clear();
            if use_rp {
                rp.unwrap().prepack_chunks(row, dst);
            } else {
                plan.conv.pack_windows_into(row, dst);
            }
        };

        for oy in 0..l.out_h {
            for ky in 0..k {
                let iy = oy as i64 + ky as i64 - pad;
                for ic_slot in 0..cin_eff {
                    let row = padded_row(x, l, iy, ic_slot, pad);
                    let ws = &mut wsums[ky * cin_eff + ic_slot];
                    for (ox, wsv) in ws.iter_mut().enumerate() {
                        *wsv = (0..k).map(|kx| row[ox + kx] as i64).sum();
                    }
                    pack_row(&row, &mut packs[ky * cin_eff + ic_slot]);
                    rows[ky * cin_eff + ic_slot] = row;
                }
            }
            let shared_rows = n_rows as u64;
            ctr.charge(
                InstrClass::Load,
                shared_rows * ((padded_w * abits as usize).div_ceil(32)) as u64,
            );
            ctr.charge(InstrClass::Bit, shared_rows * (padded_w as u64) * 2);
            ctr.charge(InstrClass::Alu, shared_rows * (l.out_w as u64) * 2);

            for oc in 0..cout {
                row_acc.fill(0);
                let mut muls_done = 0u64;
                if depthwise {
                    for ky in 0..k {
                        let iy = oy as i64 + ky as i64 - pad;
                        let row = padded_row(x, l, iy, oc, pad);
                        let ws = &mut wsums[ky * cin_eff];
                        for (ox, wsv) in ws.iter_mut().enumerate() {
                            *wsv = (0..k).map(|kx| row[ox + kx] as i64).sum();
                        }
                        pack_row(&row, &mut packs[ky * cin_eff]);
                        rows[ky * cin_eff] = row;
                    }
                }
                for ky in 0..k {
                    for ic in 0..cin_eff {
                        let slot = ky * cin_eff + ic;
                        let vk = vks[(oc * k + ky) * cin_eff + ic];
                        if use_rp {
                            rp.unwrap().conv_prepacked_into(
                                &packs[slot],
                                rows[slot].len(),
                                vk,
                                &mut row_acc,
                            );
                        } else {
                            plan.conv.conv1d_prepacked_into(&packs[slot], vk, &mut row_acc);
                        }
                        muls_done += n_mul_per_row;
                        ctr.charge(InstrClass::Load, 1);
                    }
                }
                ctr.charge(super::mul_class(&plan), muls_done);
                ctr.charge(InstrClass::Alu, muls_done);
                let flushes = muls_done.div_ceil(plan.accum_depth as u64);
                ctr.charge(InstrClass::Bit, flushes * seg_ops);
                ctr.charge(InstrClass::Alu, flushes * fields_per_flush);

                for ox in 0..l.out_w {
                    let raw = row_acc[ox + k - 1];
                    let corr: i64 = (0..n_rows).map(|r| wsums[r][ox]).sum();
                    out[(oy * l.out_w + ox) * cout + oc] = raw - off * corr;
                }
                ctr.charge(InstrClass::Mul, l.out_w as u64);
                ctr.charge(InstrClass::Alu, l.out_w as u64);
            }
            ctr.charge(InstrClass::Alu, (l.out_w * cin_eff * k) as u64);
        }
        out
    }

    fn dense_slbc(
        x: &[u32],
        w: &[i32],
        l: &LayerSpec,
        wbits: u8,
        abits: u8,
        ctr: &mut Counter,
    ) -> Vec<i64> {
        let off = 1i64 << (wbits - 1);
        let a: Vec<u64> = x.iter().take(l.cin).map(|&v| v as u64).collect();
        let sx: i64 = a.iter().map(|&v| v as i64).sum();
        let mut out = vec![0i64; l.cout];

        let g = dot_group_size(abits as u32, wbits as u32, 63);
        let n_groups = (l.cin as u64).div_ceil(g as u64);

        ctr.charge(InstrClass::Bit, 2 * l.cin as u64);
        ctr.charge(InstrClass::Alu, l.cin as u64);
        for (oc, o) in out.iter_mut().enumerate() {
            let b: Vec<u64> = (0..l.cin)
                .map(|i| (w[i * l.cout + oc] as i64 + off) as u64)
                .collect();
            let dot = dot_packed(&a, &b, abits as u32, wbits as u32) as i64;
            *o = dot - off * sx;
            ctr.charge(
                InstrClass::Load,
                ((l.cin * wbits as usize).div_ceil(32)) as u64,
            );
            ctr.charge(InstrClass::MulLong, n_groups);
            ctr.charge(InstrClass::Bit, 2 * n_groups);
            ctr.charge(InstrClass::Alu, n_groups + 2);
            ctr.charge(InstrClass::Store, 1);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::CycleModel;
    use crate::models::{vgg_tiny, LayerKind};
    use crate::ops::common;
    use crate::util::prop::check;

    fn layer(kind: LayerKind, h: usize, cin: usize, cout: usize, k: usize) -> LayerSpec {
        let mut l = vgg_tiny(10, 16).layers[0].clone();
        l.kind = kind;
        l.in_h = h;
        l.in_w = h;
        l.out_h = h;
        l.out_w = h;
        l.cin = cin;
        l.cout = cout;
        l.k = k;
        l.macs = l.compute_macs();
        l
    }

    fn rand_io(l: &LayerSpec, abits: u8, wbits: u8, seed: u64) -> (Vec<u32>, Vec<i32>) {
        common::rand_layer_operands(l, wbits, abits, seed)
    }

    #[test]
    fn slbc_conv_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 4), (4, 2), (8, 8), (3, 5)] {
            let l = layer(LayerKind::Conv, 6, 3, 4, 3);
            let (x, w) = rand_io(&l, ab, wb, 100 + wb as u64 * 10 + ab as u64);
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
            assert!(ctr.instructions() > 0);
        }
    }

    #[test]
    fn rp_slbc_conv_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 4), (5, 3)] {
            let l = layer(LayerKind::Conv, 6, 3, 4, 3);
            let (x, w) = rand_io(&l, ab, wb, 200 + wb as u64);
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, true, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_dwconv_matches_direct() {
        for (wb, ab) in [(2u8, 4u8), (4, 4), (8, 8)] {
            let l = layer(LayerKind::DwConv, 6, 8, 8, 3);
            let (x, w) = rand_io(&l, ab, wb, 300 + wb as u64);
            let want = common::direct_dwconv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_dense_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 6), (8, 8)] {
            let l = layer(LayerKind::Dense, 1, 64, 10, 1);
            let (x, w) = rand_io(&l, ab, wb, 400 + wb as u64);
            let want = common::direct_dense(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_property_random_geometry() {
        check("slbc conv == direct over random geometry", 60, |rng| {
            let wb = rng.range(2, 9) as u8;
            let ab = rng.range(2, 9) as u8;
            let h = rng.range(3, 9);
            let cin = rng.range(1, 5);
            let cout = rng.range(1, 5);
            let rp = rng.below(2) == 1;
            let l = layer(LayerKind::Conv, h, cin, cout, 3);
            let mut r = rng.fork(7);
            let (x, w) = rand_io(&l, ab, wb, r.next_u64());
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, rp, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab} h={h} cin={cin} cout={cout}");
        });
    }

    #[test]
    fn rolling_pipeline_matches_legacy_operator() {
        // The refactor must not change a single output bit relative to the
        // pre-PR operator, across kinds and reordering.
        for (kind, cin, cout) in [
            (LayerKind::Conv, 3, 5),
            (LayerKind::DwConv, 6, 6),
            (LayerKind::Dense, 40, 7),
        ] {
            for rp in [false, true] {
                for (wb, ab) in [(2u8, 2u8), (4, 4), (3, 6), (8, 8)] {
                    let l = layer(kind, 7, cin, cout, if kind == LayerKind::Dense { 1 } else { 3 });
                    let (x, w) = rand_io(&l, ab, wb, 77 + wb as u64 * 3 + ab as u64);
                    let mut c_new = Counter::new();
                    let got = run_layer(&x, &w, &l, wb, ab, rp, &mut c_new);
                    let mut c_old = Counter::new();
                    let want = legacy::run_layer(&x, &w, &l, wb, ab, rp, &mut c_old);
                    assert_eq!(got, want, "{kind:?} rp={rp} wb={wb} ab={ab}");
                }
            }
        }
    }

    #[test]
    fn ring_buffer_wraparound_odd_widths() {
        // Odd/prime widths exercise partial final packing groups and the
        // ring slot wraparound at every (iy + pad) % k phase.
        for h in [3usize, 5, 7, 9, 11] {
            for k in [1usize, 3, 5] {
                if k > h {
                    continue;
                }
                let l = layer(LayerKind::Conv, h, 2, 3, k);
                let (x, w) = rand_io(&l, 3, 3, 500 + (h * 10 + k) as u64);
                let want = common::direct_conv2d(&x, &w, &l);
                let mut ctr = Counter::new();
                let got = run_layer(&x, &w, &l, 3, 3, false, &mut ctr);
                assert_eq!(got, want, "h={h} k={k}");
            }
        }
    }

    #[test]
    fn cached_kernel_runs_without_repacking() {
        let l = layer(LayerKind::Conv, 6, 3, 4, 3);
        let (x, w) = rand_io(&l, 4, 4, 900);
        let kern = LayerKernel::build(&w, &l, 4, 4, true);
        let mut c1 = Counter::new();
        let first = run_layer_cached(&x, &l, &kern, &mut c1);
        let packs_after_first = kernel_pack_count();
        let mut c2 = Counter::new();
        let again = run_layer_cached(&x, &l, &kern, &mut c2);
        assert_eq!(first, again);
        assert_eq!(c1, c2, "cached runs must charge identically");
        assert_eq!(
            kernel_pack_count(),
            packs_after_first,
            "cached runs must not re-pack kernel registers"
        );
        // The uncached entry point does pack.
        let mut c3 = Counter::new();
        let uncached = run_layer(&x, &w, &l, 4, 4, true, &mut c3);
        assert_eq!(uncached, first);
        assert_eq!(c3, c1, "cached and uncached paths charge identically");
        assert!(kernel_pack_count() > packs_after_first);
    }

    #[test]
    fn slbc_low_bits_cheaper_than_high_bits() {
        let l = layer(LayerKind::Conv, 8, 8, 8, 3);
        let model = CycleModel::cortex_m7();
        let (x2, w2) = rand_io(&l, 2, 2, 1);
        let mut c2 = Counter::new();
        run_layer(&x2, &w2, &l, 2, 2, false, &mut c2);
        let (x8, w8) = rand_io(&l, 8, 8, 2);
        let mut c8 = Counter::new();
        run_layer(&x8, &w8, &l, 8, 8, false, &mut c8);
        assert!(
            c2.cycles(&model) < c8.cycles(&model),
            "2-bit {} vs 8-bit {}",
            c2.cycles(&model),
            c8.cycles(&model)
        );
    }

    #[test]
    fn rp_slbc_cheaper_than_slbc() {
        // Fig. 7: reordering reduces segmentation overhead.
        let l = layer(LayerKind::Conv, 8, 8, 8, 3);
        let model = CycleModel::cortex_m7();
        let (x, w) = rand_io(&l, 4, 4, 3);
        let mut cn = Counter::new();
        run_layer(&x, &w, &l, 4, 4, false, &mut cn);
        let mut cr = Counter::new();
        run_layer(&x, &w, &l, 4, 4, true, &mut cr);
        assert!(
            cr.cycles(&model) <= cn.cycles(&model),
            "rp {} vs naive {}",
            cr.cycles(&model),
            cn.cycles(&model)
        );
    }

    #[test]
    fn rolling_row_work_amortized_vs_legacy() {
        // The rolling pipeline fetches/packs each input row once, so its
        // charged row work (loads + packing bit-ops) must undercut the
        // legacy operator's once-per-output-row charging on stride-1 convs.
        let l = layer(LayerKind::Conv, 8, 4, 4, 3);
        let (x, w) = rand_io(&l, 4, 4, 4);
        let mut c_new = Counter::new();
        run_layer(&x, &w, &l, 4, 4, false, &mut c_new);
        let mut c_old = Counter::new();
        legacy::run_layer(&x, &w, &l, 4, 4, false, &mut c_old);
        assert!(
            c_new.load < c_old.load,
            "row loads must amortize: {} vs {}",
            c_new.load,
            c_old.load
        );
        assert!(
            c_new.bit < c_old.bit,
            "row packing must amortize: {} vs {}",
            c_new.bit,
            c_old.bit
        );
    }
}
