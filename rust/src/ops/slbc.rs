//! The SLBC / RP-SLBC operators (the paper's contribution, §IV).
//!
//! These operators *compute through the packed representation* — every
//! output is produced by packing sub-byte operands into wide registers,
//! performing one multiply per group and segmenting the product fields
//! (via [`crate::simd`]) — so correctness here is the packed-arithmetic
//! identity itself. Signed weights are handled with the standard offset
//! trick (also used by CMix-NN): `w_u = w + 2^(b-1)` is packed unsigned and
//! the correction `off · Σ window(x)` is subtracted per output; the window
//! sums are filter-independent and computed once, amortized over all
//! output channels.
//!
//! Instruction charging follows the adaptive lane plan (§IV.C): multiplies
//! on the chosen carrier (DSP SIMD / long-multiply), packing amortized over
//! output-channel reuse, segmentation amortized over the in-register
//! accumulation depth the guard bits allow, and — for RP-SLBC — the
//! reordered segmentation costs of Theorem IV.1.

use crate::mcu::{Counter, InstrClass};
use crate::models::{LayerKind, LayerSpec};
use crate::simd::adaptive::{best_plan, LanePlan};
use crate::simd::poly::{dot_group_size, dot_packed, field_width};

use super::common::{pad_of, padded_row};

/// Which instruction class the plan's wide multiply uses.
fn mul_class(plan: &LanePlan) -> InstrClass {
    if plan.cfg.register_bits == 64 {
        InstrClass::MulLong
    } else if plan.cfg.lanes() > 1 {
        InstrClass::Simd
    } else {
        InstrClass::Mul
    }
}

/// Run one layer through SLBC (or RP-SLBC when `reordered`).
pub fn run_layer(
    x: &[u32],
    w: &[i32],
    layer: &LayerSpec,
    wbits: u8,
    abits: u8,
    reordered: bool,
    ctr: &mut Counter,
) -> Vec<i64> {
    match layer.kind {
        LayerKind::Dense => dense_slbc(x, w, layer, wbits, abits, ctr),
        LayerKind::Conv => conv_slbc(x, w, layer, wbits, abits, reordered, false, ctr),
        LayerKind::DwConv => conv_slbc(x, w, layer, wbits, abits, reordered, true, ctr),
    }
}

#[allow(clippy::too_many_arguments)]
fn conv_slbc(
    x: &[u32],
    w: &[i32],
    l: &LayerSpec,
    wbits: u8,
    abits: u8,
    reordered: bool,
    depthwise: bool,
    ctr: &mut Counter,
) -> Vec<i64> {
    let k = l.k;
    let pad = pad_of(k);
    let padded_w = l.in_w + 2 * pad as usize;
    let cin_eff = if depthwise { 1 } else { l.cin };
    let cout = l.cout;
    let off = 1i64 << (wbits - 1);

    let plan = best_plan(abits as u32, wbits as u32, k as u32)
        .expect("SLBC plan must exist for 2..=8-bit operands");
    // Reordering is applied only where it actually reduces segmentation
    // work (compile-time adaptivity, §IV.C): e.g. single-lane pointwise
    // plans gain nothing from Theorem IV.1 and keep naive segmentation.
    let use_rp = reordered
        && plan
            .reordered
            .as_ref()
            .map(|r| r.seg_ops_per_instr() < plan.conv.seg_ops_per_instr())
            .unwrap_or(false);

    // ---- pre-pack kernels (reversed taps, offset to unsigned) -----------
    // krows[oc][ky][ic] = the k unsigned taps, reversed so the packed
    // polynomial convolution realizes the correlation orientation.
    let kidx = |ky: usize, kx: usize, ic: usize, oc: usize| -> usize {
        if depthwise {
            (ky * k + kx) * cout + oc
        } else {
            ((ky * k + kx) * l.cin + ic) * cout + oc
        }
    };
    let mut krows: Vec<Vec<u64>> = Vec::with_capacity(cout * k * cin_eff);
    for oc in 0..cout {
        for ky in 0..k {
            for ic in 0..cin_eff {
                let taps: Vec<u64> = (0..k)
                    .rev()
                    .map(|kx| (w[kidx(ky, kx, ic, oc)] as i64 + off) as u64)
                    .collect();
                krows.push(taps);
            }
        }
    }
    // Kernel packing happens once per layer: 2 bit-ops per tap + a store.
    ctr.charge(InstrClass::Bit, (cout * k * cin_eff * k * 2) as u64);
    ctr.charge(InstrClass::Store, (cout * k * cin_eff) as u64);

    let mut out = vec![0i64; l.out_h * l.out_w * cout];
    let elems_per_mul = plan.conv.elements_per_instr() as usize;
    let n_mul_per_row = padded_w.div_ceil(elems_per_mul) as u64;
    let seg_ops = if use_rp {
        plan.reordered.as_ref().unwrap().seg_ops_per_instr() as u64
    } else {
        plan.conv.seg_ops_per_instr() as u64
    };
    let fields_per_flush = (plan.conv.spec.group * plan.conv.cfg.lanes()) as u64;

    // Pre-pack every kernel register once per layer (vk broadcast).
    let vks: Vec<u64> = krows.iter().map(|taps| plan.conv.pack_kernel(taps)).collect();

    // Reused buffers (allocation-free steady state).
    let n_rows = cin_eff * k;
    let mut rows: Vec<Vec<u64>> = vec![Vec::new(); n_rows];
    let mut wsums: Vec<Vec<i64>> = vec![vec![0i64; l.out_w]; n_rows];
    let mut packs: Vec<Vec<u64>> = vec![Vec::new(); n_rows];
    let mut row_acc = vec![0i64; padded_w + k - 1];

    // Pack one row into `packs[slot]` for the active pipeline.
    let rp = plan.reordered.as_ref();
    let pack_row = |row: &[u64], dst: &mut Vec<u64>| {
        dst.clear();
        if use_rp {
            rp.unwrap().prepack_chunks(row, dst);
        } else {
            plan.conv.pack_windows_into(row, dst);
        }
    };

    for oy in 0..l.out_h {
        // Row-level work shared across all output channels: fetch, window
        // sums, and signal packing (reused by every filter — PACK_REUSE).
        for ky in 0..k {
            let iy = oy as i64 + ky as i64 - pad;
            for ic_slot in 0..cin_eff {
                // For depthwise the channel is bound per-oc below; slot 0
                // is refilled inside the oc loop.
                let row = padded_row(x, l, iy, ic_slot, pad);
                let ws = &mut wsums[ky * cin_eff + ic_slot];
                for (ox, wsv) in ws.iter_mut().enumerate() {
                    *wsv = (0..k).map(|kx| row[ox + kx] as i64).sum();
                }
                pack_row(&row, &mut packs[ky * cin_eff + ic_slot]);
                rows[ky * cin_eff + ic_slot] = row;
            }
        }
        // Charges for the shared row work (amortized over cout):
        // packed-row loads + signal packing + window sums.
        let shared_rows = n_rows as u64;
        ctr.charge(
            InstrClass::Load,
            shared_rows * ((padded_w * abits as usize).div_ceil(32)) as u64,
        );
        ctr.charge(InstrClass::Bit, shared_rows * (padded_w as u64) * 2);
        ctr.charge(InstrClass::Alu, shared_rows * (l.out_w as u64) * 2);

        for oc in 0..cout {
            row_acc.fill(0);
            let mut muls_done = 0u64;
            if depthwise {
                // depthwise: rows/packs for THIS channel.
                for ky in 0..k {
                    let iy = oy as i64 + ky as i64 - pad;
                    let row = padded_row(x, l, iy, oc, pad);
                    let ws = &mut wsums[ky * cin_eff];
                    for (ox, wsv) in ws.iter_mut().enumerate() {
                        *wsv = (0..k).map(|kx| row[ox + kx] as i64).sum();
                    }
                    pack_row(&row, &mut packs[ky * cin_eff]);
                    rows[ky * cin_eff] = row;
                }
            }
            for ky in 0..k {
                for ic in 0..cin_eff {
                    let slot = ky * cin_eff + ic;
                    let vk = vks[(oc * k + ky) * cin_eff + ic];
                    // The packed computation itself (bit-exact).
                    if use_rp {
                        rp.unwrap().conv_prepacked_into(
                            &packs[slot],
                            rows[slot].len(),
                            vk,
                            &mut row_acc,
                        );
                    } else {
                        plan.conv.conv1d_prepacked_into(&packs[slot], vk, &mut row_acc);
                    }
                    muls_done += n_mul_per_row;
                    // kernel register reload per row-pair.
                    ctr.charge(InstrClass::Load, 1);
                }
            }
            // Multiply + packed-accumulate charges.
            ctr.charge(mul_class(&plan), muls_done);
            ctr.charge(InstrClass::Alu, muls_done);
            // Segmentation flushes, amortized over the accumulation depth.
            let flushes = muls_done.div_ceil(plan.accum_depth as u64);
            ctr.charge(InstrClass::Bit, flushes * seg_ops);
            ctr.charge(InstrClass::Alu, flushes * fields_per_flush);

            // Write outputs with offset correction.
            for ox in 0..l.out_w {
                let raw = row_acc[ox + k - 1];
                let corr: i64 = (0..n_rows).map(|r| wsums[r][ox]).sum();
                out[(oy * l.out_w + ox) * cout + oc] = raw - off * corr;
            }
            // Correction charges: per output 1 MUL + 1 SUB (window-sum
            // reduction is shared row work, charged above with k·cin adds
            // per output once per row group).
            ctr.charge(InstrClass::Mul, l.out_w as u64);
            ctr.charge(InstrClass::Alu, l.out_w as u64);
        }
        // Window-sum reduction across (cin·k) rows, once per (oy, ox).
        ctr.charge(InstrClass::Alu, (l.out_w * cin_eff * k) as u64);
    }
    out
}

fn dense_slbc(
    x: &[u32],
    w: &[i32],
    l: &LayerSpec,
    wbits: u8,
    abits: u8,
    ctr: &mut Counter,
) -> Vec<i64> {
    let off = 1i64 << (wbits - 1);
    let a: Vec<u64> = x.iter().take(l.cin).map(|&v| v as u64).collect();
    let sx: i64 = a.iter().map(|&v| v as i64).sum();
    let mut out = vec![0i64; l.cout];

    let g = dot_group_size(abits as u32, wbits as u32, 63);
    let n_groups = (l.cin as u64).div_ceil(g as u64);
    let s = field_width(abits as u32, wbits as u32, g);
    let _ = s;

    // Activation packing once, reused by every output neuron.
    ctr.charge(InstrClass::Bit, 2 * l.cin as u64);
    ctr.charge(InstrClass::Alu, l.cin as u64); // Σx for the offset fix
    for oc in 0..l.cout {
        let b: Vec<u64> = (0..l.cin)
            .map(|i| (w[i * l.cout + oc] as i64 + off) as u64)
            .collect();
        let dot = dot_packed(&a, &b, abits as u32, wbits as u32) as i64;
        out[oc] = dot - off * sx;
        // Pre-packed weights stream from flash; one multiply + one
        // extract (shift+mask) + accumulate per group.
        ctr.charge(
            InstrClass::Load,
            ((l.cin * wbits as usize).div_ceil(32)) as u64,
        );
        ctr.charge(InstrClass::MulLong, n_groups);
        ctr.charge(InstrClass::Bit, 2 * n_groups);
        ctr.charge(InstrClass::Alu, n_groups + 2); // acc + offset fix
        ctr.charge(InstrClass::Store, 1);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::CycleModel;
    use crate::models::{vgg_tiny, LayerKind};
    use crate::ops::common;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    fn layer(kind: LayerKind, h: usize, cin: usize, cout: usize, k: usize) -> LayerSpec {
        let mut l = vgg_tiny(10, 16).layers[0].clone();
        l.kind = kind;
        l.in_h = h;
        l.in_w = h;
        l.out_h = h;
        l.out_w = h;
        l.cin = cin;
        l.cout = cout;
        l.k = k;
        l.macs = l.compute_macs();
        l
    }

    fn rand_io(l: &LayerSpec, abits: u8, wbits: u8, seed: u64) -> (Vec<u32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let xn = match l.kind {
            LayerKind::Dense => l.cin,
            _ => l.in_h * l.in_w * l.cin,
        };
        let wn = match l.kind {
            LayerKind::Conv => l.k * l.k * l.cin * l.cout,
            LayerKind::DwConv => l.k * l.k * l.cout,
            LayerKind::Dense => l.cin * l.cout,
        };
        let x: Vec<u32> = (0..xn).map(|_| rng.below(1 << abits) as u32).collect();
        let lim = (1i64 << (wbits - 1)) - 1;
        let w: Vec<i32> = (0..wn)
            .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
            .collect();
        (x, w)
    }

    #[test]
    fn slbc_conv_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 4), (4, 2), (8, 8), (3, 5)] {
            let l = layer(LayerKind::Conv, 6, 3, 4, 3);
            let (x, w) = rand_io(&l, ab, wb, 100 + wb as u64 * 10 + ab as u64);
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
            assert!(ctr.instructions() > 0);
        }
    }

    #[test]
    fn rp_slbc_conv_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 4), (5, 3)] {
            let l = layer(LayerKind::Conv, 6, 3, 4, 3);
            let (x, w) = rand_io(&l, ab, wb, 200 + wb as u64);
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, true, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_dwconv_matches_direct() {
        for (wb, ab) in [(2u8, 4u8), (4, 4), (8, 8)] {
            let l = layer(LayerKind::DwConv, 6, 8, 8, 3);
            let (x, w) = rand_io(&l, ab, wb, 300 + wb as u64);
            let want = common::direct_dwconv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_dense_matches_direct() {
        for (wb, ab) in [(2u8, 2u8), (4, 6), (8, 8)] {
            let l = layer(LayerKind::Dense, 1, 64, 10, 1);
            let (x, w) = rand_io(&l, ab, wb, 400 + wb as u64);
            let want = common::direct_dense(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, false, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab}");
        }
    }

    #[test]
    fn slbc_property_random_geometry() {
        check("slbc conv == direct over random geometry", 60, |rng| {
            let wb = rng.range(2, 9) as u8;
            let ab = rng.range(2, 9) as u8;
            let h = rng.range(3, 9);
            let cin = rng.range(1, 5);
            let cout = rng.range(1, 5);
            let rp = rng.below(2) == 1;
            let l = layer(LayerKind::Conv, h, cin, cout, 3);
            let mut r = rng.fork(7);
            let (x, w) = rand_io(&l, ab, wb, r.next_u64());
            let want = common::direct_conv2d(&x, &w, &l);
            let mut ctr = Counter::new();
            let got = run_layer(&x, &w, &l, wb, ab, rp, &mut ctr);
            assert_eq!(got, want, "wb={wb} ab={ab} h={h} cin={cin} cout={cout}");
        });
    }

    #[test]
    fn slbc_low_bits_cheaper_than_high_bits() {
        let l = layer(LayerKind::Conv, 8, 8, 8, 3);
        let model = CycleModel::cortex_m7();
        let (x2, w2) = rand_io(&l, 2, 2, 1);
        let mut c2 = Counter::new();
        run_layer(&x2, &w2, &l, 2, 2, false, &mut c2);
        let (x8, w8) = rand_io(&l, 8, 8, 2);
        let mut c8 = Counter::new();
        run_layer(&x8, &w8, &l, 8, 8, false, &mut c8);
        assert!(
            c2.cycles(&model) < c8.cycles(&model),
            "2-bit {} vs 8-bit {}",
            c2.cycles(&model),
            c8.cycles(&model)
        );
    }

    #[test]
    fn rp_slbc_cheaper_than_slbc() {
        // Fig. 7: reordering reduces segmentation overhead.
        let l = layer(LayerKind::Conv, 8, 8, 8, 3);
        let model = CycleModel::cortex_m7();
        let (x, w) = rand_io(&l, 4, 4, 3);
        let mut cn = Counter::new();
        run_layer(&x, &w, &l, 4, 4, false, &mut cn);
        let mut cr = Counter::new();
        run_layer(&x, &w, &l, 4, 4, true, &mut cr);
        assert!(
            cr.cycles(&model) <= cn.cycles(&model),
            "rp {} vs naive {}",
            cr.cycles(&model),
            cn.cycles(&model)
        );
    }
}
