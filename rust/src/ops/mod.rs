//! Neural-network operator library on the simulated MCU.
//!
//! Every operator computes **bit-exactly** (integer arithmetic identical to
//! what the MCU would produce) while charging the instructions it would
//! execute to a [`Counter`](crate::mcu::Counter); cycle totals come from the
//! shared [`CycleModel`](crate::mcu::CycleModel). The SLBC operators
//! actually compute *through the packed representation* (via
//! [`crate::simd`]), so their correctness is the packed-arithmetic
//! identity itself, not a shortcut.
//!
//! Implemented methods (Table I / Fig. 5–7 competitors):
//!
//! | method       | packing                      | sub-byte | module |
//! |--------------|------------------------------|----------|--------|
//! | `Naive`      | none (SISD int8)             | no       | [`baselines`] |
//! | `Simd`       | CMSIS-NN SMLAD (int8/16)     | no       | [`baselines`] |
//! | `CmixNn`     | lane-per-operand + mask unpack| {2,4,8} | [`baselines`] |
//! | `WpcDdd`     | weight-packed conv (ref [35])| {2,4,8}  | [`baselines`] |
//! | `TinyEngine` | CMSIS + kernel specialization| int8     | [`baselines`] |
//! | `Slbc`       | in-lane polynomial packing   | 2–8      | [`slbc`] |
//! | `RpSlbc`     | + reordered packing (Alg. 2) | 2–8      | [`slbc`] |

pub mod baselines;
pub mod common;
pub mod slbc;

use crate::mcu::Counter;
use crate::models::LayerSpec;

/// Convolution/dense execution method.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Naive,
    Simd,
    CmixNn,
    WpcDdd,
    TinyEngine,
    Slbc,
    RpSlbc,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Naive,
        Method::Simd,
        Method::CmixNn,
        Method::WpcDdd,
        Method::TinyEngine,
        Method::Slbc,
        Method::RpSlbc,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Naive => "naive",
            Method::Simd => "simd",
            Method::CmixNn => "cmix-nn",
            Method::WpcDdd => "wpc-ddd",
            Method::TinyEngine => "tinyengine",
            Method::Slbc => "slbc",
            Method::RpSlbc => "rp-slbc",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Which (weight, activation) bitwidths the method's kernels accept.
    pub fn supports(&self, wbits: u8, abits: u8) -> bool {
        match self {
            // No sub-byte support: kernels run everything as int8.
            Method::Naive | Method::Simd => wbits <= 8 && abits <= 8,
            Method::TinyEngine => wbits == 8 && abits == 8,
            Method::CmixNn | Method::WpcDdd => {
                matches!(wbits, 2 | 4 | 8) && matches!(abits, 2 | 4 | 8)
            }
            Method::Slbc | Method::RpSlbc => {
                (2..=8).contains(&wbits) && (2..=8).contains(&abits)
            }
        }
    }

    /// The *effective* bitwidths the method computes at (baselines round
    /// sub-byte up to their container).
    pub fn effective_bits(&self, wbits: u8, abits: u8) -> (u8, u8) {
        match self {
            Method::Naive | Method::Simd | Method::TinyEngine => (8, 8),
            Method::CmixNn | Method::WpcDdd => {
                let up = |b: u8| if b <= 2 { 2 } else if b <= 4 { 4 } else { 8 };
                (up(wbits), up(abits))
            }
            Method::Slbc | Method::RpSlbc => (wbits, abits),
        }
    }

    /// Run a quantized layer bit-exactly, charging `ctr`.
    ///
    /// * `x` — input activations, unsigned quantized, NHWC flat
    ///   (`in_h·in_w·cin`, or `cin` for dense layers);
    /// * `w` — signed quantized weights, HWIO flat (Python layout);
    /// * returns raw i64 accumulators (`out_h·out_w·cout`, or `cout`).
    ///
    /// SLBC methods pack their kernel registers on the fly here; repeated
    /// inference should run through [`slbc::run_layer_cached`] with a
    /// pre-built [`slbc::LayerKernel`] (the engine's `KernelCache` path),
    /// which charges identically but re-packs nothing.
    pub fn run_layer(
        &self,
        x: &[u32],
        w: &[i32],
        layer: &LayerSpec,
        wbits: u8,
        abits: u8,
        ctr: &mut Counter,
    ) -> Vec<i64> {
        match self {
            Method::Slbc => slbc::run_layer(x, w, layer, wbits, abits, false, ctr),
            Method::RpSlbc => slbc::run_layer(x, w, layer, wbits, abits, true, ctr),
            _ => baselines::run_layer(*self, x, w, layer, wbits, abits, ctr),
        }
    }
}

/// Raw-accumulator output of a layer plus the instruction charges.
pub struct LayerRun {
    pub out: Vec<i64>,
    pub counter: Counter,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn support_matrix() {
        assert!(Method::TinyEngine.supports(8, 8));
        assert!(!Method::TinyEngine.supports(4, 8));
        assert!(Method::CmixNn.supports(2, 4));
        assert!(!Method::CmixNn.supports(3, 4));
        assert!(Method::Slbc.supports(3, 7));
        assert!(!Method::Slbc.supports(1, 4));
    }

    #[test]
    fn effective_bits_rounding() {
        assert_eq!(Method::CmixNn.effective_bits(3, 5), (4, 8));
        assert_eq!(Method::Slbc.effective_bits(3, 5), (3, 5));
        assert_eq!(Method::Naive.effective_bits(2, 2), (8, 8));
    }
}
