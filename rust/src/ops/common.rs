//! Shared operator pieces: the direct (oracle) convolutions, pooling,
//! global average pooling and requantization — plus SAME-padding helpers.
//!
//! The direct convolutions are the correctness oracle for every method and
//! the *compute* path for the baselines (whose arithmetic is standard int8
//! MACs); the SLBC operators compute through the packed domain instead and
//! are property-tested against these.

use crate::mcu::{Counter, InstrClass};
use crate::models::{LayerKind, LayerSpec};

/// SAME-padding offset for odd kernels (k=1 → 0, k=3 → 1).
pub fn pad_of(k: usize) -> i64 {
    (k as i64 - 1) / 2
}

/// Direct 2-D convolution, NHWC x HWIO, stride 1, SAME padding, into raw
/// i64 accumulators. `x` holds unsigned quantized activations, `w` signed
/// quantized weights.
pub fn direct_conv2d(x: &[u32], w: &[i32], l: &LayerSpec) -> Vec<i64> {
    let (h, wd, cin, cout, k) = (l.in_h, l.in_w, l.cin, l.cout, l.k);
    let pad = pad_of(k);
    let mut out = vec![0i64; l.out_h * l.out_w * cout];
    for oy in 0..l.out_h {
        for ox in 0..l.out_w {
            for oc in 0..cout {
                let mut acc = 0i64;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = oy as i64 + ky as i64 - pad;
                        let ix = ox as i64 + kx as i64 - pad;
                        if iy < 0 || iy >= h as i64 || ix < 0 || ix >= wd as i64 {
                            continue;
                        }
                        for ic in 0..cin {
                            let xv = x[(iy as usize * wd + ix as usize) * cin + ic] as i64;
                            let wv = w[((ky * k + kx) * cin + ic) * cout + oc] as i64;
                            acc += xv * wv;
                        }
                    }
                }
                out[(oy * l.out_w + ox) * cout + oc] = acc;
            }
        }
    }
    out
}

/// Direct depthwise convolution: HWIO weights with I=1, O=channels.
pub fn direct_dwconv2d(x: &[u32], w: &[i32], l: &LayerSpec) -> Vec<i64> {
    let (h, wd, c, k) = (l.in_h, l.in_w, l.cout, l.k);
    let pad = pad_of(k);
    let mut out = vec![0i64; l.out_h * l.out_w * c];
    for oy in 0..l.out_h {
        for ox in 0..l.out_w {
            for ch in 0..c {
                let mut acc = 0i64;
                for ky in 0..k {
                    for kx in 0..k {
                        let iy = oy as i64 + ky as i64 - pad;
                        let ix = ox as i64 + kx as i64 - pad;
                        if iy < 0 || iy >= h as i64 || ix < 0 || ix >= wd as i64 {
                            continue;
                        }
                        let xv = x[(iy as usize * wd + ix as usize) * c + ch] as i64;
                        let wv = w[(ky * k + kx) * c + ch] as i64;
                        acc += xv * wv;
                    }
                }
                out[(oy * l.out_w + ox) * c + ch] = acc;
            }
        }
    }
    out
}

/// Direct dense layer (matvec): `w` is `[cin][cout]`.
pub fn direct_dense(x: &[u32], w: &[i32], l: &LayerSpec) -> Vec<i64> {
    let mut out = vec![0i64; l.cout];
    for (i, &xv) in x.iter().enumerate().take(l.cin) {
        for oc in 0..l.cout {
            out[oc] += xv as i64 * w[i * l.cout + oc] as i64;
        }
    }
    out
}

/// Oracle dispatch by layer kind.
pub fn direct_layer(x: &[u32], w: &[i32], l: &LayerSpec) -> Vec<i64> {
    match l.kind {
        LayerKind::Conv => direct_conv2d(x, w, l),
        LayerKind::DwConv => direct_dwconv2d(x, w, l),
        LayerKind::Dense => direct_dense(x, w, l),
    }
}

/// 2×2 max-pool (stride 2) over an HWC u32 tensor, charging the MCU cost
/// (3 compares + 4 loads + 1 store per output).
pub fn maxpool_2x2(x: &[u32], h: usize, w: usize, c: usize, ctr: &mut Counter) -> Vec<u32> {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![0u32; oh * ow * c];
    for oy in 0..oh {
        for ox in 0..ow {
            for ch in 0..c {
                let mut m = 0u32;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(x[((oy * 2 + dy) * w + (ox * 2 + dx)) * c + ch]);
                    }
                }
                out[(oy * ow + ox) * c + ch] = m;
            }
        }
    }
    let n = (oh * ow * c) as u64;
    ctr.charge(InstrClass::Load, 4 * n);
    ctr.charge(InstrClass::Alu, 3 * n); // compares/selects
    ctr.charge(InstrClass::Store, n);
    out
}

/// Global average pool over HW, returning per-channel mean accumulators
/// (sum and the divisor, to stay in integers).
pub fn global_avg_pool(x: &[u32], h: usize, w: usize, c: usize, ctr: &mut Counter) -> Vec<u32> {
    let mut out = vec![0u64; c];
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                out[ch] += x[(y * w + xx) * c + ch] as u64;
            }
        }
    }
    let n = (h * w * c) as u64;
    ctr.charge(InstrClass::Load, n);
    ctr.charge(InstrClass::Alu, n);
    ctr.charge(InstrClass::Store, c as u64);
    out.iter().map(|&s| (s / (h * w) as u64) as u32).collect()
}

/// Requantize raw accumulators to unsigned `bits`-bit activations with
/// ReLU, using a fixed-point multiplier (the standard CMSIS/TinyEngine
/// scheme: multiply + shift + saturate). Charges 1 MUL + 1 shift + 1 SAT +
/// 1 store per element. Returns the quantized activations.
///
/// The multiplier is chosen from the data range like the dynamic
/// `fake_quant` scaling (max-abs → full range), so the integer pipeline
/// tracks the float training pipeline.
pub fn requantize(acc: &[i64], bias: &[i64], cout: usize, bits: u8, ctr: &mut Counter) -> Vec<u32> {
    let n_levels = (1u64 << bits) - 1;
    // Per-tensor max after bias & ReLU.
    let mut maxv = 1i64;
    for (i, &a) in acc.iter().enumerate() {
        let v = a + bias[i % cout];
        if v > maxv {
            maxv = v;
        }
    }
    let out: Vec<u32> = acc
        .iter()
        .enumerate()
        .map(|(i, &a)| {
            let v = (a + bias[i % cout]).max(0);
            // round(v * n / max) in integer arithmetic.
            ((v as i128 * n_levels as i128 + (maxv as i128 / 2)) / maxv as i128) as u32
        })
        .collect();
    let n = acc.len() as u64;
    ctr.charge(InstrClass::Mul, n);
    ctr.charge(InstrClass::Bit, n);
    ctr.charge(InstrClass::Sat, n);
    ctr.charge(InstrClass::Store, n);
    out
}

/// Extract one padded input row for channel `ic` at input row `iy`
/// (zero-padded SAME borders): used by the SLBC row pipeline.
pub fn padded_row(x: &[u32], l: &LayerSpec, iy: i64, ic: usize, pad: i64) -> Vec<u64> {
    let mut row = vec![0u64; l.in_w + 2 * pad as usize];
    padded_row_into(x, l, iy, ic, pad, &mut row);
    row
}

/// Allocation-free [`padded_row`]: writes the padded row into `row` (a
/// ring-buffer slot of the rolling-row conv pipeline). `row` must already
/// have length `in_w + 2·pad`.
#[inline]
pub fn padded_row_into(x: &[u32], l: &LayerSpec, iy: i64, ic: usize, pad: i64, row: &mut [u64]) {
    let w = l.in_w;
    let cin = l.cin;
    debug_assert_eq!(row.len(), w + 2 * pad as usize);
    row.fill(0);
    if iy < 0 || iy >= l.in_h as i64 {
        return;
    }
    for x_pos in 0..w {
        row[x_pos + pad as usize] = x[(iy as usize * w + x_pos) * cin + ic] as u64;
    }
}

/// Seeded random operands for one layer at the given bitwidths: unsigned
/// `abits`-bit activations and signed `wbits`-bit weights in the
/// symmetric range `±(2^(w-1) - 1)` (the quantizer's range). The single
/// generator shared by the operator tests, the golden suite and the conv
/// hot-path bench, so all of them exercise identically distributed
/// operands.
pub fn rand_layer_operands(
    l: &LayerSpec,
    wbits: u8,
    abits: u8,
    seed: u64,
) -> (Vec<u32>, Vec<i32>) {
    let mut rng = crate::util::prng::Rng::new(seed);
    let xn = match l.kind {
        LayerKind::Dense => l.cin,
        _ => l.in_h * l.in_w * l.cin,
    };
    let wn = match l.kind {
        LayerKind::Conv => l.k * l.k * l.cin * l.cout,
        LayerKind::DwConv => l.k * l.k * l.cout,
        LayerKind::Dense => l.cin * l.cout,
    };
    let x: Vec<u32> = (0..xn).map(|_| rng.below(1 << abits) as u32).collect();
    let lim = (1i64 << (wbits - 1)) - 1;
    let w: Vec<i32> = (0..wn)
        .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
        .collect();
    (x, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    fn tiny_conv_layer() -> LayerSpec {
        let mut l = vgg_tiny(10, 16).layers[0].clone();
        l.in_h = 4;
        l.in_w = 4;
        l.out_h = 4;
        l.out_w = 4;
        l.cin = 2;
        l.cout = 3;
        l
    }

    #[test]
    fn direct_conv_identity_kernel() {
        // 1x1 kernel with weight 1 on the diagonal reproduces the input.
        let mut l = tiny_conv_layer();
        l.k = 1;
        l.cin = 2;
        l.cout = 2;
        let x: Vec<u32> = (0..l.in_h * l.in_w * 2).map(|i| (i % 7) as u32).collect();
        // w[0][0][ic][oc] = delta(ic, oc)
        let w = vec![1, 0, 0, 1];
        let y = direct_conv2d(&x, &w, &l);
        for (i, &v) in y.iter().enumerate() {
            assert_eq!(v, x[i] as i64);
        }
    }

    #[test]
    fn maxpool_halves_and_takes_max() {
        let mut ctr = Counter::new();
        // 2x2x1 -> 1x1x1
        let x = vec![1, 5, 3, 2];
        let y = maxpool_2x2(&x, 2, 2, 1, &mut ctr);
        assert_eq!(y, vec![5]);
        assert!(ctr.instructions() > 0);
    }

    #[test]
    fn gap_averages() {
        let mut ctr = Counter::new();
        let x = vec![2, 4, 6, 8]; // 2x2x1
        let y = global_avg_pool(&x, 2, 2, 1, &mut ctr);
        assert_eq!(y, vec![5]);
    }

    #[test]
    fn requantize_range_and_relu() {
        let mut ctr = Counter::new();
        let acc = vec![-50i64, 0, 120, 240];
        let bias = vec![0i64];
        let q = requantize(&acc, &bias, 1, 4, &mut ctr);
        assert_eq!(q[0], 0); // ReLU clips negatives
        assert_eq!(q[3], 15); // max maps to full scale
        assert!(q.iter().all(|&v| v <= 15));
    }

    #[test]
    fn padded_row_borders_zero() {
        let l = tiny_conv_layer();
        let x: Vec<u32> = (0..l.in_h * l.in_w * l.cin).map(|i| i as u32 + 1).collect();
        let row = padded_row(&x, &l, -1, 0, 1);
        assert!(row.iter().all(|&v| v == 0));
        let row0 = padded_row(&x, &l, 0, 1, 1);
        assert_eq!(row0[0], 0);
        assert_eq!(row0[1], x[1] as u64);
    }
}
