//! Typed request-lifecycle events and the [`Recorder`] sink trait.
//!
//! Every event is stamped with the virtual-time cycle it happened at (on
//! the 216 MHz reference timeline), the request id, the tenant/model key
//! index and the SLO class index (0 = interactive, 1 = standard,
//! 2 = batch). Batch-scoped events (`Flush*`) are stamped with the first
//! member's id and the batch's effective class; fleet-scoped events
//! (`Migrate`) carry the batch *ticket* as the id and
//! [`Event::NO_KEY`] as the key.
//!
//! The stream is designed to be *sufficient*: [`derive_class_misses`]
//! reconstructs the report's per-class deadline-miss accounting from
//! events alone, which the serve tests pin bit-for-bit against
//! [`ServeReport::class_misses`](crate::serve::ServeReport::class_misses).

use std::collections::VecDeque;

/// One lifecycle event on the virtual timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Virtual-time stamp in 216 MHz reference cycles.
    pub cycles: u64,
    /// Request id (or batch ticket for [`EventKind::Migrate`]).
    pub id: usize,
    /// Tenant/model key index ([`Event::NO_KEY`] when not applicable).
    pub key_idx: usize,
    /// SLO class index: 0 = interactive, 1 = standard, 2 = batch.
    pub class: u8,
    pub kind: EventKind,
}

impl Event {
    /// Sentinel `key_idx` for events not tied to a tenant/model key
    /// (currently only [`EventKind::Migrate`]).
    pub const NO_KEY: usize = usize::MAX;
}

/// What happened. Variants mirror the serve pipeline's decision points.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request entered the pipeline. Carries the absolute deadline
    /// (`u64::MAX` = none) so miss accounting is re-derivable.
    Arrive { deadline: u64 },
    /// Admitted into the batcher's per-key queue.
    Admit,
    /// Evicted from the queue by class-aware admission (a higher-priority
    /// arrival displaced it).
    Evict { had_deadline: bool },
    /// Refused at the queue door (full queue / window-doomed).
    Shed { had_deadline: bool },
    /// Rejected before batching: the model's peak SRAM does not fit any
    /// device in the fleet.
    SramReject { had_deadline: bool },
    /// Batch flushed because its batching window expired.
    FlushWindow { batch_size: usize },
    /// Batch flushed because it reached `max_batch`.
    FlushFull { batch_size: usize },
    /// Batch flushed early to rescue an urgent (window-doomed) member.
    FlushPreempt { batch_size: usize },
    /// Scheduler committed the request's batch to a device.
    Place {
        /// Scheduler policy name (`round-robin`, `slo`, ...).
        policy: &'static str,
        device: usize,
        /// Deferred-mode ticket, when placement is resolved later.
        ticket: Option<usize>,
        /// Predicted device-clock cycles for the whole batch.
        predicted_cycles: u64,
        /// Predicted energy for the whole batch on that device, joules.
        predicted_joules: f64,
    },
    /// A queued batch moved between devices (work stealing).
    Migrate { from: usize, to: usize },
    /// A fleet device came up: a churn `Join`, a restore from down, or
    /// an autoscaler growing the fleet from its standby pool.
    DeviceUp { device: usize },
    /// A fleet device went down: a churn `Leave`/`Crash` or an
    /// autoscaler shrink. `crashed` marks the in-flight batch as lost.
    DeviceDown { device: usize, crashed: bool },
    /// DVFS throttle (or restore): the device's effective clock changed;
    /// subsequent batches price cycles and joules at the new clock.
    Throttle { device: usize, clock_hz: u64 },
    /// The device stopped accepting placements; in-flight work finishes
    /// and pending batches migrate away via work stealing.
    Drain { device: usize },
    /// A member of a crashed batch re-entered the admission path.
    /// Exactly one `Readmit` is emitted per re-admission attempt.
    Readmit { device: usize },
    /// A member of a crashed batch was dropped forever (best-effort
    /// work is not re-admitted) — counted as a miss.
    Lost { device: usize },
    /// Execution began on the device.
    Start { device: usize },
    /// Execution finished; the terminal event of a completed request.
    Finish {
        device: usize,
        /// When execution began (duplicated from `Start` so a `Finish`
        /// alone suffices for queue-wait vs compute attribution).
        start: u64,
        /// Arrival-to-finish latency in reference cycles.
        latency_cycles: u64,
        /// Whether the request missed its deadline.
        miss: bool,
    },
}

impl EventKind {
    /// Stable kind name, used by the exporters and CI schema greps.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrive { .. } => "Arrive",
            EventKind::Admit => "Admit",
            EventKind::Evict { .. } => "Evict",
            EventKind::Shed { .. } => "Shed",
            EventKind::SramReject { .. } => "SramReject",
            EventKind::FlushWindow { .. } => "FlushWindow",
            EventKind::FlushFull { .. } => "FlushFull",
            EventKind::FlushPreempt { .. } => "FlushPreempt",
            EventKind::Place { .. } => "Place",
            EventKind::Migrate { .. } => "Migrate",
            EventKind::DeviceUp { .. } => "DeviceUp",
            EventKind::DeviceDown { .. } => "DeviceDown",
            EventKind::Throttle { .. } => "Throttle",
            EventKind::Drain { .. } => "Drain",
            EventKind::Readmit { .. } => "Readmit",
            EventKind::Lost { .. } => "Lost",
            EventKind::Start { .. } => "Start",
            EventKind::Finish { .. } => "Finish",
        }
    }
}

/// Human name of an SLO class index (mirrors `serve::trace::SloClass`).
pub fn class_name(class: u8) -> &'static str {
    match class {
        0 => "interactive",
        1 => "standard",
        _ => "batch",
    }
}

/// Sink for lifecycle events.
///
/// Producers MUST gate any work needed to *build* an event on
/// [`enabled`](Recorder::enabled), so the no-op recorder is genuinely
/// zero-cost and cannot perturb the virtual timeline.
pub trait Recorder {
    /// Whether this recorder wants events at all.
    fn enabled(&self) -> bool;
    /// Record one event. May be called out of timestamp order across
    /// producers (the replay loop drains batcher/fleet logs in chunks).
    fn record(&mut self, ev: Event);
}

/// The zero-cost default: discards everything, reports disabled.
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    fn enabled(&self) -> bool {
        false
    }
    #[inline]
    fn record(&mut self, _ev: Event) {}
}

/// Bounded in-memory recorder: keeps the most recent `capacity` events,
/// counting (not storing) anything older once full — million-request
/// traces stay bounded.
#[derive(Debug)]
pub struct RingRecorder {
    capacity: usize,
    events: VecDeque<Event>,
    /// Events discarded because the ring was full.
    pub dropped: u64,
}

impl RingRecorder {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "RingRecorder capacity must be > 0");
        RingRecorder {
            capacity,
            events: VecDeque::with_capacity(capacity.min(4096)),
            dropped: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Iterate over retained events, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    /// Consume the recorder into a `Vec`, oldest first.
    pub fn into_events(self) -> Vec<Event> {
        self.events.into()
    }
}

impl Recorder for RingRecorder {
    fn enabled(&self) -> bool {
        true
    }

    fn record(&mut self, ev: Event) {
        if self.events.len() == self.capacity {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }
}

/// Re-derive per-class deadline misses from an event stream: a `Finish`
/// with the miss flag, a deadline-carrying `Shed`/`Evict`/`SramReject`
/// (a request dropped before execution can only miss if it *had* a
/// deadline), or a `Lost` (crash-killed forever, a miss regardless of
/// deadline). Index 0 = interactive, 1 = standard, 2 = batch — the same
/// accounting as [`ServeReport::class_misses`](crate::serve::ServeReport::class_misses).
pub fn derive_class_misses<'a, I>(events: I) -> [u64; 3]
where
    I: IntoIterator<Item = &'a Event>,
{
    let mut out = [0u64; 3];
    for ev in events {
        let c = (ev.class as usize).min(2);
        match ev.kind {
            EventKind::Finish { miss: true, .. } => out[c] += 1,
            EventKind::Shed { had_deadline: true }
            | EventKind::Evict { had_deadline: true }
            | EventKind::SramReject { had_deadline: true }
            | EventKind::Lost { .. } => out[c] += 1,
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(cycles: u64, id: usize, class: u8, kind: EventKind) -> Event {
        Event {
            cycles,
            id,
            key_idx: 0,
            class,
            kind,
        }
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let mut r = RingRecorder::new(3);
        assert!(r.enabled());
        for i in 0..5u64 {
            r.record(ev(i, i as usize, 0, EventKind::Admit));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped, 2);
        let kept: Vec<u64> = r.iter().map(|e| e.cycles).collect();
        assert_eq!(kept, vec![2, 3, 4]);
        assert_eq!(r.into_events().len(), 3);
    }

    #[test]
    fn noop_is_disabled() {
        let mut n = NoopRecorder;
        assert!(!n.enabled());
        n.record(ev(0, 0, 0, EventKind::Admit)); // must not panic
    }

    #[test]
    fn derive_counts_finish_misses_and_deadline_drops() {
        let events = vec![
            ev(10, 1, 0, EventKind::Arrive { deadline: 100 }),
            ev(
                200,
                1,
                0,
                EventKind::Finish {
                    device: 0,
                    start: 150,
                    latency_cycles: 190,
                    miss: true,
                },
            ),
            ev(
                30,
                2,
                1,
                EventKind::Finish {
                    device: 0,
                    start: 20,
                    latency_cycles: 10,
                    miss: false,
                },
            ),
            ev(40, 3, 1, EventKind::Shed { had_deadline: true }),
            ev(50, 4, 2, EventKind::Shed { had_deadline: false }),
            ev(60, 5, 0, EventKind::Evict { had_deadline: true }),
            ev(
                70,
                6,
                2,
                EventKind::SramReject { had_deadline: true },
            ),
        ];
        assert_eq!(derive_class_misses(&events), [2, 1, 1]);
    }

    #[test]
    fn lost_requests_derive_as_misses_and_lifecycle_kinds_do_not() {
        let events = vec![
            ev(10, 0, 0, EventKind::DeviceUp { device: 1 }),
            ev(20, 0, 0, EventKind::DeviceDown { device: 1, crashed: true }),
            ev(20, 7, 0, EventKind::Readmit { device: 1 }),
            ev(20, 8, 2, EventKind::Lost { device: 1 }),
            ev(30, 0, 0, EventKind::Throttle { device: 0, clock_hz: 84_000_000 }),
            ev(40, 0, 0, EventKind::Drain { device: 0 }),
        ];
        // Only the Lost counts — lifecycle and Readmit events are not
        // misses themselves (a re-admitted request finishes or sheds).
        assert_eq!(derive_class_misses(&events), [0, 0, 1]);
    }

    #[test]
    fn kind_names_are_stable() {
        assert_eq!(EventKind::Arrive { deadline: 0 }.name(), "Arrive");
        assert_eq!(EventKind::DeviceUp { device: 0 }.name(), "DeviceUp");
        assert_eq!(
            EventKind::DeviceDown { device: 0, crashed: false }.name(),
            "DeviceDown"
        );
        assert_eq!(
            EventKind::Throttle { device: 0, clock_hz: 1 }.name(),
            "Throttle"
        );
        assert_eq!(EventKind::Drain { device: 0 }.name(), "Drain");
        assert_eq!(EventKind::Readmit { device: 0 }.name(), "Readmit");
        assert_eq!(EventKind::Lost { device: 0 }.name(), "Lost");
        assert_eq!(
            EventKind::Place {
                policy: "slo",
                device: 0,
                ticket: None,
                predicted_cycles: 0,
                predicted_joules: 0.0
            }
            .name(),
            "Place"
        );
        assert_eq!(class_name(0), "interactive");
        assert_eq!(class_name(2), "batch");
    }
}
