//! Virtual-time observability for the serving stack and the engine.
//!
//! The serving layer (PRs 1–5) reports only end-of-run aggregates, so
//! "why did this interactive request miss its deadline" — admitted late?
//! evicted by class-aware admission? window-doomed? stolen mid-queue? —
//! was unanswerable. This module adds the missing instrumentation in four
//! pieces, all denominated in the same 216 MHz reference timeline the
//! serving layer already uses:
//!
//! 1. **Lifecycle events** ([`events`]) — a [`Recorder`] trait with a
//!    zero-cost [`NoopRecorder`] default and a bounded [`RingRecorder`],
//!    fed typed [`Event`]s (`Arrive` … `Finish`) from tap points inside
//!    `serve::{batcher, fleet}` and the replay loop. The event stream is
//!    *checkable*: [`derive_class_misses`] re-derives per-class deadline
//!    misses from events alone, and tests pin it bit-for-bit against
//!    [`crate::serve::ServeReport::class_misses`] — the behavioral anchor
//!    the ROADMAP's event-driven scheduler refactor will regress against.
//! 2. **Metrics** ([`metrics`]) — a [`MetricsRegistry`] of counters,
//!    gauges and log2-bucket histograms plus virtual-time series (queue
//!    depth, in-flight batches, per-device utilization) sampled on a
//!    configurable cycle cadence.
//! 3. **Perfetto export** ([`perfetto`]) — renders an event stream as
//!    Chrome trace-event JSON (one track per device, complete slices per
//!    batch, async slices per request from arrival to finish) loadable in
//!    `ui.perfetto.dev`, behind `serve --events-out` / `--metrics-out`.
//! 4. **Per-layer profiling** ([`profile`]) — attributes an inference's
//!    cycles and joules per layer × [`InstrClass`](crate::mcu::InstrClass)
//!    from the executor's per-layer [`Counter`](crate::mcu::Counter)
//!    diffs, priced against a [`Target`](crate::target::Target)'s cycle
//!    and energy models (the `profile` CLI verb).
//!
//! Recording is strictly passive: every tap point is gated on
//! [`Recorder::enabled`], no event ever feeds back into admission,
//! placement or timing, and the RoundRobin/all-M7 bit-for-bit pin runs
//! with a [`RingRecorder`] attached to prove it.

pub mod events;
pub mod metrics;
pub mod perfetto;
pub mod profile;

pub use events::{
    class_name, derive_class_misses, Event, EventKind, NoopRecorder, Recorder, RingRecorder,
};
pub use metrics::{Histogram, MetricsRegistry};
pub use profile::{ExecutionProfile, LayerProfile};
