//! Chrome trace-event / Perfetto JSON export of a lifecycle event stream.
//!
//! Produces a `{"traceEvents": [...]}` document loadable in
//! `ui.perfetto.dev` or `chrome://tracing`:
//!
//! * one named thread (track) per fleet device, carrying `ph:"X"`
//!   complete slices per executed batch (requests sharing a device +
//!   start + finish collapse into one slice);
//! * `ph:"b"`/`ph:"e"` async slices per request spanning arrival →
//!   finish, with miss flag, SLO class and latency in the end args;
//! * an `eventCounts` side table (kind name → count) used by the CI
//!   schema checks — Perfetto ignores unknown top-level keys.
//!
//! Timestamps are microseconds: virtual-time cycles divided by 216 (the
//! 216 MHz reference clock all serve timelines are denominated in).

use super::events::{class_name, Event, EventKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Reference-timeline cycles → trace microseconds (216 MHz).
pub fn cycles_to_us(cycles: u64) -> f64 {
    cycles as f64 / (crate::target::STM32F746_CLOCK_HZ as f64 / 1e6)
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

/// Render an event stream (oldest first) as a Chrome trace JSON document.
/// `device_names` labels the per-device tracks; devices only ever
/// referenced by index fall back to `dev<i>`.
pub fn export<'a, I>(events: I, device_names: &[String]) -> Json
where
    I: IntoIterator<Item = &'a Event>,
{
    let events: Vec<&Event> = events.into_iter().collect();
    let mut trace: Vec<Json> = Vec::new();
    let mut counts: BTreeMap<&'static str, u64> = BTreeMap::new();

    // Track metadata: pid 0 = the fleet, tid i+1 = device i (tid 0 is
    // reserved for request-scoped instant events).
    let mut max_device = device_names.len();
    for ev in &events {
        let d = match ev.kind {
            EventKind::Place { device, .. }
            | EventKind::Start { device }
            | EventKind::Finish { device, .. }
            | EventKind::DeviceUp { device }
            | EventKind::DeviceDown { device, .. }
            | EventKind::Throttle { device, .. }
            | EventKind::Drain { device }
            | EventKind::Readmit { device }
            | EventKind::Lost { device } => Some(device),
            EventKind::Migrate { from, to } => Some(from.max(to)),
            _ => None,
        };
        if let Some(d) = d {
            max_device = max_device.max(d + 1);
        }
    }
    trace.push(obj(vec![
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(0.0)),
        ("name", Json::Str("process_name".into())),
        ("args", obj(vec![("name", Json::Str("mcu-fleet".into()))])),
    ]));
    for i in 0..max_device {
        let label = device_names
            .get(i)
            .cloned()
            .unwrap_or_else(|| format!("dev{i}"));
        trace.push(obj(vec![
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num((i + 1) as f64)),
            ("name", Json::Str("thread_name".into())),
            ("args", obj(vec![("name", Json::Str(label))])),
        ]));
    }

    // Batch execution slices: requests in the same batch share
    // (device, start, finish); collapse them into one slice each.
    let mut batches: BTreeMap<(usize, u64, u64), u64> = BTreeMap::new();

    for ev in &events {
        *counts.entry(ev.kind.name()).or_insert(0) += 1;
        match &ev.kind {
            EventKind::Arrive { deadline } => {
                trace.push(obj(vec![
                    ("ph", Json::Str("b".into())),
                    ("cat", Json::Str("request".into())),
                    ("name", Json::Str("request".into())),
                    ("id", Json::Num(ev.id as f64)),
                    ("pid", Json::Num(0.0)),
                    ("ts", Json::Num(cycles_to_us(ev.cycles))),
                    (
                        "args",
                        obj(vec![
                            ("class", Json::Str(class_name(ev.class).into())),
                            ("key_idx", Json::Num(ev.key_idx as f64)),
                            (
                                "deadline_us",
                                if *deadline == u64::MAX {
                                    Json::Null
                                } else {
                                    Json::Num(cycles_to_us(*deadline))
                                },
                            ),
                        ]),
                    ),
                ]));
            }
            EventKind::Finish {
                device,
                start,
                latency_cycles,
                miss,
            } => {
                *batches.entry((*device, *start, ev.cycles)).or_insert(0) += 1;
                trace.push(obj(vec![
                    ("ph", Json::Str("e".into())),
                    ("cat", Json::Str("request".into())),
                    ("name", Json::Str("request".into())),
                    ("id", Json::Num(ev.id as f64)),
                    ("pid", Json::Num(0.0)),
                    ("ts", Json::Num(cycles_to_us(ev.cycles))),
                    (
                        "args",
                        obj(vec![
                            ("miss", Json::Bool(*miss)),
                            ("class", Json::Str(class_name(ev.class).into())),
                            (
                                "latency_ms",
                                Json::Num(crate::cycles_to_ms(*latency_cycles)),
                            ),
                            ("device", Json::Num(*device as f64)),
                        ]),
                    ),
                ]));
            }
            // Fleet-lifecycle churn renders as instant events pinned to
            // the affected device's track, so joins, losses, DVFS steps
            // and drains are visible inline with the batch slices.
            EventKind::DeviceUp { device }
            | EventKind::DeviceDown { device, .. }
            | EventKind::Throttle { device, .. }
            | EventKind::Drain { device } => {
                let mut args = vec![("kind", Json::Str(ev.kind.name().into()))];
                if let EventKind::Throttle { clock_hz, .. } = &ev.kind {
                    args.push(("clock_mhz", Json::Num(*clock_hz as f64 / 1e6)));
                }
                if let EventKind::DeviceDown { crashed, .. } = &ev.kind {
                    args.push(("crashed", Json::Bool(*crashed)));
                }
                trace.push(obj(vec![
                    ("ph", Json::Str("i".into())),
                    ("s", Json::Str("t".into())),
                    ("cat", Json::Str("fleet".into())),
                    ("name", Json::Str(ev.kind.name().into())),
                    ("pid", Json::Num(0.0)),
                    ("tid", Json::Num((*device + 1) as f64)),
                    ("ts", Json::Num(cycles_to_us(ev.cycles))),
                    ("args", obj(args)),
                ]));
            }
            // Drops terminate their async slice so shed/evicted/rejected
            // and crash-lost requests don't render as unbounded open
            // spans.
            EventKind::Shed { .. }
            | EventKind::Evict { .. }
            | EventKind::SramReject { .. }
            | EventKind::Lost { .. } => {
                trace.push(obj(vec![
                    ("ph", Json::Str("e".into())),
                    ("cat", Json::Str("request".into())),
                    ("name", Json::Str("request".into())),
                    ("id", Json::Num(ev.id as f64)),
                    ("pid", Json::Num(0.0)),
                    ("ts", Json::Num(cycles_to_us(ev.cycles))),
                    (
                        "args",
                        obj(vec![
                            ("dropped", Json::Str(ev.kind.name().into())),
                            ("class", Json::Str(class_name(ev.class).into())),
                        ]),
                    ),
                ]));
            }
            _ => {}
        }
    }

    for ((device, start, finish), requests) in &batches {
        trace.push(obj(vec![
            ("ph", Json::Str("X".into())),
            ("cat", Json::Str("exec".into())),
            ("name", Json::Str(format!("batch x{requests}"))),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num((*device + 1) as f64)),
            ("ts", Json::Num(cycles_to_us(*start))),
            (
                "dur",
                Json::Num(cycles_to_us(finish.saturating_sub(*start)).max(0.001)),
            ),
            ("args", obj(vec![("requests", Json::Num(*requests as f64))])),
        ]));
    }

    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(trace));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".into()));
    top.insert(
        "eventCounts".to_string(),
        Json::Obj(
            counts
                .iter()
                .map(|(k, &v)| (k.to_string(), Json::Num(v as f64)))
                .collect(),
        ),
    );
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_produces_tracks_slices_and_counts() {
        let events = vec![
            Event {
                cycles: 0,
                id: 1,
                key_idx: 0,
                class: 0,
                kind: EventKind::Arrive { deadline: 4_320_000 },
            },
            Event {
                cycles: 0,
                id: 1,
                key_idx: 0,
                class: 0,
                kind: EventKind::Admit,
            },
            Event {
                cycles: 216,
                id: 1,
                key_idx: 0,
                class: 0,
                kind: EventKind::Start { device: 0 },
            },
            Event {
                cycles: 432,
                id: 1,
                key_idx: 0,
                class: 0,
                kind: EventKind::Finish {
                    device: 0,
                    start: 216,
                    latency_cycles: 432,
                    miss: false,
                },
            },
            Event {
                cycles: 500,
                id: 2,
                key_idx: 1,
                class: 2,
                kind: EventKind::Shed { had_deadline: false },
            },
        ];
        let names = vec!["m7 #0".to_string()];
        let doc = export(&events, &names);
        let s = doc.to_string_compact();
        assert!(s.contains("\"traceEvents\""), "{s}");
        assert!(s.contains("m7 #0"), "{s}");
        assert!(s.contains("\"Arrive\":1"), "{s}");
        assert!(s.contains("\"Finish\":1"), "{s}");
        assert!(s.contains("\"Shed\":1"), "{s}");
        // Round-trips; the batch slice lands on device 0's track (tid 1)
        // with a 1 µs duration (216 cycles @ 216 MHz).
        let parsed = Json::parse(&s).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let slice = evs
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .expect("one batch slice");
        assert_eq!(slice.get("tid").and_then(Json::as_f64), Some(1.0));
        assert!((slice.get("dur").and_then(Json::as_f64).unwrap() - 1.0).abs() < 1e-9);
        assert!((slice.get("ts").and_then(Json::as_f64).unwrap() - 1.0).abs() < 1e-9);
        // Every async begin has a matching end (finish or drop).
        let begins = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("b"))
            .count();
        let ends = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("e"))
            .count();
        assert_eq!(begins, 1);
        assert_eq!(ends, 2); // id 1 finished, id 2 shed
    }

    #[test]
    fn lifecycle_events_render_as_device_track_instants() {
        let events = vec![
            Event {
                cycles: 100,
                id: 0,
                key_idx: Event::NO_KEY,
                class: 0,
                kind: EventKind::Throttle { device: 2, clock_hz: 84_000_000 },
            },
            Event {
                cycles: 200,
                id: 0,
                key_idx: Event::NO_KEY,
                class: 0,
                kind: EventKind::DeviceDown { device: 2, crashed: true },
            },
            Event {
                cycles: 200,
                id: 9,
                key_idx: 0,
                class: 2,
                kind: EventKind::Lost { device: 2 },
            },
            Event {
                cycles: 300,
                id: 0,
                key_idx: Event::NO_KEY,
                class: 0,
                kind: EventKind::DeviceUp { device: 2 },
            },
            Event {
                cycles: 400,
                id: 0,
                key_idx: Event::NO_KEY,
                class: 0,
                kind: EventKind::Drain { device: 0 },
            },
        ];
        // No device names passed: the tid-3 track must still be created
        // from the lifecycle events alone.
        let doc = export(&events, &[]);
        let s = doc.to_string_compact();
        assert!(s.contains("\"DeviceDown\":1"), "{s}");
        assert!(s.contains("\"DeviceUp\":1"), "{s}");
        assert!(s.contains("\"Throttle\":1"), "{s}");
        assert!(s.contains("\"Drain\":1"), "{s}");
        assert!(s.contains("\"Lost\":1"), "{s}");
        assert!(s.contains("dev2"), "{s}");
        let parsed = Json::parse(&s).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let instants: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .collect();
        assert_eq!(instants.len(), 4, "one instant per lifecycle event");
        for i in &instants {
            assert_eq!(i.get("cat").and_then(Json::as_str), Some("fleet"));
        }
        // The throttle instant lands on device 2's track (tid 3) and
        // carries the new clock.
        let throttle = instants
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("Throttle"))
            .unwrap();
        assert_eq!(throttle.get("tid").and_then(Json::as_f64), Some(3.0));
        let clock = throttle
            .get("args")
            .and_then(|a| a.get("clock_mhz"))
            .and_then(Json::as_f64)
            .unwrap();
        assert!((clock - 84.0).abs() < 1e-9);
        // The crash-lost request still closes its async span.
        let lost_end = evs.iter().any(|e| {
            e.get("ph").and_then(Json::as_str) == Some("e")
                && e.get("id").and_then(Json::as_f64) == Some(9.0)
        });
        assert!(lost_end, "Lost must terminate the request span");
    }
}
