//! Counters, gauges, log2-bucket histograms and virtual-time series.
//!
//! A [`MetricsRegistry`] is the aggregate companion to the event stream:
//! where events answer "what happened to request 17", metrics answer
//! "what did queue depth look like over the run". Time series are
//! sampled on a configurable virtual-time cadence (reference cycles) by
//! the serve replay loop; everything serializes to one JSON document for
//! `serve --metrics-out`.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Power-of-two bucketed histogram of `u64` samples.
///
/// Bucket `i` counts samples `v` with `bit_width(v) == i`, i.e. bucket 0
/// holds zeros and bucket `i >= 1` holds `2^(i-1) <= v < 2^i`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    /// One bucket per possible `u64` bit width (0..=64).
    pub buckets: [u64; 65],
    pub count: u64,
    pub sum: u64,
}

impl Histogram {
    pub fn observe(&mut self, v: u64) {
        let idx = (u64::BITS - v.leading_zeros()) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Mean of observed samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    fn to_json(&self) -> Json {
        let mut buckets = Vec::new();
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let mut b = BTreeMap::new();
            // Upper bound (inclusive) of the bucket: 0, 1, 3, 7, ...
            let le = if i == 0 {
                0u64
            } else if i >= 64 {
                u64::MAX
            } else {
                (1u64 << i) - 1
            };
            b.insert("le".to_string(), Json::Num(le as f64));
            b.insert("count".to_string(), Json::Num(n as f64));
            buckets.push(Json::Obj(b));
        }
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum as f64));
        m.insert("buckets".to_string(), Json::Arr(buckets));
        Json::Obj(m)
    }
}

/// Named counters, gauges, histograms and cadence-sampled time series.
#[derive(Debug)]
pub struct MetricsRegistry {
    /// Sampling cadence in reference cycles.
    cadence_cycles: u64,
    /// Next virtual time at which [`should_sample`](Self::should_sample)
    /// fires (first call always samples).
    next_sample: u64,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    /// Series name → `(cycles, value)` samples, in sample order.
    series: BTreeMap<String, Vec<(u64, f64)>>,
}

impl MetricsRegistry {
    pub fn new(cadence_cycles: u64) -> Self {
        assert!(cadence_cycles > 0, "metrics cadence must be > 0 cycles");
        MetricsRegistry {
            cadence_cycles,
            next_sample: 0,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            histograms: BTreeMap::new(),
            series: BTreeMap::new(),
        }
    }

    pub fn cadence_cycles(&self) -> u64 {
        self.cadence_cycles
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn observe(&mut self, name: &str, v: u64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Rate-limit gate for time-series sampling: returns `true` (and
    /// advances the internal clock) at most once per cadence interval of
    /// virtual time. The first call always samples.
    pub fn should_sample(&mut self, now: u64) -> bool {
        if now < self.next_sample {
            return false;
        }
        // Jump to the next grid point strictly after `now`, so bursts of
        // same-cycle arrivals sample once.
        let intervals = now / self.cadence_cycles + 1;
        self.next_sample = intervals.saturating_mul(self.cadence_cycles);
        true
    }

    /// Append one `(cycles, value)` point to a named series.
    pub fn push_series(&mut self, name: &str, now: u64, v: f64) {
        self.series
            .entry(name.to_string())
            .or_default()
            .push((now, v));
    }

    pub fn series(&self, name: &str) -> Option<&[(u64, f64)]> {
        self.series.get(name).map(|s| s.as_slice())
    }

    /// Serialize the whole registry: `cadence_cycles`, `counters`,
    /// `gauges`, `histograms` and `series` (arrays of `[cycles, value]`
    /// pairs).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "cadence_cycles".to_string(),
            Json::Num(self.cadence_cycles as f64),
        );
        m.insert(
            "counters".to_string(),
            Json::Obj(
                self.counters
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
                    .collect(),
            ),
        );
        m.insert(
            "gauges".to_string(),
            Json::Obj(
                self.gauges
                    .iter()
                    .map(|(k, &v)| (k.clone(), Json::Num(v)))
                    .collect(),
            ),
        );
        m.insert(
            "histograms".to_string(),
            Json::Obj(
                self.histograms
                    .iter()
                    .map(|(k, h)| (k.clone(), h.to_json()))
                    .collect(),
            ),
        );
        m.insert(
            "series".to_string(),
            Json::Obj(
                self.series
                    .iter()
                    .map(|(k, pts)| {
                        (
                            k.clone(),
                            Json::Arr(
                                pts.iter()
                                    .map(|&(t, v)| {
                                        Json::Arr(vec![
                                            Json::Num(t as f64),
                                            Json::Num(v),
                                        ])
                                    })
                                    .collect(),
                            ),
                        )
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_bit_width() {
        let mut h = Histogram::default();
        h.observe(0); // bucket 0
        h.observe(1); // bucket 1
        h.observe(2); // bucket 2
        h.observe(3); // bucket 2
        h.observe(1024); // bucket 11
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[1], 1);
        assert_eq!(h.buckets[2], 2);
        assert_eq!(h.buckets[11], 1);
        assert_eq!(h.count, 5);
        assert_eq!(h.sum, 1030);
        assert!((h.mean() - 206.0).abs() < 1e-12);
        assert_eq!(Histogram::default().mean(), 0.0);
        // u64::MAX lands in the last bucket without overflow.
        let mut top = Histogram::default();
        top.observe(u64::MAX);
        assert_eq!(top.buckets[64], 1);
    }

    #[test]
    fn sampling_respects_cadence() {
        let mut m = MetricsRegistry::new(100);
        assert!(m.should_sample(0)); // first call always samples
        assert!(!m.should_sample(0));
        assert!(!m.should_sample(99));
        assert!(m.should_sample(100));
        assert!(!m.should_sample(150));
        assert!(m.should_sample(1000)); // gaps skip straight to now
        assert!(!m.should_sample(1099));
        assert!(m.should_sample(1100));
    }

    #[test]
    fn registry_serializes_all_sections() {
        let mut m = MetricsRegistry::new(1000);
        m.inc("requests", 3);
        m.inc("requests", 1);
        m.gauge("completed_frac", 0.75);
        m.observe("latency_cycles", 12_345);
        m.push_series("queue_depth", 0, 0.0);
        m.push_series("queue_depth", 1000, 4.0);
        assert_eq!(m.counter("requests"), 4);
        assert_eq!(m.series("queue_depth").unwrap().len(), 2);
        let j = m.to_json().to_string_compact();
        assert!(j.contains("\"cadence_cycles\":1000"), "{j}");
        assert!(j.contains("\"requests\":4"), "{j}");
        assert!(j.contains("\"queue_depth\":[[0,0],[1000,4]]"), "{j}");
        assert!(j.contains("\"latency_cycles\""), "{j}");
        // Round-trips through the parser.
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(
            parsed
                .get("counters")
                .and_then(|c| c.get("requests"))
                .and_then(Json::as_f64),
            Some(4.0)
        );
    }
}
