//! Per-layer execution profiling: cycles and joules per layer ×
//! [`InstrClass`], from the executor's per-layer [`Counter`] diffs.
//!
//! The executor snapshots the instruction histogram around every layer,
//! so each layer owns an exact `u64` counter diff. Pricing falls out of
//! the [`Target`]'s cycle and energy models:
//!
//! * per-layer **cycles** are the executor's own cumulative-cycle diffs,
//!   which telescope — their sum equals the run's total cycle count
//!   bit-for-bit;
//! * total **joules** are priced once over the *merged* per-layer
//!   counter, which reproduces the run's total counter exactly (integer
//!   merge), so the profile total is bit-identical to
//!   [`DeployReport::joules`](crate::engine::DeployReport) for the same
//!   target — the invariant `cmd profile` asserts;
//! * per-layer joules price each layer's counter independently
//!   (dynamic energy + static power over the layer's priced time).
//!   Floating-point summation order makes their sum only ~1e-12-close
//!   to the total, which is why the total is *not* defined as that sum.

use crate::mcu::counter::Counter;
use crate::mcu::cycles::{InstrClass, ALL_CLASSES};
use crate::target::Target;
use crate::util::bench::Table;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Stable lowercase label for an instruction class (JSON keys).
pub fn instr_label(class: InstrClass) -> &'static str {
    match class {
        InstrClass::Alu => "alu",
        InstrClass::Bit => "bit",
        InstrClass::Mul => "mul",
        InstrClass::Simd => "simd",
        InstrClass::MulLong => "mul_long",
        InstrClass::Load => "load",
        InstrClass::Store => "store",
        InstrClass::BranchTaken => "branch_taken",
        InstrClass::BranchNotTaken => "branch_not_taken",
        InstrClass::Sat => "sat",
    }
}

/// One layer's attributed execution cost.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    /// Device cycles attributed to this layer (cumulative-cycle diff).
    pub cycles: u64,
    /// Energy attributed to this layer (independent pricing; informative).
    pub joules: f64,
    /// Exact instruction histogram of this layer.
    pub counter: Counter,
}

/// A full single-inference profile on one target.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionProfile {
    /// Registry name of the target everything is priced against.
    pub target: String,
    pub layers: Vec<LayerProfile>,
    /// Sum of per-layer cycles == the run's total device cycles.
    pub total_cycles: u64,
    /// Exact merge of every per-layer counter == the run's counter.
    pub total_counter: Counter,
    /// `target.joules(&total_counter)` — bit-identical to the
    /// deploy-path energy figure for the same run.
    pub total_joules: f64,
}

impl ExecutionProfile {
    /// Build a profile from the executor's parallel per-layer arrays:
    /// `(name, cycles)` pairs plus each layer's exact counter diff.
    pub fn from_layers(
        target: &Target,
        per_layer: &[(String, u64)],
        counters: &[Counter],
    ) -> Self {
        assert_eq!(
            per_layer.len(),
            counters.len(),
            "per-layer cycles and counters must be parallel arrays"
        );
        let mut total_counter = Counter::new();
        let mut total_cycles = 0u64;
        let mut layers = Vec::with_capacity(per_layer.len());
        for ((name, cycles), ctr) in per_layer.iter().zip(counters) {
            total_counter.merge(ctr);
            total_cycles += cycles;
            layers.push(LayerProfile {
                name: name.clone(),
                cycles: *cycles,
                joules: target.joules(ctr),
                counter: ctr.clone(),
            });
        }
        ExecutionProfile {
            target: target.name.to_string(),
            layers,
            total_cycles,
            total_joules: target.joules(&total_counter),
            total_counter,
        }
    }

    /// Latency of the profiled inference on its target, in ms.
    pub fn latency_ms(&self, target: &Target) -> f64 {
        target.seconds(self.total_cycles) * 1e3
    }

    /// Aligned table: per-layer cycles, share, energy and the Eq. 12
    /// instruction-mix decomposition (SISD / SIMD / bit).
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "layer", "cycles", "cyc%", "uJ", "instrs", "sisd", "simd", "bit",
        ]);
        for l in &self.layers {
            let (sisd, simd, bit) = l.counter.eq12_components();
            let pct = if self.total_cycles == 0 {
                0.0
            } else {
                100.0 * l.cycles as f64 / self.total_cycles as f64
            };
            t.row(vec![
                l.name.clone(),
                l.cycles.to_string(),
                format!("{pct:.1}"),
                format!("{:.2}", l.joules * 1e6),
                l.counter.instructions().to_string(),
                sisd.to_string(),
                simd.to_string(),
                bit.to_string(),
            ]);
        }
        let (sisd, simd, bit) = self.total_counter.eq12_components();
        t.row(vec![
            "TOTAL".to_string(),
            self.total_cycles.to_string(),
            "100.0".to_string(),
            format!("{:.2}", self.total_joules * 1e6),
            self.total_counter.instructions().to_string(),
            sisd.to_string(),
            simd.to_string(),
            bit.to_string(),
        ]);
        t.render()
    }

    /// JSON document: totals plus per-layer cycles, joules and the full
    /// per-[`InstrClass`] histogram (zero classes omitted).
    pub fn to_json(&self) -> Json {
        let classes_json = |ctr: &Counter| {
            Json::Obj(
                ALL_CLASSES
                    .iter()
                    .filter(|&&c| ctr.get(c) > 0)
                    .map(|&c| (instr_label(c).to_string(), Json::Num(ctr.get(c) as f64)))
                    .collect::<BTreeMap<_, _>>(),
            )
        };
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(l.name.clone()));
                m.insert("cycles".to_string(), Json::Num(l.cycles as f64));
                m.insert("joules".to_string(), Json::Num(l.joules));
                m.insert("classes".to_string(), classes_json(&l.counter));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("target".to_string(), Json::Str(self.target.clone()));
        m.insert(
            "total_cycles".to_string(),
            Json::Num(self.total_cycles as f64),
        );
        m.insert("total_joules".to_string(), Json::Num(self.total_joules));
        m.insert(
            "total_instructions".to_string(),
            Json::Num(self.total_counter.instructions() as f64),
        );
        m.insert("per_layer".to_string(), Json::Arr(layers));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctr(simd: u64, load: u64) -> Counter {
        let mut c = Counter::new();
        c.charge(InstrClass::Simd, simd);
        c.charge(InstrClass::Load, load);
        c
    }

    #[test]
    fn totals_are_exact_merges() {
        let t = Target::stm32f746();
        let per_layer = vec![("conv0".to_string(), 1000u64), ("fc".to_string(), 500u64)];
        let counters = vec![ctr(100, 50), ctr(10, 200)];
        let p = ExecutionProfile::from_layers(&t, &per_layer, &counters);
        assert_eq!(p.total_cycles, 1500);
        assert_eq!(p.total_counter.simd, 110);
        assert_eq!(p.total_counter.load, 250);
        // Total joules price the merged counter, not a float sum.
        let mut merged = Counter::new();
        merged.merge(&counters[0]);
        merged.merge(&counters[1]);
        assert_eq!(p.total_joules.to_bits(), t.joules(&merged).to_bits());
        // Per-layer joules are positive and smaller than the total's
        // dynamic+static envelope.
        assert!(p.layers.iter().all(|l| l.joules > 0.0));
    }

    #[test]
    fn render_and_json_cover_every_layer() {
        let t = Target::stm32f446();
        let per_layer = vec![("conv0".to_string(), 10u64)];
        let counters = vec![ctr(3, 4)];
        let p = ExecutionProfile::from_layers(&t, &per_layer, &counters);
        let table = p.render();
        assert!(table.contains("conv0"), "{table}");
        assert!(table.contains("TOTAL"), "{table}");
        let j = p.to_json().to_string_compact();
        assert!(j.contains("\"target\":\"stm32f446\""), "{j}");
        assert!(j.contains("\"per_layer\""), "{j}");
        assert!(j.contains("\"simd\":3"), "{j}");
        assert!(j.contains("\"load\":4"), "{j}");
        assert!(p.latency_ms(&t) > 0.0);
    }

    #[test]
    #[should_panic]
    fn mismatched_arrays_panic() {
        let t = Target::stm32f746();
        ExecutionProfile::from_layers(&t, &[("a".to_string(), 1)], &[]);
    }
}
