//! Bench harness (offline substitute for `criterion`).
//!
//! Every `cargo bench` target uses [`Bench`] for wall-clock measurements
//! (warmup, N timed iterations, mean/median/stddev) and the table printers
//! to emit the paper's rows. MCU latency numbers come from the simulator's
//! cycle counts, not wall clock — the harness prints both where relevant.

use std::time::Instant;

/// Result of timing one closure.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Timing {
    /// Human-readable mean with adaptive units.
    pub fn mean_human(&self) -> String {
        human_ns(self.mean_ns)
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice
/// (`q` in `[0, 1]`; 0 for an empty slice). Shared by the timing stats
/// and the serving layer's virtual-time latency summaries.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Format nanoseconds with adaptive units.
pub fn human_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Wall-clock bench runner.
pub struct Bench {
    warmup_iters: usize,
    iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Self {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Quick configuration for cheap closures.
    pub fn fast() -> Self {
        Bench::new(10, 50)
    }

    /// Time `f`, returning iteration statistics. The closure's return value
    /// is black-boxed to prevent the optimizer from deleting the work.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Timing {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        Timing {
            name: name.to_string(),
            iters: self.iters,
            mean_ns: mean,
            median_ns: samples[samples.len() / 2],
            stddev_ns: var.sqrt(),
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
        }
    }
}

/// Fixed-width table printer used by the bench binaries to reproduce the
/// paper's tables/figures as aligned text.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Render with padded columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_statistics_sane() {
        let b = Bench::new(1, 5);
        let t = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(t.mean_ns > 0.0);
        assert!(t.min_ns <= t.median_ns && t.median_ns <= t.max_ns);
        assert_eq!(t.iters, 5);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&v, 0.5), 2.5);
        assert!((percentile(&v, 0.95) - 3.85).abs() < 1e-9);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn human_units() {
        assert!(human_ns(12.0).ends_with("ns"));
        assert!(human_ns(12_000.0).ends_with("µs"));
        assert!(human_ns(12_000_000.0).ends_with("ms"));
        assert!(human_ns(2e9).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bbbb"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["333", "4"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a  "));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
