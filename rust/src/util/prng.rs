//! Seeded xorshift64* PRNG.
//!
//! Deterministic across runs and platforms — the property-test runner,
//! the synthetic datasets and the workload generators all derive from this,
//! so every experiment in EXPERIMENTS.md is exactly reproducible.

/// xorshift64* generator (Vigna 2016). Not cryptographic; plenty for
/// synthetic data and property testing.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// has a fixed point at zero).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. `n` must be nonzero.
    pub fn below(&mut self, n: u64) -> u64 {
        // Multiply-shift bound; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal variate (Box–Muller; one of the pair is discarded
    /// for simplicity).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-7);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (for parallel deterministic streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut r = Rng::new(5);
        let mut c1 = r.fork(1);
        let mut c2 = r.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
