//! Tiny declarative CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Anything starting with `--` is an option; an
    /// option is boolean if followed by another option or nothing.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let raw: Vec<String> = raw.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| v == "true" || v == "1" || v == "yes")
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_key_value_styles() {
        // Subcommand-first convention: positionals precede options, so a
        // trailing bare option is unambiguously boolean.
        let a = args(&["run", "--steps", "100", "--lr=0.05", "--verbose"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.f32_or("lr", 0.0), 0.05);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("backbone", "vgg_tiny"), "vgg_tiny");
        assert!(!a.bool_or("verbose", false));
    }

    #[test]
    fn boolean_flag_before_option() {
        let a = args(&["--fast", "--steps", "3"]);
        assert!(a.bool_or("fast", false));
        assert_eq!(a.usize_or("steps", 0), 3);
    }
}
