//! Minimal JSON parser and serializer.
//!
//! Used to read `artifacts/manifest.json` (written by the Python AOT path)
//! and to write experiment reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP; numbers are stored as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// JSON parse error with byte position context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        self.pos = (start + len).min(self.bytes.len());
                        out.push_str(
                            std::str::from_utf8(&self.bytes[start..self.pos])
                                .map_err(|_| self.err("bad utf8"))?,
                        );
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nb""#).unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"m":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_bad_syntax() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""\u0041\u00e9""#).unwrap(),
            Json::Str("A\u{e9}".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }
}
