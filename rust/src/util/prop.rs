//! Property-based test runner (offline substitute for `proptest`).
//!
//! Runs a property over many PRNG-derived cases; on failure it reports the
//! seed of the failing case so it can be replayed deterministically:
//!
//! ```
//! use mcu_mixq::util::prop::check;
//! check("addition commutes", 256, |rng| {
//!     let a = rng.next_u32() as u64;
//!     let b = rng.next_u32() as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::prng::Rng;

/// Run `property` over `cases` independent deterministic cases. Panics with
/// the failing case index and seed on the first violation.
pub fn check<F: FnMut(&mut Rng) + std::panic::UnwindSafe + Copy>(
    name: &str,
    cases: u64,
    property: F,
) {
    for case in 0..cases {
        let seed = 0xC0FF_EE00u64 ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(move || {
            let mut rng = Rng::new(seed);
            let mut p = property;
            p(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Like [`check`] but with an explicit base seed, for replaying failures.
pub fn check_seeded<F: FnMut(&mut Rng)>(seed: u64, mut property: F) {
    let mut rng = Rng::new(seed);
    property(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("trivial", 64, |rng| {
            let v = rng.below(10);
            assert!(v < 10);
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_reports_seed() {
        check("must fail", 16, |rng| {
            assert!(rng.below(2) == 0, "hit a one");
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut v1 = 0;
        check_seeded(0xdead, |rng| v1 = rng.next_u64());
        let mut v2 = 0;
        check_seeded(0xdead, |rng| v2 = rng.next_u64());
        assert_eq!(v1, v2);
    }
}
