//! Small self-contained utilities.
//!
//! The build environment is fully offline and the usual ecosystem crates
//! (`clap`, `criterion`, `proptest`, `serde_json`, `rand`) are not in the
//! vendored set, so this module provides minimal, well-tested equivalents:
//!
//! * [`prng`]  — seeded xorshift64* PRNG (+ normal variates),
//! * [`json`]  — JSON parser/serializer for the artifact manifest & reports,
//! * [`cli`]   — declarative argument parsing for the launcher,
//! * [`bench`] — a bench harness with warmup/iteration statistics used by
//!   every `cargo bench` target,
//! * [`prop`]  — a property-based test runner (randomized cases with
//!   failure-seed reporting) used across the crate's invariants.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod prop;
