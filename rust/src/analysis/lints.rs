//! Plan-consistency lints: the parts of a [`CompiledModel`] that must
//! agree with each other — codegen plans vs packed kernels, kernel
//! register layouts vs lane configs, quant params vs representable
//! ranges, the arena plan vs the graph's tensor lifetimes.

use std::collections::HashMap;

use crate::engine::CompiledModel;
use crate::ops::slbc::LayerKernel;
use crate::ops::Method;
use crate::quant::weight_limit;
use crate::simd::poly::dot_group_size;

use super::diag::{rules, Diagnostic};

fn is_slbc(method: Method) -> bool {
    matches!(method, Method::Slbc | Method::RpSlbc)
}

/// Run every lint over `cm`, returning the findings.
pub fn lint_model(cm: &CompiledModel) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    // (sx, sk, taps, field) -> layers sharing that lane plan.
    let mut plan_users: HashMap<(u32, u32, u32, u32), Vec<usize>> = HashMap::new();

    for (i, l) in cm.model.layers.iter().enumerate() {
        let kc = &cm.codegen.kernels[i];
        let kernel = cm.kernels.layer(i);

        if is_slbc(cm.method) && kernel.is_none() {
            diags.push(Diagnostic::error(
                rules::MISSING_KERNEL,
                Some(i),
                format!("{} layer has no pre-packed kernel", cm.method.name()),
                "KernelCache::build skipped this layer; the run path would fall back \
                 to on-the-fly packing"
                    .into(),
            ));
        }
        if !is_slbc(cm.method) && kc.lane_plan.is_some() {
            diags.push(Diagnostic::warning(
                rules::DEAD_LANE_PLAN,
                Some(i),
                format!("codegen carries a lane plan but {} never packs", cm.method.name()),
                "drop the plan or switch the method".into(),
            ));
        }

        match kernel {
            Some(LayerKernel::Conv(ck)) => {
                let spec = ck.plan.conv.spec;
                plan_users
                    .entry((spec.sx_bits, spec.sk_bits, spec.k_taps, spec.field))
                    .or_default()
                    .push(i);
                if ck.plan.field != spec.field {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "LanePlan.field {} disagrees with its own spec's field {}",
                            ck.plan.field, spec.field
                        ),
                        "the plan was mutated after planning".into(),
                    ));
                }
                // Codegen prices `cfg.abits[i]`; the kernel packs the
                // width actually flowing in (8-bit at layer 0). Both
                // are intentional today — surface the divergence.
                if let Some(p) = kc.lane_plan {
                    if p.conv.spec != spec {
                        diags.push(Diagnostic::warning(
                            rules::STALE_LANE_PLAN,
                            Some(i),
                            format!(
                                "codegen planned (sx={}, sk={}, field={}) but the packed \
                                 kernel runs (sx={}, sk={}, field={})",
                                p.conv.spec.sx_bits,
                                p.conv.spec.sk_bits,
                                p.conv.spec.field,
                                spec.sx_bits,
                                spec.sk_bits,
                                spec.field
                            ),
                            "perf predictions price the codegen plan; the runtime \
                             executes the kernel's"
                                .into(),
                        ));
                    }
                }
                // Packed tap registers: one carrier per (out-channel,
                // tap, effective in-channel).
                let chan_eff = if ck.depthwise { 1 } else { l.cin };
                let want = l.cout * l.k * chan_eff;
                if ck.vks.len() != want {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "kernel holds {} packed tap registers, layout needs {} \
                             (cout {} x k {} x chan {})",
                            ck.vks.len(),
                            want,
                            l.cout,
                            l.k,
                            chan_eff
                        ),
                        "rebuild the KernelCache".into(),
                    ));
                }
                if ck.off != 1i64 << (ck.wbits - 1) {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "offset {} is not 2^(wbits-1) = {} — the unsigned-tap \
                             correction would be wrong",
                            ck.off,
                            1i64 << (ck.wbits - 1)
                        ),
                        "rebuild the KernelCache".into(),
                    ));
                }
            }
            Some(LayerKernel::Dense(dk)) => {
                let g = dot_group_size(dk.abits as u32, dk.wbits as u32, 63);
                let want_regs = l.cin.div_ceil(g);
                if dk.regs_per_oc != want_regs {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "dense kernel packs {} registers per output channel, the \
                             dot layout needs {} (cin {} / group {})",
                            dk.regs_per_oc, want_regs, l.cin, g
                        ),
                        "rebuild the KernelCache".into(),
                    ));
                }
                if dk.b_regs.len() != l.cout * dk.regs_per_oc {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "dense kernel holds {} packed registers, layout needs {} \
                             (cout {} x {})",
                            dk.b_regs.len(),
                            l.cout * dk.regs_per_oc,
                            l.cout,
                            dk.regs_per_oc
                        ),
                        "rebuild the KernelCache".into(),
                    ));
                }
                if dk.off != 1i64 << (dk.wbits - 1) {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "dense offset {} is not 2^(wbits-1) = {}",
                            dk.off,
                            1i64 << (dk.wbits - 1)
                        ),
                        "rebuild the KernelCache".into(),
                    ));
                }
                // Codegen's conv-style lane plan on a dense layer is a
                // code-size proxy only; the dot packing above is what
                // runs. Expected by construction — no finding.
            }
            None => {}
        }

        // Quant representability. `quantize_weights` clamps into the
        // symmetric range, so any violation means the artifact was
        // mutated or deserialized from a bad image.
        let (qw, _) = &cm.quantized[i];
        if qw.bits != cm.cfg.wbits[i] {
            diags.push(Diagnostic::error(
                rules::WEIGHT_OUT_OF_RANGE,
                Some(i),
                format!(
                    "quantized weights carry {}-bit values, config says {}",
                    qw.bits, cm.cfg.wbits[i]
                ),
                "re-quantize from the BitConfig actually compiled".into(),
            ));
        }
        if !qw.in_range() {
            diags.push(Diagnostic::error(
                rules::WEIGHT_OUT_OF_RANGE,
                Some(i),
                format!(
                    "weight values escape the symmetric {}-bit range [{}, {}]",
                    qw.bits,
                    -weight_limit(qw.bits),
                    weight_limit(qw.bits)
                ),
                "re-quantize; packed kernels assume the symmetric range".into(),
            ));
        }
        if !qw.scale.is_finite() || qw.scale <= 0.0 {
            diags.push(Diagnostic::error(
                rules::SCALE_OUT_OF_RANGE,
                Some(i),
                format!("dequant scale {} is not finite-positive", qw.scale),
                "re-quantize; a degenerate scale collapses every activation".into(),
            ));
        }

        // Documented bitwidth clamping (Method::effective_bits): the
        // kernels silently run at different widths than requested.
        let (we, ae) = cm.method.effective_bits(cm.cfg.wbits[i], cm.cfg.abits[i]);
        if (we, ae) != (cm.cfg.wbits[i], cm.cfg.abits[i]) {
            diags.push(Diagnostic::info(
                rules::UNSUPPORTED_BITS,
                Some(i),
                format!(
                    "{} clamps w{}/a{} to w{we}/a{ae}",
                    cm.method.name(),
                    cm.cfg.wbits[i],
                    cm.cfg.abits[i]
                ),
                "perf and accuracy are priced at the clamped widths".into(),
            ));
        }

    }

    // Dedup note: layers sharing one lane plan is the memoized-planner
    // fast path working as intended; surface it so a future per-layer
    // field search knows which layers are coupled.
    for (key, layers) in &plan_users {
        if layers.len() > 1 {
            let mut sorted = layers.clone();
            sorted.sort_unstable();
            diags.push(Diagnostic::info(
                rules::DUPLICATE_LANE_PLAN,
                Some(sorted[0]),
                format!(
                    "layers {:?} share one lane plan (sx={}, sk={}, k={}, field={})",
                    sorted, key.0, key.1, key.2, key.3
                ),
                "expected: best_plan memoizes per (bits, taps)".into(),
            ));
        }
    }

    // Arena plan structural checks. `MemoryPlan::validate` re-proves
    // no two simultaneously-live tensors overlap.
    if cm.plan.offsets.len() != cm.graph.tensors.len() {
        diags.push(Diagnostic::error(
            rules::ARENA_OVERLAP,
            None,
            format!(
                "arena plan has {} offsets for {} tensors",
                cm.plan.offsets.len(),
                cm.graph.tensors.len()
            ),
            "re-run plan_memory on the compiled graph".into(),
        ));
    } else if let Err(e) = cm.plan.validate(&cm.graph) {
        diags.push(Diagnostic::error(
            rules::ARENA_OVERLAP,
            None,
            e,
            "re-run plan_memory on the compiled graph".into(),
        ));
    }

    // Flash round-trip: the image must decode back to the quantized
    // weights the kernels were packed from.
    if !cm.flash.matches(&cm.quantized) {
        diags.push(Diagnostic::error(
            rules::LAYOUT_MISMATCH,
            None,
            "flash image does not round-trip to the compiled quantized weights".into(),
            "rebuild the FlashImage; a stale image ships wrong weights".into(),
        ));
    }

    // Sort for stable output: severity descending, then layer.
    diags.sort_by(|a, b| b.severity.cmp(&a.severity).then(a.layer.cmp(&b.layer)));
    diags
}
