//! Structured diagnostics for the static analyzer.
//!
//! Every finding the analyzer emits is a [`Diagnostic`]: a severity, a
//! stable machine-readable rule id (`"packing/lane-overflow"`), the layer
//! it anchors to (when layer-scoped), a human message, and a hint that
//! says what to do about it. Rule ids are `&'static str` constants in
//! [`rules`] so tests and the strict compile gate can pin the exact
//! rejection reason instead of matching message prose.

use crate::util::json::Json;

/// How bad a finding is. Ordering is `Info < Warning < Error`, so
/// `max()` over a report yields the worst severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Expected-by-construction observations worth surfacing (plan
    /// dedup, documented bitwidth clamping, the per-report summary).
    Info,
    /// Suspicious but not provably wrong: stale codegen plans, >90%
    /// resource watermarks, unsupported-bitwidth clamping.
    Warning,
    /// A proof of unsoundness or a hard resource violation. Any Error
    /// finding fails `CompiledModel::verify_strict`.
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One analyzer finding. See the module doc for field semantics.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable rule id from [`rules`] — the machine-readable contract.
    pub rule: &'static str,
    /// Layer index the finding anchors to; `None` for model-wide rules.
    pub layer: Option<usize>,
    pub message: String,
    pub hint: String,
}

impl Diagnostic {
    pub fn error(rule: &'static str, layer: Option<usize>, message: String, hint: String) -> Self {
        Diagnostic { severity: Severity::Error, rule, layer, message, hint }
    }

    pub fn warning(rule: &'static str, layer: Option<usize>, message: String, hint: String) -> Self {
        Diagnostic { severity: Severity::Warning, rule, layer, message, hint }
    }

    pub fn info(rule: &'static str, layer: Option<usize>, message: String, hint: String) -> Self {
        Diagnostic { severity: Severity::Info, rule, layer, message, hint }
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("severity".to_string(), Json::Str(self.severity.name().to_string()));
        o.insert("rule".to_string(), Json::Str(self.rule.to_string()));
        o.insert(
            "layer".to_string(),
            match self.layer {
                Some(i) => Json::Num(i as f64),
                None => Json::Null,
            },
        );
        o.insert("message".to_string(), Json::Str(self.message.clone()));
        o.insert("hint".to_string(), Json::Str(self.hint.clone()));
        Json::Obj(o)
    }
}

/// Stable rule ids. Grouped by namespace: `packing/` (lane arithmetic),
/// `resource/` (SRAM/flash fit), `plan/` (artifact self-consistency),
/// `quant/` (parameter representability), `graph/` (cross-layer range
/// flow), `analysis/` (report meta).
pub mod rules {
    /// A packed field's worst-case partial sum exceeds its capacity —
    /// lanes can silently corrupt neighbours. The pinned over-pack rule.
    pub const LANE_OVERFLOW: &str = "packing/lane-overflow";
    /// The kernel taps don't fit the carrier at the chosen field width.
    pub const KERNEL_EXCEEDS_LANE: &str = "packing/kernel-exceeds-lane";
    /// Kernel bitwidths disagree with the layer's quant config / the
    /// graph's input tensor width.
    pub const INPUT_WIDTH_MISMATCH: &str = "packing/input-width-mismatch";
    /// Worst-case per-output accumulation can overflow the i64/u64
    /// accumulator the kernels reduce into.
    pub const ACCUMULATOR_OVERFLOW: &str = "packing/accumulator-overflow";

    /// Arena + scratch peak exceeds the target's SRAM.
    pub const SRAM_EXCEEDED: &str = "resource/sram-exceeded";
    /// SRAM peak above 90% of the target budget.
    pub const SRAM_HIGH_WATERMARK: &str = "resource/sram-high-watermark";
    /// Flash image exceeds the target's flash.
    pub const FLASH_EXCEEDED: &str = "resource/flash-exceeded";
    /// Flash image above 90% of the target budget.
    pub const FLASH_HIGH_WATERMARK: &str = "resource/flash-high-watermark";

    /// Codegen's lane plan disagrees with the packed kernel actually
    /// executed (e.g. layer 0 packs 8-bit inputs, codegen priced cfg
    /// bits) — the perf model and the runtime diverge.
    pub const STALE_LANE_PLAN: &str = "plan/stale-lane-plan";
    /// Several layers resolved to the same lane plan (dedup note).
    pub const DUPLICATE_LANE_PLAN: &str = "plan/duplicate-lane-plan";
    /// A lane plan exists that no runtime path can execute.
    pub const DEAD_LANE_PLAN: &str = "plan/dead-lane-plan";
    /// An SLBC-family layer has no pre-packed kernel.
    pub const MISSING_KERNEL: &str = "plan/missing-kernel";
    /// Packed kernel registers disagree with the lane config's layout
    /// (wrong register count / offsets / duplicated field widths).
    pub const LAYOUT_MISMATCH: &str = "plan/layout-mismatch";
    /// The arena plan double-books live tensors or is malformed.
    pub const ARENA_OVERLAP: &str = "plan/arena-overlap";

    /// Quantized weights outside the symmetric representable range, or
    /// bitwidth disagreeing with the layer config.
    pub const WEIGHT_OUT_OF_RANGE: &str = "quant/weight-out-of-range";
    /// Non-finite or non-positive dequant scale.
    pub const SCALE_OUT_OF_RANGE: &str = "quant/scale-out-of-range";
    /// The method silently clamps the requested bitwidths
    /// (`Method::effective_bits`) — documented behaviour, surfaced.
    pub const UNSUPPORTED_BITS: &str = "quant/unsupported-bits";

    /// A layer's graph input tensor width disagrees with the width the
    /// kernels consume — cross-layer range flow is broken.
    pub const WIDTH_MISMATCH: &str = "graph/width-mismatch";

    /// Per-report roll-up (always emitted, Info).
    pub const SUMMARY: &str = "analysis/summary";
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_info_warning_error() {
        assert!(Severity::Info < Severity::Warning);
        assert!(Severity::Warning < Severity::Error);
        assert_eq!(
            [Severity::Warning, Severity::Error, Severity::Info].iter().max(),
            Some(&Severity::Error)
        );
    }

    #[test]
    fn diagnostic_json_carries_schema_keys() {
        let d = Diagnostic::error(
            rules::LANE_OVERFLOW,
            Some(3),
            "worst-case 450 > capacity 255".into(),
            "widen the field".into(),
        );
        let js = d.to_json().to_string_compact();
        assert!(js.contains("\"rule\":\"packing/lane-overflow\""));
        assert!(js.contains("\"severity\":\"error\""));
        assert!(js.contains("\"layer\":3"));
    }
}
