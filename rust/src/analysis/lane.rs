//! Worst-case interval analysis of packed-lane arithmetic.
//!
//! For every packed kernel in a [`CompiledModel`] this pass computes the
//! exact worst-case value any guard-bit field can take during a packed
//! multiply and compares it against the field's capacity. The bound is
//! exact, not an over-approximation (pinned against brute-force
//! enumeration in `tests/analysis_check.rs`):
//!
//! A field of the product `pack(x) * pack(k)` accumulates one term per
//! aligned (signal, tap) pair. With group size `G` signal elements per
//! carrier and `K` kernel taps, no field can receive more than
//! `min(G, K)` terms, and each term is at most `(2^sx − 1)·(2^sk − 1)`
//! (the SLBC offset trick makes taps unsigned in `[0, 2^sk − 1]` with
//! the maximum attained at `off + raw_max = 2^(sk−1) + 2^(sk−1) − 1`).
//! So the exact bound is
//!
//! ```text
//! worst = min(G, K) · (2^sx − 1) · (2^sk − 1)
//! ```
//!
//! and a plan is lane-safe iff `worst ≤ 2^field − 1`. Note this is
//! *tighter* than the planner's sufficient condition
//! `field ≥ sx + sk + ceil(log2 K)`: when the carrier truncates the
//! group below the tap count (`G < K`), a narrower field can still be
//! safe. The analyzer proves exactly that.

use crate::engine::{layer_in_bits, CompiledModel};
use crate::ops::slbc::LayerKernel;
use crate::simd::poly::{dot_group_size, field_width, PackSpec};
use crate::util::json::Json;

use super::diag::{rules, Diagnostic};

/// Largest value a `field`-bit unsigned field can hold.
pub fn field_capacity(field: u32) -> u128 {
    if field >= 128 {
        u128::MAX
    } else {
        (1u128 << field) - 1
    }
}

/// Exact worst-case value of any guard-bit field in a packed conv
/// multiply: `min(group, k_taps) · (2^sx − 1) · (2^sk − 1)`.
pub fn worst_case_field_sum(sx_bits: u32, sk_bits: u32, k_taps: u32, group: u32) -> u128 {
    let terms = group.min(k_taps) as u128;
    let xmax = (1u128 << sx_bits) - 1;
    let kmax = (1u128 << sk_bits) - 1;
    terms * xmax * kmax
}

/// One audited packing plan — a row of the `check` verb's lane table.
#[derive(Debug, Clone)]
pub struct LaneAudit {
    pub layer: usize,
    pub name: String,
    /// `"conv"`, `"dw-conv"` or `"dense"` (dense uses the dot-product
    /// packing, audited against its own capacity formula).
    pub kind: &'static str,
    pub sx_bits: u32,
    pub sk_bits: u32,
    pub k_taps: u32,
    pub register_bits: u32,
    pub field: u32,
    pub group: u32,
    pub worst: u128,
    pub capacity: u128,
    pub safe: bool,
}

impl LaneAudit {
    /// Unused capacity in bits: how much narrower the field could get
    /// before `worst` no longer fits (0 when tight or overflowing).
    pub fn headroom_bits(&self) -> u32 {
        let need = 128 - self.worst.leading_zeros(); // bits to represent worst
        self.field.saturating_sub(need.max(1))
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("layer".into(), Json::Num(self.layer as f64));
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("kind".into(), Json::Str(self.kind.to_string()));
        o.insert("sx_bits".into(), Json::Num(self.sx_bits as f64));
        o.insert("sk_bits".into(), Json::Num(self.sk_bits as f64));
        o.insert("k_taps".into(), Json::Num(self.k_taps as f64));
        o.insert("register_bits".into(), Json::Num(self.register_bits as f64));
        o.insert("field".into(), Json::Num(self.field as f64));
        o.insert("group".into(), Json::Num(self.group as f64));
        o.insert("worst".into(), Json::Num(self.worst as f64));
        o.insert("capacity".into(), Json::Num(self.capacity as f64));
        o.insert("headroom_bits".into(), Json::Num(self.headroom_bits() as f64));
        o.insert("safe".into(), Json::Bool(self.safe));
        Json::Obj(o)
    }
}

/// Audit one conv `PackSpec` against `field_capacity`, appending any
/// findings to `out`. Factored out so tests can drive hand-built specs.
pub fn audit_conv_spec(spec: &PackSpec, layer: usize, out: &mut Vec<Diagnostic>) -> (u128, u128) {
    let worst = worst_case_field_sum(spec.sx_bits, spec.sk_bits, spec.k_taps, spec.group);
    let cap = field_capacity(spec.field);
    if spec.group == 0
        || (spec.group + spec.k_taps.saturating_sub(1)) * spec.field > spec.register_bits
    {
        out.push(Diagnostic::error(
            rules::KERNEL_EXCEEDS_LANE,
            Some(layer),
            format!(
                "{} taps x {}-bit fields span {} bits but the carrier holds {}",
                spec.k_taps,
                spec.field,
                (spec.group + spec.k_taps.saturating_sub(1)) * spec.field,
                spec.register_bits
            ),
            "shrink the tap count or widen the carrier (LaneCfg::lane_bits)".into(),
        ));
    }
    if worst > cap {
        let need = 128 - worst.leading_zeros();
        out.push(Diagnostic::error(
            rules::LANE_OVERFLOW,
            Some(layer),
            format!(
                "worst-case field sum {} exceeds {}-bit field capacity {} \
                 (min(G={}, K={}) terms x {} x {})",
                worst,
                spec.field,
                cap,
                spec.group,
                spec.k_taps,
                (1u128 << spec.sx_bits) - 1,
                (1u128 << spec.sk_bits) - 1
            ),
            format!(
                "field must be at least {} bits (sx + sk + ceil(log2(min(G, K))) = {})",
                need,
                field_width(spec.sx_bits, spec.sk_bits, spec.k_taps.min(spec.group.max(1)))
            ),
        ));
    }
    (worst, cap)
}

/// Walk every packed kernel plus the graph's width chain; return the
/// per-layer audits and any diagnostics.
pub fn audit_model(cm: &CompiledModel) -> (Vec<LaneAudit>, Vec<Diagnostic>) {
    let mut audits = Vec::new();
    let mut diags = Vec::new();

    for (i, l) in cm.model.layers.iter().enumerate() {
        // Cross-layer range flow: the width the graph says arrives at
        // this layer must be the width the kernels consume. Holds for
        // every method — the quant pipeline re-quantizes activations to
        // `layer_in_bits` between layers.
        let expected_in = layer_in_bits(&cm.cfg, i) as u32;
        if let Some(node) = cm.graph.layer_node(i) {
            let got = cm.graph.tensors[node.input].bits as u32;
            if got != expected_in {
                diags.push(Diagnostic::error(
                    rules::WIDTH_MISMATCH,
                    Some(i),
                    format!(
                        "graph feeds {got}-bit activations into a layer whose kernels \
                         consume {expected_in}-bit inputs"
                    ),
                    "re-run Graph::build from the BitConfig actually compiled".into(),
                ));
            }
        }

        let Some(kernel) = cm.kernels.layer(i) else { continue };
        match kernel {
            LayerKernel::Conv(ck) => {
                let spec = ck.plan.conv.spec;
                if ck.abits as u32 != expected_in || ck.wbits != cm.cfg.wbits[i] {
                    diags.push(Diagnostic::error(
                        rules::INPUT_WIDTH_MISMATCH,
                        Some(i),
                        format!(
                            "packed kernel is a{}/w{} but the layer compiles a{}/w{}",
                            ck.abits, ck.wbits, expected_in, cm.cfg.wbits[i]
                        ),
                        "rebuild the KernelCache for this BitConfig".into(),
                    ));
                }
                if spec.sx_bits != ck.abits as u32
                    || spec.sk_bits != ck.wbits as u32
                    || spec.k_taps != l.k as u32
                {
                    diags.push(Diagnostic::error(
                        rules::LAYOUT_MISMATCH,
                        Some(i),
                        format!(
                            "lane spec (sx={}, sk={}, k={}) disagrees with the kernel \
                             (a{}, w{}, k={})",
                            spec.sx_bits, spec.sk_bits, spec.k_taps, ck.abits, ck.wbits, l.k
                        ),
                        "the plan was built for a different layer shape".into(),
                    ));
                }
                let (worst, cap) = audit_conv_spec(&spec, i, &mut diags);
                // The row accumulator folds k * k * chan_eff windowed
                // products per output pixel in i64 (unsigned domain
                // before the offset correction).
                let chan_eff: u128 = if ck.depthwise { 1 } else { l.cin as u128 };
                let terms = (l.k as u128) * (l.k as u128) * chan_eff;
                let per_term =
                    ((1u128 << spec.sx_bits) - 1) * ((1u128 << spec.sk_bits) - 1);
                if terms * per_term > i64::MAX as u128 {
                    diags.push(Diagnostic::error(
                        rules::ACCUMULATOR_OVERFLOW,
                        Some(i),
                        format!(
                            "{terms} worst-case terms x {per_term} overflows the i64 \
                             output accumulator"
                        ),
                        "tile the channel reduction or lower the bitwidths".into(),
                    ));
                }
                audits.push(LaneAudit {
                    layer: i,
                    name: l.name.clone(),
                    kind: if ck.depthwise { "dw-conv" } else { "conv" },
                    sx_bits: spec.sx_bits,
                    sk_bits: spec.sk_bits,
                    k_taps: spec.k_taps,
                    register_bits: spec.register_bits,
                    field: spec.field,
                    group: spec.group,
                    worst,
                    capacity: cap,
                    safe: worst <= cap,
                });
            }
            LayerKernel::Dense(dk) => {
                // Dense layers use the dot-product packing: ascending
                // fields in A, descending in B, the dot lands in the
                // mid field of each group product.
                let sa = dk.abits as u32;
                let sb = dk.wbits as u32;
                let g = dot_group_size(sa, sb, 63) as u32;
                let field = field_width(sa, sb, g);
                let worst = (g as u128) * ((1u128 << sa) - 1) * ((1u128 << sb) - 1);
                let cap = field_capacity(field);
                if worst > cap {
                    diags.push(Diagnostic::error(
                        rules::LANE_OVERFLOW,
                        Some(i),
                        format!(
                            "dense dot group of {g} worst-case terms sums to {worst}, \
                             over the {field}-bit field capacity {cap}"
                        ),
                        "shrink dot_group_size for these bitwidths".into(),
                    ));
                }
                if g == 0 || (2 * g - 1) * field > 63 {
                    diags.push(Diagnostic::error(
                        rules::KERNEL_EXCEEDS_LANE,
                        Some(i),
                        format!(
                            "dense group product spans {} fields x {field} bits, over \
                             the 63-bit carrier",
                            2 * g.max(1) - 1
                        ),
                        "shrink dot_group_size for these bitwidths".into(),
                    ));
                }
                // The dense core reduces cin terms into a u64 cast to
                // i64 at the end.
                let terms = l.cin as u128;
                let per_term = ((1u128 << sa) - 1) * ((1u128 << sb) - 1);
                if terms * per_term > i64::MAX as u128 {
                    diags.push(Diagnostic::error(
                        rules::ACCUMULATOR_OVERFLOW,
                        Some(i),
                        format!(
                            "cin={terms} worst-case dot terms x {per_term} overflows \
                             the i64 dense accumulator"
                        ),
                        "split the input reduction".into(),
                    ));
                }
                if dk.abits as u32 != expected_in || dk.wbits != cm.cfg.wbits[i] {
                    diags.push(Diagnostic::error(
                        rules::INPUT_WIDTH_MISMATCH,
                        Some(i),
                        format!(
                            "dense kernel is a{}/w{} but the layer compiles a{}/w{}",
                            dk.abits, dk.wbits, expected_in, cm.cfg.wbits[i]
                        ),
                        "rebuild the KernelCache for this BitConfig".into(),
                    ));
                }
                audits.push(LaneAudit {
                    layer: i,
                    name: l.name.clone(),
                    kind: "dense",
                    sx_bits: sa,
                    sk_bits: sb,
                    k_taps: 1,
                    register_bits: 63,
                    field,
                    group: g,
                    worst,
                    capacity: cap,
                    safe: worst <= cap,
                });
            }
        }
    }

    (audits, diags)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worst_case_uses_min_of_group_and_taps() {
        // G=2 < K=5: only 2 terms can ever align into one field.
        assert_eq!(worst_case_field_sum(4, 4, 5, 2), 2 * 15 * 15);
        // G=8 > K=3: capped by the tap count.
        assert_eq!(worst_case_field_sum(4, 4, 3, 8), 3 * 15 * 15);
    }

    #[test]
    fn planner_chosen_specs_are_always_safe() {
        // Every spec PackSpec::new produces carries the guard-bit
        // minimum field, which dominates the exact bound.
        for sx in 1..=8u32 {
            for sk in 1..=8u32 {
                for k in 1..=8u32 {
                    for rb in [16, 32, 63, 64] {
                        if let Some(s) = PackSpec::new(sx, sk, k, rb) {
                            let worst =
                                worst_case_field_sum(s.sx_bits, s.sk_bits, s.k_taps, s.group);
                            assert!(
                                worst <= field_capacity(s.field),
                                "spec {s:?} would overflow: worst={worst}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn narrow_field_flags_lane_overflow() {
        let mut s = PackSpec::new(4, 4, 3, 64).unwrap();
        s.field = 4; // capacity 15 < one worst-case term (225)
        let mut out = Vec::new();
        let (worst, cap) = audit_conv_spec(&s, 0, &mut out);
        assert!(worst > cap);
        assert!(out.iter().any(|d| d.rule == rules::LANE_OVERFLOW));
    }

    #[test]
    fn sub_minimum_field_can_still_be_safe_when_group_truncates() {
        // sx=sk=4, K=5 needs field >= 11 by the sufficient condition,
        // but a 64-bit carrier at field 10 only fits G=2 < K groups:
        // worst = 2*15*15 = 450 <= 1023. The exact analysis accepts it.
        // (PackSpec::with_field refuses sub-minimum fields, so build
        // the spec literally — 64/10 = 6 fields, group = 6 - 4 = 2.)
        let s = PackSpec {
            sx_bits: 4,
            sk_bits: 4,
            k_taps: 5,
            field: 10,
            group: 2,
            register_bits: 64,
        };
        assert!(s.group < s.k_taps);
        let mut out = Vec::new();
        let (worst, cap) = audit_conv_spec(&s, 0, &mut out);
        assert!(worst <= cap, "worst={worst} cap={cap}");
        assert!(out.is_empty(), "{out:?}");
    }
}
