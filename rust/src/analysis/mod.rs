//! `mixq-check` — static packing-safety & resource analysis of compiled
//! models. No inference is executed: every verdict is proved from the
//! artifact alone.
//!
//! # Why a static pass
//!
//! The whole SLBC premise is packing several sub-byte operands into one
//! SIMD register and multiplying once. That is only sound if every
//! guard-bit field provably contains its worst-case partial sum; the
//! planner encodes that arithmetic when *choosing* a plan, but nothing
//! audited a whole [`CompiledModel`] end to end — kernels can be
//! rebuilt, mutated, or deserialized from a stale image after planning.
//! This module is that auditor, and doubles as the legality oracle for
//! the mixed-precision NAS search (ROADMAP item 1): a candidate
//! `BitConfig` is feasible iff `analyze` reports no Error.
//!
//! # The guard-bit math
//!
//! Pack an `sx`-bit signal `x` and an `sk`-bit kernel `k` at field
//! stride `S`:
//!
//! ```text
//! R1 = Σ_i x[i]·2^(i·S),   R2 = Σ_j k[j]·2^(j·S)
//! R1·R2 = Σ_n y[n]·2^(n·S)   with   y = conv_full(x, k)
//! ```
//!
//! Field `n` of the product accumulates `y[n] = Σ_{i+j=n} x[i]·k[j]`.
//! With `G` signal elements and `K` taps, the number of `(i, j)` pairs
//! summing to any fixed `n` is at most `min(G, K)`, and each term is at
//! most `(2^sx − 1)·(2^sk − 1)` — the SLBC offset trick (`k + 2^(sk−1)`)
//! makes taps unsigned with maximum exactly `2^sk − 1`. Hence the exact
//! worst case
//!
//! ```text
//! worst(S-field) = min(G, K) · (2^sx − 1) · (2^sk − 1)
//! ```
//!
//! The planner's *sufficient* condition is the classical derivation:
//! `min(G, K) ≤ K`, so
//!
//! ```text
//! worst ≤ K · (2^sx − 1)(2^sk − 1) < 2^(sx + sk + ceil(log2 K))
//! ```
//!
//! i.e. **field width S ≥ sx_bits + sk_bits + ceil(log2(taps))** never
//! overflows — that is `simd::poly::field_width`, the lower bound
//! `PackSpec::new` builds with and `best_plan` searches up from. The
//! analyzer checks the exact bound instead, so it (a) accepts every
//! planner-chosen spec by construction, (b) proves the *tighter* safety
//! of carrier-truncated specs where `G < K`, and (c) refutes any
//! hand-mutated or corrupted plan whose field undercuts the bound. The
//! bound's exactness (no false "safe", no over-tightness) is pinned
//! against brute-force enumeration in `tests/analysis_check.rs`.
//!
//! # What runs
//!
//! [`analyze`] composes three passes over a [`CompiledModel`]:
//!
//! 1. [`lane`] — per-layer worst-case interval propagation (above),
//!    plus the cross-layer width chain through the graph and i64
//!    accumulator bounds;
//! 2. [`resources`] — SRAM peak (arena high-water mark **plus** kernel
//!    scratch: ring rows, window sums, packed registers, correction,
//!    row accumulator) and flash footprint, layer by layer, against the
//!    compiled-in [`Target`](crate::target::Target) budgets;
//! 3. [`lints`] — plan self-consistency: stale/dead/duplicate lane
//!    plans, kernel register layouts vs lane configs, quant params vs
//!    representable ranges, arena overlap, flash round-trip.
//!
//! Findings are [`Diagnostic`]s with stable rule ids (see
//! [`diag::rules`]); `CompiledModel::verify_strict` turns any Error
//! into a compile rejection, and the serve registry lints each key on
//! first compile.

pub mod diag;
pub mod lane;
pub mod lints;
pub mod resources;

pub use diag::{rules, Diagnostic, Severity};
pub use lane::{field_capacity, worst_case_field_sum, LaneAudit};
pub use resources::{LayerResources, ResourceAudit};

use crate::engine::CompiledModel;
use crate::util::bench::Table;
use crate::util::json::Json;

/// Everything `analyze` proved about one compiled model.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    pub model: String,
    pub method: &'static str,
    pub target: &'static str,
    pub lanes: Vec<LaneAudit>,
    pub resources: ResourceAudit,
    /// All findings, severity-descending. Always contains at least the
    /// `analysis/summary` Info roll-up.
    pub diagnostics: Vec<Diagnostic>,
    /// Number of distinct rule ids the pass evaluated.
    pub rules_checked: usize,
}

impl AnalysisReport {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    /// No Error-severity finding — the strict gate's predicate.
    pub fn is_safe(&self) -> bool {
        self.errors() == 0
    }

    /// Deduped rule ids of the Error findings, first-seen order.
    pub fn error_rules(&self) -> Vec<&'static str> {
        let mut seen = Vec::new();
        for d in &self.diagnostics {
            if d.severity == Severity::Error && !seen.contains(&d.rule) {
                seen.push(d.rule);
            }
        }
        seen
    }

    /// Human-readable tables: lanes, resources, then findings.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "static check: {} / {} on {}\n\n",
            self.model, self.method, self.target
        ));

        if !self.lanes.is_empty() {
            let mut t = Table::new(vec![
                "layer", "kind", "a", "w", "taps", "lane", "field", "G", "worst", "cap",
                "headroom", "verdict",
            ]);
            for a in &self.lanes {
                t.row(vec![
                    format!("{} {}", a.layer, a.name),
                    a.kind.to_string(),
                    a.sx_bits.to_string(),
                    a.sk_bits.to_string(),
                    a.k_taps.to_string(),
                    a.register_bits.to_string(),
                    a.field.to_string(),
                    a.group.to_string(),
                    a.worst.to_string(),
                    a.capacity.to_string(),
                    format!("{}b", a.headroom_bits()),
                    if a.safe { "safe".into() } else { "OVERFLOW".into() },
                ]);
            }
            out.push_str("lane-overflow safety (worst-case interval propagation):\n");
            out.push_str(&t.render());
            out.push('\n');
        }

        let r = &self.resources;
        let mut t = Table::new(vec!["layer", "weights B", "code B", "scratch B", "in B", "out B"]);
        for l in &r.per_layer {
            t.row(vec![
                format!("{} {}", l.layer, l.name),
                l.weight_flash_bytes.to_string(),
                l.code_flash_bytes.to_string(),
                l.scratch_bytes.to_string(),
                l.in_bytes.to_string(),
                l.out_bytes.to_string(),
            ]);
        }
        out.push_str("resource fit (layer by layer):\n");
        out.push_str(&t.render());
        out.push_str(&format!(
            "\nSRAM peak {} B = arena {} + scratch {}  ({:.1}% of {} B on {})\n\
             flash {} B = weights {} + code {}  ({:.1}% of {} B)\n\
             predicted: {} cycles, {:.3} ms\n\n",
            r.sram_peak_bytes,
            r.arena_bytes,
            r.scratch_peak_bytes,
            r.sram_utilization() * 100.0,
            r.sram_budget_bytes,
            self.target,
            r.flash_total_bytes,
            r.flash_weight_bytes,
            r.flash_code_bytes,
            r.flash_utilization() * 100.0,
            r.flash_budget_bytes,
            r.predicted_cycles,
            r.predicted_latency_ms,
        ));

        out.push_str(&format!(
            "findings: {} error(s), {} warning(s)\n",
            self.errors(),
            self.warnings()
        ));
        for d in &self.diagnostics {
            let at = match d.layer {
                Some(i) => format!("layer {i}"),
                None => "model".to_string(),
            };
            out.push_str(&format!(
                "  [{}] {} ({}): {}\n        hint: {}\n",
                d.severity.name(),
                d.rule,
                at,
                d.message,
                d.hint
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("model".into(), Json::Str(self.model.clone()));
        o.insert("method".into(), Json::Str(self.method.to_string()));
        o.insert("target".into(), Json::Str(self.target.to_string()));
        o.insert("safe".into(), Json::Bool(self.is_safe()));
        o.insert("errors".into(), Json::Num(self.errors() as f64));
        o.insert("warnings".into(), Json::Num(self.warnings() as f64));
        o.insert("rules_checked".into(), Json::Num(self.rules_checked as f64));
        // Headline resource figures at top level — the trend artifact's
        // schema contract (`sram_peak_bytes` is grepped in CI).
        o.insert(
            "sram_peak_bytes".into(),
            Json::Num(self.resources.sram_peak_bytes as f64),
        );
        o.insert(
            "flash_total_bytes".into(),
            Json::Num(self.resources.flash_total_bytes as f64),
        );
        o.insert(
            "predicted_cycles".into(),
            Json::Num(self.resources.predicted_cycles as f64),
        );
        o.insert("resources".into(), self.resources.to_json());
        o.insert(
            "lanes".into(),
            Json::Arr(self.lanes.iter().map(|a| a.to_json()).collect()),
        );
        o.insert(
            "diagnostics".into(),
            Json::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
        );
        Json::Obj(o)
    }
}

/// Count of distinct rule ids the three passes evaluate (for the
/// report's `rules_checked`; keep in sync with [`diag::rules`]).
const RULES_EVALUATED: usize = 18;

/// Run the full static verification pass. Pure: no inference, no
/// mutation, deterministic for a given artifact.
pub fn analyze(cm: &CompiledModel) -> AnalysisReport {
    let (lanes, mut diags) = lane::audit_model(cm);
    let (resources, res_diags) = resources::audit_model(cm);
    diags.extend(res_diags);
    diags.extend(lints::lint_model(cm));

    let errors = diags.iter().filter(|d| d.severity == Severity::Error).count();
    let warnings = diags.iter().filter(|d| d.severity == Severity::Warning).count();
    // Always-on roll-up: guarantees every report (and every JSON line
    // in the trend artifact) carries at least one diagnostic.
    diags.push(Diagnostic::info(
        rules::SUMMARY,
        None,
        format!(
            "{} layer(s) audited: {} error(s), {} warning(s) over {} rules",
            cm.model.layers.len(),
            errors,
            warnings,
            RULES_EVALUATED
        ),
        if errors == 0 {
            "model is statically safe to deploy on this target".into()
        } else {
            "fix Error findings before deploying; strict compile rejects them".into()
        },
    ));
    diags.sort_by(|a, b| b.severity.cmp(&a.severity).then(a.layer.cmp(&b.layer)));

    AnalysisReport {
        model: cm.model.name.clone(),
        method: cm.method.name(),
        target: cm.target.name,
        lanes,
        resources,
        diagnostics: diags,
        rules_checked: RULES_EVALUATED,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::CompiledModel;
    use crate::models;
    use crate::ops::Method;
    use crate::quant::BitConfig;
    use crate::target::Target;
    use crate::util::prng::Rng;

    fn compiled(bits: u8, method: Method) -> CompiledModel {
        let model = models::vgg_tiny(10, 16);
        let mut rng = Rng::new(7);
        let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
        let cfg = BitConfig::uniform(model.layers.len(), bits);
        let target = Target::lookup("stm32f746").unwrap();
        CompiledModel::compile_for(&model, &params, &cfg, method, target).unwrap()
    }

    #[test]
    fn clean_artifact_reports_zero_errors() {
        let cm = compiled(4, Method::RpSlbc);
        let rep = analyze(&cm);
        assert!(rep.is_safe(), "unexpected errors: {:?}", rep.error_rules());
        assert!(!rep.lanes.is_empty());
        assert!(rep.lanes.iter().all(|a| a.safe));
    }

    #[test]
    fn summary_diag_always_present() {
        let cm = compiled(8, Method::TinyEngine);
        let rep = analyze(&cm);
        assert!(rep.diagnostics.iter().any(|d| d.rule == rules::SUMMARY));
        let js = rep.to_json().to_string_compact();
        assert!(js.contains("\"rule\""));
        assert!(js.contains("\"severity\""));
        assert!(js.contains("\"sram_peak_bytes\""));
    }

    #[test]
    fn sram_peak_counts_scratch_above_arena() {
        let cm = compiled(4, Method::Slbc);
        let rep = analyze(&cm);
        assert!(rep.resources.scratch_peak_bytes > 0);
        assert_eq!(
            rep.resources.sram_peak_bytes,
            rep.resources.arena_bytes + rep.resources.scratch_peak_bytes
        );
        assert_eq!(rep.resources.arena_bytes, cm.peak_sram());
        assert_eq!(rep.resources.flash_total_bytes, cm.flash_bytes());
    }

    #[test]
    fn render_mentions_every_section() {
        let cm = compiled(4, Method::RpSlbc);
        let txt = analyze(&cm).render();
        assert!(txt.contains("lane-overflow safety"));
        assert!(txt.contains("resource fit"));
        assert!(txt.contains("findings:"));
        assert!(txt.contains("SRAM peak"));
    }
}
