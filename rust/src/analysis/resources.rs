//! Static SRAM / flash / latency accounting per target.
//!
//! SRAM peak = activation arena high-water mark (from [`MemoryPlan`],
//! which the compile gate already checks) **plus** the kernel scratch
//! the runtime actually allocates (`ConvScratch`: ring rows, window
//! sums, packed registers, correction terms, the row accumulator) —
//! the part the single compile-time gate never saw. Scratch buffers
//! grow monotonically and are shared across layers, so the model peak
//! is the per-buffer maximum across layers, summed over buffers.
//!
//! Byte costs use MCU-realistic storage: sub-byte activations are
//! bit-packed in the ring rows, packed registers take
//! `register_bits / 8` bytes, window sums and corrections are i32,
//! the row accumulator is the 64-bit carrier.

use crate::engine::CompiledModel;
use crate::ops::common::pad_of;
use crate::ops::slbc::LayerKernel;
use crate::perf::predict_model;
use crate::util::json::Json;

use super::diag::{rules, Diagnostic};

/// One layer's demand — a row of the `check` verb's resource table.
#[derive(Debug, Clone)]
pub struct LayerResources {
    pub layer: usize,
    pub name: String,
    pub weight_flash_bytes: usize,
    pub code_flash_bytes: usize,
    /// This layer's total demand on the shared kernel scratch.
    pub scratch_bytes: usize,
    pub in_bytes: usize,
    pub out_bytes: usize,
}

impl LayerResources {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("layer".into(), Json::Num(self.layer as f64));
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("weight_flash_bytes".into(), Json::Num(self.weight_flash_bytes as f64));
        o.insert("code_flash_bytes".into(), Json::Num(self.code_flash_bytes as f64));
        o.insert("scratch_bytes".into(), Json::Num(self.scratch_bytes as f64));
        o.insert("in_bytes".into(), Json::Num(self.in_bytes as f64));
        o.insert("out_bytes".into(), Json::Num(self.out_bytes as f64));
        Json::Obj(o)
    }
}

/// Model-wide totals plus the per-layer breakdown.
#[derive(Debug, Clone)]
pub struct ResourceAudit {
    pub per_layer: Vec<LayerResources>,
    /// Activation arena high-water mark (`MemoryPlan::peak_bytes`).
    pub arena_bytes: usize,
    /// Kernel scratch high-water mark (component-wise max over layers).
    pub scratch_peak_bytes: usize,
    /// `arena_bytes + scratch_peak_bytes` — what must fit in SRAM.
    pub sram_peak_bytes: usize,
    pub flash_weight_bytes: usize,
    pub flash_code_bytes: usize,
    pub flash_total_bytes: usize,
    pub sram_budget_bytes: usize,
    pub flash_budget_bytes: usize,
    pub predicted_cycles: u64,
    pub predicted_latency_ms: f64,
}

impl ResourceAudit {
    pub fn sram_utilization(&self) -> f64 {
        if self.sram_budget_bytes == 0 {
            return f64::INFINITY;
        }
        self.sram_peak_bytes as f64 / self.sram_budget_bytes as f64
    }

    pub fn flash_utilization(&self) -> f64 {
        if self.flash_budget_bytes == 0 {
            return f64::INFINITY;
        }
        self.flash_total_bytes as f64 / self.flash_budget_bytes as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("arena_bytes".into(), Json::Num(self.arena_bytes as f64));
        o.insert("scratch_peak_bytes".into(), Json::Num(self.scratch_peak_bytes as f64));
        o.insert("sram_peak_bytes".into(), Json::Num(self.sram_peak_bytes as f64));
        o.insert("flash_weight_bytes".into(), Json::Num(self.flash_weight_bytes as f64));
        o.insert("flash_code_bytes".into(), Json::Num(self.flash_code_bytes as f64));
        o.insert("flash_total_bytes".into(), Json::Num(self.flash_total_bytes as f64));
        o.insert("sram_budget_bytes".into(), Json::Num(self.sram_budget_bytes as f64));
        o.insert("flash_budget_bytes".into(), Json::Num(self.flash_budget_bytes as f64));
        o.insert("sram_utilization".into(), Json::Num(self.sram_utilization()));
        o.insert("flash_utilization".into(), Json::Num(self.flash_utilization()));
        o.insert("predicted_cycles".into(), Json::Num(self.predicted_cycles as f64));
        o.insert("predicted_latency_ms".into(), Json::Num(self.predicted_latency_ms));
        o.insert(
            "per_layer".into(),
            Json::Arr(self.per_layer.iter().map(|l| l.to_json()).collect()),
        );
        Json::Obj(o)
    }
}

/// The five shared `ConvScratch` components, in MCU-realistic bytes.
#[derive(Default, Clone, Copy)]
struct ScratchModel {
    rows: usize,
    wsums: usize,
    packs: usize,
    corr: usize,
    row_acc: usize,
}

impl ScratchModel {
    fn max(self, o: ScratchModel) -> ScratchModel {
        ScratchModel {
            rows: self.rows.max(o.rows),
            wsums: self.wsums.max(o.wsums),
            packs: self.packs.max(o.packs),
            corr: self.corr.max(o.corr),
            row_acc: self.row_acc.max(o.row_acc),
        }
    }

    fn total(self) -> usize {
        self.rows + self.wsums + self.packs + self.corr + self.row_acc
    }
}

/// Audit `cm` against its compiled-in target.
pub fn audit_model(cm: &CompiledModel) -> (ResourceAudit, Vec<Diagnostic>) {
    let mut diags = Vec::new();
    let mut per_layer = Vec::new();
    let mut scratch_peak = ScratchModel::default();
    let mut worst_scratch_layer = (0usize, 0usize); // (layer, bytes)

    for (i, l) in cm.model.layers.iter().enumerate() {
        let (in_bytes, out_bytes) = match cm.graph.layer_node(i) {
            Some(node) => (
                cm.graph.tensors[node.input].bytes(),
                cm.graph.tensors[node.output].bytes(),
            ),
            None => (0, 0),
        };

        let scratch = match cm.kernels.layer(i) {
            Some(LayerKernel::Conv(ck)) => {
                let pad = pad_of(l.k) as usize;
                let padded_w = l.in_w + 2 * pad;
                let chan = if ck.depthwise { l.cout } else { l.cin };
                let slots = l.k * chan;
                // A verifier must not panic on malformed input: a
                // use_rp kernel without a reordered plan is itself a
                // finding, priced at the plain-conv register count.
                let regs_per_row = match (ck.use_rp, ck.plan.reordered) {
                    (true, Some(rp)) => rp.n_chunks(padded_w),
                    (true, None) => {
                        diags.push(Diagnostic::error(
                            rules::LAYOUT_MISMATCH,
                            Some(i),
                            "kernel claims RP reordering but carries no reordered plan"
                                .into(),
                            "rebuild the KernelCache".into(),
                        ));
                        ck.plan.conv.n_regs(padded_w)
                    }
                    (false, _) => ck.plan.conv.n_regs(padded_w),
                };
                let reg_bytes = (ck.plan.conv.spec.register_bits as usize).div_ceil(8);
                let abits = ck.abits as usize;
                ScratchModel {
                    rows: slots * (padded_w * abits).div_ceil(8),
                    wsums: slots * l.out_w * 4,
                    packs: slots * regs_per_row * reg_bytes,
                    corr: l.out_w * 4,
                    row_acc: (padded_w + l.k - 1) * 8,
                }
            }
            Some(LayerKernel::Dense(dk)) => ScratchModel {
                // Dense staging: the bit-packed input vector plus the
                // pre-packed A registers (one 64-bit carrier each).
                rows: (l.cin * dk.abits as usize).div_ceil(8),
                packs: dk.regs_per_oc * 8,
                ..Default::default()
            },
            // Library-kernel methods (naive / simd / cmix-nn / ...)
            // operate out of the arena tensors directly.
            None => ScratchModel::default(),
        };
        let scratch_bytes = scratch.total();
        if scratch_bytes > worst_scratch_layer.1 {
            worst_scratch_layer = (i, scratch_bytes);
        }
        scratch_peak = scratch_peak.max(scratch);

        per_layer.push(LayerResources {
            layer: i,
            name: l.name.clone(),
            weight_flash_bytes: l.weight_bytes_at(cm.cfg.wbits[i]),
            code_flash_bytes: cm.codegen.kernels[i].code_bytes,
            scratch_bytes,
            in_bytes,
            out_bytes,
        });
    }

    let arena_bytes = cm.plan.peak_bytes;
    let scratch_peak_bytes = scratch_peak.total();
    let sram_peak_bytes = arena_bytes + scratch_peak_bytes;
    let flash_total_bytes = cm.flash.total_bytes();
    let cost = predict_model(&cm.model, cm.method, &cm.cfg);
    let predicted_cycles = cost.cycles_on(&cm.target);

    let audit = ResourceAudit {
        per_layer,
        arena_bytes,
        scratch_peak_bytes,
        sram_peak_bytes,
        flash_weight_bytes: cm.flash.weight_bytes(),
        flash_code_bytes: cm.flash.code_bytes,
        flash_total_bytes,
        sram_budget_bytes: cm.target.sram_bytes,
        flash_budget_bytes: cm.target.flash_bytes,
        predicted_cycles,
        predicted_latency_ms: cost.latency_ms_on(&cm.target),
    };

    if audit.sram_peak_bytes > audit.sram_budget_bytes {
        diags.push(Diagnostic::error(
            rules::SRAM_EXCEEDED,
            None,
            format!(
                "SRAM peak {} B (arena {} + scratch {}) exceeds {}'s {} B",
                audit.sram_peak_bytes,
                audit.arena_bytes,
                audit.scratch_peak_bytes,
                cm.target.name,
                audit.sram_budget_bytes
            ),
            format!(
                "layer {} carries the largest scratch demand ({} B); shrink its \
                 channels or switch to a lifetime-planned method",
                worst_scratch_layer.0, worst_scratch_layer.1
            ),
        ));
    } else if audit.sram_utilization() > 0.9 {
        diags.push(Diagnostic::warning(
            rules::SRAM_HIGH_WATERMARK,
            None,
            format!(
                "SRAM peak {} B is {:.0}% of {}'s budget",
                audit.sram_peak_bytes,
                audit.sram_utilization() * 100.0,
                cm.target.name
            ),
            "headroom under 10% leaves no room for the serve runtime's stacks".into(),
        ));
    }

    if audit.flash_total_bytes > audit.flash_budget_bytes {
        diags.push(Diagnostic::error(
            rules::FLASH_EXCEEDED,
            None,
            format!(
                "flash image {} B (weights {} + code {}) exceeds {}'s {} B",
                audit.flash_total_bytes,
                audit.flash_weight_bytes,
                audit.flash_code_bytes,
                cm.target.name,
                audit.flash_budget_bytes
            ),
            "lower the weight bitwidths or drop kernel specialization".into(),
        ));
    } else if audit.flash_utilization() > 0.9 {
        diags.push(Diagnostic::warning(
            rules::FLASH_HIGH_WATERMARK,
            None,
            format!(
                "flash image {} B is {:.0}% of {}'s budget",
                audit.flash_total_bytes,
                audit.flash_utilization() * 100.0,
                cm.target.name
            ),
            "the next OTA delta may not fit".into(),
        ));
    }

    (audit, diags)
}
