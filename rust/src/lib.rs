//! # MCU-MixQ
//!
//! A HW/SW co-optimized mixed-precision neural network (MPNN) design
//! framework for microcontrollers, reproducing:
//!
//! > Gong, Liu, Cheng, Li, Li. *MCU-MixQ: A HW/SW Co-optimized
//! > Mixed-precision Neural Network Design Framework for MCUs.* (2024)
//!
//! The framework has three pillars, mirrored by the module tree:
//!
//! 1. **SLBC** — SIMD-based Low-Bitwidth Convolution: multiple sub-byte
//!    operands are packed *within* each SIMD lane (polynomial-multiplication
//!    packing), so a single SIMD `MUL` performs many low-bitwidth MACs
//!    ([`simd`], [`ops`]). The reordered-packing variant (RP-SLBC) merges
//!    segmentation work across registers, and adaptive lane sizing picks the
//!    best lane configuration per convolution at compile time.
//! 2. **Hardware-aware quantization search** — a differentiable NAS
//!    (EdMIPS-style supernet, built in JAX at Layer 2) whose complexity loss
//!    is driven by the *packing-aware* performance model of Eq. 12
//!    ([`perf`], [`nas`], [`coordinator`]).
//! 3. **Deployment substrate** — a TinyEngine-like inference engine
//!    ([`engine`]) running on a cycle-approximate Cortex-M7 (ARMv7E-M DSP)
//!    simulator ([`mcu`]), with model zoo ([`models`]), quantization
//!    machinery ([`quant`]) and synthetic datasets ([`datasets`]).
//! 4. **Serving layer** — the production-scale pillar on top of the
//!    engine's compile/run split ([`engine::CompiledModel`]): a
//!    multi-tenant model registry with a compile-once LRU artifact cache
//!    and cross-tenant weight sharing ([`serve::registry`]), a
//!    heterogeneous pool of simulated M7/M4-class devices
//!    ([`serve::fleet`]) under pluggable SLO-aware scheduling policies
//!    ([`serve::sched`]), dynamic batching with admission control
//!    ([`serve::batcher`]) and virtual-time latency/throughput/deadline
//!    reporting ([`serve::stats`]) — driven by the `serve` /
//!    `bench-serve` CLI subcommands over deterministic synthetic or
//!    file-recorded traces ([`serve::trace`]).
//! 5. **Target layer** — the unified device description ([`target`]):
//!    a named-target registry (`stm32f746`/`m7`, `stm32f446`/`m4`)
//!    owning clocks, memory maps, cycle tables and [`target::EnergyModel`]s,
//!    consumed by the engine (compile-for-target), the Eq. 12 predictor
//!    (cycles *and* joules) and the serving fleet (energy-aware
//!    placement).
//! 6. **Observability layer** — virtual-time tracing and profiling
//!    ([`obs`]): typed request-lifecycle events behind a zero-cost
//!    [`obs::Recorder`], a metrics registry with virtual-time series,
//!    a Perfetto/Chrome trace exporter (`serve --events-out`), and a
//!    per-layer cycles × joules execution profiler (the `profile` CLI
//!    verb).
//! 7. **Static analysis layer** — `mixq-check` ([`analysis`]): a
//!    no-execution verification pass over compiled artifacts proving
//!    lane-overflow safety (worst-case guard-bit interval propagation),
//!    SRAM/flash resource fit per target, and plan self-consistency,
//!    surfaced through the `check` CLI verb, the strict compile gate
//!    (`CompiledModel::verify_strict`) and per-key lints in the serve
//!    registry.
//!
//! ## Three-layer architecture
//!
//! * **Layer 1 (Pallas, build time)** — `python/compile/kernels/slbc.py`
//!   implements the packed-arithmetic convolution as a Pallas kernel,
//!   checked against the pure-`jnp` oracle `ref.py`.
//! * **Layer 2 (JAX, build time)** — `python/compile/model.py` builds the
//!   mixed-precision CNN and the NAS supernet; `aot.py` lowers train / eval
//!   steps to HLO text in `artifacts/`.
//! * **Layer 3 (this crate, run time)** — loads the HLO artifacts through
//!   PJRT ([`runtime`]) and drives quantization search, QAT and MCU
//!   deployment without any Python on the hot path.

pub mod analysis;
pub mod coordinator;
pub mod datasets;
pub mod engine;
pub mod mcu;
pub mod models;
pub mod nas;
pub mod obs;
pub mod ops;
pub mod perf;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod target;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

// Device constants live in the [`target`] registry (the single source of
// truth for clocks/SRAM/flash); these are compatibility re-exports.
pub use target::{
    STM32F446_CLOCK_HZ, STM32F446_FLASH_BYTES, STM32F446_SRAM_BYTES, STM32F746_CLOCK_HZ,
    STM32F746_FLASH_BYTES, STM32F746_SRAM_BYTES,
};

/// Convert a cycle count on the simulated Cortex-M7 into milliseconds at the
/// paper's 216 MHz clock. This is also the conversion for the serving
/// layer's virtual timeline, which is denominated in 216 MHz reference
/// cycles regardless of each device's own clock.
pub fn cycles_to_ms(cycles: u64) -> f64 {
    cycles as f64 / STM32F746_CLOCK_HZ as f64 * 1e3
}
