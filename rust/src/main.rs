//! `mcu-mixq` — the MCU-MixQ leader binary.
//!
//! Subcommands:
//!
//! * `info`                         — artifacts, backbones, calibration
//! * `search    --backbone B ...`   — hardware-aware quantization search
//! * `qat       --backbone B ...`   — QAT at a fixed bit configuration
//! * `pipeline  --backbone B ...`   — full search→QAT→deploy→compare run
//! * `deploy    --backbone B ...`   — deploy + simulate one method
//! * `check     --backbone B ...`   — static packing-safety & resource analysis
//! * `profile   --backbone B ...`   — per-layer cycle/energy execution profile
//! * `serve     --mix M ...`        — replay a request trace on an MCU fleet
//! * `bench-serve`                  — fixed-protocol serving benchmark (JSON)
//! * `slbc-demo`                    — Layer-1 Pallas kernel vs Rust packing
//! * `calibrate`                    — fit & report the Eq. 12 coefficients
//!
//! The supernet search/QAT/pipeline commands run from the AOT artifacts
//! in `--artifacts DIR` (default `artifacts/`); Python is never invoked.
//! `search --native`, `deploy`, `check`, `profile`, `serve` and
//! `bench-serve` need neither artifacts nor PJRT: they fall back to zoo
//! backbones with seeded synthetic parameters.

use mcu_mixq::coordinator::qat::QatCfg;
use mcu_mixq::coordinator::{self, PipelineCfg, QatRunner, SearchCfg, SupernetSearch};
use mcu_mixq::engine;
use mcu_mixq::mcu::CycleModel;
use mcu_mixq::nas::CostProxy;
use mcu_mixq::obs::{ExecutionProfile, MetricsRegistry, RingRecorder};
use mcu_mixq::ops::Method;
use mcu_mixq::perf::{calibrate_alpha_beta, PerfModel};
use mcu_mixq::quant::BitConfig;
use mcu_mixq::runtime::{lit, ArtifactStore, Runtime};
use mcu_mixq::serve::{
    self, AdmissionKind, DeviceCfg, SchedulerKind, ServeCfg, ServeReport, TraceCfg, Workload,
};
use mcu_mixq::target::Target;
use mcu_mixq::util::bench::Table;
use mcu_mixq::util::cli::Args;
use mcu_mixq::Result;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    let cmd = args.positional().first().cloned().unwrap_or_default();
    match cmd.as_str() {
        "info" => cmd_info(args),
        "search" => cmd_search(args),
        "qat" => cmd_qat(args),
        "pipeline" => cmd_pipeline(args),
        "deploy" => cmd_deploy(args),
        "check" => cmd_check(args),
        "profile" => cmd_profile(args),
        "serve" => cmd_serve(args),
        "bench-serve" => cmd_bench_serve(args),
        "bench-conv" => cmd_bench_conv(args),
        "slbc-demo" => cmd_slbc_demo(args),
        "calibrate" => cmd_calibrate(args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown command `{other}`")
        }
    }
}

fn print_help() {
    println!(
        "mcu-mixq — HW/SW co-optimized mixed-precision NN framework for MCUs\n\n\
         USAGE: mcu-mixq <COMMAND> [--artifacts DIR] [options]\n\n\
         COMMANDS:\n\
         \x20 info                          show artifacts / backbones / calibration\n\
         \x20 search   --backbone B         run the quantization explorer\n\
         \x20          [--native] (Pareto co-design search, see SEARCH below)\n\
         \x20          [--steps N] [--lam F] [--proxy simd|edmips]\n\
         \x20 qat      --backbone B         QAT at fixed bits\n\
         \x20          [--steps N] [--wbits 4,4,..] [--abits 4,4,..]\n\
         \x20 pipeline --backbone B         full search→QAT→deploy→compare\n\
         \x20          [--target stm32f746] [--config-file CFG.json]\n\
         \x20 deploy   --backbone B         deploy one method\n\
         \x20          [--method rp-slbc] [--bits 4] [--config-file CFG.json]\n\
         \x20          [--target stm32f746]\n\
         \x20 check    --backbone B         static packing-safety & resource\n\
         \x20                               analysis of one compiled model (no\n\
         \x20                               inference executed)\n\
         \x20          [--method rp-slbc] [--bits 4] [--target stm32f746]\n\
         \x20          [--json] [--out check.json] [--strict]\n\
         \x20 profile  --backbone B         per-layer execution profile: cycles,\n\
         \x20                               joules and instruction mix per layer,\n\
         \x20                               totals asserted bit-identical to deploy\n\
         \x20          [--method rp-slbc] [--bits 4] [--target stm32f746]\n\
         \x20          [--out profile.json]\n\
         \x20 serve                         replay a request trace on an MCU fleet\n\
         \x20          [--mix backbone:method:bits[:weight],...]\n\
         \x20           (bits also takes cfg@FILE, a saved searched config)\n\
         \x20          [--fleet m7:4,m4:4] [--sched rr|least|slo|energy]\n\
         \x20          [--admission fifo|class] [--preempt] [--steal]\n\
         \x20          [--requests N] [--devices N] [--mean-gap-ms F]\n\
         \x20          [--skew F] [--slo-mix I,S,B] [--burst P,S]\n\
         \x20          [--trace-file IN.json|IN.jsonl] (JSON-lines\n\
         \x20           traces stream one request at a time)\n\
         \x20          [--dump-trace OUT.json]\n\
         \x20          [--batch N] [--wait-ms F] [--queue N] [--depth N]\n\
         \x20          [--cache N] [--seed S] [--json]\n\
         \x20          [--churn RATE] [--no-readmit]\n\
         \x20          [--legacy-loop] (pre-event-loop replay core:\n\
         \x20           linear scans + per-image inference; the\n\
         \x20           equivalence oracle and benchmark baseline)\n\
         \x20          [--autoscale FLEETSPEC] [--autoscale-budget J]\n\
         \x20          [--events-out EV.json] [--metrics-out M.json]\n\
         \x20          [--metrics-cadence CYCLES]\n\
         \x20 bench-serve                   fixed-protocol serving benchmark:\n\
         \x20                               >=200-request mixed trace, >=4 devices,\n\
         \x20                               prints tables + one JSON summary line\n\
         \x20                               (same fleet/sched/trace flags as serve,\n\
         \x20                               plus [--out FILE] for the JSON line)\n\
         \x20 bench-conv                    conv hot-path benchmark (rolling-row\n\
         \x20                               pipeline vs pre-PR operator):\n\
         \x20                               [--smoke] [--repeats N] [--out FILE]\n\
         \x20 slbc-demo                     run the Layer-1 kernel via PJRT\n\
         \x20 calibrate                     fit Eq. 12 coefficients"
    );
    // Target lines come from the registry itself, so the help can never
    // drift from the constants it documents.
    println!("\nTARGETS (named device registry; `--target`, `--fleet` entries):");
    for t in &mcu_mixq::target::REGISTRY {
        println!(
            "  {:<9} | {:<2}  {:>3} MHz  {:>3} KB SRAM  {:>4} KB flash",
            t.name,
            t.class.name(),
            t.clock_hz / 1_000_000,
            t.sram_bytes / 1024,
            t.flash_bytes / 1024
        );
    }
    println!(
        "\nSEARCH (`search --native`; no PJRT or artifacts needed):\n\
         \x20 search --native               native mixed-precision co-design\n\
         \x20                               search: DP over the layer graph\n\
         \x20                               (MPIC-style MACs/cycle LUT derived\n\
         \x20                               from the target CycleModel) + a\n\
         \x20                               seeded evolutionary loop keeping a\n\
         \x20                               Pareto archive over cycles x joules\n\
         \x20                               x SRAM peak x accuracy proxy (MAC-\n\
         \x20                               weighted SQNR). Candidates are\n\
         \x20                               pruned through analysis::analyze —\n\
         \x20                               lane-overflow/SRAM/flash-infeasible\n\
         \x20                               configs are never scored\n\
         \x20        [--backbone B] [--method rp-slbc] [--seed S]\n\
         \x20        [--targets stm32f746,stm32f446] [--generations N]\n\
         \x20        [--population N] [--out search_pareto.json]\n\
         \x20        [--save-config CFG.json] (best-cycles config, reusable)\n\
         Saved configs are first-class artifacts: deploy/pipeline take them\n\
         via --config-file, serve via a `backbone:method:cfg@CFG.json` mix\n\
         entry (each searched config gets its own registry ModelKey)."
    );
    println!(
        "\nSCHEDULERS (`--sched`): rr (round-robin), least (least-loaded),\n\
         \x20 slo (deadline-miss-minimizing), energy (minimize predicted\n\
         \x20 joules subject to deadlines — deadline-free work routes to\n\
         \x20 the most energy-efficient device class)"
    );
    println!(
        "\nTRACING & PROFILING:\n\
         \x20 serve --events-out EV.json    write the request lifecycle trace\n\
         \x20                               (Perfetto/Chrome trace-event JSON:\n\
         \x20                               load in ui.perfetto.dev or\n\
         \x20                               chrome://tracing)\n\
         \x20 serve --metrics-out M.json    write sampled time series (queue\n\
         \x20                               depth, in-flight batches, per-device\n\
         \x20                               utilization), counters and latency\n\
         \x20                               histograms\n\
         \x20 serve --metrics-cadence N     sampling cadence in virtual cycles\n\
         \x20                               (default 216000 = 1ms at 216 MHz)\n\
         \x20 profile --backbone B          per-layer cycles / joules / Eq. 12\n\
         \x20                               instruction mix for one deployment\n\
         Recording is passive: an attached recorder never changes placement,\n\
         batching, timing or energy results (pinned by serve tests)."
    );
    println!(
        "\nFAULT INJECTION & ELASTICITY:\n\
         \x20 serve --churn RATE            inject a seeded fleet-event stream:\n\
         \x20                               at each arrival, with probability\n\
         \x20                               RATE, one device joins, leaves,\n\
         \x20                               crashes, throttles (DVFS), restores\n\
         \x20                               or drains. Crashed batches lose\n\
         \x20                               their in-flight work; deadline-\n\
         \x20                               carrying members re-enter through\n\
         \x20                               admission, the rest count as lost\n\
         \x20                               (always an SLO miss)\n\
         \x20 serve --no-readmit            naive drop-on-crash baseline: every\n\
         \x20                               crashed member is lost outright\n\
         \x20 serve --autoscale SPEC        reactive standby pool (same syntax\n\
         \x20                               as --fleet, e.g. m7:2): devices join\n\
         \x20                               when the windowed interactive miss\n\
         \x20                               rate runs hot, drain back out when\n\
         \x20                               it cools\n\
         \x20 serve --autoscale-budget J    stop growing once cumulative fleet\n\
         \x20                               energy exceeds J joules\n\
         \x20 --dump-trace / --trace-file   carry the fleet-event stream with\n\
         \x20                               the requests (JSON round-trip;\n\
         \x20                               churn-free files stay byte-\n\
         \x20                               compatible with the legacy format)\n\
         Clocks can also be pinned statically per device: --fleet m4@84mhz:2\n\
         runs two M4s throttled to 84 MHz for the whole replay."
    );
    println!(
        "\nSTATIC CHECKS (`check`; no inference executed):\n\
         \x20 packing/*                     lane-overflow safety: exact worst-case\n\
         \x20                               interval propagation per packed field\n\
         \x20                               (min(G,K)·(2^sx-1)·(2^sk-1) vs the\n\
         \x20                               field capacity), carrier fit, i64\n\
         \x20                               accumulator bounds\n\
         \x20 resource/*                    SRAM peak (arena + kernel scratch) and\n\
         \x20                               flash footprint vs the target budgets,\n\
         \x20                               layer by layer, with 90% watermarks\n\
         \x20 plan/* quant/* graph/*        artifact self-consistency: stale/dead/\n\
         \x20                               duplicate lane plans, register layouts,\n\
         \x20                               weight ranges, arena overlap, the\n\
         \x20                               cross-layer activation width chain\n\
         check --strict exits non-zero on any Error finding (same gate as\n\
         CompiledModel::compile_for_strict); --json emits the machine form\n\
         with rule ids. The serve registry runs the same pass once per\n\
         compiled key (RegistryStats.lint_errors/lint_warnings)."
    );
}

fn store(args: &Args) -> Result<ArtifactStore> {
    ArtifactStore::open(args.str_or("artifacts", "artifacts"))
}

fn backbone_arg(args: &Args) -> String {
    args.str_or("backbone", "vgg_tiny")
}

/// Backbone geometry + flat parameters: artifact-trained when the store
/// has the backbone, otherwise the seeded synthetic parameters the
/// serving path uses — the artifact-free fallback shared by `check`,
/// `profile`, `deploy` and `search --native`.
fn load_model_params(args: &Args) -> Result<(mcu_mixq::models::ModelDesc, Vec<f32>)> {
    match store(args).and_then(|s| {
        let arts = s.backbone(&backbone_arg(args))?;
        let p = arts.load_init_params()?;
        Ok((arts.model.clone(), p))
    }) {
        Ok(mp) => Ok(mp),
        Err(_) => {
            let model = mcu_mixq::models::by_name(&backbone_arg(args))
                .ok_or_else(|| anyhow::anyhow!("unknown backbone `{}`", backbone_arg(args)))?;
            let mut rng = mcu_mixq::util::prng::Rng::new(args.u64_or("seed", 1000));
            let params = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
            Ok((model, params))
        }
    }
}

/// Resolve the layer bit configuration for `deploy`-style commands:
/// `--config-file` (a saved `search --native` artifact, backbone-checked)
/// wins over `--bits`.
fn parse_config(args: &Args, model: &mcu_mixq::models::ModelDesc) -> Result<BitConfig> {
    let n = model.num_layers();
    if let Some(path) = args.get("config-file") {
        let (backbone, cfg) = mcu_mixq::quant::load_config(path)?;
        anyhow::ensure!(
            backbone == model.name,
            "{path} was searched for `{backbone}`, not `{}`",
            model.name
        );
        anyhow::ensure!(
            cfg.num_layers() == n,
            "{path}: config has {} layers, {} has {n}",
            cfg.num_layers(),
            model.name
        );
        return Ok(cfg);
    }
    Ok(BitConfig {
        wbits: parse_bits(&args.str_or("bits", "4"), n)?,
        abits: parse_bits(&args.str_or("bits", "4"), n)?,
    })
}

fn parse_bits(s: &str, n: usize) -> Result<Vec<u8>> {
    if let Ok(b) = s.parse::<u8>() {
        return Ok(vec![b; n]);
    }
    let v: Vec<u8> = s
        .split(',')
        .map(|t| t.trim().parse::<u8>())
        .collect::<std::result::Result<_, _>>()?;
    anyhow::ensure!(v.len() == n, "expected {n} bit entries, got {}", v.len());
    Ok(v)
}

fn cmd_info(args: &Args) -> Result<()> {
    let store = store(args)?;
    let rt = Runtime::cpu()?;
    println!("platform: {} ({} device(s))", rt.platform(), rt.device_count());
    println!("artifacts: {}", store.dir.display());
    println!("options: {:?}  momentum: {}", store.options, store.momentum);
    let mut t = Table::new(vec!["backbone", "layers", "params", "MACs", "train/eval batch"]);
    for name in store.backbone_names() {
        let b = store.backbone(&name)?;
        t.row(vec![
            name.clone(),
            format!("{}", b.model.num_layers()),
            format!("{}", b.model.param_count),
            format!("{}", b.model.total_macs()),
            format!("{}/{}", b.train_batch, b.eval_batch),
        ]);
    }
    t.print();
    let cal = calibrate_alpha_beta(&CycleModel::cortex_m7());
    println!(
        "Eq.12 calibration: alpha={:.3} beta={:.3} (max rel err {:.1}% over {} probes)",
        cal.model.alpha,
        cal.model.beta,
        cal.max_rel_err * 100.0,
        cal.samples
    );
    Ok(())
}

fn cmd_search(args: &Args) -> Result<()> {
    if args.bool_or("native", false) {
        return cmd_search_native(args);
    }
    let store = store(args)?;
    let rt = Runtime::cpu()?;
    let arts = store.backbone(&backbone_arg(args))?;
    let proxy = match args.str_or("proxy", "simd").as_str() {
        "edmips" => CostProxy::EdMipsMacs,
        _ => CostProxy::SimdAware(PerfModel::cortex_m7(), Method::RpSlbc),
    };
    let mut cfg = SearchCfg::default();
    cfg.steps = args.usize_or("steps", cfg.steps);
    cfg.lam = args.f32_or("lam", cfg.lam);
    cfg.lr = args.f32_or("lr", cfg.lr);
    cfg.lr_alpha = args.f32_or("lr-alpha", cfg.lr_alpha);
    cfg.seed = args.u64_or("seed", cfg.seed);

    println!("searching {} with {} proxy ...", arts.model.name, proxy.name());
    let search = SupernetSearch::new(&rt, &arts, proxy, cfg.seed)?;
    let out = search.run(&cfg)?;
    for log in &out.history {
        println!(
            "  step {:>4}  loss {:.4}  ce {:.4}  comp {:.4}  acc {:.3}",
            log.step, log.loss, log.ce, log.comp, log.acc
        );
    }
    println!("selected wbits: {:?}", out.config.wbits);
    println!("selected abits: {:?}", out.config.abits);
    println!(
        "avg bits: w={:.2} a={:.2}  entropy={:.3}",
        out.config.avg_wbits(),
        out.config.avg_abits(),
        out.final_entropy
    );
    Ok(())
}

/// Native Pareto-front co-design search (`search --native`): no PJRT,
/// no artifacts required — DP seeding over the MPIC-style MACs/cycle
/// LUT plus a seeded evolutionary loop, every candidate pruned through
/// the static analyzer before scoring. Emits one Pareto front per
/// `--targets` entry into `--out` and optionally saves the first
/// target's best-cycles configuration as a reusable `--config-file`
/// artifact.
fn cmd_search_native(args: &Args) -> Result<()> {
    use mcu_mixq::nas::search::{native_search, outcomes_to_json, NativeSearchCfg};

    let (model, params) = load_model_params(args)?;
    let method = Method::parse(&args.str_or("method", "rp-slbc"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let mut cfg = NativeSearchCfg {
        method,
        ..NativeSearchCfg::default()
    };
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.generations = args.usize_or("generations", cfg.generations);
    cfg.population = args.usize_or("population", cfg.population);

    let target_spec = args.str_or("targets", "stm32f746,stm32f446");
    let targets: Vec<&'static Target> = target_spec
        .split(',')
        .map(|t| Target::resolve(t.trim()))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!targets.is_empty(), "--targets wants at least one name");

    println!(
        "native search: {} via {} (seed {}, {} generation(s) x {} offspring)\n",
        model.name,
        method.name(),
        cfg.seed,
        cfg.generations,
        cfg.population
    );
    let mut outcomes = Vec::new();
    for target in targets {
        let out = native_search(&model, &params, target, &cfg)?;
        let best = out.best_cycles().clone();
        println!(
            "{}: {} Pareto point(s) ({} scored, {} pruned by the analyzer)",
            target.name,
            out.front.len(),
            out.evaluated,
            out.pruned
        );
        let mut t = Table::new(vec![
            "cycles", "joules", "SRAM KB", "flash KB", "SQNR dB", "avg w", "avg a",
        ]);
        for p in &out.front {
            t.row(vec![
                format!("{}", p.obj.cycles),
                format!("{:.4}", p.obj.joules),
                format!("{:.1}", p.obj.sram_peak_bytes as f64 / 1024.0),
                format!("{:.1}", p.obj.flash_total_bytes as f64 / 1024.0),
                format!("{:.1}", p.obj.accuracy_proxy_db),
                format!("{:.2}", p.cfg.avg_wbits()),
                format!("{:.2}", p.cfg.avg_abits()),
            ]);
        }
        t.print();
        println!(
            "best-cycles vs uniform-8: {:.2}x cycles, {:.2}x flash  (u8: {} cycles, {:.1} KB)\n",
            best.obj.cycles as f64 / out.uniform8.cycles as f64,
            best.obj.flash_total_bytes as f64 / out.uniform8.flash_total_bytes as f64,
            out.uniform8.cycles,
            out.uniform8.flash_total_bytes as f64 / 1024.0
        );
        outcomes.push(out);
    }

    if let Some(path) = args.get("save-config") {
        let best = outcomes[0].best_cycles();
        mcu_mixq::quant::save_config(path, &model.name, &best.cfg)?;
        println!(
            "saved best-cycles config for {} ({}) to {path}",
            model.name, outcomes[0].target
        );
    }
    let json = outcomes_to_json(&model.name, method, cfg.seed, &outcomes);
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{}\n", json.to_string_compact()))?;
        println!("wrote {path}");
    } else {
        println!("{}", json.to_string_compact());
    }
    Ok(())
}

fn cmd_qat(args: &Args) -> Result<()> {
    let store = store(args)?;
    let rt = Runtime::cpu()?;
    let arts = store.backbone(&backbone_arg(args))?;
    let n = arts.model.num_layers();
    let config = BitConfig {
        wbits: parse_bits(&args.str_or("wbits", "4"), n)?,
        abits: parse_bits(&args.str_or("abits", "4"), n)?,
    };
    let mut cfg = QatCfg::default();
    cfg.steps = args.usize_or("steps", cfg.steps);
    cfg.lr = args.f32_or("lr", cfg.lr);
    cfg.seed = args.u64_or("seed", cfg.seed);

    let runner = QatRunner::new(&rt, &arts, cfg.seed)?;
    let init = arts.load_init_params()?;
    println!(
        "QAT {} at w={:?} a={:?}",
        arts.model.name, config.wbits, config.abits
    );
    let out = runner.run(&init, &config, &cfg)?;
    for log in &out.history {
        println!("  step {:>4}  loss {:.4}  acc {:.3}", log.step, log.loss, log.acc);
    }
    println!("eval: loss {:.4}  acc {:.3}", out.eval_loss, out.eval_acc);
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<()> {
    let store = store(args)?;
    let rt = Runtime::cpu()?;
    let backbone = backbone_arg(args);
    let mut cfg = PipelineCfg::new(&backbone);
    cfg.target = parse_target(args)?.name.to_string();
    cfg.search.steps = args.usize_or("search-steps", cfg.search.steps);
    cfg.qat.steps = args.usize_or("qat-steps", cfg.qat.steps);
    cfg.use_edmips_proxy = args.str_or("proxy", "simd") == "edmips";
    if let Some(path) = args.get("config-file") {
        // A saved `search --native` artifact replaces the supernet
        // search: QAT and the comparison table run at this config.
        let (saved_backbone, fixed) = mcu_mixq::quant::load_config(path)?;
        anyhow::ensure!(
            saved_backbone == backbone,
            "{path} was searched for `{saved_backbone}`, not `{backbone}`"
        );
        cfg.fixed_config = Some(fixed);
    }

    let report = coordinator::run_pipeline(&rt, &store, &cfg)?;
    println!("== search ==");
    for log in &report.search_history {
        println!(
            "  step {:>4}  loss {:.4}  ce {:.4}  comp {:.4}  acc {:.3}",
            log.step, log.loss, log.ce, log.comp, log.acc
        );
    }
    println!("selected wbits {:?}", report.searched_wbits);
    println!("selected abits {:?}", report.searched_abits);
    println!("== qat ==");
    for log in &report.qat_history {
        println!("  step {:>4}  loss {:.4}  acc {:.3}", log.step, log.loss, log.acc);
    }
    println!("== deployment comparison ==");
    println!("{}", coordinator::deploy::render_rows(&backbone, &report.rows));
    for (m, s) in &report.speedups {
        println!("MCU-MixQ speedup over {m}: {s:.2}x");
    }
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let method = Method::parse(&args.str_or("method", "rp-slbc"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let (model, params) = load_model_params(args)?;
    let cfg = parse_config(args, &model)?;
    let target = parse_target(args)?;
    let probe = mcu_mixq::datasets::generate(
        mcu_mixq::datasets::Task::for_backbone(&model.name),
        1,
        model.input_hw,
        7,
    );
    let rep = engine::deploy_for(&model, &params, &cfg, method, probe.image(0), target)?;
    println!(
        "{} via {} on {}: peak {:.2}KB flash {:.2}KB clocks {} latency {:.2}ms energy {:.2}mJ",
        rep.backbone,
        rep.method.name(),
        rep.target,
        rep.peak_sram as f64 / 1024.0,
        rep.flash_bytes as f64 / 1024.0,
        rep.cycles,
        rep.latency_ms,
        rep.joules * 1e3
    );
    for ((name, cyc), joules) in rep.per_layer.iter().zip(&rep.per_layer_joules) {
        println!("  {name:<14} {cyc:>10} cycles  {:>9.2} uJ", joules * 1e6);
    }
    Ok(())
}

/// Static packing-safety & resource analysis of one compiled model
/// (`mixq-check`): proves or refutes lane-overflow safety, SRAM/flash
/// fit and plan consistency without running any inference. `--strict`
/// exits non-zero on any Error-severity finding — the same gate as
/// `CompiledModel::compile_for_strict`.
fn cmd_check(args: &Args) -> Result<()> {
    let method = Method::parse(&args.str_or("method", "rp-slbc"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let (model, params) = load_model_params(args)?;
    let cfg = parse_config(args, &model)?;
    let target = parse_target(args)?;
    // Unbounded compile on purpose: a model over the SRAM budget must
    // *report* resource/sram-exceeded, not die in the compile gate —
    // the analyzer's own rules are the verdict here.
    let cm = engine::CompiledModel::compile_unbounded_for(&model, &params, &cfg, method, target);
    let report = mcu_mixq::analysis::analyze(&cm);

    if args.bool_or("json", false) {
        println!("{}", report.to_json().to_string_compact());
    } else {
        print!("{}", report.render());
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{}\n", report.to_json().to_string_compact()))?;
        if !args.bool_or("json", false) {
            println!("wrote {path}");
        }
    }
    if args.bool_or("strict", false) {
        anyhow::ensure!(
            report.is_safe(),
            "{}: static analysis found {} error(s): [{}]",
            model.name,
            report.errors(),
            report.error_rules().join(", ")
        );
    }
    Ok(())
}

/// Per-layer execution profile for one deployment: cycles, joules and the
/// Eq. 12 instruction-mix split per layer, with totals asserted
/// bit-identical to the `deploy` report for the same artifact — the
/// acceptance invariant CI's profile smoke exercises.
fn cmd_profile(args: &Args) -> Result<()> {
    let method = Method::parse(&args.str_or("method", "rp-slbc"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let (model, params) = load_model_params(args)?;
    let cfg = parse_config(args, &model)?;
    let target = parse_target(args)?;
    let probe = mcu_mixq::datasets::generate(
        mcu_mixq::datasets::Task::for_backbone(&model.name),
        1,
        model.input_hw,
        7,
    );
    let cm = engine::CompiledModel::compile_for(&model, &params, &cfg, method, target)?;
    let res = cm.run(probe.image(0))?;
    let profile =
        ExecutionProfile::from_layers(target, &res.per_layer, &res.per_layer_counters);
    println!(
        "{} via {} on {}: {} cycles, {:.3}ms, {:.3}mJ\n",
        model.name,
        method.name(),
        target.name,
        profile.total_cycles,
        profile.latency_ms(target),
        profile.total_joules * 1e3
    );
    print!("{}", profile.render());

    // Bit-for-bit acceptance gate: the profiler must reproduce the deploy
    // report's totals exactly (cycles in u64, joules by pricing the merged
    // instruction histogram once — not by summing per-layer f64 prices).
    let rep = cm.report(probe.image(0))?;
    anyhow::ensure!(
        profile.total_cycles == rep.cycles,
        "profile cycle total {} != deploy report {}",
        profile.total_cycles,
        rep.cycles
    );
    anyhow::ensure!(
        profile.total_joules.to_bits() == rep.joules.to_bits(),
        "profile joule total {} not bit-identical to deploy report {}",
        profile.total_joules,
        rep.joules
    );
    println!("\nprofile totals match deploy report bit-for-bit");
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{}\n", profile.to_json().to_string_compact()))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_slbc_demo(args: &Args) -> Result<()> {
    let store = store(args)?;
    let rt = Runtime::cpu()?;
    let demo = store.slbc_demo()?;
    let program = rt.load_program(&demo.path)?;
    println!(
        "slbc_demo: n={} k={} sx={} sk={} group={} field={} (compiled in {:.2}s)",
        demo.n,
        demo.k,
        demo.sx_bits,
        demo.sk_bits,
        demo.group_size,
        demo.field_width,
        program.compile_time_s
    );
    // Random sub-byte operands, run through the Pallas-lowered HLO.
    let mut rng = mcu_mixq::util::prng::Rng::new(args.u64_or("seed", 3));
    let x: Vec<i64> = (0..demo.n).map(|_| rng.below(1 << demo.sx_bits) as i64).collect();
    let k: Vec<i64> = (0..demo.k).map(|_| rng.below(1 << demo.sk_bits) as i64).collect();
    let outs = program.run(&[lit::i64_vec(&x), lit::i64_vec(&k)])?;
    let got = lit::to_i64_vec(&outs[0])?;
    // Rust-side packed conv oracle.
    let want = mcu_mixq::simd::poly::conv1d_full_direct(
        &x.iter().map(|&v| v as u64).collect::<Vec<_>>(),
        &k.iter().map(|&v| v as u64).collect::<Vec<_>>(),
    );
    let want: Vec<i64> = want.iter().map(|&v| v as i64).collect();
    anyhow::ensure!(got == want, "PJRT result differs from Rust packing oracle");
    println!(
        "Layer-1 kernel output matches the Rust packed-arithmetic oracle ({} taps)",
        got.len()
    );
    Ok(())
}

/// Parse a `--mix` spec: comma-separated `backbone:method:bits[:weight]`
/// entries, each becoming one served workload with seeded synthetic
/// parameters. The bits field also accepts `cfg@FILE` — a saved
/// `search --native` configuration (`quant::save_config`), which serves
/// the searched per-layer mixed-precision config as its own `ModelKey`.
fn parse_mix(spec: &str) -> Result<(Vec<Workload>, Vec<f64>)> {
    let mut workloads = Vec::new();
    let mut weights = Vec::new();
    for (i, entry) in spec.split(',').enumerate() {
        let parts: Vec<&str> = entry.trim().split(':').collect();
        anyhow::ensure!(
            parts.len() == 3 || parts.len() == 4,
            "mix entry `{entry}` is not backbone:method:bits[:weight]"
        );
        let method = Method::parse(parts[1])
            .ok_or_else(|| anyhow::anyhow!("unknown method `{}` in mix", parts[1]))?;
        let weight: f64 = if parts.len() == 4 { parts[3].parse()? } else { 1.0 };
        anyhow::ensure!(weight > 0.0, "mix weight must be positive in `{entry}`");
        let workload = if let Some(path) = parts[2].strip_prefix("cfg@") {
            let (backbone, cfg) = mcu_mixq::quant::load_config(path)?;
            anyhow::ensure!(
                backbone == parts[0],
                "{path} was searched for `{backbone}`, not `{}` (mix entry `{entry}`)",
                parts[0]
            );
            Workload::with_config(parts[0], method, cfg, 1000 + i as u64)?
        } else {
            Workload::synth(parts[0], method, parts[2].parse()?, 1000 + i as u64)?
        };
        workloads.push(workload);
        weights.push(weight);
    }
    Ok((workloads, weights))
}

/// Parse a `--fleet` spec: comma-separated `target[:count]` entries,
/// e.g. `m7:4,m4:4` — a delegation to the [`Target`] registry, whose
/// errors name the offending token and the known target names.
fn parse_fleet(spec: &str) -> Result<Vec<DeviceCfg>> {
    Target::parse_fleet(spec)
}

/// Resolve a `--target` argument through the registry, with the known
/// names in the error.
fn parse_target(args: &Args) -> Result<&'static Target> {
    Target::resolve(&args.str_or("target", "stm32f746"))
}

/// Parse a `--slo-mix` spec: three comma-separated weights for the
/// interactive, standard and batch deadline classes.
fn parse_slo_mix(spec: &str) -> Result<Vec<f64>> {
    let v: Vec<f64> = spec
        .split(',')
        .map(|t| t.trim().parse::<f64>())
        .collect::<std::result::Result<_, _>>()?;
    anyhow::ensure!(v.len() == 3, "--slo-mix wants interactive,standard,batch weights");
    anyhow::ensure!(v.iter().all(|w| *w >= 0.0) && v.iter().sum::<f64>() > 0.0,
        "--slo-mix weights must be non-negative and not all zero");
    Ok(v)
}

/// Parse a `--burst` spec: `period,size` — every `period` requests,
/// `size` extra requests arrive simultaneously with the period leader.
fn parse_burst(spec: &str) -> Result<(usize, usize)> {
    let (p, s) = spec
        .split_once(',')
        .ok_or_else(|| anyhow::anyhow!("--burst wants period,size (e.g. 64,32)"))?;
    let period: usize = p.trim().parse()?;
    let size: usize = s.trim().parse()?;
    anyhow::ensure!(period > 0, "--burst period must be positive");
    anyhow::ensure!(size >= 1 && size < period, "--burst size must be in 1..period");
    Ok((period, size))
}

/// Shared serve/bench-serve scenario runner: build the mix + fleet +
/// scheduler + trace from args (with per-command defaults), replay,
/// print the report tables.
fn run_serve_scenario(
    args: &Args,
    default_requests: usize,
    default_devices: usize,
) -> Result<ServeReport> {
    let mix = args.str_or("mix", "vgg_tiny:rp-slbc:4,mobilenet_tiny:tinyengine:8");
    let (workloads, weights) = parse_mix(&mix)?;

    let mut cfg = ServeCfg::default();
    cfg.fleet = match args.get("fleet") {
        Some(spec) => parse_fleet(spec)?,
        None => vec![DeviceCfg::stm32f746(); args.usize_or("devices", default_devices)],
    };
    let sched_spec = args.str_or("sched", "rr");
    cfg.scheduler = SchedulerKind::parse(&sched_spec).ok_or_else(|| {
        anyhow::anyhow!("unknown scheduler `{sched_spec}` (rr|least|slo|energy)")
    })?;
    let adm_spec = args.str_or("admission", "fifo");
    cfg.batcher.admission = AdmissionKind::parse(&adm_spec)
        .ok_or_else(|| anyhow::anyhow!("unknown admission policy `{adm_spec}` (fifo|class)"))?;
    cfg.batcher.preempt = args.bool_or("preempt", false);
    cfg.steal = args.bool_or("steal", false);
    cfg.legacy_loop = args.bool_or("legacy-loop", false);
    cfg.max_queue_depth = args.usize_or("depth", cfg.max_queue_depth);
    cfg.cache_capacity = args.usize_or("cache", cfg.cache_capacity);
    cfg.batcher.max_batch = args.usize_or("batch", cfg.batcher.max_batch);
    let wait_ms = args.f32_or("wait-ms", 2.0) as f64;
    cfg.batcher.max_wait_cycles =
        (wait_ms * mcu_mixq::STM32F746_CLOCK_HZ as f64 / 1e3).max(1.0) as u64;
    cfg.batcher.max_queue = args.usize_or("queue", cfg.batcher.max_queue);

    // Fault injection & elasticity.
    let churn = args.f32_or("churn", 0.0) as f64;
    anyhow::ensure!(
        (0.0..=1.0).contains(&churn),
        "--churn must be a probability in [0,1], got {churn}"
    );
    cfg.readmit = !args.bool_or("no-readmit", false);
    if let Some(spec) = args.get("autoscale") {
        let standby = parse_fleet(spec)?;
        let mut asc = serve::AutoscaleCfg {
            standby,
            ..serve::AutoscaleCfg::default()
        };
        if let Some(b) = args.get("autoscale-budget") {
            asc.joules_budget = b
                .parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--autoscale-budget wants joules, got `{b}`"))?;
        }
        cfg.autoscale = Some(asc);
    }

    let (trace, fleet_events) = match args.get("trace-file") {
        Some(path) => {
            // JSON-lines traces parse one request at a time through
            // TraceSource; the CLI still materializes the vector for the
            // banner, dump-trace, and the report. Library callers that
            // want true streaming use serve::run_trace_source directly.
            let (t, ev) = if path.ends_with(".jsonl") {
                let t: Vec<_> =
                    serve::TraceSource::open(path)?.collect::<anyhow::Result<_>>()?;
                (t, Vec::new())
            } else {
                serve::load_full_trace(path)?
            };
            println!(
                "replaying {} recorded request(s) (+{} fleet event(s)) from {path}",
                t.len(),
                ev.len()
            );
            (t, ev)
        }
        None => {
            let requests = args.usize_or("requests", default_requests);
            let mean_gap_ms = args.f32_or("mean-gap-ms", 5.0) as f64;
            let mean_gap_cycles =
                (mean_gap_ms * mcu_mixq::STM32F746_CLOCK_HZ as f64 / 1e3).max(1.0) as u64;
            let mut tcfg = TraceCfg::new(requests, mean_gap_cycles, args.u64_or("seed", 42));
            let skew = args.f32_or("skew", 0.0) as f64;
            if skew > 0.0 {
                // Zipf skew generates the tenant weights itself, so it
                // cannot be combined with explicit per-entry weights.
                anyhow::ensure!(
                    weights.iter().all(|w| *w == 1.0),
                    "--skew conflicts with explicit :weight entries in --mix"
                );
                tcfg.tenant_skew = skew;
            } else {
                tcfg.weights = weights;
            }
            if let Some(slo) = args.get("slo-mix") {
                tcfg.slo_weights = parse_slo_mix(slo)?;
            }
            if let Some(burst) = args.get("burst") {
                // parse_burst pre-validates with a friendly error; the
                // builder's own asserts stay the single semantic gate.
                let (period, size) = parse_burst(burst)?;
                tcfg = tcfg.with_burst(period, size);
            }
            if churn > 0.0 {
                tcfg = tcfg.with_churn(churn);
            }
            let t = serve::synth_trace(&tcfg, workloads.len());
            let ev = serve::synth_fleet_events(&tcfg, &t, cfg.fleet.len());
            (t, ev)
        }
    };
    if let Some(path) = args.get("dump-trace") {
        // Round-trips through load_full_trace; with no fleet events the
        // file is byte-identical to the legacy save_trace format.
        serve::save_full_trace(path, &trace, &fleet_events)?;
        println!(
            "wrote {} request(s) (+{} fleet event(s)) to {path}",
            trace.len(),
            fleet_events.len()
        );
    }

    let m4s = cfg
        .fleet
        .iter()
        .filter(|d| d.class == serve::DeviceClass::M4)
        .count();
    println!(
        "serving {} model(s) on {} device(s) ({} m7 + {} m4, {} scheduler, {} admission{}{}{}{}): {} requests, batch<= {}, wait {:.2}ms\n",
        workloads.len(),
        cfg.fleet.len(),
        cfg.fleet.len() - m4s,
        m4s,
        cfg.scheduler.name(),
        cfg.batcher.admission.name(),
        if cfg.batcher.preempt { ", preempt" } else { "" },
        if cfg.steal { ", steal" } else { "" },
        if fleet_events.is_empty() {
            String::new()
        } else {
            format!(
                ", {} fleet event(s){}",
                fleet_events.len(),
                if cfg.readmit { "" } else { ", no-readmit" }
            )
        },
        if let Some(a) = &cfg.autoscale {
            format!(", autoscale +{}", a.standby.len())
        } else {
            String::new()
        },
        trace.len(),
        cfg.batcher.max_batch,
        wait_ms
    );
    let events_out = args.get("events-out");
    let metrics_out = args.get("metrics-out");
    let report = if events_out.is_some() || metrics_out.is_some() {
        // Observed replay: bounded ring of lifecycle events + sampled
        // metrics, both passive (bit-identical report to the plain path).
        let mut rec = RingRecorder::new(1 << 20);
        let cadence = args.u64_or("metrics-cadence", 216_000);
        let mut metrics = MetricsRegistry::new(cadence);
        let report = serve::run_trace_full_observed(
            &workloads,
            &trace,
            &fleet_events,
            &cfg,
            &mut rec,
            Some(&mut metrics),
        )?;
        if let Some(path) = events_out {
            // Standby devices get tracks too — the autoscaler's joins
            // render as instants on them.
            let standby = cfg
                .autoscale
                .iter()
                .flat_map(|a| a.standby.iter())
                .map(|d| (d, "standby"));
            let names: Vec<String> = cfg
                .fleet
                .iter()
                .map(|d| (d, ""))
                .chain(standby)
                .enumerate()
                .map(|(i, (d, tag))| format!("{} #{i}{}{}", d.name, if tag.is_empty() { "" } else { " " }, tag))
                .collect();
            if rec.dropped > 0 {
                eprintln!("warning: event ring overflowed, {} event(s) dropped", rec.dropped);
            }
            let json = mcu_mixq::obs::perfetto::export(rec.iter(), &names);
            std::fs::write(path, format!("{}\n", json.to_string_compact()))?;
            println!("wrote {} event(s) to {path}", rec.iter().count());
        }
        if let Some(path) = metrics_out {
            std::fs::write(path, format!("{}\n", metrics.to_json().to_string_compact()))?;
            println!("wrote metrics to {path}");
        }
        report
    } else {
        serve::run_trace_full(&workloads, &trace, &fleet_events, &cfg)?
    };
    println!("{}", report.render());
    Ok(report)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let report = run_serve_scenario(args, 128, 4)?;
    if args.bool_or("json", false) {
        println!("{}", report.to_json().to_string_compact());
    }
    Ok(())
}

fn cmd_bench_serve(args: &Args) -> Result<()> {
    let report = run_serve_scenario(args, 256, 4)?;
    let json = report.to_json().to_string_compact();
    println!("{json}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{json}\n"))?;
        println!("wrote {path}");
    }

    // Fixed-protocol guarantees (this process is single-threaded, so the
    // global compile counter is exact here).
    anyhow::ensure!(report.requests >= 200, "bench-serve needs >= 200 requests");
    anyhow::ensure!(
        report.per_device.len() >= 4,
        "bench-serve needs >= 4 devices"
    );
    anyhow::ensure!(report.completed > 0, "no request completed");
    anyhow::ensure!(
        report.completed as u64 + report.rejected_queue + report.rejected_sram + report.lost
            == report.requests as u64,
        "request conservation violated ({} completed + {} shed + {} sram + {} lost != {})",
        report.completed,
        report.rejected_queue,
        report.rejected_sram,
        report.lost,
        report.requests
    );
    anyhow::ensure!(
        report.engine_compiles == report.cache.compiles,
        "every engine compilation must come from the registry ({} vs {})",
        report.engine_compiles,
        report.cache.compiles
    );
    for m in &report.per_model {
        anyhow::ensure!(
            m.requests == 0 || m.cache_hits > 1,
            "{}: compile-once not amortized (requests {}, cache hits {})",
            m.label,
            m.requests,
            m.cache_hits
        );
    }
    println!("\nbench-serve OK: compile-once + >1 cache hit per served model verified");
    Ok(())
}

/// Conv hot-path benchmark: rolling-row pipeline (pre-packed kernels +
/// reusable scratch) vs the pre-PR operator, host ns/layer + modeled
/// cycles per method and bitwidth. `--smoke` runs the cheap CI protocol;
/// `--out FILE` additionally writes the JSON trend line to a file so the
/// workflow can archive the trajectory per PR.
fn cmd_bench_conv(args: &Args) -> Result<()> {
    let smoke = args.bool_or("smoke", false);
    let mut cfg = if smoke {
        mcu_mixq::perf::conv_hotpath::ConvBenchCfg::smoke()
    } else {
        mcu_mixq::perf::conv_hotpath::ConvBenchCfg::default()
    };
    cfg.repeats = args.usize_or("repeats", cfg.repeats);

    println!(
        "bench-conv — rolling-row SLBC pipeline vs pre-PR operator ({} mode, {} repeat(s))\n",
        if smoke { "smoke" } else { "full" },
        cfg.repeats
    );
    let rep = mcu_mixq::perf::conv_hotpath::run(&cfg);
    print!("{}", rep.render());
    let sp = rep.mean_speedup_conv3x3();
    println!(
        "\nmean host speedup on stride-1 k=3 convs: {sp:.2}x  (modeled cycle ratio {:.3}x)",
        rep.mean_cycle_ratio()
    );
    let json = rep.to_json().to_string_compact();
    println!("{json}");
    if let Some(path) = args.get("out") {
        std::fs::write(path, format!("{json}\n"))?;
        println!("wrote {path}");
    }
    // Deterministic gate always; the wall-clock acceptance bar (>= 2x on
    // stride-1 k=3 convs, the PR criterion) only in full mode — single-
    // repeat smoke timings are recorded, not enforced.
    rep.check_cycle_invariant().map_err(|e| anyhow::anyhow!(e))?;
    if !smoke {
        rep.check_speedup(2.0).map_err(|e| anyhow::anyhow!(e))?;
    }
    Ok(())
}

fn cmd_calibrate(_args: &Args) -> Result<()> {
    for (name, cm) in [
        ("cortex-m7", CycleModel::cortex_m7()),
        ("cortex-m4", CycleModel::cortex_m4()),
    ] {
        let cal = calibrate_alpha_beta(&cm);
        println!(
            "{name}: alpha={:.4} beta={:.4} scale={:.3} max_rel_err={:.2}% ({} probes)",
            cal.model.alpha,
            cal.model.beta,
            cal.scale,
            cal.max_rel_err * 100.0,
            cal.samples
        );
    }
    Ok(())
}
