//! Deterministic synthetic datasets (DESIGN.md §3 substitution for
//! VWW / CIFAR-10, which cannot be downloaded in this environment).
//!
//! Both tasks are built so that *accuracy responds to quantization
//! bitwidth* — the property the NAS experiments need — while remaining
//! learnable by the tiny backbones within a few hundred SGD steps:
//!
//! * **synth-CIFAR** — 10 classes; each class is a fixed smooth random
//!   template, samples are `mix · template + (1-mix) · noise`.
//! * **synth-VWW** — 2 classes ("person present?"); positives contain a
//!   bright localized blob at a random position over a textured
//!   background, negatives only the background.

use crate::util::prng::Rng;

/// A batch of NHWC f32 images in `[0, 1]` with int32 labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub n: usize,
    pub hw: usize,
    pub c: usize,
}

impl Batch {
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.hw * self.hw * self.c;
        &self.images[i * sz..(i + 1) * sz]
    }
}

/// Which synthetic task a backbone trains on (Table I pairing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    SynthCifar,
    SynthVww,
}

impl Task {
    pub fn num_classes(&self) -> usize {
        match self {
            Task::SynthCifar => 10,
            Task::SynthVww => 2,
        }
    }

    /// Table I pairing: VGG-Tiny ↔ CIFAR-class task, MobileNet-Tiny ↔ VWW.
    pub fn for_backbone(name: &str) -> Task {
        if name.contains("mobilenet") {
            Task::SynthVww
        } else {
            Task::SynthCifar
        }
    }
}

/// Smooth a flat HxWxC image in place with a 3x3 box blur (`rounds` times)
/// to produce low-frequency class templates.
fn smooth(img: &mut [f32], hw: usize, c: usize, rounds: usize) {
    let mut tmp = img.to_vec();
    for _ in 0..rounds {
        for y in 0..hw {
            for x in 0..hw {
                for ch in 0..c {
                    let mut acc = 0.0;
                    let mut cnt = 0.0;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let yy = y as i64 + dy;
                            let xx = x as i64 + dx;
                            if yy >= 0 && yy < hw as i64 && xx >= 0 && xx < hw as i64 {
                                acc += img[(yy as usize * hw + xx as usize) * c + ch];
                                cnt += 1.0;
                            }
                        }
                    }
                    tmp[(y * hw + x) * c + ch] = acc / cnt;
                }
            }
        }
        img.copy_from_slice(&tmp);
    }
}

/// The fixed per-class templates of synth-CIFAR.
///
/// Templates depend ONLY on the class index (plus a fixed dataset
/// constant) — never on the per-batch seed — so every batch of the
/// stream, and the train and eval splits, share one class definition.
/// (Deriving them from the batch seed would re-randomize the classes
/// every step and make the task unlearnable.)
fn cifar_templates(hw: usize, c: usize) -> Vec<Vec<f32>> {
    (0..10)
        .map(|class| {
            let mut rng = Rng::new(0xC1FA_0000 + class as u64);
            let mut t: Vec<f32> = (0..hw * hw * c).map(|_| rng.f32()).collect();
            smooth(&mut t, hw, c, 2);
            // Normalize to full [0,1] contrast.
            let (mn, mx) = t
                .iter()
                .fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
            for v in &mut t {
                *v = (*v - mn) / (mx - mn + 1e-8);
            }
            t
        })
        .collect()
}

/// Generate a synth-CIFAR batch.
pub fn synth_cifar(n: usize, hw: usize, seed: u64) -> Batch {
    let c = 3;
    let templates = cifar_templates(hw, c);
    let mut rng = Rng::new(seed);
    let mut images = Vec::with_capacity(n * hw * hw * c);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(10) as usize;
        labels.push(class as i32);
        let mix = rng.f32_range(0.55, 0.8);
        for &tv in &templates[class] {
            let noise = rng.f32();
            images.push((mix * tv + (1.0 - mix) * noise).clamp(0.0, 1.0));
        }
    }
    Batch {
        images,
        labels,
        n,
        hw,
        c,
    }
}

/// Generate a synth-VWW batch ("is a person-blob present?").
pub fn synth_vww(n: usize, hw: usize, seed: u64) -> Batch {
    let c = 3;
    let mut rng = Rng::new(seed ^ 0x7157_0001);
    let mut images = Vec::with_capacity(n * hw * hw * c);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let present = rng.below(2) == 1;
        labels.push(present as i32);
        // Textured background.
        let mut img: Vec<f32> = (0..hw * hw * c).map(|_| rng.f32() * 0.5).collect();
        smooth(&mut img, hw, c, 1);
        if present {
            // A bright 2D Gaussian blob ("person") at a random location.
            let cx = rng.f32_range(0.25, 0.75) * hw as f32;
            let cy = rng.f32_range(0.25, 0.75) * hw as f32;
            let sigma = rng.f32_range(0.12, 0.22) * hw as f32;
            for y in 0..hw {
                for x in 0..hw {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    let g = (-d2 / (2.0 * sigma * sigma)).exp();
                    for ch in 0..c {
                        let v = &mut img[(y * hw + x) * c + ch];
                        *v = (*v + 0.8 * g).min(1.0);
                    }
                }
            }
        }
        images.extend_from_slice(&img);
    }
    Batch {
        images,
        labels,
        n,
        hw,
        c,
    }
}

/// Generate a batch for a task (train/eval splits via distinct seeds).
pub fn generate(task: Task, n: usize, hw: usize, seed: u64) -> Batch {
    match task {
        Task::SynthCifar => synth_cifar(n, hw, seed),
        Task::SynthVww => synth_vww(n, hw, seed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cifar_shapes_and_determinism() {
        let b1 = synth_cifar(8, 16, 42);
        let b2 = synth_cifar(8, 16, 42);
        assert_eq!(b1.images.len(), 8 * 16 * 16 * 3);
        assert_eq!(b1.labels.len(), 8);
        assert_eq!(b1.images, b2.images);
        assert_eq!(b1.labels, b2.labels);
    }

    #[test]
    fn different_seeds_different_data() {
        let b1 = synth_cifar(8, 16, 1);
        let b2 = synth_cifar(8, 16, 2);
        assert_ne!(b1.images, b2.images);
    }

    #[test]
    fn values_in_unit_interval() {
        for b in [synth_cifar(16, 16, 7), synth_vww(16, 16, 7)] {
            assert!(b.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn vww_labels_binary_and_blob_brightens() {
        let b = synth_vww(64, 16, 3);
        assert!(b.labels.iter().all(|&l| l == 0 || l == 1));
        // Positives should be brighter on average than negatives.
        let mean_of = |lbl: i32| {
            let mut s = 0.0f64;
            let mut cnt = 0usize;
            for i in 0..b.n {
                if b.labels[i] == lbl {
                    s += b.image(i).iter().map(|&v| v as f64).sum::<f64>();
                    cnt += 1;
                }
            }
            s / cnt as f64
        };
        assert!(mean_of(1) > mean_of(0));
    }

    #[test]
    fn cifar_classes_are_separable_by_template_corr() {
        // Nearest-template classification should beat chance easily —
        // i.e. the task is actually learnable.
        let hw = 16;
        let b = synth_cifar(64, hw, 9);
        let templates = cifar_templates(hw, 3);
        let center = |v: &[f32]| -> Vec<f32> {
            let m = v.iter().sum::<f32>() / v.len() as f32;
            v.iter().map(|&x| x - m).collect()
        };
        let ctpl: Vec<Vec<f32>> = templates.iter().map(|t| center(t)).collect();
        let mut correct = 0;
        for i in 0..b.n {
            let img = center(b.image(i));
            let best = (0..10)
                .max_by(|&a, &c| {
                    let sa: f32 = ctpl[a].iter().zip(&img).map(|(t, v)| t * v).sum();
                    let sc: f32 = ctpl[c].iter().zip(&img).map(|(t, v)| t * v).sum();
                    sa.partial_cmp(&sc).unwrap()
                })
                .unwrap();
            if best as i32 == b.labels[i] {
                correct += 1;
            }
        }
        assert!(correct as f64 / b.n as f64 > 0.5, "correct={correct}/64");
    }

    #[test]
    fn task_pairing() {
        assert_eq!(Task::for_backbone("vgg_tiny"), Task::SynthCifar);
        assert_eq!(Task::for_backbone("mobilenet_tiny"), Task::SynthVww);
    }
}
