//! Layer-1 (Pallas/TPU) resource estimation — DESIGN.md §Hardware-
//! Adaptation.
//!
//! The Pallas kernels run under `interpret=True` on CPU (the CPU PJRT
//! plugin cannot execute Mosaic custom-calls), so real-TPU efficiency is
//! *estimated* from the BlockSpec geometry instead of measured: VMEM
//! footprint per grid step, arithmetic intensity, and the packed-
//! multiplier utilization that plays the role the paper gives SIMD lanes.
//! EXPERIMENTS.md §Perf quotes these numbers.

use crate::models::{LayerSpec, ModelDesc};
use crate::quant::BitConfig;

/// TPU-generation parameters used for the estimate (v4-like defaults).
#[derive(Debug, Clone, Copy)]
pub struct TpuParams {
    /// VMEM per core, bytes.
    pub vmem_bytes: usize,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Peak int multiply-accumulate rate of the scalar/vector unit used
    /// by the packed path, MACs/s.
    pub peak_macs: f64,
}

impl Default for TpuParams {
    fn default() -> Self {
        TpuParams {
            vmem_bytes: 16 * 1024 * 1024,
            hbm_bw: 1.2e12,
            peak_macs: 2.75e14 / 2.0, // bf16 MXU peak / 2 for int path
        }
    }
}

/// Resource estimate of one layer's Pallas execution.
#[derive(Debug, Clone)]
pub struct LayerEstimate {
    pub name: String,
    /// Bytes resident in VMEM for one grid step (x tile + w tile + out).
    pub vmem_per_step: usize,
    /// Arithmetic intensity (MACs per HBM byte moved).
    pub intensity: f64,
    /// Effective MACs per wide multiply after packing.
    pub packed_macs_per_mul: u32,
    /// Roofline-limited efficiency in [0,1]: min(1, intensity/critical).
    pub efficiency: f64,
}

/// Estimate one layer with the SLBC packing plan at `(wbits, abits)`.
pub fn estimate_layer(l: &LayerSpec, wbits: u8, abits: u8, tpu: &TpuParams) -> LayerEstimate {
    // Tile: one output row of all channels + the k input rows feeding it
    // (the BlockSpec used by python/compile/kernels/slbc.py), packed
    // sub-byte storage.
    let in_tile = l.k * l.in_w * l.cin * abits as usize / 8 + 1;
    let w_tile = l.k * l.k * l.cin * l.cout * wbits as usize / 8 + 1;
    let out_tile = l.out_w * l.cout * 4;
    let vmem = in_tile + w_tile + out_tile;

    // HBM traffic per full layer: inputs once, weights once, outputs once.
    let bytes = l.in_elems() * abits as usize / 8
        + l.w_size * wbits as usize / 8
        + l.out_elems() * 4;
    let intensity = l.macs as f64 / bytes.max(1) as f64;

    let plan = crate::simd::adaptive::best_plan(abits as u32, wbits as u32, l.k as u32);
    let packed = plan.map(|p| p.macs_per_instr).unwrap_or(1);

    // Critical intensity: MACs/byte where compute == memory time.
    let critical = tpu.peak_macs / tpu.hbm_bw;
    let efficiency = (intensity / critical).min(1.0);

    LayerEstimate {
        name: l.name.clone(),
        vmem_per_step: vmem,
        intensity,
        packed_macs_per_mul: packed,
        efficiency,
    }
}

/// Whole-model estimate under a bit configuration.
pub fn estimate_model(model: &ModelDesc, cfg: &BitConfig, tpu: &TpuParams) -> Vec<LayerEstimate> {
    model
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| estimate_layer(l, cfg.wbits[i], cfg.abits[i], tpu))
        .collect()
}

/// True iff every layer's working set fits VMEM (the Pallas BlockSpec
/// feasibility condition).
pub fn fits_vmem(estimates: &[LayerEstimate], tpu: &TpuParams) -> bool {
    estimates.iter().all(|e| e.vmem_per_step <= tpu.vmem_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    #[test]
    fn tiles_fit_vmem_easily() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let est = estimate_model(&m, &cfg, &TpuParams::default());
        assert!(fits_vmem(&est, &TpuParams::default()));
        for e in &est {
            assert!(e.vmem_per_step < 512 * 1024, "{}: {}", e.name, e.vmem_per_step);
        }
    }

    #[test]
    fn lower_bits_raise_intensity() {
        // Packing the operands shrinks HBM traffic -> higher MACs/byte.
        let m = vgg_tiny(10, 16);
        let l = &m.layers[2];
        let tpu = TpuParams::default();
        let e2 = estimate_layer(l, 2, 2, &tpu);
        let e8 = estimate_layer(l, 8, 8, &tpu);
        assert!(e2.intensity > e8.intensity);
        assert!(e2.packed_macs_per_mul > e8.packed_macs_per_mul);
    }

    #[test]
    fn efficiency_bounded() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        for e in estimate_model(&m, &cfg, &TpuParams::default()) {
            assert!((0.0..=1.0).contains(&e.efficiency), "{}", e.name);
        }
    }
}
