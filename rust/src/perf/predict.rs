//! Analytic instruction-count prediction per operator (§IV.D).
//!
//! Every function here reproduces — term by term — the `Counter` charges of
//! the corresponding bit-exact operator in [`crate::ops`], but from layer
//! geometry alone, without touching data. Because the operators' charging
//! is geometry-determined, prediction is **exact**; the calibration tests
//! assert `predict == measure` across methods/bitwidths/layer kinds so the
//! two can never drift apart silently.

use crate::mcu::{Counter, InstrClass};
use crate::models::{LayerKind, LayerSpec, ModelDesc};
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::simd::adaptive::{best_plan, LanePlan};
use crate::simd::poly::{dot_group_size, field_width};

/// Predicted instruction mix of one layer execution.
#[derive(Debug, Clone)]
pub struct PredictedCost {
    /// The full predicted instruction-class histogram.
    pub counter: Counter,
    /// Eq. 12 components (scalar, SIMD-like, bit-manipulation counts).
    pub sisd: u64,
    pub simd: u64,
    pub bit: u64,
}

impl PredictedCost {
    fn from_counter(counter: Counter) -> Self {
        let (sisd, simd, bit) = counter.eq12_components();
        PredictedCost {
            counter,
            sisd,
            simd,
            bit,
        }
    }

    /// Price the predicted histogram in a target's cycles — identical
    /// to folding `self.counter` through the target's cycle table (the
    /// pre-`Target` pricing path), pinned by the `target_api` tests on
    /// the fig5/fig6 operand sets.
    pub fn cycles_on(&self, target: &crate::target::Target) -> u64 {
        target.cycles(&self.counter)
    }

    /// Price the predicted histogram in joules on a target: dynamic
    /// per-instruction energy plus static power over the predicted
    /// execution time.
    pub fn joules_on(&self, target: &crate::target::Target) -> f64 {
        target.joules(&self.counter)
    }

    /// Predicted single-inference latency in milliseconds on `target` —
    /// the static analyzer's headline figure (no inference executed).
    pub fn latency_ms_on(&self, target: &crate::target::Target) -> f64 {
        target.seconds(self.cycles_on(target)) * 1e3
    }
}

/// Predict the instruction mix of running `layer` with `method` at
/// `(wbits, abits)`.
pub fn predict_layer(layer: &LayerSpec, method: Method, wbits: u8, abits: u8) -> PredictedCost {
    let mut ctr = Counter::new();
    match method {
        Method::Slbc => predict_slbc(layer, wbits, abits, false, &mut ctr),
        Method::RpSlbc => predict_slbc(layer, wbits, abits, true, &mut ctr),
        _ => predict_baseline(layer, method, wbits, abits, &mut ctr),
    }
    PredictedCost::from_counter(ctr)
}

/// Predict the summed instruction mix of a whole model.
pub fn predict_model(model: &ModelDesc, method: Method, cfg: &BitConfig) -> PredictedCost {
    let mut total = Counter::new();
    for (i, l) in model.layers.iter().enumerate() {
        let p = predict_layer(l, method, cfg.wbits[i], cfg.abits[i]);
        total.merge(&p.counter);
    }
    PredictedCost::from_counter(total)
}

// ---------------------------------------------------------------------------
// SLBC / RP-SLBC (mirror of the rolling-row pipeline in ops::slbc)
// ---------------------------------------------------------------------------

fn mul_class(plan: &LanePlan) -> InstrClass {
    if plan.cfg.register_bits == 64 {
        InstrClass::MulLong
    } else if plan.cfg.lanes() > 1 {
        InstrClass::Simd
    } else {
        InstrClass::Mul
    }
}

fn predict_slbc(l: &LayerSpec, wbits: u8, abits: u8, reordered: bool, ctr: &mut Counter) {
    if l.kind == LayerKind::Dense {
        return predict_slbc_dense(l, wbits, abits, ctr);
    }
    let depthwise = l.kind == LayerKind::DwConv;
    let k = l.k;
    let pad = crate::ops::common::pad_of(k);
    let padded_w = l.in_w + 2 * pad as usize;
    // Ring channels vs kernel channels (mirror of the rolling-row core).
    let chan = if depthwise { l.cout } else { l.cin };
    let cin_eff = if depthwise { 1 } else { l.cin };
    let cout = l.cout;

    let plan = best_plan(abits as u32, wbits as u32, k as u32)
        .expect("SLBC plan must exist for 2..=8-bit operands");
    // Mirror of ops::slbc: reordering only where it wins (§IV.C).
    let use_rp = reordered && plan.reordering_wins();

    // Kernel-register streaming, once per layer.
    ctr.charge(InstrClass::Bit, (cout * k * cin_eff * k * 2) as u64);
    ctr.charge(InstrClass::Store, (cout * k * cin_eff) as u64);

    // Rolling-row work, charged once per fetched row: every channel of the
    // ring fetches `out_h + k - 1` distinct (padded) input rows per layer,
    // each paying the packed-row load, the signal packing and the window
    // sums exactly once.
    let rows_fetched = (chan * (l.out_h + k - 1)) as u64;
    ctr.charge(
        InstrClass::Load,
        rows_fetched * ((padded_w * abits as usize).div_ceil(32)) as u64,
    );
    ctr.charge(InstrClass::Bit, rows_fetched * (padded_w as u64) * 2);
    ctr.charge(InstrClass::Alu, rows_fetched * (l.out_w as u64) * 2);

    let elems_per_mul = plan.conv.elements_per_instr() as usize;
    let n_mul_per_row = padded_w.div_ceil(elems_per_mul) as u64;
    let seg_ops = if use_rp {
        plan.reordered.as_ref().unwrap().seg_ops_per_instr() as u64
    } else {
        plan.conv.seg_ops_per_instr() as u64
    };
    let fields_per_flush = (plan.conv.spec.group * plan.conv.cfg.lanes()) as u64;
    let muls_per_oc = (k * cin_eff) as u64 * n_mul_per_row;
    let flushes = muls_per_oc.div_ceil(plan.accum_depth as u64);

    for _oy in 0..l.out_h {
        // Per output channel.
        let co = cout as u64;
        ctr.charge(mul_class(&plan), co * muls_per_oc);
        ctr.charge(InstrClass::Alu, co * muls_per_oc);
        ctr.charge(InstrClass::Bit, co * flushes * seg_ops);
        ctr.charge(InstrClass::Alu, co * flushes * fields_per_flush);
        ctr.charge(InstrClass::Load, co * (k * cin_eff) as u64);
        ctr.charge(InstrClass::Mul, co * l.out_w as u64);
        ctr.charge(InstrClass::Alu, co * l.out_w as u64);

        // Window-sum reduction: shared across output channels for regular
        // convs (the correction row is filter-independent), per output
        // channel for depthwise (each channel owns its window sums).
        if depthwise {
            ctr.charge(InstrClass::Alu, (cout * l.out_w * k) as u64);
        } else {
            ctr.charge(InstrClass::Alu, (l.out_w * l.cin * k) as u64);
        }
    }
}

fn predict_slbc_dense(l: &LayerSpec, wbits: u8, abits: u8, ctr: &mut Counter) {
    let g = dot_group_size(abits as u32, wbits as u32, 63);
    let n_groups = (l.cin as u64).div_ceil(g as u64);
    let _ = field_width(abits as u32, wbits as u32, g);

    ctr.charge(InstrClass::Bit, 2 * l.cin as u64);
    ctr.charge(InstrClass::Alu, l.cin as u64);
    let co = l.cout as u64;
    ctr.charge(InstrClass::Load, co * ((l.cin * wbits as usize).div_ceil(32)) as u64);
    ctr.charge(InstrClass::MulLong, co * n_groups);
    ctr.charge(InstrClass::Bit, co * 2 * n_groups);
    ctr.charge(InstrClass::Alu, co * (n_groups + 2));
    ctr.charge(InstrClass::Store, co);
}

// ---------------------------------------------------------------------------
// Baselines (mirror of ops::baselines::charge_conv)
// ---------------------------------------------------------------------------

fn unpack_bit_ops(method: Method, eff_bits: u8) -> u64 {
    match (method, eff_bits) {
        (Method::Simd, _) => 4,
        (Method::TinyEngine, _) => 2,
        (Method::CmixNn, 8) => 4,
        (Method::CmixNn, 4) => 8,
        (Method::CmixNn, 2) => 10,
        (Method::WpcDdd, 8) => 4,
        (Method::WpcDdd, 4) => 6,
        (Method::WpcDdd, 2) => 8,
        _ => 4,
    }
}

fn loads_per_4macs(method: Method, wbits: u8, abits: u8) -> f64 {
    match method {
        Method::Naive => 8.0,
        Method::Simd | Method::TinyEngine => 2.0,
        Method::CmixNn | Method::WpcDdd => {
            (4.0 * wbits as f64 / 32.0) + (4.0 * abits as f64 / 32.0)
        }
        _ => 2.0,
    }
}

fn predict_baseline(l: &LayerSpec, method: Method, wbits: u8, abits: u8, ctr: &mut Counter) {
    let macs = l.macs;
    let outputs = l.out_elems() as u64;
    let (we, ae) = method.effective_bits(wbits, abits);
    match method {
        Method::Naive => {
            ctr.charge(InstrClass::Load, 2 * macs);
            ctr.charge(InstrClass::Mul, macs);
            ctr.charge(InstrClass::Alu, macs);
            ctr.charge(InstrClass::Alu, 3 * outputs);
            ctr.charge(InstrClass::BranchTaken, outputs);
        }
        Method::Simd | Method::TinyEngine | Method::CmixNn | Method::WpcDdd => {
            let groups = macs.div_ceil(4);
            ctr.charge(InstrClass::Simd, 2 * groups);
            ctr.charge(
                InstrClass::Load,
                (groups as f64 * loads_per_4macs(method, we, ae)).ceil() as u64,
            );
            ctr.charge(InstrClass::Bit, groups * unpack_bit_ops(method, we.max(ae)));
            if method == Method::WpcDdd {
                ctr.charge(InstrClass::Load, macs.div_ceil(8));
            }
            if matches!(method, Method::CmixNn | Method::WpcDdd) {
                ctr.charge(InstrClass::Mul, outputs);
                ctr.charge(InstrClass::Alu, outputs);
            }
            let (alu_per_out, branch_per_out) = match method {
                Method::TinyEngine => (2u64, 1u64),
                _ => (4, 4),
            };
            ctr.charge(InstrClass::Alu, alu_per_out * outputs);
            ctr.charge(InstrClass::BranchTaken, (branch_per_out * outputs).div_ceil(4));
        }
        _ => unreachable!("SLBC predicted in predict_slbc"),
    }
    if l.kind == LayerKind::Dense {
        ctr.charge(InstrClass::Store, outputs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    #[test]
    fn predictions_nonzero_for_all_methods() {
        let m = vgg_tiny(10, 16);
        for l in &m.layers {
            for method in Method::ALL {
                let p = predict_layer(l, method, 4, 4);
                assert!(
                    p.counter.instructions() > 0,
                    "{} on {}",
                    method.name(),
                    l.name
                );
            }
        }
    }

    #[test]
    fn predict_model_is_layer_sum() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let whole = predict_model(&m, Method::Slbc, &cfg);
        let mut acc = Counter::new();
        for l in &m.layers {
            acc.merge(&predict_layer(l, Method::Slbc, 4, 4).counter);
        }
        assert_eq!(whole.counter, acc);
    }

    #[test]
    fn naive_prediction_closed_form() {
        let m = vgg_tiny(10, 16);
        let l = &m.layers[0];
        let p = predict_layer(l, Method::Naive, 8, 8);
        let outputs = l.out_elems() as u64;
        assert_eq!(p.counter.mul, l.macs);
        assert_eq!(p.counter.load, 2 * l.macs);
        assert_eq!(p.counter.alu, l.macs + 3 * outputs);
    }
}
