//! Calibration of the Eq. 12 coefficients against the MCU simulator.
//!
//! The paper obtains `α` and `β` "with experiments" on the STM32F746; our
//! substitute testbed is the cycle-approximate simulator, so calibration
//! runs the bit-exact operators over a probe set of layers/bitwidths,
//! collects `(C_SISD, C_SIMD, C_bit, cycles)` samples and solves the
//! intercept-free least-squares system
//!
//! ```text
//! cycles ≈ s·C_SISD + a·C_SIMD + b·C_bit,   α = a/s,  β = b/s
//! ```
//!
//! (linear in `(s, a, b)`). The fit quality (max relative error) is
//! reported so EXPERIMENTS.md can quote how faithful the Eq. 12 proxy is
//! on this testbed.

use crate::mcu::{Counter, CycleModel};
use crate::models::{vgg_tiny, LayerSpec};
use crate::ops::Method;
use crate::util::prng::Rng;

use super::PerfModel;

/// Result of a calibration run.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub model: PerfModel,
    /// Scale factor `s` (cycles per SISD instruction).
    pub scale: f64,
    /// Max relative error of `s·C` vs measured cycles over the probe set.
    pub max_rel_err: f64,
    /// Number of probe samples used.
    pub samples: usize,
}

/// Run `method` on `layer` with fresh random operands and return the
/// charged instruction histogram.
pub fn measure_layer(
    layer: &LayerSpec,
    method: Method,
    wbits: u8,
    abits: u8,
    seed: u64,
) -> Counter {
    let mut rng = Rng::new(seed);
    let xn = layer.in_elems();
    let wn = layer.w_size.max(match layer.kind {
        crate::models::LayerKind::Conv => layer.k * layer.k * layer.cin * layer.cout,
        crate::models::LayerKind::DwConv => layer.k * layer.k * layer.cout,
        crate::models::LayerKind::Dense => layer.cin * layer.cout,
    });
    let x: Vec<u32> = (0..xn).map(|_| rng.below(1 << abits) as u32).collect();
    let lim = (1i64 << (wbits - 1)) - 1;
    let w: Vec<i32> = (0..wn)
        .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
        .collect();
    let mut ctr = Counter::new();
    method.run_layer(&x, &w, layer, wbits, abits, &mut ctr);
    ctr
}

/// Default probe set: a few VGG-Tiny-shaped layers shrunk to keep the
/// calibration fast, crossed with methods and bitwidths.
fn probe_layers() -> Vec<LayerSpec> {
    let m = vgg_tiny(10, 16);
    let mut probes = Vec::new();
    for (idx, shrink) in [(0usize, 2usize), (2, 2), (5, 1)] {
        let mut l = m.layers[idx].clone();
        if l.kind != crate::models::LayerKind::Dense {
            l.in_h /= shrink;
            l.in_w /= shrink;
            l.out_h /= shrink;
            l.out_w /= shrink;
        }
        l.macs = l.compute_macs();
        probes.push(l);
    }
    probes
}

/// Fit `(α, β)` from operator runs under `cycles`; see module docs.
pub fn calibrate_alpha_beta(cycles: &CycleModel) -> Calibration {
    let methods = [Method::Naive, Method::Simd, Method::CmixNn, Method::Slbc, Method::RpSlbc];
    let bit_pairs: [(u8, u8); 4] = [(2, 2), (4, 4), (8, 8), (4, 8)];

    // Collect samples.
    let mut rows: Vec<[f64; 3]> = Vec::new();
    let mut ys: Vec<f64> = Vec::new();
    for (li, layer) in probe_layers().iter().enumerate() {
        for (mi, &method) in methods.iter().enumerate() {
            for (bi, &(wb, ab)) in bit_pairs.iter().enumerate() {
                if !method.supports(wb, ab) {
                    continue;
                }
                let seed = 1000 + (li * 100 + mi * 10 + bi) as u64;
                let ctr = measure_layer(layer, method, wb, ab, seed);
                let (sisd, simd, bit) = ctr.eq12_components();
                rows.push([sisd as f64, simd as f64, bit as f64]);
                ys.push(ctr.cycles(cycles) as f64);
            }
        }
    }

    // Normal equations for least squares (3 unknowns: s, a, b).
    let mut ata = [[0.0f64; 3]; 3];
    let mut atb = [0.0f64; 3];
    for (r, &y) in rows.iter().zip(&ys) {
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += r[i] * r[j];
            }
            atb[i] += r[i] * y;
        }
    }
    let coef = solve3(ata, atb).expect("calibration system is well-posed");
    let (s, a, b) = (coef[0], coef[1], coef[2]);
    let model = PerfModel {
        alpha: a / s,
        beta: b / s,
    };

    let mut max_rel = 0.0f64;
    for (r, &y) in rows.iter().zip(&ys) {
        let pred = s * r[0] + a * r[1] + b * r[2];
        let rel = ((pred - y) / y).abs();
        max_rel = max_rel.max(rel);
    }
    Calibration {
        model,
        scale: s,
        max_rel_err: max_rel,
        samples: rows.len(),
    }
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // Pivot.
        let piv = (col..3).max_by(|&i, &j| {
            a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..3 {
            let f = a[row][col] / a[col][col];
            for k in col..3 {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut s = b[row];
        for k in (row + 1)..3 {
            s -= a[row][k] * x[k];
        }
        x[row] = s / a[row][row];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::predict::predict_layer;

    #[test]
    fn solve3_identity() {
        let x = solve3([[1.0, 0.0, 0.0], [0.0, 2.0, 0.0], [0.0, 0.0, 4.0]], [3.0, 4.0, 8.0])
            .unwrap();
        assert_eq!(x, [3.0, 2.0, 2.0]);
    }

    #[test]
    fn calibration_recovers_sane_coefficients() {
        let cal = calibrate_alpha_beta(&CycleModel::cortex_m7());
        assert!(cal.samples > 20, "samples {}", cal.samples);
        assert!(cal.model.alpha > 0.0, "alpha {}", cal.model.alpha);
        assert!(cal.model.beta > 0.0, "beta {}", cal.model.beta);
        // The fit must explain the probe set well — this is the claim that
        // the Eq. 12 proxy tracks MCU latency (paper §V.C).
        assert!(cal.max_rel_err < 0.35, "max rel err {}", cal.max_rel_err);
    }

    #[test]
    fn prediction_matches_measurement_exactly() {
        // predict.rs mirrors ops charging term by term; charging is
        // geometry-determined, so the histograms must be identical.
        for layer in probe_layers() {
            for method in Method::ALL {
                for (wb, ab) in [(2u8, 2u8), (4, 4), (8, 8), (3, 5)] {
                    if !method.supports(wb, ab) {
                        continue;
                    }
                    let measured = measure_layer(&layer, method, wb, ab, 7);
                    let predicted = predict_layer(&layer, method, wb, ab);
                    assert_eq!(
                        predicted.counter, measured,
                        "{} {}x{} on {}",
                        method.name(),
                        wb,
                        ab,
                        layer.name
                    );
                }
            }
        }
    }
}
