//! Conv hot-path trend line: host-side throughput and modeled cycles of
//! the SLBC operator stack, per method and bitwidth.
//!
//! This is the repo's first conv-kernel perf trajectory (the fig5–fig8
//! benches track *modeled* MCU cycles; serving tracks virtual-time
//! throughput — neither watches the host-side cost of the operator
//! itself, which is what bounds simulation and serving speed). The
//! protocol compares, on a fixed layer set:
//!
//! * the **rolling-row pipeline** over a pre-packed
//!   [`LayerKernel`](crate::ops::slbc::LayerKernel) and caller-owned
//!   [`ConvScratch`](crate::ops::slbc::ConvScratch) — the steady state a
//!   serve request pays after this PR;
//! * the **legacy operator** ([`crate::ops::slbc::legacy`]) — the
//!   re-fetch/re-pack-per-output-row implementation each request paid
//!   before it (retained verbatim for exactly this comparison).
//!
//! Both are bit-exact with the direct-convolution oracle, so the ratio is
//! pure pipeline overhead. Results are emitted as an aligned table plus a
//! single JSON line in the same style as `serve_throughput`, consumed by
//! `benches/conv_hotpath.rs` and the `bench-conv` CLI subcommand (CI runs
//! the latter in smoke mode and archives the JSON per PR).

use std::collections::BTreeMap;

use crate::mcu::{Counter, CycleModel};
use crate::models::{LayerKind, LayerSpec};
use crate::ops::slbc::{self, ConvScratch, LayerKernel};
use crate::util::bench::{human_ns, Bench, Table};
use crate::util::json::Json;

/// Bench configuration.
#[derive(Debug, Clone)]
pub struct ConvBenchCfg {
    /// Timed iterations per case.
    pub repeats: usize,
    /// Warmup iterations per case.
    pub warmup: usize,
    /// Smoke mode: small shapes, minimal repeats (CI trend line).
    pub smoke: bool,
}

impl Default for ConvBenchCfg {
    fn default() -> Self {
        ConvBenchCfg {
            repeats: 20,
            warmup: 3,
            smoke: false,
        }
    }
}

impl ConvBenchCfg {
    pub fn smoke() -> Self {
        ConvBenchCfg {
            repeats: 1,
            warmup: 1,
            smoke: true,
        }
    }
}

/// One measured (layer, method, bitwidth) case.
#[derive(Debug, Clone)]
pub struct ConvCase {
    pub layer: String,
    pub kind: LayerKind,
    pub k: usize,
    pub method: &'static str,
    pub wbits: u8,
    pub abits: u8,
    /// Host ns per layer, rolling-row pipeline over a cached kernel.
    pub host_ns: f64,
    /// Host ns per layer, pre-PR operator (per-request packing).
    pub host_ns_legacy: f64,
    /// Modeled cycles per layer, rolling-row charging.
    pub cycles: u64,
    /// Modeled cycles per layer, pre-PR charging.
    pub cycles_legacy: u64,
}

impl ConvCase {
    pub fn speedup(&self) -> f64 {
        self.host_ns_legacy / self.host_ns.max(1e-9)
    }
}

/// Whole-run report.
#[derive(Debug, Clone)]
pub struct ConvHotpathReport {
    pub cases: Vec<ConvCase>,
    pub smoke: bool,
}

/// The fixed layer set: stride-1 k=3 convs of both backbone families
/// (where the paper's speedup claim lives), a depthwise layer (the
/// charging-fix target) and a pointwise conv (k=1, single-row ring).
fn bench_layers(smoke: bool) -> Vec<LayerSpec> {
    let hw = if smoke { 6 } else { 12 };
    let (c_small, c_mid) = if smoke { (4, 8) } else { (8, 16) };
    let mk = |name: &str, kind: LayerKind, cin: usize, cout: usize, k: usize| -> LayerSpec {
        let mut l = crate::models::vgg_tiny(10, 16).layers[0].clone();
        l.name = name.into();
        l.kind = kind;
        l.cin = cin;
        l.cout = cout;
        l.k = k;
        l.in_h = hw;
        l.in_w = hw;
        l.out_h = hw;
        l.out_w = hw;
        l.macs = l.compute_macs();
        l
    };
    vec![
        mk("conv3x3_a", LayerKind::Conv, c_small, c_mid, 3),
        mk("conv3x3_b", LayerKind::Conv, c_mid, c_mid, 3),
        mk("dwconv3x3", LayerKind::DwConv, c_mid, c_mid, 3),
        mk("pwconv1x1", LayerKind::Conv, c_mid, c_mid, 1),
    ]
}

/// Run the protocol.
pub fn run(cfg: &ConvBenchCfg) -> ConvHotpathReport {
    let cm = CycleModel::cortex_m7();
    let bench = Bench::new(cfg.warmup, cfg.repeats.max(1));
    let bit_pairs: &[(u8, u8)] = if cfg.smoke {
        &[(2, 2), (4, 4)]
    } else {
        &[(2, 2), (4, 4), (8, 8), (4, 8)]
    };
    let mut cases = Vec::new();
    for l in bench_layers(cfg.smoke) {
        for &(wb, ab) in bit_pairs {
            for (method, reordered) in [("slbc", false), ("rp-slbc", true)] {
                let (x, w) =
                    crate::ops::common::rand_layer_operands(&l, wb, ab, 40 + wb as u64 * 5 + ab as u64);
                let kern = LayerKernel::build(&w, &l, wb, ab, reordered);
                let mut scratch = ConvScratch::new();

                // Bit-exactness guard: the two operators must agree before
                // their speeds are compared.
                let mut c_new = Counter::new();
                let got =
                    slbc::run_layer_with_scratch(&x, &l, &kern, &mut c_new, &mut scratch);
                let mut c_old = Counter::new();
                let want = slbc::legacy::run_layer(&x, &w, &l, wb, ab, reordered, &mut c_old);
                assert_eq!(got, want, "{} {method} w{wb}a{ab}: operators disagree", l.name);

                let t_new = bench.run("rolling", || {
                    let mut ctr = Counter::new();
                    slbc::run_layer_with_scratch(&x, &l, &kern, &mut ctr, &mut scratch)
                });
                let t_old = bench.run("legacy", || {
                    let mut ctr = Counter::new();
                    slbc::legacy::run_layer(&x, &w, &l, wb, ab, reordered, &mut ctr)
                });
                cases.push(ConvCase {
                    layer: l.name.clone(),
                    kind: l.kind,
                    k: l.k,
                    method,
                    wbits: wb,
                    abits: ab,
                    host_ns: t_new.mean_ns,
                    host_ns_legacy: t_old.mean_ns,
                    cycles: c_new.cycles(&cm),
                    cycles_legacy: c_old.cycles(&cm),
                });
            }
        }
    }
    ConvHotpathReport {
        cases,
        smoke: cfg.smoke,
    }
}

impl ConvHotpathReport {
    /// Mean host-side speedup over the stride-1 k=3 regular conv cases —
    /// the acceptance headline.
    pub fn mean_speedup_conv3x3(&self) -> f64 {
        let v: Vec<f64> = self
            .cases
            .iter()
            .filter(|c| c.kind == LayerKind::Conv && c.k == 3)
            .map(|c| c.speedup())
            .collect();
        if v.is_empty() {
            return 0.0;
        }
        v.iter().sum::<f64>() / v.len() as f64
    }

    /// Mean modeled-cycle ratio (legacy / rolling) over all cases: > 1
    /// where the amortized charging pays off, exactly 1 where a layer has
    /// no row reuse to exploit (k=1), and < 1 for depthwise layers, whose
    /// per-channel row work the legacy operator never charged.
    pub fn mean_cycle_ratio(&self) -> f64 {
        let v: Vec<f64> = self
            .cases
            .iter()
            .map(|c| c.cycles_legacy as f64 / c.cycles.max(1) as f64)
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    }

    /// Deterministic acceptance gate (safe for single-repeat smoke runs):
    /// the rolling pipeline must never charge more modeled cycles than
    /// the pre-PR operator on regular convs — row work only amortizes.
    pub fn check_cycle_invariant(&self) -> Result<(), String> {
        for c in self.cases.iter().filter(|c| c.kind == LayerKind::Conv) {
            if c.cycles > c.cycles_legacy {
                return Err(format!(
                    "{} {}: rolling pipeline charges more than the pre-PR operator ({} vs {})",
                    c.layer, c.method, c.cycles, c.cycles_legacy
                ));
            }
        }
        Ok(())
    }

    /// Wall-clock acceptance gate (full mode only — single-repeat means
    /// are too noisy to fail a build over): mean host speedup on stride-1
    /// k=3 convs must reach `min`.
    pub fn check_speedup(&self, min: f64) -> Result<(), String> {
        let sp = self.mean_speedup_conv3x3();
        if sp < min {
            Err(format!(
                "mean k=3 conv host speedup {sp:.2}x below the required {min:.1}x"
            ))
        } else {
            Ok(())
        }
    }

    /// Aligned table of every case.
    pub fn render(&self) -> String {
        let mut t = Table::new(vec![
            "layer", "method", "w", "a", "host/layer", "legacy", "speedup", "cycles",
            "legacy cyc",
        ]);
        for c in &self.cases {
            t.row(vec![
                c.layer.clone(),
                c.method.to_string(),
                format!("{}", c.wbits),
                format!("{}", c.abits),
                human_ns(c.host_ns),
                human_ns(c.host_ns_legacy),
                format!("{:.2}x", c.speedup()),
                format!("{}", c.cycles),
                format!("{}", c.cycles_legacy),
            ]);
        }
        t.render()
    }

    /// One-line JSON summary (the per-PR trend record).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("bench".into(), Json::Str("conv_hotpath".into()));
        o.insert("smoke".into(), Json::Bool(self.smoke));
        o.insert(
            "mean_speedup_conv3x3".into(),
            Json::Num(self.mean_speedup_conv3x3()),
        );
        o.insert("mean_cycle_ratio".into(), Json::Num(self.mean_cycle_ratio()));
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                let mut e = BTreeMap::new();
                e.insert("layer".into(), Json::Str(c.layer.clone()));
                e.insert("method".into(), Json::Str(c.method.into()));
                e.insert("wbits".into(), Json::Num(c.wbits as f64));
                e.insert("abits".into(), Json::Num(c.abits as f64));
                e.insert("host_ns".into(), Json::Num(c.host_ns));
                e.insert("host_ns_legacy".into(), Json::Num(c.host_ns_legacy));
                e.insert("speedup".into(), Json::Num(c.speedup()));
                e.insert("cycles".into(), Json::Num(c.cycles as f64));
                e.insert("cycles_legacy".into(), Json::Num(c.cycles_legacy as f64));
                Json::Obj(e)
            })
            .collect();
        o.insert("cases".into(), Json::Arr(cases));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_run_produces_consistent_report() {
        let rep = run(&ConvBenchCfg::smoke());
        assert!(!rep.cases.is_empty());
        for c in &rep.cases {
            assert!(c.host_ns > 0.0 && c.host_ns_legacy > 0.0, "{}", c.layer);
            assert!(c.cycles > 0 && c.cycles_legacy > 0, "{}", c.layer);
        }
        // The shared deterministic gate every entry point enforces.
        rep.check_cycle_invariant().unwrap();
        let json = rep.to_json().to_string_compact();
        assert!(json.contains("conv_hotpath"));
        assert!(json.contains("mean_speedup_conv3x3"));
    }
}
