//! Packing performance prediction (paper §IV.D, Eq. 12).
//!
//! The HW/SW co-design loop needs the complexity of every layer at every
//! `(weight-bits, activation-bits)` pair — `L × K × K` evaluations per
//! backbone, re-queried as the search anneals. Deploying each candidate on
//! the (simulated) MCU would be orders of magnitude too slow, so MCU-MixQ
//! predicts cost analytically:
//!
//! ```text
//! C = C_SISD + α · C_SIMD + β · C_bit            (Eq. 12)
//! ```
//!
//! where the three components are *instruction counts* by class (scalar,
//! DSP/SIMD and bit-manipulation) derived from the layer geometry and the
//! operator's kernel structure, and `α`, `β` calibrate the classes' cycle
//! costs against scalar instructions.
//!
//! Fidelity contract: [`predict_layer`] mirrors, term by term, the
//! instruction charging of the bit-exact operators in [`crate::ops`]; the
//! agreement is enforced by the [`calibrate`] tests (prediction equals
//! measurement for the geometry-determined operators). The EdMIPS-style
//! MAC-count proxy the paper compares against in Fig. 8 is [`mac_proxy`].

pub mod calibrate;
pub mod conv_hotpath;
pub mod roofline;
pub mod predict;

pub use calibrate::{calibrate_alpha_beta, measure_layer, Calibration};
pub use predict::{predict_layer, predict_model, PredictedCost};

use crate::mcu::CycleModel;
use crate::models::{LayerSpec, ModelDesc};
use crate::ops::Method;
use crate::quant::BitConfig;

/// The Eq. 12 performance model: proportion coefficients for the SIMD and
/// bit-operation instruction classes relative to SISD instructions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfModel {
    pub alpha: f64,
    pub beta: f64,
}

impl PerfModel {
    /// Coefficients implied by a cycle model (the paper obtains them "with
    /// experiments"; we fit them from the simulator's cycle table — see
    /// [`calibrate_alpha_beta`] for the measured fit).
    pub fn from_cycles(m: &CycleModel) -> PerfModel {
        let (alpha, beta) = m.alpha_beta();
        PerfModel { alpha, beta }
    }

    /// Default model: Cortex-M7 coefficients.
    pub fn cortex_m7() -> PerfModel {
        PerfModel::from_cycles(&CycleModel::cortex_m7())
    }

    /// Coefficients for a named [`Target`](crate::target::Target) — the
    /// registry-routed way to build the Eq. 12 model for whatever core
    /// the pipeline is deploying to.
    pub fn for_target(t: &crate::target::Target) -> PerfModel {
        PerfModel::from_cycles(&t.cycle_model)
    }

    /// Eq. 12: collapse an instruction-class decomposition into the scalar
    /// complexity metric.
    pub fn complexity(&self, sisd: f64, simd: f64, bit: f64) -> f64 {
        sisd + self.alpha * simd + self.beta * bit
    }

    /// Predicted complexity of one layer under `method` at `(wbits, abits)`.
    pub fn layer_complexity(
        &self,
        layer: &LayerSpec,
        method: Method,
        wbits: u8,
        abits: u8,
    ) -> f64 {
        let p = predict_layer(layer, method, wbits, abits);
        self.complexity(p.sisd as f64, p.simd as f64, p.bit as f64)
    }

    /// Predicted complexity of a whole model under a bit configuration.
    pub fn model_complexity(&self, model: &ModelDesc, method: Method, cfg: &BitConfig) -> f64 {
        model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| self.layer_complexity(l, method, cfg.wbits[i], cfg.abits[i]))
            .sum()
    }
}

/// The EdMIPS-style complexity proxy the paper's Fig. 8 baseline uses:
/// effective MACs weighted by `wbits·abits / 64` (bit-operations count of
/// the multiply), blind to packing/segmentation overheads and to the
/// non-proportional implementation efficiency of SLBC.
pub fn mac_proxy(layer: &LayerSpec, wbits: u8, abits: u8) -> f64 {
    layer.macs as f64 * (wbits as f64 * abits as f64) / 64.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    #[test]
    fn eq12_linear_form() {
        let pm = PerfModel { alpha: 2.0, beta: 0.5 };
        assert_eq!(pm.complexity(10.0, 4.0, 8.0), 10.0 + 8.0 + 4.0);
    }

    #[test]
    fn m7_coefficients_positive() {
        let pm = PerfModel::cortex_m7();
        assert!(pm.alpha > 0.0 && pm.beta > 0.0);
    }

    #[test]
    fn complexity_monotonic_in_bits_for_slbc() {
        // Fewer bits -> more operands per register -> lower complexity.
        let pm = PerfModel::cortex_m7();
        let m = vgg_tiny(10, 16);
        let l = &m.layers[2];
        let c2 = pm.layer_complexity(l, Method::Slbc, 2, 2);
        let c4 = pm.layer_complexity(l, Method::Slbc, 4, 4);
        let c8 = pm.layer_complexity(l, Method::Slbc, 8, 8);
        assert!(c2 < c4 && c4 < c8, "c2={c2} c4={c4} c8={c8}");
    }

    #[test]
    fn mac_proxy_proportional_to_bit_product() {
        let m = vgg_tiny(10, 16);
        let l = &m.layers[0];
        let p44 = mac_proxy(l, 4, 4);
        let p88 = mac_proxy(l, 8, 8);
        assert!((p88 / p44 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn model_complexity_sums_layers() {
        let pm = PerfModel::cortex_m7();
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let total = pm.model_complexity(&m, Method::Slbc, &cfg);
        let by_hand: f64 = m
            .layers
            .iter()
            .map(|l| pm.layer_complexity(l, Method::Slbc, 4, 4))
            .sum();
        assert_eq!(total, by_hand);
    }
}
