//! Per-layer quantization-error accuracy proxy.
//!
//! No labeled evaluation exists offline, so the native search scores
//! candidates by signal-to-quantization-noise ratio (SQNR): round-trip
//! each layer's weights through [`quantize_weights`]/[`dequantize_weights`]
//! and a seeded synthetic activation sample through [`quantize_acts`],
//! measure error power against signal power in dB, and MAC-weight the
//! per-layer scores (a mis-quantized heavy layer hurts more than a light
//! one). The whole `[L, K, K]` grid is precomputed once per search — a
//! candidate's proxy is then a table lookup, which is what lets the DP
//! and the evolutionary loop score thousands of configs cheaply.

use crate::models::ModelDesc;
use crate::quant::{dequantize_weights, quantize_acts, quantize_weights};
use crate::util::prng::Rng;

/// SQNR ceiling (dB): a round-trip with vanishing error saturates here
/// instead of diverging, keeping the proxy finite and comparable.
pub const SQNR_CAP_DB: f64 = 96.0;

/// Activation sample size per layer for the activation-side SQNR.
const ACT_SAMPLES: usize = 256;

fn sqnr_db(signal: &[f32], recon: impl Iterator<Item = f32>) -> f64 {
    let mut p_sig = 0.0f64;
    let mut p_err = 0.0f64;
    for (&s, r) in signal.iter().zip(recon) {
        p_sig += (s as f64) * (s as f64);
        p_err += (s as f64 - r as f64) * (s as f64 - r as f64);
    }
    if p_sig <= 0.0 {
        return 0.0;
    }
    if p_err <= 0.0 {
        return SQNR_CAP_DB;
    }
    (10.0 * (p_sig / p_err).log10()).clamp(0.0, SQNR_CAP_DB)
}

/// Precomputed per-layer SQNR grid over the bit options: `q[l][i][j]` is
/// layer `l`'s quality (dB) at `(wbits = options[i], abits = options[j])`,
/// the mean of the weight and activation round-trip SQNRs.
#[derive(Debug, Clone)]
pub struct QualityTable {
    pub options: Vec<u8>,
    pub num_layers: usize,
    q: Vec<f64>,
    mac_share: Vec<f64>,
}

impl QualityTable {
    /// Build the grid from the model's real weights (`params`, the flat
    /// parameter vector) and seeded half-normal activation samples. The
    /// samples depend only on `(seed, layer)`, never on the candidate
    /// bits, so scores are comparable across configurations.
    pub fn build(model: &ModelDesc, params: &[f32], options: &[u8], seed: u64) -> QualityTable {
        let k = options.len();
        let lnum = model.num_layers();
        let total_macs = model.total_macs().max(1) as f64;
        let mut q = vec![0.0f64; lnum * k * k];
        let mut mac_share = Vec::with_capacity(lnum);
        let base = Rng::new(seed);
        for (l, layer) in model.layers.iter().enumerate() {
            mac_share.push(layer.macs as f64 / total_macs);
            let w = &params[layer.w_offset..layer.w_offset + layer.w_size];
            // Half-normal activation sample (post-ReLU shape), fixed per
            // (seed, layer).
            let mut rng = base.clone().fork(l as u64 + 1);
            let acts: Vec<f32> = (0..ACT_SAMPLES).map(|_| rng.normal().abs()).collect();
            let w_sqnr: Vec<f64> = options
                .iter()
                .map(|&wb| {
                    let qw = quantize_weights(w, wb);
                    sqnr_db(w, dequantize_weights(&qw).into_iter())
                })
                .collect();
            let a_sqnr: Vec<f64> = options
                .iter()
                .map(|&ab| {
                    let qa = quantize_acts(&acts, ab);
                    sqnr_db(&acts, qa.data.iter().map(|&v| v as f32 * qa.scale))
                })
                .collect();
            for i in 0..k {
                for j in 0..k {
                    q[(l * k + i) * k + j] = 0.5 * (w_sqnr[i] + a_sqnr[j]);
                }
            }
        }
        QualityTable {
            options: options.to_vec(),
            num_layers: lnum,
            q,
            mac_share,
        }
    }

    fn idx_of(&self, b: u8) -> usize {
        self.options
            .iter()
            .position(|&o| o == b)
            .unwrap_or_else(|| panic!("bitwidth {b} outside search options"))
    }

    /// Layer `l`'s SQNR (dB) at `(wbits, abits)`.
    pub fn at(&self, l: usize, wbits: u8, abits: u8) -> f64 {
        let k = self.options.len();
        self.q[(l * k + self.idx_of(wbits)) * k + self.idx_of(abits)]
    }

    /// MAC share of layer `l` in the whole model (the proxy's weights).
    pub fn mac_share(&self, l: usize) -> f64 {
        self.mac_share[l]
    }

    /// MAC-weighted model SQNR (dB) of a full configuration — the search's
    /// accuracy-proxy objective (higher is better).
    pub fn proxy(&self, cfg: &crate::quant::BitConfig) -> f64 {
        (0..self.num_layers)
            .map(|l| self.mac_share[l] * self.at(l, cfg.wbits[l], cfg.abits[l]))
            .sum()
    }

    /// MAC-weighted quality *drop* of layer `l` at `(w, a)` relative to
    /// the best option pair — the DP's per-layer error cost (>= 0).
    pub fn err_cost(&self, l: usize, wbits: u8, abits: u8) -> f64 {
        let k = self.options.len();
        let best = (0..k * k)
            .map(|ij| self.q[l * k * k + ij])
            .fold(f64::NEG_INFINITY, f64::max);
        self.mac_share[l] * (best - self.at(l, wbits, abits))
    }
}

/// One-shot MAC-weighted SQNR proxy (dB) for a single configuration —
/// convenience wrapper over [`QualityTable`] for callers outside the
/// search loop (benches, reports).
pub fn accuracy_proxy(
    model: &ModelDesc,
    params: &[f32],
    cfg: &crate::quant::BitConfig,
    seed: u64,
) -> f64 {
    let mut options: Vec<u8> = cfg.wbits.iter().chain(&cfg.abits).copied().collect();
    options.sort_unstable();
    options.dedup();
    QualityTable::build(model, params, &options, seed).proxy(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;
    use crate::quant::BitConfig;

    fn setup() -> (ModelDesc, Vec<f32>) {
        let m = vgg_tiny(10, 16);
        let mut rng = Rng::new(11);
        let params = (0..m.param_count).map(|_| rng.normal() * 0.1).collect();
        (m, params)
    }

    #[test]
    fn more_bits_better_proxy() {
        let (m, params) = setup();
        let t = QualityTable::build(&m, &params, &[2, 4, 8], 5);
        let p2 = t.proxy(&BitConfig::uniform(m.num_layers(), 2));
        let p4 = t.proxy(&BitConfig::uniform(m.num_layers(), 4));
        let p8 = t.proxy(&BitConfig::uniform(m.num_layers(), 8));
        assert!(p2 < p4 && p4 < p8, "{p2} < {p4} < {p8} violated");
        assert!(p8 <= SQNR_CAP_DB);
    }

    #[test]
    fn err_cost_zero_at_best_pair() {
        let (m, params) = setup();
        let t = QualityTable::build(&m, &params, &[2, 4, 8], 5);
        for l in 0..m.num_layers() {
            // 8/8 is the highest-SQNR pair, so its drop is ~0.
            assert!(t.err_cost(l, 8, 8) < 1e-9);
            assert!(t.err_cost(l, 2, 2) > 0.0);
        }
    }

    #[test]
    fn proxy_deterministic_and_seed_sensitive_samples() {
        let (m, params) = setup();
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let a = accuracy_proxy(&m, &params, &cfg, 5);
        let b = accuracy_proxy(&m, &params, &cfg, 5);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
