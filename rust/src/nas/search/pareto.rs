//! Pareto archive over the native search's four objective axes.
//!
//! Minimize cycles, joules and SRAM peak; maximize the accuracy proxy.
//! Flash footprint rides along in every point (it is the model-size axis
//! of the fig8 acceptance check) but is not a dominance axis — it is a
//! monotone function of `wbits`, which cycles already price.

use crate::quant::BitConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// The scored objectives of one feasible configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Objectives {
    /// Predicted single-inference cycles on the search target.
    pub cycles: u64,
    /// Predicted single-inference joules (dynamic + static).
    pub joules: f64,
    /// Static SRAM high-water mark: arena + kernel scratch.
    pub sram_peak_bytes: usize,
    /// Flash footprint: packed weights + biases + scales + code.
    pub flash_total_bytes: usize,
    /// MAC-weighted SQNR proxy in dB (higher is better).
    pub accuracy_proxy_db: f64,
}

impl Objectives {
    /// `self` dominates `other`: no objective worse, at least one
    /// strictly better.
    pub fn dominates(&self, other: &Objectives) -> bool {
        let no_worse = self.cycles <= other.cycles
            && self.joules <= other.joules
            && self.sram_peak_bytes <= other.sram_peak_bytes
            && self.accuracy_proxy_db >= other.accuracy_proxy_db;
        let strictly_better = self.cycles < other.cycles
            || self.joules < other.joules
            || self.sram_peak_bytes < other.sram_peak_bytes
            || self.accuracy_proxy_db > other.accuracy_proxy_db;
        no_worse && strictly_better
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("cycles".into(), Json::Num(self.cycles as f64));
        o.insert("joules".into(), Json::Num(self.joules));
        o.insert("sram_peak_bytes".into(), Json::Num(self.sram_peak_bytes as f64));
        o.insert("flash_total_bytes".into(), Json::Num(self.flash_total_bytes as f64));
        o.insert("accuracy_proxy".into(), Json::Num(self.accuracy_proxy_db));
        Json::Obj(o)
    }
}

/// One archived non-dominated configuration.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub cfg: BitConfig,
    pub obj: Objectives,
}

impl ParetoPoint {
    pub fn to_json(&self) -> Json {
        let mut o = match self.obj.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!(),
        };
        let bits = |v: &[u8]| Json::Arr(v.iter().map(|&b| Json::Num(b as f64)).collect());
        o.insert("wbits".into(), bits(&self.cfg.wbits));
        o.insert("abits".into(), bits(&self.cfg.abits));
        o.insert("avg_wbits".into(), Json::Num(self.cfg.avg_wbits()));
        o.insert("avg_abits".into(), Json::Num(self.cfg.avg_abits()));
        Json::Obj(o)
    }
}

/// A deterministic Pareto archive: insertion order is the tiebreak, and
/// [`sorted_points`](ParetoArchive::sorted_points) emits a canonical
/// cycles-ascending order, so a fixed seed reproduces the front
/// bit-for-bit.
#[derive(Debug, Clone, Default)]
pub struct ParetoArchive {
    points: Vec<ParetoPoint>,
}

impl ParetoArchive {
    pub fn new() -> Self {
        ParetoArchive::default()
    }

    /// Offer a scored configuration. Returns `true` if it entered the
    /// archive (i.e. no existing point dominates or duplicates it);
    /// dominated incumbents are evicted.
    pub fn insert(&mut self, cfg: BitConfig, obj: Objectives) -> bool {
        for p in &self.points {
            if p.obj.dominates(&obj) || (p.obj == obj && p.cfg == cfg) {
                return false;
            }
        }
        self.points.retain(|p| !obj.dominates(&p.obj));
        self.points.push(ParetoPoint { cfg, obj });
        true
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Archive members in insertion order (the evolutionary loop's
    /// parent pool).
    pub fn points(&self) -> &[ParetoPoint] {
        &self.points
    }

    /// The front in canonical order: cycles ascending, then SRAM, then
    /// joules, then the configuration bits — a total order, so equal
    /// fronts render identically.
    pub fn sorted_points(&self) -> Vec<ParetoPoint> {
        let mut pts = self.points.clone();
        pts.sort_by(|a, b| {
            a.obj
                .cycles
                .cmp(&b.obj.cycles)
                .then(a.obj.sram_peak_bytes.cmp(&b.obj.sram_peak_bytes))
                .then(a.obj.joules.total_cmp(&b.obj.joules))
                .then(a.cfg.wbits.cmp(&b.cfg.wbits))
                .then(a.cfg.abits.cmp(&b.cfg.abits))
        });
        pts
    }

    /// The minimum-cycles point (the fig8 acceptance row).
    pub fn best_cycles(&self) -> Option<ParetoPoint> {
        self.sorted_points().into_iter().next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(cycles: u64, joules: f64, sram: usize, acc: f64) -> Objectives {
        Objectives {
            cycles,
            joules,
            sram_peak_bytes: sram,
            flash_total_bytes: 0,
            accuracy_proxy_db: acc,
        }
    }

    fn cfg(b: u8) -> BitConfig {
        BitConfig::uniform(2, b)
    }

    #[test]
    fn dominance_axes() {
        let a = obj(100, 1.0, 10, 40.0);
        assert!(a.dominates(&obj(200, 1.0, 10, 40.0)));
        assert!(a.dominates(&obj(100, 2.0, 10, 30.0)));
        assert!(!a.dominates(&obj(100, 1.0, 10, 40.0))); // equal: no strict edge
        assert!(!a.dominates(&obj(50, 2.0, 10, 40.0))); // trade-off
        assert!(obj(50, 0.5, 5, 50.0).dominates(&a));
    }

    #[test]
    fn archive_keeps_tradeoffs_evicts_dominated() {
        let mut ar = ParetoArchive::new();
        assert!(ar.insert(cfg(8), obj(200, 2.0, 20, 60.0)));
        assert!(ar.insert(cfg(2), obj(100, 1.0, 10, 30.0))); // trade-off: both stay
        assert_eq!(ar.len(), 2);
        // Dominates the 8-bit point (same accuracy, cheaper everywhere).
        assert!(ar.insert(cfg(4), obj(150, 1.5, 15, 60.0)));
        assert_eq!(ar.len(), 2);
        // Dominated by the 2-bit point: rejected.
        assert!(!ar.insert(cfg(3), obj(120, 1.2, 12, 29.0)));
        assert_eq!(ar.len(), 2);
    }

    #[test]
    fn sorted_points_cycles_ascending() {
        let mut ar = ParetoArchive::new();
        ar.insert(cfg(8), obj(200, 2.0, 20, 60.0));
        ar.insert(cfg(2), obj(100, 1.0, 10, 30.0));
        let pts = ar.sorted_points();
        assert_eq!(pts[0].obj.cycles, 100);
        assert_eq!(ar.best_cycles().unwrap().obj.cycles, 100);
    }
}
