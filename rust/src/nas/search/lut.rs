//! MPIC-style effective-MACs/cycle LUT per `(a_bit, w_bit)` pair.
//!
//! Ottavi et al.'s MPIC core publishes a table of effective MACs/cycle
//! per activation × weight bitwidth — the shape every mixed-precision
//! search wants as its fast hardware cost. Here the same table falls out
//! of the repo's own [`CycleModel`](crate::mcu::CycleModel): price one
//! reference conv layer with [`crate::perf::predict_layer`] at every
//! `(w, a)` pair on a [`Target`] and divide the layer's MACs by the
//! predicted cycles. The LUT is the DP seeding cost of the native search
//! (cheap: one multiply per layer instead of a model compile) and a
//! reported diagnostic in the Pareto-front JSON.

use crate::models::{vgg_tiny, LayerSpec};
use crate::ops::Method;
use crate::perf::predict_layer;
use crate::target::Target;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Effective MACs/cycle per `(a_bit, w_bit)` pair on one target, derived
/// from the cycle model — the native analogue of the MPIC table.
#[derive(Debug, Clone)]
pub struct MacsPerCycleLut {
    /// Bit options, ascending (the table's axes).
    pub bits: Vec<u8>,
    /// Row-major `[a][w]` effective MACs/cycle.
    pub data: Vec<f64>,
    pub method: Method,
    /// Registry name of the target the table was priced on.
    pub target: &'static str,
}

/// The reference geometry the table is priced on: a mid-stack 3×3 conv
/// (vgg_tiny's conv2, 16→16 at 16×16) — packed-SIMD behavior without
/// dense-layer or first-layer edge cases.
fn reference_layer() -> LayerSpec {
    vgg_tiny(10, 16).layers[1].clone()
}

impl MacsPerCycleLut {
    /// Price the table for `method` on `target` over bit options 2..=8.
    pub fn for_target(target: &Target, method: Method) -> MacsPerCycleLut {
        let bits: Vec<u8> = (2..=8).collect();
        let layer = reference_layer();
        let mut data = Vec::with_capacity(bits.len() * bits.len());
        for &a in &bits {
            for &w in &bits {
                let cycles = predict_layer(&layer, method, w, a).cycles_on(target);
                data.push(layer.macs as f64 / cycles.max(1) as f64);
            }
        }
        MacsPerCycleLut {
            bits,
            data,
            method,
            target: target.name,
        }
    }

    /// Effective MACs/cycle at `(a_bit, w_bit)`.
    pub fn at(&self, abits: u8, wbits: u8) -> f64 {
        let idx = |b: u8| {
            self.bits
                .iter()
                .position(|&o| o == b)
                .unwrap_or_else(|| panic!("bitwidth {b} outside LUT options"))
        };
        self.data[idx(abits) * self.bits.len() + idx(wbits)]
    }

    /// Estimated cycles for `macs` multiply-accumulates at `(a, w)` — the
    /// DP's per-layer cost.
    pub fn est_cycles(&self, macs: u64, wbits: u8, abits: u8) -> f64 {
        macs as f64 / self.at(abits, wbits)
    }

    /// The table as JSON: `{"bits": [...], "macs_per_cycle": [[..w..] per a]}`.
    pub fn to_json(&self) -> Json {
        let k = self.bits.len();
        let rows: Vec<Json> = (0..k)
            .map(|i| Json::Arr(self.data[i * k..(i + 1) * k].iter().map(|&v| Json::Num(v)).collect()))
            .collect();
        let mut obj = BTreeMap::new();
        obj.insert("bits".into(), Json::Arr(self.bits.iter().map(|&b| Json::Num(b as f64)).collect()));
        obj.insert("macs_per_cycle".into(), Json::Arr(rows));
        obj.insert("method".into(), Json::Str(self.method.name().into()));
        obj.insert("target".into(), Json::Str(self.target.into()));
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn luts() -> Vec<MacsPerCycleLut> {
        ["stm32f746", "stm32f446"]
            .iter()
            .map(|n| MacsPerCycleLut::for_target(Target::resolve(n).unwrap(), Method::RpSlbc))
            .collect()
    }

    #[test]
    fn monotone_non_increasing_in_each_axis() {
        // More bits never buy throughput: MACs/cycle is non-increasing
        // along each of the a_bit and w_bit axes (MPIC table shape).
        for lut in luts() {
            for &a in &lut.bits {
                for win in lut.bits.windows(2) {
                    assert!(
                        lut.at(a, win[0]) >= lut.at(a, win[1]) - 1e-12,
                        "{}: a={a}: w{} -> w{} raised MACs/cycle",
                        lut.target,
                        win[0],
                        win[1]
                    );
                }
            }
            for &w in &lut.bits {
                for win in lut.bits.windows(2) {
                    assert!(
                        lut.at(win[0], w) >= lut.at(win[1], w) - 1e-12,
                        "{}: w={w}: a{} -> a{} raised MACs/cycle",
                        lut.target,
                        win[0],
                        win[1]
                    );
                }
            }
        }
    }

    #[test]
    fn diagonal_ordering_matches_mpic_shape() {
        // SNIPPETS.md Snippet 1 (MPIC, Ottavi et al.): the (2,2) corner
        // is strictly fastest and the diagonal decays toward (8,8) —
        // 6.5 > 3.5 > 2.1 in the reference table.
        for lut in luts() {
            let d2 = lut.at(2, 2);
            let d4 = lut.at(4, 4);
            let d8 = lut.at(8, 8);
            assert!(d2 > d4 && d4 > d8, "{}: {d2} > {d4} > {d8} violated", lut.target);
            assert!(d8 > 0.0);
        }
    }

    #[test]
    fn est_cycles_inverts_the_table() {
        let lut = luts().remove(0);
        let c = lut.est_cycles(1_000_000, 4, 4);
        assert!((c - 1_000_000.0 / lut.at(4, 4)).abs() < 1e-6);
    }

    #[test]
    fn json_shape() {
        let lut = luts().remove(0);
        let j = lut.to_json();
        assert_eq!(j.req("bits").unwrap().as_arr().unwrap().len(), 7);
        assert_eq!(j.req("macs_per_cycle").unwrap().as_arr().unwrap().len(), 7);
    }
}
