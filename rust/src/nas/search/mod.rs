//! Native mixed-precision co-design search (paper §III.B, no PJRT).
//!
//! The offline engine that closes the HW/SW co-design loop in pure Rust:
//!
//! 1. **DP seeding** — a dynamic program over the layer graph with the
//!    [`MacsPerCycleLut`] (MPIC-style effective MACs/cycle derived from
//!    the target's `CycleModel`) as its fast cycle cost and the
//!    MAC-weighted SQNR drop from [`QualityTable`] as its error budget.
//!    Sweeping the budget yields a spine of seed configurations from
//!    fastest-but-lossy to slowest-but-accurate.
//! 2. **Evolutionary refinement** — a seeded loop of mutation and
//!    crossover over [`BitConfig`]s, every candidate scored on the real
//!    objectives: cycles and joules from
//!    [`crate::perf::predict_model`] priced on the [`Target`], SRAM peak
//!    and flash from the static analyzer's [`ResourceAudit`], accuracy
//!    from the SQNR table. A [`ParetoArchive`] keeps the non-dominated
//!    set over cycles × joules × SRAM × accuracy.
//! 3. **Legality pruning** — candidates compile through
//!    [`CompiledModel::compile_unbounded_for`] and must pass
//!    [`crate::analysis::analyze`] with zero Error findings
//!    (lane-overflow, SRAM, flash, plan consistency) *before* they reach
//!    the archive; infeasible configs are never scored.
//!
//! Everything is driven by the seeded [`Rng`], so a fixed `--seed`
//! reproduces the front bit-for-bit.

pub mod accuracy;
pub mod lut;
pub mod pareto;

pub use accuracy::{accuracy_proxy, QualityTable};
pub use lut::MacsPerCycleLut;
pub use pareto::{Objectives, ParetoArchive, ParetoPoint};

use crate::analysis;
use crate::engine::CompiledModel;
use crate::models::ModelDesc;
use crate::ops::Method;
use crate::perf::predict_model;
use crate::quant::BitConfig;
use crate::target::Target;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::Result;
use std::collections::BTreeMap;

/// Native search configuration.
#[derive(Debug, Clone)]
pub struct NativeSearchCfg {
    /// Deployed kernel the candidates are compiled and priced with.
    pub method: Method,
    /// Bitwidth options per layer (paper: every width in `[2, 8]`).
    pub options: Vec<u8>,
    pub seed: u64,
    /// Evolutionary generations after DP seeding.
    pub generations: usize,
    /// Offspring per generation.
    pub population: usize,
    /// Error-budget buckets of the DP sweep (one seed per bucket).
    pub dp_buckets: usize,
}

impl Default for NativeSearchCfg {
    fn default() -> Self {
        NativeSearchCfg {
            method: Method::RpSlbc,
            options: (2..=8).collect(),
            seed: 7,
            generations: 8,
            population: 16,
            dp_buckets: 12,
        }
    }
}

impl NativeSearchCfg {
    /// The cheap protocol for tests and CI smokes.
    pub fn smoke(seed: u64) -> Self {
        NativeSearchCfg {
            seed,
            generations: 3,
            population: 8,
            dp_buckets: 8,
            ..NativeSearchCfg::default()
        }
    }
}

/// Everything one native search produced on one target.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub target: &'static str,
    pub front: Vec<ParetoPoint>,
    /// The uniform 8-bit baseline's objectives (always feasible on the
    /// registry targets — the row the front must beat).
    pub uniform8: Objectives,
    /// The MPIC-style diagnostic LUT the DP seeded from.
    pub lut: MacsPerCycleLut,
    /// Distinct configurations scored (compile + analyze + predict).
    pub evaluated: usize,
    /// Distinct configurations rejected by the legality oracle.
    pub pruned: usize,
}

impl SearchOutcome {
    /// The minimum-cycles front point.
    pub fn best_cycles(&self) -> &ParetoPoint {
        &self.front[0]
    }

    /// One target's JSON block for `search_pareto.json`.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("target".into(), Json::Str(self.target.into()));
        o.insert("front".into(), Json::Arr(self.front.iter().map(|p| p.to_json()).collect()));
        o.insert("uniform8".into(), self.uniform8.to_json());
        o.insert("lut".into(), self.lut.to_json());
        o.insert("evaluated".into(), Json::Num(self.evaluated as f64));
        o.insert("pruned".into(), Json::Num(self.pruned as f64));
        Json::Obj(o)
    }
}

/// The per-search evaluator: owns the quality table and the memo of
/// scored configs, and enforces the legality gate.
struct Evaluator<'a> {
    model: &'a ModelDesc,
    params: &'a [f32],
    target: &'a Target,
    method: Method,
    quality: QualityTable,
    cache: BTreeMap<(Vec<u8>, Vec<u8>), Option<Objectives>>,
    pruned: usize,
}

impl<'a> Evaluator<'a> {
    /// Score a candidate, or `None` if the method rejects its widths or
    /// the static analyzer finds any Error (lane overflow, SRAM/flash
    /// over budget, plan inconsistency). Memoized per configuration.
    fn evaluate(&mut self, cfg: &BitConfig) -> Option<Objectives> {
        let key = (cfg.wbits.clone(), cfg.abits.clone());
        if let Some(cached) = self.cache.get(&key) {
            return cached.clone();
        }
        let obj = self.evaluate_uncached(cfg);
        if obj.is_none() {
            self.pruned += 1;
        }
        self.cache.insert(key, obj.clone());
        obj
    }

    fn evaluate_uncached(&mut self, cfg: &BitConfig) -> Option<Objectives> {
        // Cheap pre-filter: the kernel must support every layer's widths
        // (layer 0 consumes the 8-bit input image — the engine contract).
        for (i, (&w, &a)) in cfg.wbits.iter().zip(&cfg.abits).enumerate() {
            let consumed = if i == 0 { 8 } else { a };
            if !self.method.supports(w, consumed) {
                return None;
            }
        }
        // Legality oracle: unbounded compile so over-budget configs are
        // *reported* by the analyzer's rules rather than dying in the
        // compile gate, then zero-Error required.
        let cm = CompiledModel::compile_unbounded_for(
            self.model,
            self.params,
            cfg,
            self.method,
            self.target,
        );
        let report = analysis::analyze(&cm);
        if !report.is_safe() {
            return None;
        }
        let pred = predict_model(self.model, self.method, cfg);
        Some(Objectives {
            cycles: pred.cycles_on(self.target),
            joules: pred.joules_on(self.target),
            sram_peak_bytes: report.resources.sram_peak_bytes,
            flash_total_bytes: report.resources.flash_total_bytes,
            accuracy_proxy_db: self.quality.proxy(cfg),
        })
    }
}

/// DP over the layer graph: `dp[b]` is the minimum LUT-estimated cycle
/// total over layers processed so far with cumulative MAC-weighted SQNR
/// drop inside error bucket `b`. Backtracking every final bucket yields
/// one seed per achievable accuracy budget — the spine the evolutionary
/// loop refines.
fn dp_seeds(
    model: &ModelDesc,
    lut: &MacsPerCycleLut,
    quality: &QualityTable,
    options: &[u8],
    buckets: usize,
) -> Vec<BitConfig> {
    let lnum = model.num_layers();
    let pairs: Vec<(u8, u8)> = options
        .iter()
        .flat_map(|&w| options.iter().map(move |&a| (w, a)))
        .collect();
    // Worst-case total error: every layer at its own worst pair.
    let max_err: f64 = (0..lnum)
        .map(|l| {
            pairs
                .iter()
                .map(|&(w, a)| quality.err_cost(l, w, a))
                .fold(0.0f64, f64::max)
        })
        .sum();
    let bucket_of = |e: f64| -> usize {
        if max_err <= 0.0 {
            0
        } else {
            (((e / max_err) * buckets as f64) as usize).min(buckets)
        }
    };

    const INF: f64 = f64::INFINITY;
    let nb = buckets + 1;
    // dp[l][b], choice[l][b] = (pair index, predecessor bucket).
    let mut dp = vec![INF; nb];
    dp[0] = 0.0;
    let mut choice: Vec<Vec<(usize, usize)>> = Vec::with_capacity(lnum);
    let mut err_acc = vec![0.0f64; nb];
    for (l, layer) in model.layers.iter().enumerate() {
        let mut next = vec![INF; nb];
        let mut next_err = vec![0.0f64; nb];
        let mut ch = vec![(usize::MAX, usize::MAX); nb];
        for b in 0..nb {
            if dp[b] == INF {
                continue;
            }
            for (pi, &(w, a)) in pairs.iter().enumerate() {
                let cost = dp[b] + lut.est_cycles(layer.macs, w, a);
                let e = err_acc[b] + quality.err_cost(l, w, a);
                let tb = bucket_of(e);
                if cost < next[tb] {
                    next[tb] = cost;
                    next_err[tb] = e;
                    ch[tb] = (pi, b);
                }
            }
        }
        dp = next;
        err_acc = next_err;
        choice.push(ch);
    }

    let mut seeds = Vec::new();
    for end in 0..nb {
        if dp[end] == INF {
            continue;
        }
        let mut wbits = vec![0u8; lnum];
        let mut abits = vec![0u8; lnum];
        let mut b = end;
        for l in (0..lnum).rev() {
            let (pi, prev) = choice[l][b];
            let (w, a) = pairs[pi];
            wbits[l] = w;
            abits[l] = a;
            b = prev;
        }
        let cfg = BitConfig { wbits, abits };
        if !seeds.contains(&cfg) {
            seeds.push(cfg);
        }
    }
    seeds
}

/// Run the native co-design search for one model on one target.
pub fn native_search(
    model: &ModelDesc,
    params: &[f32],
    target: &'static Target,
    cfg: &NativeSearchCfg,
) -> Result<SearchOutcome> {
    anyhow::ensure!(!cfg.options.is_empty(), "empty bitwidth option set");
    anyhow::ensure!(
        params.len() >= model.param_count,
        "parameter vector too short for {}",
        model.name
    );
    let lut = MacsPerCycleLut::for_target(target, cfg.method);
    let quality = QualityTable::build(model, params, &cfg.options, cfg.seed);
    let mut ev = Evaluator {
        model,
        params,
        target,
        method: cfg.method,
        quality,
        cache: BTreeMap::new(),
        pruned: 0,
    };

    let n = model.num_layers();
    let uniform8 = ev
        .evaluate(&BitConfig::uniform(n, 8))
        .ok_or_else(|| anyhow::anyhow!("{}: uniform 8-bit infeasible on {}", model.name, target.name))?;

    let mut archive = ParetoArchive::new();
    // Seed generation: the DP spine plus every uniform configuration.
    let mut population = dp_seeds(model, &lut, &ev.quality, &cfg.options, cfg.dp_buckets);
    for &b in &cfg.options {
        let u = BitConfig::uniform(n, b);
        if !population.contains(&u) {
            population.push(u);
        }
    }

    let mut rng = Rng::new(cfg.seed);
    let pick_bits = |rng: &mut Rng, options: &[u8]| options[rng.below(options.len() as u64) as usize];
    for _gen in 0..=cfg.generations {
        for cand in &population {
            if let Some(obj) = ev.evaluate(cand) {
                archive.insert(cand.clone(), obj);
            }
        }
        if archive.is_empty() {
            anyhow::bail!(
                "{}: no feasible configuration on {} (every candidate pruned)",
                model.name,
                target.name
            );
        }
        // Breed the next generation from the current front.
        let parents: Vec<BitConfig> =
            archive.points().iter().map(|p| p.cfg.clone()).collect();
        let mut next = Vec::with_capacity(cfg.population);
        while next.len() < cfg.population {
            let mut child = parents[rng.below(parents.len() as u64) as usize].clone();
            match rng.below(3) {
                0 => {
                    // Point mutation: one layer gets a fresh (w, a) pair.
                    let l = rng.below(n as u64) as usize;
                    child.wbits[l] = pick_bits(&mut rng, &cfg.options);
                    child.abits[l] = pick_bits(&mut rng, &cfg.options);
                }
                1 => {
                    // Uniform crossover with a second parent.
                    let other = &parents[rng.below(parents.len() as u64) as usize];
                    for l in 0..n {
                        if rng.below(2) == 1 {
                            child.wbits[l] = other.wbits[l];
                            child.abits[l] = other.abits[l];
                        }
                    }
                }
                _ => {
                    // Directional nudge: push one layer a step down (cheaper)
                    // or up (more accurate) within the option ladder.
                    let l = rng.below(n as u64) as usize;
                    let step = |b: u8, up: bool, options: &[u8]| -> u8 {
                        let i = options.iter().position(|&o| o == b).unwrap_or(0);
                        if up {
                            options[(i + 1).min(options.len() - 1)]
                        } else {
                            options[i.saturating_sub(1)]
                        }
                    };
                    let up = rng.below(2) == 1;
                    child.wbits[l] = step(child.wbits[l], up, &cfg.options);
                    child.abits[l] = step(child.abits[l], up, &cfg.options);
                }
            }
            next.push(child);
        }
        population = next;
    }

    let front = archive.sorted_points();
    Ok(SearchOutcome {
        target: target.name,
        front,
        uniform8,
        lut,
        evaluated: ev.cache.len() - ev.pruned,
        pruned: ev.pruned,
    })
}

/// Bundle per-target outcomes into the `search_pareto.json` document.
pub fn outcomes_to_json(
    backbone: &str,
    method: Method,
    seed: u64,
    outcomes: &[SearchOutcome],
) -> Json {
    let mut o = BTreeMap::new();
    o.insert("backbone".into(), Json::Str(backbone.into()));
    o.insert("method".into(), Json::Str(method.name().into()));
    o.insert("seed".into(), Json::Num(seed as f64));
    o.insert(
        "targets".into(),
        Json::Arr(outcomes.iter().map(|s| s.to_json()).collect()),
    );
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    fn setup() -> (ModelDesc, Vec<f32>) {
        let m = vgg_tiny(10, 16);
        let mut rng = Rng::new(1000);
        let params = (0..m.param_count).map(|_| rng.normal() * 0.1).collect();
        (m, params)
    }

    #[test]
    fn dp_seeds_span_fast_to_accurate() {
        let (m, params) = setup();
        let t = Target::resolve("m7").unwrap();
        let lut = MacsPerCycleLut::for_target(t, Method::RpSlbc);
        let q = QualityTable::build(&m, &params, &[2, 4, 8], 7);
        let seeds = dp_seeds(&m, &lut, &q, &[2, 4, 8], 8);
        assert!(seeds.len() >= 2, "want a spine, got {}", seeds.len());
        // The spine must include both extremes of the trade-off.
        let avgs: Vec<f64> = seeds.iter().map(|c| c.avg_wbits()).collect();
        let min = avgs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = avgs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(max - min > 1.0, "avg wbits span [{min}, {max}] too narrow");
    }

    #[test]
    fn infeasible_widths_never_scored() {
        let (m, params) = setup();
        let t = Target::resolve("m7").unwrap();
        let q = QualityTable::build(&m, &params, &[2, 4, 8], 7);
        let mut ev = Evaluator {
            model: &m,
            params: &params,
            target: t,
            method: Method::TinyEngine, // int8 only
            quality: q,
            cache: BTreeMap::new(),
            pruned: 0,
        };
        assert!(ev.evaluate(&BitConfig::uniform(m.num_layers(), 4)).is_none());
        assert_eq!(ev.pruned, 1);
        assert!(ev.evaluate(&BitConfig::uniform(m.num_layers(), 8)).is_some());
    }
}
