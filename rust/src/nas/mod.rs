//! Hardware-aware quantization search (paper §III.B, Fig. 8).
//!
//! Two search engines share this module, one per execution tier:
//!
//! * **Native co-design search** ([`search`]) — the offline engine: a DP
//!   pass over the layer graph seeded from per-layer `(w_bit, a_bit)`
//!   candidates, refined by a seeded evolutionary loop that maintains a
//!   Pareto archive over cycles × joules × SRAM peak × accuracy proxy.
//!   It needs no Python/PJRT: cycle and joule objectives come from
//!   [`crate::perf::predict_model`], legality from [`crate::analysis`],
//!   and the accuracy proxy from SQNR round-trips through
//!   [`crate::quant`]. This is the `search --native` CLI path.
//! * **Layer-2 supernet search** (the rest of this module) — the
//!   differentiable EdMIPS-style supernet lives at Layer 2 (JAX,
//!   `model.py::make_supernet_train_step`) and is executed through PJRT
//!   by the coordinator. This module owns everything *around* that
//!   program: the search space `Q`, the **cost tables** `cost[l, i, j]`
//!   fed to the supernet's complexity loss — either the EdMIPS-style MAC
//!   proxy (the Fig. 8 baseline) or the SIMD-aware Eq. 12 model of
//!   [`crate::perf`] (the paper's contribution) — and branch-logit
//!   bookkeeping: softmax, entropy, argmax selection of the final
//!   [`BitConfig`].

pub mod search;

use crate::models::ModelDesc;
use crate::ops::Method;
use crate::perf::{mac_proxy, PerfModel};
use crate::quant::BitConfig;

/// The quantization search space (paper: every bitwidth in `[2, 8]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchSpace {
    pub options: Vec<u8>,
}

impl Default for SearchSpace {
    fn default() -> Self {
        SearchSpace {
            options: vec![2, 3, 4, 5, 6, 7, 8],
        }
    }
}

impl SearchSpace {
    pub fn k(&self) -> usize {
        self.options.len()
    }

    /// Size of the full per-layer design space `(K_w × K_a)^L`.
    pub fn cardinality(&self, num_layers: usize) -> f64 {
        ((self.k() * self.k()) as f64).powi(num_layers as i32)
    }
}

/// Which complexity signal drives the differentiable search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CostProxy {
    /// EdMIPS baseline: bit-weighted MAC count, implementation-blind.
    EdMipsMacs,
    /// MCU-MixQ: the Eq. 12 packing-aware model for a target operator
    /// (normally [`Method::RpSlbc`], the deployed kernel).
    SimdAware(PerfModel, Method),
}

impl CostProxy {
    pub fn name(&self) -> &'static str {
        match self {
            CostProxy::EdMipsMacs => "edmips-macs",
            CostProxy::SimdAware(..) => "simd-aware-eq12",
        }
    }

    fn layer_cost(&self, l: &crate::models::LayerSpec, wb: u8, ab: u8) -> f64 {
        match self {
            CostProxy::EdMipsMacs => mac_proxy(l, wb, ab),
            CostProxy::SimdAware(pm, method) => pm.layer_complexity(l, *method, wb, ab),
        }
    }
}

/// A dense `[L, K, K]` cost table (row-major `l·K·K + i·K + j` with `i`
/// indexing weight options and `j` activation options), normalized so the
/// all-8-bit configuration sums to 1 — which makes the supernet's `λ`
/// comparable across backbones and proxies.
#[derive(Debug, Clone)]
pub struct CostTable {
    pub data: Vec<f32>,
    pub num_layers: usize,
    pub k: usize,
    /// The normalizer: model cost at uniform 8-bit under the same proxy.
    pub norm: f64,
}

impl CostTable {
    pub fn at(&self, l: usize, i: usize, j: usize) -> f32 {
        self.data[(l * self.k + i) * self.k + j]
    }

    /// Expected complexity under per-layer branch distributions
    /// (`softmax(alpha_w)`, `softmax(alpha_a)`, row-major `[L, K]`) — the
    /// same bilinear form the Layer-2 loss computes; used for logging.
    pub fn expected(&self, sm_w: &[f32], sm_a: &[f32]) -> f64 {
        let (lnum, k) = (self.num_layers, self.k);
        let mut total = 0.0f64;
        for l in 0..lnum {
            for i in 0..k {
                for j in 0..k {
                    total += sm_w[l * k + i] as f64
                        * self.at(l, i, j) as f64
                        * sm_a[l * k + j] as f64;
                }
            }
        }
        total
    }

    /// Complexity of a concrete configuration (sum of selected entries).
    pub fn config_cost(&self, space: &SearchSpace, cfg: &BitConfig) -> f64 {
        let idx_of = |b: u8| space.options.iter().position(|&o| o == b).unwrap();
        (0..self.num_layers)
            .map(|l| self.at(l, idx_of(cfg.wbits[l]), idx_of(cfg.abits[l])) as f64)
            .sum::<f64>()
    }
}

/// Build the `[L, K, K]` cost table of `model` under `proxy`.
pub fn cost_table(model: &ModelDesc, space: &SearchSpace, proxy: CostProxy) -> CostTable {
    let (lnum, k) = (model.num_layers(), space.k());
    let mut raw = vec![0.0f64; lnum * k * k];
    for (l, layer) in model.layers.iter().enumerate() {
        for (i, &wb) in space.options.iter().enumerate() {
            for (j, &ab) in space.options.iter().enumerate() {
                raw[(l * k + i) * k + j] = proxy.layer_cost(layer, wb, ab);
            }
        }
    }
    // Normalizer: the uniform-8-bit model cost (last option is 8).
    let i8 = space.options.iter().position(|&o| o == 8).unwrap_or(k - 1);
    let norm: f64 = (0..lnum).map(|l| raw[(l * k + i8) * k + i8]).sum();
    let norm = if norm > 0.0 { norm } else { 1.0 };
    CostTable {
        data: raw.iter().map(|&c| (c / norm) as f32).collect(),
        num_layers: lnum,
        k,
        norm,
    }
}

/// Row-wise softmax of `[L, K]` logits.
pub fn softmax_rows(logits: &[f32], k: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; logits.len()];
    for (row_out, row) in out.chunks_mut(k).zip(logits.chunks(k)) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for (o, &x) in row_out.iter_mut().zip(row) {
            *o = (x - m).exp();
            z += *o;
        }
        for o in row_out.iter_mut() {
            *o /= z;
        }
    }
    out
}

/// Mean per-layer entropy (nats) of branch distributions — the search's
/// convergence diagnostic logged by the coordinator.
pub fn mean_entropy(logits: &[f32], k: usize) -> f64 {
    let sm = softmax_rows(logits, k);
    let rows = logits.len() / k;
    let mut h = 0.0f64;
    for row in sm.chunks(k) {
        for &p in row {
            if p > 0.0 {
                h -= (p as f64) * (p as f64).ln();
            }
        }
    }
    h / rows as f64
}

/// Argmax selection of the final sub-net `q*` from trained branch logits
/// (`alpha_w`, `alpha_a` row-major `[L, K]`).
pub fn select_config(space: &SearchSpace, alpha_w: &[f32], alpha_a: &[f32]) -> BitConfig {
    let k = space.k();
    let pick = |logits: &[f32]| -> Vec<u8> {
        logits
            .chunks(k)
            .map(|row| {
                let mut best = 0usize;
                for i in 1..k {
                    if row[i] > row[best] {
                        best = i;
                    }
                }
                space.options[best]
            })
            .collect()
    };
    BitConfig {
        wbits: pick(alpha_w),
        abits: pick(alpha_a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    fn space() -> SearchSpace {
        SearchSpace::default()
    }

    #[test]
    fn table_shape_and_normalization() {
        let m = vgg_tiny(10, 16);
        let s = space();
        let t = cost_table(&m, &s, CostProxy::EdMipsMacs);
        assert_eq!(t.data.len(), m.num_layers() * s.k() * s.k());
        // Uniform 8-bit config must cost exactly 1 after normalization.
        let cfg8 = BitConfig::uniform(m.num_layers(), 8);
        let c = t.config_cost(&s, &cfg8);
        assert!((c - 1.0).abs() < 1e-5, "c = {c}");
    }

    #[test]
    fn simd_aware_table_monotone_in_bits() {
        let m = vgg_tiny(10, 16);
        let s = space();
        let pm = PerfModel::cortex_m7();
        let t = cost_table(&m, &s, CostProxy::SimdAware(pm, Method::RpSlbc));
        for l in 0..t.num_layers {
            assert!(t.at(l, 0, 0) < t.at(l, s.k() - 1, s.k() - 1));
        }
    }

    #[test]
    fn edmips_and_simd_aware_disagree() {
        // The whole point of Fig. 8: the proxies rank configs differently.
        let m = vgg_tiny(10, 16);
        let s = space();
        let pm = PerfModel::cortex_m7();
        let te = cost_table(&m, &s, CostProxy::EdMipsMacs);
        let ts = cost_table(&m, &s, CostProxy::SimdAware(pm, Method::RpSlbc));
        // EdMIPS is exactly proportional to wb·ab; Eq. 12 is not. Compare
        // the (2,8) vs (4,4) ratio on a conv layer: same MAC proxy value,
        // different packing cost.
        let l = 2;
        let i2 = 0; // 2-bit
        let i4 = 2; // 4-bit
        let i8 = s.k() - 1;
        let e_ratio = te.at(l, i2, i8) / te.at(l, i4, i4);
        let s_ratio = ts.at(l, i2, i8) / ts.at(l, i4, i4);
        assert!((e_ratio - 1.0).abs() < 1e-4, "edmips ratio {e_ratio}");
        assert!((s_ratio - 1.0).abs() > 0.02, "simd-aware ratio {s_ratio}");
    }

    #[test]
    fn softmax_rows_normalized() {
        let sm = softmax_rows(&[0.0, 1.0, 2.0, -1.0, 0.0, 1.0], 3);
        for row in sm.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(row[2] > row[1] && row[1] > row[0]);
        }
    }

    #[test]
    fn entropy_bounds() {
        let k = 7;
        let uniform = vec![0.0f32; 2 * k];
        let h = mean_entropy(&uniform, k);
        assert!((h - (k as f64).ln()).abs() < 1e-6);
        let mut peaked = vec![0.0f32; 2 * k];
        peaked[0] = 50.0;
        peaked[k] = 50.0;
        assert!(mean_entropy(&peaked, k) < 1e-3);
    }

    #[test]
    fn select_config_argmax() {
        let s = space();
        let k = s.k();
        let mut aw = vec![0.0f32; 2 * k];
        let mut aa = vec![0.0f32; 2 * k];
        aw[3] = 5.0; // layer 0 -> option 3 (5 bits)
        aw[k + 6] = 5.0; // layer 1 -> option 6 (8 bits)
        aa[0] = 5.0; // layer 0 -> 2 bits
        aa[k + 2] = 5.0; // layer 1 -> 4 bits
        let cfg = select_config(&s, &aw, &aa);
        assert_eq!(cfg.wbits, vec![5, 8]);
        assert_eq!(cfg.abits, vec![2, 4]);
    }

    #[test]
    fn cardinality_is_astronomical() {
        let s = space();
        assert!(s.cardinality(6) > 1e10);
    }
}
