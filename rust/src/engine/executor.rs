//! Bit-exact integer inference over a deployment graph.
//!
//! The executor mirrors the Layer-2 float pipeline (`model.py::forward`)
//! in integer arithmetic: symmetric weight quantization, unsigned
//! activation requantization with dynamic range (the integer twin of the
//! `fake_quant` kernels), ReLU folded into requantization, max-pool and
//! GAP on quantized activations. Every instruction is charged to a
//! [`Counter`] through the selected [`Method`]'s kernels, so one inference
//! yields both the logits and the Table I cycle count.

use anyhow::Result;

use super::KernelCache;
use crate::mcu::{Counter, CycleModel};
use crate::models::ModelDesc;
use crate::ops::slbc::ConvScratch;
use crate::ops::{common, slbc, Method};
use crate::quant::{quantize_acts, BitConfig, QWeights};

/// Outcome of one (batch-1) inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    /// Dequantized logits.
    pub logits: Vec<f32>,
    /// Argmax class.
    pub pred: usize,
    /// Total cycles on the MCU cycle model.
    pub cycles: u64,
    /// Full instruction histogram.
    pub counter: Counter,
    /// Per-layer cycle breakdown.
    pub per_layer: Vec<(String, u64)>,
    /// Per-layer instruction-histogram diffs, parallel to `per_layer`.
    /// Their class-wise merge reproduces `counter` exactly (the
    /// profiler's bit-for-bit invariant).
    pub per_layer_counters: Vec<Counter>,
}

/// Run one image through the quantized model with `method`, re-packing
/// SLBC kernel registers on the fly. Repeated inference should go through
/// [`infer_with_kernels`] (what [`super::CompiledModel::run`] does) so the
/// packing happens once at compile time.
pub fn infer(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
    cycle_model: &CycleModel,
) -> Result<InferenceResult> {
    infer_with_kernels(model, quantized, cfg, method, image, cycle_model, None)
}

/// [`infer`] over an optional pre-packed [`KernelCache`]: layers with a
/// cached kernel skip host-side packing entirely (charging is identical —
/// the modeled MCU streams packed registers from flash either way, so
/// cached and uncached runs stay cycle-exact with each other).
#[allow(clippy::too_many_arguments)]
pub fn infer_with_kernels(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
    cycle_model: &CycleModel,
    kernels: Option<&KernelCache>,
) -> Result<InferenceResult> {
    infer_with_kernels_scratch(model, quantized, cfg, method, image, cycle_model, kernels, None)
}

/// [`infer_with_kernels`] over a caller-owned [`ConvScratch`]: cached
/// layers reuse the given scratch instead of the global thread-local,
/// so callers that own their workers (the serving layer) keep pipeline
/// state private per worker. `None` falls back to the thread-local.
/// Results are identical either way — the scratch only holds transient
/// per-layer buffers.
#[allow(clippy::too_many_arguments)]
pub fn infer_with_kernels_scratch(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
    cycle_model: &CycleModel,
    kernels: Option<&KernelCache>,
    mut scratch: Option<&mut ConvScratch>,
) -> Result<InferenceResult> {
    anyhow::ensure!(
        image.len() == model.input_hw * model.input_hw * model.input_c,
        "image size {} != model input {}",
        image.len(),
        model.input_hw * model.input_hw * model.input_c
    );
    let mut ctr = Counter::new();
    let mut per_layer = Vec::with_capacity(model.layers.len());
    let mut per_layer_counters = Vec::with_capacity(model.layers.len());

    // Input image quantized to 8-bit (the first layer consumes the raw
    // image in the float pipeline; int8 input is the standard deployment
    // contract, cf. TinyEngine).
    let qin = quantize_acts(image, 8);
    let mut x = qin.data;
    let mut a_scale = qin.scale;

    let n = model.layers.len();
    let mut logits = Vec::new();
    for (i, l) in model.layers.iter().enumerate() {
        // The activation width this layer consumes — the same derivation
        // KernelCache::build packs for (single source of truth).
        let in_bits = super::layer_in_bits(cfg, i);
        let cycles_before = ctr.cycles(cycle_model);
        let ctr_before = ctr.clone();
        // GAP before the classifier (MobileNet-Tiny).
        if l.gap_before {
            // x currently holds the previous layer's HWC activations.
            let (h, w) = prev_hw(model, i);
            x = common::global_avg_pool(&x, h, w, l.cin, &mut ctr);
        }
        let (qw, bias) = &quantized[i];
        let sf = qw.scale * a_scale;
        let bias_i: Vec<i64> = bias.iter().map(|&b| (b / sf).round() as i64).collect();
        let acc = match kernels.and_then(|kc| kc.layer(i)) {
            Some(lk) => {
                debug_assert_eq!(
                    lk.bits(),
                    (cfg.wbits[i], in_bits),
                    "cached kernel packed for different bitwidths ({})",
                    l.name
                );
                match scratch.as_deref_mut() {
                    Some(s) => slbc::run_layer_with_scratch(&x, l, lk, &mut ctr, s),
                    None => slbc::run_layer_cached(&x, l, lk, &mut ctr),
                }
            }
            None => method.run_layer(&x, &qw.data, l, cfg.wbits[i], in_bits, &mut ctr),
        };

        if i + 1 == n {
            // Final logits: dequantize.
            logits = acc
                .iter()
                .enumerate()
                .map(|(j, &a)| (a + bias_i[j % l.cout]) as f32 * sf)
                .collect();
            per_layer.push((l.name.clone(), ctr.cycles(cycle_model) - cycles_before));
            per_layer_counters.push(ctr.diff(&ctr_before));
            break;
        }

        // Requantize to the next layer's activation width (ReLU folded).
        let next_bits = cfg.abits[i + 1];
        // Track the real-unit activation scale for the next layer.
        let mut maxv = 1i64;
        for (j, &a) in acc.iter().enumerate() {
            maxv = maxv.max(a + bias_i[j % l.cout]);
        }
        x = common::requantize(&acc, &bias_i, l.cout, next_bits, &mut ctr);
        a_scale = maxv as f32 * sf / ((1u64 << next_bits) - 1) as f32;

        if l.pool_after {
            x = common::maxpool_2x2(&x, l.out_h, l.out_w, l.cout, &mut ctr);
        }
        per_layer.push((l.name.clone(), ctr.cycles(cycle_model) - cycles_before));
        per_layer_counters.push(ctr.diff(&ctr_before));
    }

    let pred = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    Ok(InferenceResult {
        logits,
        pred,
        cycles: ctr.cycles(cycle_model),
        counter: ctr,
        per_layer,
        per_layer_counters,
    })
}

/// Spatial size of the activations feeding layer `i` (for GAP).
fn prev_hw(model: &ModelDesc, i: usize) -> (usize, usize) {
    let prev = &model.layers[i - 1];
    if prev.pool_after {
        (prev.out_h / 2, prev.out_w / 2)
    } else {
        (prev.out_h, prev.out_w)
    }
}

/// Run a batch of images, returning the full per-image results (logits,
/// cycle counts, instruction histograms). The serving layer uses this to
/// charge each request its own virtual-time latency.
pub fn infer_batch_detailed(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    images: &[f32],
    cycle_model: &CycleModel,
) -> Result<Vec<InferenceResult>> {
    infer_batch_with_kernels(model, quantized, cfg, method, images, cycle_model, None)
}

/// [`infer_batch_detailed`] over an optional pre-packed [`KernelCache`].
#[allow(clippy::too_many_arguments)]
pub fn infer_batch_with_kernels(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    images: &[f32],
    cycle_model: &CycleModel,
    kernels: Option<&KernelCache>,
) -> Result<Vec<InferenceResult>> {
    let img_sz = model.input_hw * model.input_hw * model.input_c;
    anyhow::ensure!(
        img_sz > 0 && images.len() % img_sz == 0,
        "batch bytes {} not a multiple of image size {}",
        images.len(),
        img_sz
    );
    (0..images.len() / img_sz)
        .map(|i| {
            infer_with_kernels(
                model,
                quantized,
                cfg,
                method,
                &images[i * img_sz..(i + 1) * img_sz],
                cycle_model,
                kernels,
            )
        })
        .collect()
}

/// Run a batch of images; returns per-image predictions, mean cycles and
/// accuracy against `labels`.
pub fn infer_batch(
    model: &ModelDesc,
    quantized: &[(QWeights, Vec<f32>)],
    cfg: &BitConfig,
    method: Method,
    images: &[f32],
    labels: &[i32],
    cycle_model: &CycleModel,
) -> Result<(Vec<usize>, f64, f64)> {
    let img_sz = model.input_hw * model.input_hw * model.input_c;
    let n = labels.len();
    anyhow::ensure!(images.len() == n * img_sz, "batch size mismatch");
    let results = infer_batch_detailed(model, quantized, cfg, method, images, cycle_model)?;
    let cycles_total: u64 = results.iter().map(|r| r.cycles).sum();
    let correct = results
        .iter()
        .zip(labels)
        .filter(|(r, &y)| r.pred as i32 == y)
        .count();
    let preds = results.iter().map(|r| r.pred).collect();
    Ok((
        preds,
        cycles_total as f64 / n as f64,
        correct as f64 / n as f64,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobilenet_tiny, vgg_tiny};
    use crate::quant::quantize_model;
    use crate::util::prng::Rng;

    fn setup(model: &ModelDesc, bits: u8, seed: u64) -> (Vec<(QWeights, Vec<f32>)>, BitConfig) {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.2).collect();
        let cfg = BitConfig::uniform(model.num_layers(), bits);
        (quantize_model(model, &flat, &cfg), cfg)
    }

    #[test]
    fn infer_runs_both_backbones() {
        for m in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
            let (q, cfg) = setup(&m, 4, 1);
            let img = vec![0.3f32; 16 * 16 * 3];
            let r = infer(&m, &q, &cfg, Method::RpSlbc, &img, &CycleModel::cortex_m7()).unwrap();
            assert_eq!(r.logits.len(), m.num_classes);
            assert!(r.pred < m.num_classes);
            assert!(r.cycles > 0);
            assert_eq!(r.per_layer.len(), m.num_layers());
        }
    }

    #[test]
    fn per_layer_counters_merge_to_the_run_total() {
        let m = vgg_tiny(10, 16);
        let (q, cfg) = setup(&m, 4, 5);
        let img = vec![0.25f32; 16 * 16 * 3];
        let cm = CycleModel::cortex_m7();
        let r = infer(&m, &q, &cfg, Method::RpSlbc, &img, &cm).unwrap();
        assert_eq!(r.per_layer_counters.len(), r.per_layer.len());
        let mut merged = Counter::new();
        for c in &r.per_layer_counters {
            merged.merge(c);
        }
        assert_eq!(merged, r.counter, "layer diffs must telescope exactly");
        // Per-layer cycles agree with each layer's own histogram priced
        // by the same model, and sum to the run total.
        for ((_, cyc), c) in r.per_layer.iter().zip(&r.per_layer_counters) {
            assert_eq!(*cyc, c.cycles(&cm));
        }
        let sum: u64 = r.per_layer.iter().map(|(_, c)| c).sum();
        assert_eq!(sum, r.cycles);
    }

    #[test]
    fn methods_agree_on_prediction_at_8bit() {
        // All kernels are bit-exact over the same integer pipeline, so at
        // identical quantization they must produce identical logits.
        let m = vgg_tiny(10, 16);
        let (q, cfg) = setup(&m, 8, 2);
        let mut rng = Rng::new(77);
        let img: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.f32()).collect();
        let cm = CycleModel::cortex_m7();
        let base = infer(&m, &q, &cfg, Method::Naive, &img, &cm).unwrap();
        for method in [Method::Simd, Method::TinyEngine, Method::Slbc, Method::RpSlbc] {
            let r = infer(&m, &q, &cfg, method, &img, &cm).unwrap();
            assert_eq!(r.logits, base.logits, "method {}", method.name());
        }
    }

    #[test]
    fn slbc_cycles_beat_naive() {
        let m = vgg_tiny(10, 16);
        let (q, cfg) = setup(&m, 4, 3);
        let img = vec![0.4f32; 16 * 16 * 3];
        let cm = CycleModel::cortex_m7();
        let naive = infer(&m, &q, &cfg, Method::Naive, &img, &cm).unwrap();
        let slbc = infer(&m, &q, &cfg, Method::Slbc, &img, &cm).unwrap();
        assert!(
            slbc.cycles * 2 < naive.cycles,
            "slbc {} vs naive {}",
            slbc.cycles,
            naive.cycles
        );
    }

    #[test]
    fn caller_owned_scratch_matches_thread_local() {
        let m = vgg_tiny(10, 16);
        let (q, cfg) = setup(&m, 4, 9);
        let kernels = KernelCache::build(&m, &q, &cfg, Method::RpSlbc);
        let img = vec![0.35f32; 16 * 16 * 3];
        let cm = CycleModel::cortex_m7();
        let via_tls =
            infer_with_kernels(&m, &q, &cfg, Method::RpSlbc, &img, &cm, Some(&kernels)).unwrap();
        let mut scratch = ConvScratch::new();
        for _ in 0..2 {
            let via_own = infer_with_kernels_scratch(
                &m,
                &q,
                &cfg,
                Method::RpSlbc,
                &img,
                &cm,
                Some(&kernels),
                Some(&mut scratch),
            )
            .unwrap();
            assert_eq!(via_own.logits, via_tls.logits);
            assert_eq!(via_own.cycles, via_tls.cycles);
            assert_eq!(via_own.counter, via_tls.counter);
        }
    }

    #[test]
    fn batch_accuracy_bounds() {
        let m = vgg_tiny(10, 16);
        let (q, cfg) = setup(&m, 4, 4);
        let batch = crate::datasets::synth_cifar(8, 16, 42);
        let (preds, mean_cycles, acc) = infer_batch(
            &m,
            &q,
            &cfg,
            Method::Slbc,
            &batch.images,
            &batch.labels,
            &CycleModel::cortex_m7(),
        )
        .unwrap();
        assert_eq!(preds.len(), 8);
        assert!(mean_cycles > 0.0);
        assert!((0.0..=1.0).contains(&acc));
    }
}
