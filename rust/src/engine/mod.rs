//! TinyEngine-like deployment engine (DESIGN.md §3 substitution).
//!
//! The paper deploys MPNNs through TinyEngine — a code-generating,
//! memory-planning inference framework for MCUs — with SLBC integrated as
//! its sub-byte convolution backend. This module reproduces the same
//! mechanisms natively:
//!
//! * [`graph`] — inference graph IR built from a model descriptor and a
//!   bit configuration (conv / pool / GAP / dense nodes, sub-byte
//!   activation tensors);
//! * [`planner`] — lifetime-based SRAM arena planning (the "model-adaptive
//!   memory scheduling" that gives TinyEngine its Table I peak-memory
//!   edge) vs the all-buffers-live allocation CMix-NN-class libraries use;
//! * [`flash`] — flash image layout: sub-byte packed weights, int32
//!   biases, per-layer scales, and a code-size model for the generated
//!   kernels;
//! * [`codegen`] — per-layer kernel specialization (method + lane plan
//!   selection, the compile-time choice of §IV.C);
//! * [`executor`] — bit-exact integer inference over the graph, charging
//!   every instruction to the MCU cycle model.
//!
//! The [`deploy`] entry point ties these together and produces the
//! [`DeployReport`] rows of Table I.

pub mod codegen;
pub mod executor;
pub mod flash;
pub mod graph;
pub mod planner;

pub use codegen::{CodegenPlan, KernelChoice};
pub use executor::{infer, infer_batch, InferenceResult};
pub use flash::FlashImage;
pub use graph::{Graph, Node, NodeOp, TensorInfo};
pub use planner::{plan_memory, MemoryPlan, PlanStrategy};

use crate::mcu::CycleModel;
use crate::models::ModelDesc;
use crate::ops::Method;
use crate::quant::{quantize_model, BitConfig};
use crate::{cycles_to_ms, Result};

/// Everything Table I reports for one (backbone, method, config) triple.
#[derive(Debug, Clone)]
pub struct DeployReport {
    pub backbone: String,
    pub method: Method,
    pub config: BitConfig,
    /// Peak SRAM of the activation arena (bytes).
    pub peak_sram: usize,
    /// Flash usage: packed weights + biases + scales + generated code.
    pub flash_bytes: usize,
    /// Cycles for one inference (batch 1).
    pub cycles: u64,
    /// Milliseconds at the paper's 216 MHz clock.
    pub latency_ms: f64,
    /// Per-layer cycle breakdown (layer name, cycles).
    pub per_layer: Vec<(String, u64)>,
}

/// Deploy `model` (trained flat f32 params) with `method` under `cfg`,
/// running one inference on `image` to obtain the cycle/memory numbers.
pub fn deploy(
    model: &ModelDesc,
    flat_params: &[f32],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
) -> Result<DeployReport> {
    let strategy = planner::strategy_for(method);
    let graph = Graph::build(model, cfg);
    let plan = plan_memory(&graph, strategy);
    let quantized = quantize_model(model, flat_params, cfg);
    let codegen = CodegenPlan::generate(model, cfg, method);
    let flash = FlashImage::layout(model, cfg, &quantized, &codegen);
    let cycle_model = CycleModel::cortex_m7();

    let result = infer(model, &quantized, cfg, method, image, &cycle_model)?;

    anyhow::ensure!(
        plan.peak_bytes <= crate::STM32F746_SRAM_BYTES,
        "{}: activation arena {}B exceeds STM32F746 SRAM",
        model.name,
        plan.peak_bytes
    );

    Ok(DeployReport {
        backbone: model.name.clone(),
        method,
        config: cfg.clone(),
        peak_sram: plan.peak_bytes,
        flash_bytes: flash.total_bytes(),
        cycles: result.cycles,
        latency_ms: cycles_to_ms(result.cycles),
        per_layer: result.per_layer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;
    use crate::util::prng::Rng;

    fn fake_params(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(99);
        (0..n).map(|_| rng.normal() * 0.1).collect()
    }

    #[test]
    fn deploy_produces_table1_row() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let img = vec![0.5f32; 16 * 16 * 3];
        let rep = deploy(&m, &params, &cfg, Method::RpSlbc, &img).unwrap();
        assert!(rep.peak_sram > 0);
        assert!(rep.flash_bytes > 0);
        assert!(rep.cycles > 0);
        assert!(rep.latency_ms > 0.0);
        assert_eq!(rep.per_layer.len(), m.num_layers());
    }

    #[test]
    fn mixq_deploy_beats_int8_tinyengine() {
        // The headline: mixed sub-byte SLBC vs int8 TinyEngine (Table I).
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let img = vec![0.5f32; 16 * 16 * 3];
        let cfg4 = BitConfig::uniform(m.num_layers(), 4);
        let cfg8 = BitConfig::uniform(m.num_layers(), 8);
        let mixq = deploy(&m, &params, &cfg4, Method::RpSlbc, &img).unwrap();
        let tiny = deploy(&m, &params, &cfg8, Method::TinyEngine, &img).unwrap();
        assert!(
            mixq.cycles < tiny.cycles,
            "mixq {} vs tinyengine {}",
            mixq.cycles,
            tiny.cycles
        );
    }
}
