//! TinyEngine-like deployment engine (DESIGN.md §3 substitution).
//!
//! The paper deploys MPNNs through TinyEngine — a code-generating,
//! memory-planning inference framework for MCUs — with SLBC integrated as
//! its sub-byte convolution backend. This module reproduces the same
//! mechanisms natively:
//!
//! * [`graph`] — inference graph IR built from a model descriptor and a
//!   bit configuration (conv / pool / GAP / dense nodes, sub-byte
//!   activation tensors);
//! * [`planner`] — lifetime-based SRAM arena planning (the "model-adaptive
//!   memory scheduling" that gives TinyEngine its Table I peak-memory
//!   edge) vs the all-buffers-live allocation CMix-NN-class libraries use;
//! * [`flash`] — flash image layout: sub-byte packed weights, int32
//!   biases, per-layer scales, and a code-size model for the generated
//!   kernels;
//! * [`codegen`] — per-layer kernel specialization (method + lane plan
//!   selection, the compile-time choice of §IV.C);
//! * [`executor`] — bit-exact integer inference over the graph, charging
//!   every instruction to the MCU cycle model.
//!
//! Compilation and execution are split, mirroring real MCU deployment
//! stacks: [`CompiledModel::compile`] does the one-time work (graph,
//! memory plan, quantized params, codegen plan, flash image, and the
//! [`KernelCache`] of pre-packed SLBC kernel registers) and
//! [`CompiledModel::run`] is the cheap per-inference path the serving
//! layer ([`crate::serve`]) reuses across requests — zero kernel
//! re-packing per request, enforced by tests against
//! [`crate::ops::slbc::kernel_pack_count`]. The [`deploy`] entry
//! point is a thin compile-then-run wrapper that produces the
//! [`DeployReport`] rows of Table I.

pub mod codegen;
pub mod executor;
pub mod flash;
pub mod graph;
pub mod planner;

pub use codegen::{CodegenPlan, KernelChoice};
pub use executor::{
    infer, infer_batch, infer_batch_detailed, infer_batch_with_kernels, infer_with_kernels,
    infer_with_kernels_scratch, InferenceResult,
};
pub use flash::FlashImage;
pub use graph::{Graph, Node, NodeOp, TensorInfo};
pub use planner::{plan_memory, MemoryPlan, PlanStrategy};

use std::sync::atomic::{AtomicU64, Ordering};

use crate::models::ModelDesc;
use crate::ops::slbc::LayerKernel;
use crate::ops::Method;
use crate::quant::{quantize_model, BitConfig, QWeights};
use crate::target::Target;
use crate::Result;

/// Everything Table I reports for one (backbone, method, config) triple.
#[derive(Debug, Clone)]
pub struct DeployReport {
    pub backbone: String,
    pub method: Method,
    pub config: BitConfig,
    /// Registry name of the target the model was compiled for.
    pub target: String,
    /// Peak SRAM of the activation arena (bytes).
    pub peak_sram: usize,
    /// Flash usage: packed weights + biases + scales + generated code.
    pub flash_bytes: usize,
    /// Cycles for one inference (batch 1), in the target's own cycles.
    pub cycles: u64,
    /// Milliseconds at the target's clock.
    pub latency_ms: f64,
    /// Joules for one inference on the target (dynamic + static).
    pub joules: f64,
    /// Per-layer cycle breakdown (layer name, cycles).
    pub per_layer: Vec<(String, u64)>,
    /// Per-layer energy breakdown (joules), parallel to `per_layer`:
    /// each layer's instruction histogram priced through the target's
    /// energy model. Sums are *not* expected to reproduce `joules`
    /// bit-for-bit (f64 addition is not associative); the bit-exact
    /// total lives in [`crate::obs::ExecutionProfile`], which prices the
    /// merged histogram once.
    pub per_layer_joules: Vec<f64>,
}

/// Global count of [`CompiledModel::compile`] invocations. The serving
/// registry's compile-once guarantee is verified against this counter
/// (tests and `bench-serve` assert one compilation per distinct model).
static COMPILE_COUNT: AtomicU64 = AtomicU64::new(0);

/// Number of model compilations performed by this process so far.
pub fn compile_count() -> u64 {
    COMPILE_COUNT.load(Ordering::Relaxed)
}

/// Activation bitwidth layer `i` consumes at run time: the executor feeds
/// layer 0 the 8-bit quantized input image (the standard deployment
/// contract, cf. TinyEngine); every later layer consumes its own
/// configured activation width. The single source of truth shared by the
/// executor's dispatch and [`KernelCache::build`] — the packed plan must
/// match the runtime width exactly.
pub(crate) fn layer_in_bits(cfg: &BitConfig, i: usize) -> u8 {
    if i == 0 {
        8
    } else {
        cfg.abits[i]
    }
}

/// Per-layer pre-packed SLBC kernel state (packed kernel registers + the
/// memoized lane plan, each entry keyed by its layer's shape and
/// `(wbits, abits)` pair), built once at compile time so repeated
/// inference never re-packs weights — the register-file-resident packing
/// discipline of CMix-NN-class kernels, hoisted to deploy time.
///
/// Baseline (non-SLBC) methods carry an empty cache: their kernels hold
/// no packed state. The zero-repack guarantee is observable through
/// [`crate::ops::slbc::kernel_pack_count`].
#[derive(Debug, Clone, Default)]
pub struct KernelCache {
    layers: Vec<Option<LayerKernel>>,
}

impl KernelCache {
    /// Pre-pack every layer's kernel registers for an SLBC method; empty
    /// for methods without packed kernel state.
    pub fn build(
        model: &ModelDesc,
        quantized: &[(QWeights, Vec<f32>)],
        cfg: &BitConfig,
        method: Method,
    ) -> KernelCache {
        let reordered = match method {
            Method::Slbc => false,
            Method::RpSlbc => true,
            _ => return KernelCache::default(),
        };
        let layers = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let abits = layer_in_bits(cfg, i);
                Some(LayerKernel::build(
                    &quantized[i].0.data,
                    l,
                    cfg.wbits[i],
                    abits,
                    reordered,
                ))
            })
            .collect();
        KernelCache { layers }
    }

    /// The pre-packed kernel of layer `i`, if this method carries one.
    pub fn layer(&self, i: usize) -> Option<&LayerKernel> {
        self.layers.get(i).and_then(|o| o.as_ref())
    }

    /// Number of layers with pre-packed kernel state.
    pub fn packed_layers(&self) -> usize {
        self.layers.iter().filter(|o| o.is_some()).count()
    }

    /// Replace layer `i`'s pre-packed kernel — the fault-injection seam
    /// for the static analyzer's tests (e.g. planting a deliberately
    /// over-packed plan and proving both `analysis::analyze` and
    /// `verify_strict` reject it). Grows the cache as needed.
    pub fn set_layer(&mut self, i: usize, kernel: Option<LayerKernel>) {
        if self.layers.len() <= i {
            self.layers.resize(i + 1, None);
        }
        self.layers[i] = kernel;
    }
}

/// The one-time compilation product for one (model, config, method)
/// triple: everything `deploy` used to rebuild per call, built once and
/// reusable across arbitrarily many [`run`](CompiledModel::run) calls.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub model: ModelDesc,
    pub cfg: BitConfig,
    pub method: Method,
    pub graph: Graph,
    pub plan: MemoryPlan,
    pub quantized: Vec<(QWeights, Vec<f32>)>,
    pub codegen: CodegenPlan,
    pub flash: FlashImage,
    /// The deployment target this artifact was compiled for — the
    /// single source of the SRAM gate, the executor's cycle table and
    /// the energy pricing in [`report`](CompiledModel::report).
    pub target: Target,
    /// Pre-packed SLBC kernel registers (empty for baseline methods):
    /// the run path streams these instead of re-packing per inference.
    pub kernels: KernelCache,
}

impl CompiledModel {
    /// Build the full deployment artifact for the default target (the
    /// paper platform, registry name `stm32f746`).
    pub fn compile(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
    ) -> Result<CompiledModel> {
        Self::compile_for(model, flat_params, cfg, method, &Target::stm32f746())
    }

    /// Build the full deployment artifact *for a target*: the memory
    /// plan is gated on the target's SRAM capacity and inference is
    /// priced with the target's cycle table. The SRAM-capacity check
    /// runs immediately after memory planning, so oversized models fail
    /// fast without paying for quantization, codegen or a simulated
    /// inference.
    pub fn compile_for(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
        target: &Target,
    ) -> Result<CompiledModel> {
        let strategy = planner::strategy_for(method);
        let graph = Graph::build(model, cfg);
        let plan = plan_memory(&graph, strategy);
        anyhow::ensure!(
            plan.fits(target.sram_bytes),
            "{}: activation arena {}B exceeds {} SRAM ({}B)",
            model.name,
            plan.peak_bytes,
            target.name,
            target.sram_bytes
        );
        Ok(Self::finish(model, flat_params, cfg, method, graph, plan, target))
    }

    /// Opt-in strict compilation: [`compile_for`](Self::compile_for)
    /// followed by the full static verification pass
    /// ([`crate::analysis::analyze`]). Any Error-severity finding —
    /// lane overflow, resource violation, plan inconsistency — rejects
    /// the artifact, with the offending rule ids in the error text.
    pub fn compile_for_strict(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
        target: &Target,
    ) -> Result<CompiledModel> {
        let cm = Self::compile_for(model, flat_params, cfg, method, target)?;
        cm.verify_strict()?;
        Ok(cm)
    }

    /// Run the static analyzer over this artifact and fail on any
    /// Error-severity finding. The error message carries the rule ids
    /// (e.g. `packing/lane-overflow`) so callers can pin the exact
    /// rejection reason.
    pub fn verify_strict(&self) -> Result<()> {
        let report = crate::analysis::analyze(self);
        let errs = report.error_rules();
        anyhow::ensure!(
            errs.is_empty(),
            "{}: static analysis found {} error(s): [{}]",
            self.model.name,
            report.errors(),
            errs.join(", ")
        );
        Ok(())
    }

    /// Build without the SRAM-capacity gate. Comparison tables (Table I)
    /// want a row even for deployments that exceed the budget — the
    /// peak-memory column is exactly where the violation shows.
    pub fn compile_unbounded(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
    ) -> CompiledModel {
        Self::compile_unbounded_for(model, flat_params, cfg, method, &Target::stm32f746())
    }

    /// [`compile_unbounded`](CompiledModel::compile_unbounded) for an
    /// explicit target.
    pub fn compile_unbounded_for(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
        target: &Target,
    ) -> CompiledModel {
        let strategy = planner::strategy_for(method);
        let graph = Graph::build(model, cfg);
        let plan = plan_memory(&graph, strategy);
        Self::finish(model, flat_params, cfg, method, graph, plan, target)
    }

    #[allow(clippy::too_many_arguments)]
    fn finish(
        model: &ModelDesc,
        flat_params: &[f32],
        cfg: &BitConfig,
        method: Method,
        graph: Graph,
        plan: MemoryPlan,
        target: &Target,
    ) -> CompiledModel {
        let quantized = quantize_model(model, flat_params, cfg);
        let codegen = CodegenPlan::generate(model, cfg, method);
        let flash = FlashImage::layout(model, cfg, &quantized, &codegen);
        debug_assert!(
            flash.matches(&quantized),
            "flash image must round-trip the quantized weights"
        );
        let kernels = KernelCache::build(model, &quantized, cfg, method);
        COMPILE_COUNT.fetch_add(1, Ordering::Relaxed);
        CompiledModel {
            model: model.clone(),
            cfg: cfg.clone(),
            method,
            graph,
            plan,
            quantized,
            codegen,
            flash,
            target: *target,
            kernels,
        }
    }

    /// Execute one inference on the precompiled artifact (the cheap path:
    /// no graph/plan/quantize/codegen/flash work, and — for SLBC methods —
    /// no kernel re-packing: the [`KernelCache`] registers are streamed).
    pub fn run(&self, image: &[f32]) -> Result<InferenceResult> {
        executor::infer_with_kernels(
            &self.model,
            &self.quantized,
            &self.cfg,
            self.method,
            image,
            &self.target.cycle_model,
            Some(&self.kernels),
        )
    }

    /// [`run`](CompiledModel::run) with a caller-owned
    /// [`ConvScratch`](crate::ops::slbc::ConvScratch) instead of the
    /// global thread-local — what serve workers use so concurrent fleet
    /// simulations never share pipeline state. Bit- and cycle-identical
    /// to [`run`](CompiledModel::run).
    pub fn run_with_scratch(
        &self,
        image: &[f32],
        scratch: &mut crate::ops::slbc::ConvScratch,
    ) -> Result<InferenceResult> {
        executor::infer_with_kernels_scratch(
            &self.model,
            &self.quantized,
            &self.cfg,
            self.method,
            image,
            &self.target.cycle_model,
            Some(&self.kernels),
            Some(scratch),
        )
    }

    /// Execute a batch of images, returning every per-image result.
    pub fn run_batch(&self, images: &[f32]) -> Result<Vec<InferenceResult>> {
        executor::infer_batch_with_kernels(
            &self.model,
            &self.quantized,
            &self.cfg,
            self.method,
            images,
            &self.target.cycle_model,
            Some(&self.kernels),
        )
    }

    /// Peak SRAM of the planned activation arena (bytes).
    pub fn peak_sram(&self) -> usize {
        self.plan.peak_bytes
    }

    /// Total flash footprint (packed weights + metadata + code).
    pub fn flash_bytes(&self) -> usize {
        self.flash.total_bytes()
    }

    /// Run one inference and assemble the Table I row for it: cycles
    /// from the target's cycle table, latency at the target's clock,
    /// joules from the target's energy model.
    pub fn report(&self, image: &[f32]) -> Result<DeployReport> {
        let result = self.run(image)?;
        let per_layer_joules = result
            .per_layer_counters
            .iter()
            .map(|c| self.target.joules(c))
            .collect();
        Ok(DeployReport {
            backbone: self.model.name.clone(),
            method: self.method,
            config: self.cfg.clone(),
            target: self.target.name.to_string(),
            peak_sram: self.peak_sram(),
            flash_bytes: self.flash_bytes(),
            cycles: result.cycles,
            latency_ms: self.target.seconds(result.cycles) * 1e3,
            joules: self.target.joules(&result.counter),
            per_layer: result.per_layer,
            per_layer_joules,
        })
    }
}

/// Deploy `model` (trained flat f32 params) with `method` under `cfg`,
/// running one inference on `image` to obtain the cycle/memory numbers.
///
/// Thin wrapper over [`CompiledModel::compile`] + [`CompiledModel::report`];
/// callers that run more than one inference should hold on to the
/// [`CompiledModel`] (or use [`crate::serve::Registry`]) instead of
/// calling this repeatedly.
pub fn deploy(
    model: &ModelDesc,
    flat_params: &[f32],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
) -> Result<DeployReport> {
    CompiledModel::compile(model, flat_params, cfg, method)?.report(image)
}

/// [`deploy`] against an explicit [`Target`] (resolved by name through
/// [`Target::lookup`] at the CLI): SRAM gate, cycle pricing, latency
/// clock and energy model all come from the target.
pub fn deploy_for(
    model: &ModelDesc,
    flat_params: &[f32],
    cfg: &BitConfig,
    method: Method,
    image: &[f32],
    target: &Target,
) -> Result<DeployReport> {
    CompiledModel::compile_for(model, flat_params, cfg, method, target)?.report(image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;
    use crate::util::prng::Rng;

    fn fake_params(n: usize) -> Vec<f32> {
        let mut rng = Rng::new(99);
        (0..n).map(|_| rng.normal() * 0.1).collect()
    }

    #[test]
    fn deploy_produces_table1_row() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let img = vec![0.5f32; 16 * 16 * 3];
        let rep = deploy(&m, &params, &cfg, Method::RpSlbc, &img).unwrap();
        assert!(rep.peak_sram > 0);
        assert!(rep.flash_bytes > 0);
        assert!(rep.cycles > 0);
        assert!(rep.latency_ms > 0.0);
        assert_eq!(rep.per_layer.len(), m.num_layers());
        assert_eq!(rep.per_layer_joules.len(), rep.per_layer.len());
        // Energy is linear in the instruction histogram, so the per-layer
        // prices sum to the total up to f64 rounding.
        let sum: f64 = rep.per_layer_joules.iter().sum();
        assert!((sum - rep.joules).abs() <= 1e-12 * rep.joules.max(1.0));
        assert!(rep.per_layer_joules.iter().all(|&j| j > 0.0));
    }

    #[test]
    fn mixq_deploy_beats_int8_tinyengine() {
        // The headline: mixed sub-byte SLBC vs int8 TinyEngine (Table I).
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let img = vec![0.5f32; 16 * 16 * 3];
        let cfg4 = BitConfig::uniform(m.num_layers(), 4);
        let cfg8 = BitConfig::uniform(m.num_layers(), 8);
        let mixq = deploy(&m, &params, &cfg4, Method::RpSlbc, &img).unwrap();
        let tiny = deploy(&m, &params, &cfg8, Method::TinyEngine, &img).unwrap();
        assert!(
            mixq.cycles < tiny.cycles,
            "mixq {} vs tinyengine {}",
            mixq.cycles,
            tiny.cycles
        );
    }

    #[test]
    fn compile_once_run_many() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let before = compile_count();
        let cm = CompiledModel::compile(&m, &params, &cfg, Method::RpSlbc).unwrap();
        // The counter is global (other test threads may also compile), so
        // only monotonicity is asserted here; strict per-model equality is
        // checked single-threaded in `bench-serve` and the serve tests.
        assert!(compile_count() > before);
        let img = vec![0.5f32; 16 * 16 * 3];
        let a = cm.run(&img).unwrap();
        let b = cm.run(&img).unwrap();
        // Reusing the artifact stays bit-exact + cycle-exact.
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.per_layer, b.per_layer);
    }

    #[test]
    fn repeated_runs_never_repack_kernels() {
        // The KernelCache acceptance guarantee: once compiled, inference
        // performs zero kernel-register packing — host-side packing is
        // compile-time work, observable through the global pack counter.
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let cm = CompiledModel::compile(&m, &params, &cfg, Method::RpSlbc).unwrap();
        assert_eq!(cm.kernels.packed_layers(), m.num_layers());
        let img = vec![0.5f32; 16 * 16 * 3];
        // The pack counter is thread-local, so this thread's snapshot is
        // immune to parallel test threads compiling their own models.
        let a = cm.run(&img).unwrap();
        let before = crate::ops::slbc::kernel_pack_count();
        for _ in 0..3 {
            let b = cm.run(&img).unwrap();
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.cycles, b.cycles);
        }
        assert_eq!(
            crate::ops::slbc::kernel_pack_count(),
            before,
            "CompiledModel::run must not re-pack kernel registers"
        );
    }

    #[test]
    fn baseline_methods_carry_empty_kernel_cache() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 8);
        let cm = CompiledModel::compile(&m, &params, &cfg, Method::TinyEngine).unwrap();
        assert_eq!(cm.kernels.packed_layers(), 0);
        // The empty cache must not break the run path.
        let img = vec![0.5f32; 16 * 16 * 3];
        assert!(cm.run(&img).is_ok());
    }

    #[test]
    fn oversized_model_fails_fast_without_inference() {
        // 128×128 input under all-live allocation blows the 320 KB SRAM
        // budget; compile must reject it before any simulated inference.
        let m = vgg_tiny(10, 128);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 8);
        let err = CompiledModel::compile(&m, &params, &cfg, Method::CmixNn)
            .err()
            .expect("oversized model must be rejected");
        assert!(format!("{err:#}").contains("exceeds stm32f746 SRAM"));
        // The unbounded path still builds the artifact so comparison
        // tables can report the violation in their peak-memory column.
        let cm = CompiledModel::compile_unbounded(&m, &params, &cfg, Method::CmixNn);
        assert!(cm.peak_sram() > crate::STM32F746_SRAM_BYTES);
    }

    #[test]
    fn compile_for_target_prices_with_the_target_models() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let m7 = Target::lookup("m7").unwrap();
        let m4 = Target::lookup("m4").unwrap();
        let a = CompiledModel::compile_for(&m, &params, &cfg, Method::RpSlbc, m7).unwrap();
        let b = CompiledModel::compile_for(&m, &params, &cfg, Method::RpSlbc, m4).unwrap();
        let img = vec![0.5f32; 16 * 16 * 3];
        let ra = a.report(&img).unwrap();
        let rb = b.report(&img).unwrap();
        assert_eq!(ra.target, "stm32f746");
        assert_eq!(rb.target, "stm32f446");
        // Same computation, device-specific pricing: the M4 never runs
        // it in fewer cycles, always in more wall-clock, and always for
        // fewer joules.
        let run_a = a.run(&img).unwrap();
        let run_b = b.run(&img).unwrap();
        assert_eq!(run_a.logits, run_b.logits, "bit-exact across targets");
        assert_eq!(run_a.counter, run_b.counter, "same instruction histogram");
        assert!(rb.cycles >= ra.cycles);
        assert!(rb.latency_ms > ra.latency_ms, "slower clock, longer latency");
        assert!(rb.joules < ra.joules, "smaller core, fewer joules");
        assert!(ra.joules > 0.0);

        // The SRAM gate is the *target's* gate, not a global constant.
        let mut tiny = *m7;
        tiny.sram_bytes = 16;
        let err = CompiledModel::compile_for(&m, &params, &cfg, Method::RpSlbc, &tiny)
            .err()
            .expect("16B SRAM must reject everything");
        assert!(format!("{err:#}").contains("exceeds stm32f746 SRAM"));
    }

    #[test]
    fn batch_run_matches_single_runs() {
        let m = vgg_tiny(10, 16);
        let params = fake_params(m.param_count);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let cm = CompiledModel::compile(&m, &params, &cfg, Method::Slbc).unwrap();
        let batch = crate::datasets::synth_cifar(3, 16, 7);
        let detailed = cm.run_batch(&batch.images).unwrap();
        assert_eq!(detailed.len(), 3);
        for (i, r) in detailed.iter().enumerate() {
            let single = cm.run(batch.image(i)).unwrap();
            assert_eq!(r.logits, single.logits, "image {i}");
            assert_eq!(r.cycles, single.cycles, "image {i}");
        }
    }
}
