//! SRAM arena planning.
//!
//! TinyEngine's "model-adaptive memory scheduling" assigns every
//! activation tensor an offset in one flat arena such that tensors with
//! overlapping lifetimes never overlap in space; peak memory is the arena
//! high-water mark instead of the sum of all buffers. We implement the
//! standard greedy best-fit-by-decreasing-size planner (the same family
//! as TFLite-Micro's and TinyEngine's planners), plus the baseline
//! [`PlanStrategy::AllLive`] allocation that library-style deployments
//! (CMix-NN, WPC&DDD, CMSIS-NN) effectively use — reproducing the Table I
//! peak-memory gap between the two deployment styles.

use crate::ops::Method;

use super::graph::Graph;

/// Allocation strategy of a deployment framework.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanStrategy {
    /// Lifetime-aware arena planning (TinyEngine, MCU-MixQ).
    Lifetime,
    /// Every buffer statically allocated (CMix-NN / WPC&DDD style).
    AllLive,
}

/// Which strategy a Table I method row uses.
pub fn strategy_for(method: Method) -> PlanStrategy {
    match method {
        Method::TinyEngine | Method::Slbc | Method::RpSlbc => PlanStrategy::Lifetime,
        _ => PlanStrategy::AllLive,
    }
}

/// A planned arena: per-tensor offsets plus the peak.
#[derive(Debug, Clone)]
pub struct MemoryPlan {
    /// Byte offset per tensor id (same indexing as `graph.tensors`).
    pub offsets: Vec<usize>,
    /// Arena high-water mark in bytes.
    pub peak_bytes: usize,
    pub strategy: PlanStrategy,
}

impl MemoryPlan {
    /// Does the planned arena fit in `sram_bytes`? Deployment rejects the
    /// model up front when this fails; the serving layer's admission
    /// control also consults it per device.
    pub fn fits(&self, sram_bytes: usize) -> bool {
        self.peak_bytes <= sram_bytes
    }

    /// Fraction of `sram_bytes` the arena high-water mark occupies —
    /// the analyzer's watermark input (infinite when the budget is 0,
    /// so a zero-SRAM target always reads as over-committed).
    pub fn utilization(&self, sram_bytes: usize) -> f64 {
        if sram_bytes == 0 {
            return f64::INFINITY;
        }
        self.peak_bytes as f64 / sram_bytes as f64
    }

    /// Check the invariant: tensors with overlapping lifetimes must not
    /// overlap in arena space (used by tests and debug assertions).
    pub fn validate(&self, graph: &Graph) -> Result<(), String> {
        let ts = &graph.tensors;
        for a in ts {
            for b in ts {
                if a.id >= b.id {
                    continue;
                }
                if lifetimes_overlap(graph, a.id, b.id) {
                    let (ao, bo) = (self.offsets[a.id], self.offsets[b.id]);
                    let disjoint = ao + a.bytes() <= bo || bo + b.bytes() <= ao;
                    if !disjoint {
                        return Err(format!(
                            "tensors {} and {} overlap in space and time",
                            a.id, b.id
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Lifetime interval of tensor `id` in node order: `[birth, death]`.
fn lifetime(graph: &Graph, id: usize) -> (usize, usize) {
    let t = &graph.tensors[id];
    // The graph input is live from before node 0.
    let birth = t.producer.unwrap_or(0);
    (birth, t.last_use)
}

fn lifetimes_overlap(graph: &Graph, a: usize, b: usize) -> bool {
    let (ab, ad) = lifetime(graph, a);
    let (bb, bd) = lifetime(graph, b);
    ab <= bd && bb <= ad
}

/// Plan the activation arena of `graph` under `strategy`.
pub fn plan_memory(graph: &Graph, strategy: PlanStrategy) -> MemoryPlan {
    match strategy {
        PlanStrategy::AllLive => {
            let mut offsets = vec![0usize; graph.tensors.len()];
            let mut cur = 0usize;
            for t in &graph.tensors {
                offsets[t.id] = cur;
                cur += t.bytes();
            }
            MemoryPlan {
                offsets,
                peak_bytes: cur,
                strategy,
            }
        }
        PlanStrategy::Lifetime => {
            // Greedy best-fit, largest tensors first.
            let mut order: Vec<usize> = (0..graph.tensors.len()).collect();
            order.sort_by_key(|&id| std::cmp::Reverse(graph.tensors[id].bytes()));

            let mut offsets = vec![usize::MAX; graph.tensors.len()];
            let mut placed: Vec<usize> = Vec::new();
            let mut peak = 0usize;
            for &id in &order {
                let size = graph.tensors[id].bytes();
                // Collect forbidden intervals from temporally-overlapping,
                // already-placed tensors.
                let mut busy: Vec<(usize, usize)> = placed
                    .iter()
                    .filter(|&&p| lifetimes_overlap(graph, id, p))
                    .map(|&p| (offsets[p], offsets[p] + graph.tensors[p].bytes()))
                    .collect();
                busy.sort_unstable();
                // First gap that fits.
                let mut candidate = 0usize;
                for &(lo, hi) in &busy {
                    if candidate + size <= lo {
                        break;
                    }
                    candidate = candidate.max(hi);
                }
                offsets[id] = candidate;
                peak = peak.max(candidate + size);
                placed.push(id);
            }
            let plan = MemoryPlan {
                offsets,
                peak_bytes: peak,
                strategy,
            };
            debug_assert!(plan.validate(graph).is_ok());
            plan
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobilenet_tiny, vgg_tiny};
    use crate::quant::BitConfig;

    #[test]
    fn lifetime_plan_valid_and_smaller() {
        for m in [vgg_tiny(10, 16), mobilenet_tiny(2, 16)] {
            for bits in [2u8, 4, 8] {
                let cfg = BitConfig::uniform(m.num_layers(), bits);
                let g = Graph::build(&m, &cfg);
                let lt = plan_memory(&g, PlanStrategy::Lifetime);
                let al = plan_memory(&g, PlanStrategy::AllLive);
                lt.validate(&g).unwrap();
                al.validate(&g).unwrap();
                assert!(
                    lt.peak_bytes < al.peak_bytes,
                    "{} @{}bit: lifetime {} >= all-live {}",
                    m.name,
                    bits,
                    lt.peak_bytes,
                    al.peak_bytes
                );
            }
        }
    }

    #[test]
    fn peak_at_least_live_pair() {
        // Peak must cover at least the largest producer+consumer pair.
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 8);
        let g = Graph::build(&m, &cfg);
        let plan = plan_memory(&g, PlanStrategy::Lifetime);
        let mut min_needed = 0usize;
        for n in &g.nodes {
            let need = g.tensors[n.input].bytes() + g.tensors[n.output].bytes();
            min_needed = min_needed.max(need);
        }
        assert!(plan.peak_bytes >= min_needed);
    }

    #[test]
    fn fits_is_peak_comparison() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 8);
        let g = Graph::build(&m, &cfg);
        let p = plan_memory(&g, PlanStrategy::Lifetime);
        assert!(p.fits(p.peak_bytes));
        assert!(!p.fits(p.peak_bytes - 1));
    }

    #[test]
    fn strategies_assigned_per_method() {
        assert_eq!(strategy_for(Method::RpSlbc), PlanStrategy::Lifetime);
        assert_eq!(strategy_for(Method::TinyEngine), PlanStrategy::Lifetime);
        assert_eq!(strategy_for(Method::CmixNn), PlanStrategy::AllLive);
        assert_eq!(strategy_for(Method::WpcDdd), PlanStrategy::AllLive);
    }

    #[test]
    fn subbyte_activations_shrink_peak() {
        let m = vgg_tiny(10, 16);
        let g2 = Graph::build(&m, &BitConfig::uniform(m.num_layers(), 2));
        let g8 = Graph::build(&m, &BitConfig::uniform(m.num_layers(), 8));
        let p2 = plan_memory(&g2, PlanStrategy::Lifetime).peak_bytes;
        let p8 = plan_memory(&g8, PlanStrategy::Lifetime).peak_bytes;
        assert!(p2 < p8, "2-bit {} vs 8-bit {}", p2, p8);
    }
}
