//! Flash image layout.
//!
//! The flash budget of Table I is weights + constants + code. Weights are
//! stored **packed at their quantized width** (a 2-bit layer costs ¼ the
//! flash of its 8-bit version); biases stay int32 and every layer carries
//! its requantization scale. The code contribution comes from
//! [`super::codegen`].
//!
//! [`FlashImage`] actually materializes the packed byte stream (not just
//! its size): the executor can reload weights from the image, which is
//! what proves the sub-byte packing round-trips losslessly.

use crate::models::ModelDesc;
use crate::quant::{BitConfig, QWeights};

use super::codegen::CodegenPlan;

/// Per-layer record inside the flash image.
#[derive(Debug, Clone)]
pub struct FlashRecord {
    pub layer_idx: usize,
    /// Byte offset of the packed weight blob.
    pub weights_off: usize,
    /// Packed weight bytes.
    pub weights_len: usize,
    /// Bits per weight.
    pub bits: u8,
    /// Weight count (for unpacking).
    pub count: usize,
    /// Byte offset of the int32 bias array.
    pub bias_off: usize,
    pub bias_len: usize,
    /// Requantization scale.
    pub scale: f32,
}

/// A laid-out flash image: metadata + the packed payload.
#[derive(Debug, Clone)]
pub struct FlashImage {
    pub records: Vec<FlashRecord>,
    pub payload: Vec<u8>,
    /// Generated/linked code bytes (not materialized, size only).
    pub code_bytes: usize,
}

impl FlashImage {
    /// Pack quantized weights + biases into a flash payload.
    pub fn layout(
        model: &ModelDesc,
        cfg: &BitConfig,
        quantized: &[(QWeights, Vec<f32>)],
        codegen: &CodegenPlan,
    ) -> FlashImage {
        assert_eq!(quantized.len(), model.layers.len());
        let mut payload: Vec<u8> = Vec::new();
        let mut records = Vec::with_capacity(quantized.len());
        for (i, (qw, bias)) in quantized.iter().enumerate() {
            let bits = cfg.wbits[i];
            debug_assert_eq!(qw.bits, bits);
            let weights_off = payload.len();
            pack_signed(&qw.data, bits, &mut payload);
            let weights_len = payload.len() - weights_off;
            let bias_off = payload.len();
            for &b in bias {
                payload.extend_from_slice(&(b.to_bits()).to_le_bytes());
            }
            records.push(FlashRecord {
                layer_idx: i,
                weights_off,
                weights_len,
                bits,
                count: qw.data.len(),
                bias_off,
                bias_len: bias.len() * 4,
                scale: qw.scale,
            });
        }
        FlashImage {
            records,
            payload,
            code_bytes: codegen.code_bytes(),
        }
    }

    /// Unpack layer `i`'s weights back to i32 (bit-exact round-trip).
    pub fn unpack_weights(&self, i: usize) -> Vec<i32> {
        let r = &self.records[i];
        unpack_signed(
            &self.payload[r.weights_off..r.weights_off + r.weights_len],
            r.bits,
            r.count,
        )
    }

    /// Total flash bytes: payload + per-layer metadata + code.
    pub fn total_bytes(&self) -> usize {
        self.payload.len() + self.records.len() * 24 + self.code_bytes
    }

    /// Weights-only bytes (the Table I "model size" component).
    pub fn weight_bytes(&self) -> usize {
        self.records.iter().map(|r| r.weights_len + r.bias_len).sum()
    }

    /// Does every layer of the image round-trip bit-exactly to
    /// `quantized`? `CompiledModel::compile` debug-asserts this, proving
    /// the artifact the registry caches is faithful to the weights it was
    /// built from.
    pub fn matches(&self, quantized: &[(QWeights, Vec<f32>)]) -> bool {
        self.records.len() == quantized.len()
            && quantized
                .iter()
                .enumerate()
                .all(|(i, (qw, _))| self.unpack_weights(i) == qw.data)
    }
}

/// Pack signed `bits`-wide values little-endian into a bit stream
/// (two's-complement within the field).
fn pack_signed(vals: &[i32], bits: u8, out: &mut Vec<u8>) {
    let start = out.len();
    let total_bits = vals.len() * bits as usize;
    out.resize(start + total_bits.div_ceil(8), 0);
    let mask = ((1u64 << bits) - 1) as u32;
    for (idx, &v) in vals.iter().enumerate() {
        let field = (v as u32) & mask;
        let bit_pos = idx * bits as usize;
        let byte = start + bit_pos / 8;
        let shift = bit_pos % 8;
        // A field spans at most 2 bytes for bits <= 8.
        out[byte] |= (field << shift) as u8;
        if shift + bits as usize > 8 {
            out[byte + 1] |= (field >> (8 - shift)) as u8;
        }
    }
}

/// Inverse of [`pack_signed`] with sign extension.
fn unpack_signed(bytes: &[u8], bits: u8, count: usize) -> Vec<i32> {
    let mask = ((1u64 << bits) - 1) as u32;
    let sign_bit = 1u32 << (bits - 1);
    (0..count)
        .map(|idx| {
            let bit_pos = idx * bits as usize;
            let byte = bit_pos / 8;
            let shift = bit_pos % 8;
            let mut field = (bytes[byte] as u32) >> shift;
            if shift + bits as usize > 8 {
                field |= (bytes[byte + 1] as u32) << (8 - shift);
            }
            field &= mask;
            if field & sign_bit != 0 {
                (field | !mask) as i32
            } else {
                field as i32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;
    use crate::ops::Method;
    use crate::quant::quantize_model;
    use crate::util::prng::Rng;
    use crate::util::prop::check;

    #[test]
    fn pack_roundtrip_all_bitwidths() {
        check("flash pack/unpack roundtrip", 40, |rng| {
            let bits = rng.range(2, 9) as u8;
            let n = rng.range(1, 200);
            let lim = (1i64 << (bits - 1)) - 1;
            let vals: Vec<i32> = (0..n)
                .map(|_| (rng.below(2 * lim as u64 + 1) as i64 - lim) as i32)
                .collect();
            let mut buf = Vec::new();
            pack_signed(&vals, bits, &mut buf);
            assert_eq!(unpack_signed(&buf, bits, n), vals, "bits={bits} n={n}");
        });
    }

    #[test]
    fn image_roundtrips_model_weights() {
        let m = vgg_tiny(10, 16);
        let mut rng = Rng::new(5);
        let flat: Vec<f32> = (0..m.param_count).map(|_| rng.normal() * 0.2).collect();
        let cfg = BitConfig {
            wbits: vec![2, 3, 4, 5, 6, 8],
            abits: vec![4; 6],
        };
        let q = quantize_model(&m, &flat, &cfg);
        let cg = CodegenPlan::generate(&m, &cfg, Method::RpSlbc);
        let img = FlashImage::layout(&m, &cfg, &q, &cg);
        for (i, (qw, _)) in q.iter().enumerate() {
            assert_eq!(img.unpack_weights(i), qw.data, "layer {i}");
        }
        assert!(img.matches(&q));
        // Any payload corruption in a weight region must be detected.
        let mut bad = img.clone();
        bad.payload[bad.records[0].weights_off] ^= 1;
        assert!(!bad.matches(&q));
    }

    #[test]
    fn flash_scales_with_bits() {
        let m = vgg_tiny(10, 16);
        let mut rng = Rng::new(6);
        let flat: Vec<f32> = (0..m.param_count).map(|_| rng.normal()).collect();
        let cg = |cfg: &BitConfig| {
            let q = quantize_model(&m, &flat, cfg);
            let plan = CodegenPlan::generate(&m, cfg, Method::RpSlbc);
            FlashImage::layout(&m, cfg, &q, &plan).weight_bytes()
        };
        let w2 = cg(&BitConfig::uniform(6, 2));
        let w4 = cg(&BitConfig::uniform(6, 4));
        let w8 = cg(&BitConfig::uniform(6, 8));
        assert!(w2 < w4 && w4 < w8, "{w2} {w4} {w8}");
        // 4-bit weights ≈ half the 8-bit payload (biases are constant).
        let m4 = (w4 as f64) / (w8 as f64);
        assert!(m4 > 0.4 && m4 < 0.7, "ratio {m4}");
    }
}
