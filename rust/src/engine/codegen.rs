//! Kernel specialization (TinyEngine-style code generation, §IV.C).
//!
//! TinyEngine emits a specialized kernel per layer instead of calling a
//! generic library routine: loop bounds become constants, addresses fold,
//! and branches unroll. MCU-MixQ inherits this and additionally resolves —
//! at compile time, per convolution — the adaptive SLBC lane plan (lane
//! size + field stride, paper §IV.C).
//!
//! We model the *outcome* of codegen: the per-layer [`KernelChoice`]
//! (method variant, lane plan, unrolling) used by the executor and the
//! code-size estimate used by the flash layout. Code-size constants are
//! calibrated to the published footprints of the respective libraries
//! (CMSIS-NN ≈ 20 KB runtime, TinyEngine ≈ 40–80 KB generated code for
//! MCUNet-scale models; Table I shows the same ordering).

use crate::models::{LayerKind, ModelDesc};
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::simd::adaptive::{best_plan, LanePlan};

/// The resolved kernel of one layer.
#[derive(Debug, Clone)]
pub struct KernelChoice {
    pub layer_idx: usize,
    pub method: Method,
    /// Adaptive lane plan (SLBC methods only), resolved through the
    /// memoized `best_plan` search — one search per distinct
    /// `(abits, wbits, k)` triple per process, not one per layer.
    pub lane_plan: Option<LanePlan>,
    /// Whether the emitted kernel actually uses RP-SLBC's reordered
    /// segmentation: compile-time adaptivity keeps naive segmentation
    /// where Theorem IV.1 buys nothing (mirrors `ops::slbc`).
    pub uses_reordering: bool,
    /// Whether codegen emits an unrolled, shape-specialized loop nest.
    pub specialized: bool,
    /// Estimated generated-code bytes for this kernel.
    pub code_bytes: usize,
}

/// Per-model codegen result.
#[derive(Debug, Clone)]
pub struct CodegenPlan {
    pub method: Method,
    pub kernels: Vec<KernelChoice>,
    /// Fixed runtime footprint (scheduler, requantization, pooling, I/O).
    pub runtime_bytes: usize,
}

impl CodegenPlan {
    /// Resolve every layer's kernel for `method` under `cfg`.
    pub fn generate(model: &ModelDesc, cfg: &BitConfig, method: Method) -> CodegenPlan {
        let specialized = matches!(method, Method::TinyEngine | Method::Slbc | Method::RpSlbc);
        let kernels = model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let lane_plan = match method {
                    Method::Slbc | Method::RpSlbc => {
                        best_plan(cfg.abits[i] as u32, cfg.wbits[i] as u32, l.k as u32)
                    }
                    _ => None,
                };
                let uses_reordering = method == Method::RpSlbc
                    && lane_plan
                        .as_ref()
                        .map(|p| p.reordering_wins())
                        .unwrap_or(false);
                let base = match l.kind {
                    LayerKind::Conv => 900,
                    LayerKind::DwConv => 700,
                    LayerKind::Dense => 400,
                };
                // Specialized kernels cost more flash (unrolled copies),
                // generic library kernels are shared across layers.
                let code_bytes = if specialized { base + 600 } else { base / 2 };
                KernelChoice {
                    layer_idx: i,
                    method,
                    lane_plan,
                    uses_reordering,
                    specialized,
                    code_bytes,
                }
            })
            .collect();
        let runtime_bytes = match method {
            // Generated-code runtimes carry the scheduler + planner glue.
            Method::TinyEngine | Method::Slbc | Method::RpSlbc => 42 * 1024,
            // Library runtimes are lean but generic.
            Method::CmixNn | Method::WpcDdd => 24 * 1024,
            Method::Naive | Method::Simd => 16 * 1024,
        };
        CodegenPlan {
            method,
            kernels,
            runtime_bytes,
        }
    }

    /// Mean MACs per SIMD multiply across layers with a lane plan (1.0
    /// for methods without in-lane packing). The serving stats report
    /// this per model as the packing-density headline.
    pub fn mean_macs_per_instr(&self) -> f64 {
        let plans: Vec<u32> = self
            .kernels
            .iter()
            .filter_map(|k| k.lane_plan.map(|p| p.macs_per_instr))
            .collect();
        if plans.is_empty() {
            1.0
        } else {
            plans.iter().map(|&m| m as f64).sum::<f64>() / plans.len() as f64
        }
    }

    /// Total generated/linked code bytes.
    pub fn code_bytes(&self) -> usize {
        // Generic library kernels are deduplicated by (kind): only one
        // copy of each is linked.
        if self.kernels.first().map(|k| k.specialized).unwrap_or(false) {
            self.runtime_bytes + self.kernels.iter().map(|k| k.code_bytes).sum::<usize>()
        } else {
            let mut seen = std::collections::BTreeSet::new();
            let mut sum = 0usize;
            for k in &self.kernels {
                if seen.insert(k.code_bytes) {
                    sum += k.code_bytes;
                }
            }
            self.runtime_bytes + sum
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::vgg_tiny;

    #[test]
    fn slbc_kernels_carry_lane_plans() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 3);
        let plan = CodegenPlan::generate(&m, &cfg, Method::RpSlbc);
        assert!(plan.kernels.iter().all(|k| k.lane_plan.is_some()));
        assert!(plan.kernels.iter().all(|k| k.specialized));
    }

    #[test]
    fn reordering_flag_mirrors_operator_adaptivity() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 2);
        // Naive SLBC never reorders.
        let slbc = CodegenPlan::generate(&m, &cfg, Method::Slbc);
        assert!(slbc.kernels.iter().all(|k| !k.uses_reordering));
        // RP-SLBC at 2-bit: the dense sub-byte fields make Theorem IV.1
        // profitable on the conv layers.
        let rp = CodegenPlan::generate(&m, &cfg, Method::RpSlbc);
        assert!(rp.kernels.iter().any(|k| k.uses_reordering));
        // The flag is only ever set where a reordered plan exists and wins.
        for k in &rp.kernels {
            if k.uses_reordering {
                let p = k.lane_plan.as_ref().unwrap();
                let r = p.reordered.as_ref().unwrap();
                assert!(r.seg_ops_per_instr() < p.conv.seg_ops_per_instr());
            }
        }
    }

    #[test]
    fn library_methods_share_kernels() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let spec = CodegenPlan::generate(&m, &cfg, Method::TinyEngine);
        let lib = CodegenPlan::generate(&m, &cfg, Method::CmixNn);
        // Specialized codegen linked per layer > shared library kernels.
        assert!(spec.code_bytes() > lib.code_bytes());
    }

    #[test]
    fn packing_density_summary() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 2);
        let slbc = CodegenPlan::generate(&m, &cfg, Method::RpSlbc);
        let lib = CodegenPlan::generate(&m, &cfg, Method::CmixNn);
        assert!(slbc.mean_macs_per_instr() > 1.0);
        assert_eq!(lib.mean_macs_per_instr(), 1.0);
    }

    #[test]
    fn lane_plan_adapts_to_bits() {
        let m = vgg_tiny(10, 16);
        let cfg2 = BitConfig::uniform(m.num_layers(), 2);
        let cfg8 = BitConfig::uniform(m.num_layers(), 8);
        let p2 = CodegenPlan::generate(&m, &cfg2, Method::Slbc);
        let p8 = CodegenPlan::generate(&m, &cfg8, Method::Slbc);
        let m2 = p2.kernels[0].lane_plan.unwrap().macs_per_instr;
        let m8 = p8.kernels[0].lane_plan.unwrap().macs_per_instr;
        assert!(
            m2 > m8,
            "2-bit should pack more MACs/instr ({m2}) than 8-bit ({m8})"
        );
    }
}
