//! Inference-graph IR.
//!
//! A [`Graph`] is the deployment-time view of a model: one node per
//! compute step (conv / depthwise conv / dense / max-pool / global-average
//! -pool), one tensor per intermediate activation. Activation tensors
//! carry their *quantized, packed* byte sizes — sub-byte activations are
//! stored packed (`ceil(elems·bits/8)`), which is one of the two levers
//! (with the planner) behind the Table I peak-memory column.

use crate::models::{LayerSpec, ModelDesc};
use crate::quant::BitConfig;

/// Graph node operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NodeOp {
    /// Convolution / depthwise / dense over `layer_idx` of the model.
    Layer { layer_idx: usize },
    /// 2×2 max-pool after `layer_idx`.
    MaxPool { layer_idx: usize },
    /// Global average pool before the final dense layer.
    GlobalAvgPool { layer_idx: usize },
}

/// One node: consumes `input`, produces `output` (tensor ids).
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    pub op: NodeOp,
    pub input: usize,
    pub output: usize,
    pub name: String,
}

/// An activation tensor in the SRAM arena.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub id: usize,
    /// Element count.
    pub elems: usize,
    /// Storage bits per element (activation quantization width; the model
    /// input stays 8-bit).
    pub bits: u8,
    /// First node producing it (`None` for the graph input).
    pub producer: Option<usize>,
    /// Last node consuming it (filled by `Graph::build`).
    pub last_use: usize,
}

impl TensorInfo {
    /// Packed byte size in the arena.
    pub fn bytes(&self) -> usize {
        (self.elems * self.bits as usize).div_ceil(8)
    }
}

/// The deployment graph of one model under one bit configuration.
#[derive(Debug, Clone)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub tensors: Vec<TensorInfo>,
    /// Graph input tensor id.
    pub input: usize,
    /// Graph output tensor id.
    pub output: usize,
}

impl Graph {
    /// Build the graph of `model` with activation bitwidths from `cfg`.
    ///
    /// Activation storage width of a layer's *output* is the consuming
    /// layer's activation bitwidth (quantize-at-production), except the
    /// final logits which stay 32-bit.
    pub fn build(model: &ModelDesc, cfg: &BitConfig) -> Graph {
        assert_eq!(cfg.num_layers(), model.layers.len());
        let mut tensors: Vec<TensorInfo> = Vec::new();
        let mut nodes: Vec<Node> = Vec::new();

        // Input tensor: 8-bit image.
        let input_elems = model.input_hw * model.input_hw * model.input_c;
        tensors.push(TensorInfo {
            id: 0,
            elems: input_elems,
            bits: 8,
            producer: None,
            last_use: 0,
        });
        let mut cur = 0usize;

        let n = model.layers.len();
        for (i, l) in model.layers.iter().enumerate() {
            // Optional GAP before a dense layer.
            if l.gap_before {
                let t = new_tensor(&mut tensors, l.cin, act_bits(cfg, i, n));
                push_node(
                    &mut nodes,
                    &mut tensors,
                    NodeOp::GlobalAvgPool { layer_idx: i },
                    cur,
                    t,
                    format!("{}::gap", l.name),
                );
                cur = t;
            }
            // The layer itself.
            let out_bits = if i + 1 == n { 32 } else { act_bits(cfg, i + 1, n) };
            let t = new_tensor(&mut tensors, l.out_elems(), out_bits);
            push_node(
                &mut nodes,
                &mut tensors,
                NodeOp::Layer { layer_idx: i },
                cur,
                t,
                l.name.clone(),
            );
            cur = t;
            // Optional 2×2 max-pool.
            if l.pool_after {
                let pooled = (l.out_h / 2) * (l.out_w / 2) * l.cout;
                let t = new_tensor(&mut tensors, pooled, out_bits);
                push_node(
                    &mut nodes,
                    &mut tensors,
                    NodeOp::MaxPool { layer_idx: i },
                    cur,
                    t,
                    format!("{}::pool", l.name),
                );
                cur = t;
            }
        }

        Graph {
            input: 0,
            output: cur,
            nodes,
            tensors,
        }
    }

    /// The *compute* node of layer `layer_idx` (not its pool/GAP
    /// followers) — how the static analyzer anchors width-chain and
    /// tensor-size checks to layers.
    pub fn layer_node(&self, layer_idx: usize) -> Option<&Node> {
        self.nodes
            .iter()
            .find(|n| matches!(n.op, NodeOp::Layer { layer_idx: i } if i == layer_idx))
    }

    /// Layer spec behind a node (pool nodes reference their source layer).
    pub fn layer_of<'m>(&self, model: &'m ModelDesc, node: &Node) -> &'m LayerSpec {
        let idx = match node.op {
            NodeOp::Layer { layer_idx }
            | NodeOp::MaxPool { layer_idx }
            | NodeOp::GlobalAvgPool { layer_idx } => layer_idx,
        };
        &model.layers[idx]
    }

    /// Total bytes if every tensor were live simultaneously (the
    /// no-planning allocation of library-style deployments).
    pub fn all_live_bytes(&self) -> usize {
        self.tensors.iter().map(|t| t.bytes()).sum()
    }
}

fn act_bits(cfg: &BitConfig, layer: usize, n: usize) -> u8 {
    if layer >= n {
        32
    } else {
        cfg.abits[layer]
    }
}

fn new_tensor(tensors: &mut Vec<TensorInfo>, elems: usize, bits: u8) -> usize {
    let id = tensors.len();
    tensors.push(TensorInfo {
        id,
        elems,
        bits,
        producer: None,
        last_use: 0,
    });
    id
}

fn push_node(
    nodes: &mut Vec<Node>,
    tensors: &mut [TensorInfo],
    op: NodeOp,
    input: usize,
    output: usize,
    name: String,
) {
    let id = nodes.len();
    tensors[output].producer = Some(id);
    tensors[input].last_use = id;
    tensors[output].last_use = id; // provisional; later consumers extend it
    nodes.push(Node {
        id,
        op,
        input,
        output,
        name,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{mobilenet_tiny, vgg_tiny};

    #[test]
    fn vgg_graph_structure() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let g = Graph::build(&m, &cfg);
        // 6 layers + 3 pools = 9 nodes.
        assert_eq!(g.nodes.len(), 9);
        assert_eq!(g.tensors.len(), 10);
        assert_eq!(g.tensors[g.output].bits, 32); // logits
    }

    #[test]
    fn mobilenet_graph_has_gap() {
        let m = mobilenet_tiny(2, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let g = Graph::build(&m, &cfg);
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.op, NodeOp::GlobalAvgPool { .. })));
    }

    #[test]
    fn subbyte_tensors_pack() {
        let m = vgg_tiny(10, 16);
        let cfg2 = BitConfig::uniform(m.num_layers(), 2);
        let cfg8 = BitConfig::uniform(m.num_layers(), 8);
        let g2 = Graph::build(&m, &cfg2);
        let g8 = Graph::build(&m, &cfg8);
        assert!(g2.all_live_bytes() < g8.all_live_bytes());
        // 2-bit tensor of 100 elems = 25 bytes.
        let t = TensorInfo {
            id: 0,
            elems: 100,
            bits: 2,
            producer: None,
            last_use: 0,
        };
        assert_eq!(t.bytes(), 25);
    }

    #[test]
    fn lifetimes_are_ordered() {
        let m = vgg_tiny(10, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let g = Graph::build(&m, &cfg);
        for t in &g.tensors {
            if let Some(p) = t.producer {
                assert!(t.last_use >= p, "tensor {} dies before birth", t.id);
            }
        }
    }
}
