//! Multi-tenant model registry with a compile-once artifact cache.
//!
//! A serving deployment hosts many (backbone, method, bit-config) tenants
//! but compiles each at most once: the registry maps a [`ModelKey`] to an
//! `Arc<CompiledModel>` under an LRU policy, so sustained traffic pays
//! only [`CompiledModel::run`](crate::engine::CompiledModel::run) per
//! request. Hit/miss/compile/eviction counters make the compile-once
//! guarantee observable (cross-checked against
//! [`crate::engine::compile_count`] in tests and `bench-serve`).
//!
//! Keys carry a parameter fingerprint ([`hash_params`]), so *distinct
//! tenants* deploying the same `(backbone, method, bits)` with identical
//! trained weights collapse onto one cached artifact — cross-tenant
//! weight sharing, surfaced by [`RegistryStats::shared_hits`] — while
//! same-triple tenants with different weights stay separate.
//!
//! Since the rolling-row conv refactor, the cached artifact also carries
//! the engine's [`KernelCache`](crate::engine::KernelCache) of pre-packed
//! SLBC kernel registers, so a registry hit serves requests with **zero
//! kernel re-packing** — compilation cost *and* packing cost amortize
//! across the tenant's whole request stream (asserted below against
//! [`crate::ops::slbc::kernel_pack_count`]).

use std::sync::Arc;

use crate::engine::CompiledModel;
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::Result;

/// FNV-1a over the raw bit patterns of the trained parameters — the
/// weight-sharing fingerprint: tenants whose params hash identically
/// (and match on backbone/method/bits) deploy one shared artifact.
pub fn hash_params(params: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for p in params {
        for b in p.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Identity of one served model: the triple Table I rows are keyed by,
/// plus the parameter fingerprint that gates cross-tenant weight sharing.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelKey {
    pub backbone: String,
    pub method: Method,
    pub cfg: BitConfig,
    /// [`hash_params`] of the deployed parameters (0 when unknown —
    /// such keys only share with other unknown-params keys).
    pub params_hash: u64,
}

impl ModelKey {
    pub fn new(backbone: &str, method: Method, cfg: BitConfig) -> ModelKey {
        ModelKey {
            backbone: backbone.to_string(),
            method,
            cfg,
            params_hash: 0,
        }
    }

    /// Key with the parameter fingerprint filled in (what
    /// [`Workload`](super::Workload) construction uses).
    pub fn with_params(backbone: &str, method: Method, cfg: BitConfig, params: &[f32]) -> ModelKey {
        ModelKey {
            params_hash: hash_params(params),
            ..ModelKey::new(backbone, method, cfg)
        }
    }

    /// Human label, e.g. `vgg_tiny/rp-slbc/w4.0a4.0`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/w{:.1}a{:.1}",
            self.backbone,
            self.method.name(),
            self.cfg.avg_wbits(),
            self.cfg.avg_abits()
        )
    }
}

/// Cache effectiveness counters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistryStats {
    pub hits: u64,
    pub misses: u64,
    pub compiles: u64,
    pub evictions: u64,
    /// Hits served to a tenant other than the one whose lookup compiled
    /// the artifact — the cross-tenant weight-sharing win.
    pub shared_hits: u64,
    /// Error-severity static-analysis findings over all first compiles
    /// (each key is linted exactly once, on its compiling miss).
    pub lint_errors: u64,
    /// Warning-severity static-analysis findings over all first compiles.
    pub lint_warnings: u64,
}

impl RegistryStats {
    /// Hits over lookups (0 when the registry was never queried).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct CacheEntry {
    key: ModelKey,
    model: Arc<CompiledModel>,
    last_use: u64,
    /// Tenant whose lookup compiled this entry (for shared-hit
    /// attribution).
    owner_tenant: usize,
}

/// LRU cache of compiled deployment artifacts.
///
/// Entries are kept in a flat `Vec` (tenant counts are small and
/// `BitConfig` is not hashable); recency is a logical clock bumped per
/// lookup, which keeps eviction order deterministic. Per-model hit
/// counts live outside the entries so eviction never loses them.
pub struct Registry {
    capacity: usize,
    clock: u64,
    entries: Vec<CacheEntry>,
    stats: RegistryStats,
    /// Lifetime hits per model label (first-hit order, survives
    /// eviction and re-insertion).
    hits_by_label: Vec<(String, u64)>,
    /// Static-analysis outcome per compiled key, in first-compile
    /// order. One record per compiling miss — hits never re-lint.
    lints: Vec<KeyLint>,
}

/// The registry's record of one key's first-compile static analysis.
#[derive(Debug, Clone)]
pub struct KeyLint {
    pub label: String,
    pub errors: usize,
    pub warnings: usize,
    /// Deduped Error rule ids (empty for a clean artifact).
    pub error_rules: Vec<&'static str>,
}

impl Registry {
    /// A registry holding at most `capacity` compiled models.
    pub fn new(capacity: usize) -> Registry {
        assert!(capacity >= 1, "registry capacity must be >= 1");
        Registry {
            capacity,
            clock: 0,
            entries: Vec::new(),
            stats: RegistryStats::default(),
            hits_by_label: Vec::new(),
            lints: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, key: &ModelKey) -> bool {
        self.entries.iter().any(|e| e.key == *key)
    }

    /// Fetch the artifact for `key`, compiling (through `build`) only on
    /// a miss. Evicts the least-recently-used entry when full.
    /// Single-tenant convenience over
    /// [`get_or_compile_for`](Registry::get_or_compile_for).
    pub fn get_or_compile<F>(&mut self, key: &ModelKey, build: F) -> Result<Arc<CompiledModel>>
    where
        F: FnOnce() -> Result<CompiledModel>,
    {
        self.get_or_compile_for(0, key, build)
    }

    /// [`get_or_compile`](Registry::get_or_compile) with tenant
    /// attribution: a hit served to a tenant other than the entry's
    /// compiler counts as a *shared* hit — tenants deploying the same
    /// `(backbone, method, bits)` with identical parameters collapse to
    /// one artifact, and `shared_hits` makes the collapse observable.
    pub fn get_or_compile_for<F>(
        &mut self,
        tenant: usize,
        key: &ModelKey,
        build: F,
    ) -> Result<Arc<CompiledModel>>
    where
        F: FnOnce() -> Result<CompiledModel>,
    {
        self.clock += 1;
        if let Some(e) = self.entries.iter_mut().find(|e| e.key == *key) {
            e.last_use = self.clock;
            self.stats.hits += 1;
            if e.owner_tenant != tenant {
                self.stats.shared_hits += 1;
            }
            let model = e.model.clone();
            let label = key.label();
            match self.hits_by_label.iter_mut().find(|(l, _)| *l == label) {
                Some((_, h)) => *h += 1,
                None => self.hits_by_label.push((label, 1)),
            }
            return Ok(model);
        }
        self.stats.misses += 1;
        let model = Arc::new(build()?);
        self.stats.compiles += 1;
        // Lint on first compile per key: the static analyzer runs once
        // per artifact (hits never re-lint) so a fleet silently serving
        // an unsound or over-budget model is observable in the stats.
        let lint = crate::analysis::analyze(&model);
        self.stats.lint_errors += lint.errors() as u64;
        self.stats.lint_warnings += lint.warnings() as u64;
        self.lints.push(KeyLint {
            label: key.label(),
            errors: lint.errors(),
            warnings: lint.warnings(),
            error_rules: lint.error_rules(),
        });
        if self.entries.len() == self.capacity {
            let lru = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .expect("capacity >= 1 so the cache is non-empty");
            self.entries.remove(lru);
            self.stats.evictions += 1;
        }
        self.entries.push(CacheEntry {
            key: key.clone(),
            model: model.clone(),
            last_use: self.clock,
            owner_tenant: tenant,
        });
        Ok(model)
    }

    pub fn stats(&self) -> &RegistryStats {
        &self.stats
    }

    /// Lifetime per-model hit counts `(label, hits)` in first-hit order.
    /// Counts survive eviction and re-insertion, so they always reflect
    /// the true amortization of each model's compilations.
    pub fn per_model_hits(&self) -> Vec<(String, u64)> {
        self.hits_by_label.clone()
    }

    /// Static-analysis outcome per compiled key, in first-compile order
    /// (one record per compiling miss; cache hits never re-lint).
    pub fn lints(&self) -> &[KeyLint] {
        &self.lints
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine;
    use crate::models::mobilenet_tiny;
    use crate::util::prng::Rng;

    fn key(bits: u8, method: Method) -> ModelKey {
        let m = mobilenet_tiny(2, 16);
        ModelKey::new(&m.name, method, BitConfig::uniform(m.num_layers(), bits))
    }

    fn build(bits: u8, method: Method) -> Result<CompiledModel> {
        let m = mobilenet_tiny(2, 16);
        let mut rng = Rng::new(11);
        let params: Vec<f32> = (0..m.param_count).map(|_| rng.normal() * 0.1).collect();
        CompiledModel::compile(&m, &params, &BitConfig::uniform(m.num_layers(), bits), method)
    }

    #[test]
    fn hit_avoids_recompilation() {
        let mut reg = Registry::new(4);
        let k = key(4, Method::RpSlbc);
        // Count actual constructions through the closure (the global
        // engine::compile_count is shared across test threads, so it is
        // only checked for monotonicity here).
        let built = std::cell::Cell::new(0u32);
        let before = engine::compile_count();
        for _ in 0..3 {
            reg.get_or_compile(&k, || {
                built.set(built.get() + 1);
                build(4, Method::RpSlbc)
            })
            .unwrap();
        }
        assert_eq!(built.get(), 1, "the artifact must be compiled exactly once");
        assert!(engine::compile_count() > before);
        assert_eq!(reg.stats().compiles, 1);
        assert_eq!(reg.stats().hits, 2);
        assert_eq!(reg.stats().misses, 1);
        assert_eq!(reg.per_model_hits(), vec![(k.label(), 2)]);
    }

    #[test]
    fn distinct_keys_compile_separately() {
        let mut reg = Registry::new(4);
        reg.get_or_compile(&key(4, Method::RpSlbc), || build(4, Method::RpSlbc))
            .unwrap();
        reg.get_or_compile(&key(8, Method::TinyEngine), || build(8, Method::TinyEngine))
            .unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.stats().compiles, 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut reg = Registry::new(2);
        let (k2, k4, k8) = (
            key(2, Method::RpSlbc),
            key(4, Method::RpSlbc),
            key(8, Method::RpSlbc),
        );
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap();
        reg.get_or_compile(&k4, || build(4, Method::RpSlbc)).unwrap();
        // Touch k2 so k4 becomes the LRU, then insert k8.
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap();
        reg.get_or_compile(&k8, || build(8, Method::RpSlbc)).unwrap();
        assert_eq!(reg.len(), 2);
        assert!(reg.contains(&k2));
        assert!(!reg.contains(&k4), "LRU entry must be evicted");
        assert!(reg.contains(&k8));
        assert_eq!(reg.stats().evictions, 1);
        // Re-fetching the evicted key recompiles.
        reg.get_or_compile(&k4, || build(4, Method::RpSlbc)).unwrap();
        assert_eq!(reg.stats().compiles, 4);
    }

    #[test]
    fn per_model_hits_survive_eviction() {
        let mut reg = Registry::new(1);
        let (k2, k4) = (key(2, Method::RpSlbc), key(4, Method::RpSlbc));
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap();
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap(); // hit
        reg.get_or_compile(&k4, || build(4, Method::RpSlbc)).unwrap(); // evicts k2
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap(); // recompile
        reg.get_or_compile(&k2, || build(2, Method::RpSlbc)).unwrap(); // hit again
        assert!(!reg.contains(&k4));
        let hits = reg.per_model_hits();
        let k2_hits = hits.iter().find(|(l, _)| *l == k2.label()).map(|(_, h)| *h);
        // Both hits survive the eviction + re-insertion cycle.
        assert_eq!(k2_hits, Some(2));
        assert_eq!(reg.stats().evictions, 2);
        assert_eq!(reg.stats().compiles, 3);
    }

    #[test]
    fn registry_hits_serve_prepacked_kernels() {
        // A registry hit must hand back an artifact whose kernel registers
        // are already packed; serving requests from it re-packs nothing.
        let mut reg = Registry::new(2);
        let k = key(4, Method::RpSlbc);
        let m = mobilenet_tiny(2, 16);
        reg.get_or_compile(&k, || build(4, Method::RpSlbc)).unwrap();
        let art = reg.get_or_compile(&k, || build(4, Method::RpSlbc)).unwrap();
        assert_eq!(art.kernels.packed_layers(), m.num_layers());
        let img = vec![0.4f32; m.input_hw * m.input_hw * m.input_c];
        let first = art.run(&img).unwrap();
        let packs = crate::ops::slbc::kernel_pack_count();
        for _ in 0..2 {
            let again = art.run(&img).unwrap();
            assert_eq!(first.logits, again.logits);
        }
        assert_eq!(
            crate::ops::slbc::kernel_pack_count(),
            packs,
            "serving from a registry hit must not re-pack kernels"
        );
    }

    #[test]
    fn identical_params_share_one_artifact_across_tenants() {
        let m = mobilenet_tiny(2, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let mut rng = Rng::new(55);
        let params: Vec<f32> = (0..m.param_count).map(|_| rng.normal() * 0.1).collect();
        let shared_key = ModelKey::with_params(&m.name, Method::RpSlbc, cfg.clone(), &params);

        let mut reg = Registry::new(4);
        let built = std::cell::Cell::new(0u32);
        let fetch = |tenant: usize, reg: &mut Registry| {
            reg.get_or_compile_for(tenant, &shared_key, || {
                built.set(built.get() + 1);
                CompiledModel::compile(&m, &params, &cfg, Method::RpSlbc)
            })
            .unwrap()
        };
        let a = fetch(0, &mut reg);
        let b = fetch(1, &mut reg); // other tenant, same weights
        let c = fetch(0, &mut reg); // owner again
        assert_eq!(built.get(), 1, "identical tenants share one compilation");
        assert!(Arc::ptr_eq(&a, &b) && Arc::ptr_eq(&b, &c), "one shared artifact");
        assert_eq!(reg.stats().compiles, 1);
        assert_eq!(reg.stats().hits, 2);
        assert_eq!(reg.stats().shared_hits, 1, "only the foreign tenant's hit is shared");
    }

    #[test]
    fn differing_params_do_not_share() {
        let m = mobilenet_tiny(2, 16);
        let cfg = BitConfig::uniform(m.num_layers(), 4);
        let mk_params = |seed: u64| -> Vec<f32> {
            let mut rng = Rng::new(seed);
            (0..m.param_count).map(|_| rng.normal() * 0.1).collect()
        };
        let (pa, pb) = (mk_params(1), mk_params(2));
        let ka = ModelKey::with_params(&m.name, Method::RpSlbc, cfg.clone(), &pa);
        let kb = ModelKey::with_params(&m.name, Method::RpSlbc, cfg.clone(), &pb);
        assert_ne!(ka, kb, "same triple, different weights: distinct keys");

        let mut reg = Registry::new(4);
        reg.get_or_compile_for(0, &ka, || CompiledModel::compile(&m, &pa, &cfg, Method::RpSlbc))
            .unwrap();
        reg.get_or_compile_for(1, &kb, || CompiledModel::compile(&m, &pb, &cfg, Method::RpSlbc))
            .unwrap();
        assert_eq!(reg.stats().compiles, 2, "different weights compile separately");
        assert_eq!(reg.stats().shared_hits, 0);
    }

    #[test]
    fn hash_params_is_stable_and_discriminating() {
        let a = vec![0.1f32, -0.2, 0.3];
        let b = vec![0.1f32, -0.2, 0.3];
        let c = vec![0.1f32, -0.2, 0.4];
        assert_eq!(hash_params(&a), hash_params(&b));
        assert_ne!(hash_params(&a), hash_params(&c));
        assert_ne!(hash_params(&a), hash_params(&a[..2]));
    }

    #[test]
    fn hit_rate_bounds() {
        let mut reg = Registry::new(2);
        assert_eq!(reg.stats().hit_rate(), 0.0);
        let k = key(4, Method::Slbc);
        reg.get_or_compile(&k, || build(4, Method::Slbc)).unwrap();
        reg.get_or_compile(&k, || build(4, Method::Slbc)).unwrap();
        assert_eq!(reg.stats().hit_rate(), 0.5);
    }

    #[test]
    fn registry_lints_each_key_once_on_first_compile() {
        let mut reg = Registry::new(4);
        let k = key(4, Method::RpSlbc);
        for _ in 0..3 {
            reg.get_or_compile(&k, || build(4, Method::RpSlbc)).unwrap();
        }
        // One compiling miss, two hits: exactly one lint record.
        assert_eq!(reg.lints().len(), 1, "cache hits must not re-lint");
        assert_eq!(reg.lints()[0].label, k.label());
        assert_eq!(reg.lints()[0].errors, 0, "{:?}", reg.lints()[0].error_rules);
        assert_eq!(reg.stats().lint_errors, 0);

        let k2 = key(8, Method::Slbc);
        reg.get_or_compile(&k2, || build(8, Method::Slbc)).unwrap();
        assert_eq!(reg.lints().len(), 2);
        assert!(reg.lints().iter().all(|l| l.error_rules.is_empty()));
    }
}
