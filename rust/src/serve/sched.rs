//! Pluggable batch-placement policies over a heterogeneous [`Fleet`].
//!
//! The serving pipeline hands every flushed batch to a [`Scheduler`],
//! which decides *where* it runs; the fleet keeps the mechanics (virtual
//! timeline, queue-depth backpressure, accounting). Policies see the
//! batch as a [`BatchWork`]: an instruction histogram each candidate
//! device prices with its own [`CycleModel`](crate::mcu::CycleModel),
//! plus the members' absolute deadlines.
//!
//! Four built-in policies:
//!
//! * [`RoundRobin`] — the original homogeneous-fleet behavior: a cursor
//!   walks the pool, skipping ineligible devices. On an all-M7 fleet the
//!   produced timeline is bit-identical to the pre-scheduler pipeline
//!   (pinned by a regression test in [`super`]).
//! * [`LeastLoaded`] — earliest `busy_until` among eligible devices;
//!   naturally shifts work toward faster devices as queues build.
//! * [`SloAware`] — per-candidate predicted finish via the *device's
//!   own* cycle model and clock; picks the device minimizing predicted
//!   deadline misses, breaking ties by earliest finish. Deadline-miss
//!   counts surface in [`ServeReport`](super::ServeReport).
//! * [`EnergyAware`] — minimizes predicted energy *subject to
//!   deadlines*: same predicted-miss primary key as [`SloAware`], but
//!   zero-miss ties break to the device whose
//!   [`EnergyModel`](crate::target::EnergyModel) prices the batch
//!   cheapest (then earliest finish). Deadline-free work concentrates on
//!   the most efficient device class (the M4s), with queue-depth
//!   backpressure spilling overflow; deadline work takes a faster
//!   device only when the efficient one would miss.
//!
//! All policies share the same backpressure discipline through the
//! provided [`Scheduler::place`]: when no device is eligible, virtual
//! time advances to the fleet's next in-flight completion and the pick
//! retries — batches are delayed, never reordered.
//!
//! # Indexed candidate selection
//!
//! With [`Fleet::indexed`] on (the default), picks avoid re-deriving
//! per-device state the fleet already indexes: [`LeastLoaded`] walks
//! [`Fleet::by_busy_order`] — devices in exactly the `(busy_until, id)`
//! order its scan minimized — and stops at the first eligible one, and
//! [`SloAware`] / [`EnergyAware`] price the batch once per device
//! *kind* (registry name + effective clock) instead of once per device:
//! the registry models behind a name are immutable and only the clock
//! mutates at runtime (DVFS throttling), so same-kind devices price a
//! histogram identically and the memoized values are bit-identical to
//! per-device recomputation. Every policy keeps its scan path for
//! `indexed = false` (the `--legacy-loop` baseline), and both paths
//! pick the same device on every input.
//!
//! Fleet lifecycle (fault injection) is transparent to policies: the
//! fleet's eligibility, SRAM-fit and next-wake primitives all filter to
//! *live* (up, not draining) devices, so a policy written against a
//! static fleet places correctly on a churning one — a downed or
//! draining device simply stops appearing as a candidate, and a DVFS
//! throttle shows up as that device pricing batches slower.
//!
//! `place` is also the fleet's *dispatch step*: in work-stealing mode
//! ([`Fleet::steal`]) every placement first
//! [`advance`](Fleet::advance)s the fleet (started batches resolve and
//! pin to their device) and then [`rebalance`](Fleet::rebalance)s it
//! (drained devices steal the latest-deadline pending batch from the
//! most-backlogged SRAM-compatible neighbor). Both calls are no-ops
//! with stealing off, which is what keeps the RoundRobin / all-M7
//! timeline bit-identical to the pre-steal pipeline.
//!
//! Observability: every committed placement is surfaced to an attached
//! [`Recorder`](crate::obs::Recorder) as a `Place` event — policy name
//! ([`Scheduler::name`]), chosen device, and the predicted cycle/joule
//! price — by the replay loop in [`super`]. Policies themselves stay
//! tap-free; recording cannot influence a placement decision.

use super::fleet::{BatchWork, Dispatch, Fleet};

/// A batch-placement policy.
pub trait Scheduler {
    /// Policy name (reports, CLI).
    fn name(&self) -> &'static str;

    /// Pick an [eligible](Fleet::eligible) device for `work` at virtual
    /// time `now`, or `None` when every SRAM-capable device is at the
    /// queue-depth cap (placement will retry at the fleet's next wake).
    /// Implementations must only return eligible device indices.
    fn pick(&mut self, now: u64, work: &BatchWork, fleet: &Fleet) -> Option<usize>;

    /// Place `work` on the fleet: retry `pick` under the shared
    /// backpressure discipline, then commit. Returns `None` only when no
    /// device's SRAM fits the model (callers should have rejected such
    /// requests at admission).
    fn place(&mut self, work: &BatchWork, fleet: &mut Fleet) -> Option<Dispatch> {
        if !fleet.fits_anywhere(work.peak_sram) {
            return None;
        }
        let mut now = work.ready;
        loop {
            // Dispatch step: resolve started batches, then let drained
            // devices steal pending work (no-ops unless `fleet.steal`).
            fleet.advance(now);
            fleet.rebalance(now);
            if let Some(idx) = self.pick(now, work, fleet) {
                return Some(fleet.commit(idx, now, work));
            }
            // Everyone eligible is saturated: wait for the earliest
            // completion among devices that could host this model.
            now = fleet.next_wake(now, work.peak_sram)?;
        }
    }
}

/// The original policy: a cursor walks the pool, first eligible device
/// wins, cursor advances past it.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> RoundRobin {
        RoundRobin { next: 0 }
    }
}

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn pick(&mut self, now: u64, work: &BatchWork, fleet: &Fleet) -> Option<usize> {
        let n = fleet.len();
        for off in 0..n {
            let idx = (self.next + off) % n;
            if fleet.eligible(idx, now, work.peak_sram) {
                self.next = (idx + 1) % n;
                return Some(idx);
            }
        }
        None
    }
}

/// Earliest `busy_until` among eligible devices (ties to the lowest id).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl Scheduler for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn pick(&mut self, now: u64, work: &BatchWork, fleet: &Fleet) -> Option<usize> {
        if fleet.indexed {
            // `by_busy_order` yields ascending (busy_until, id) — the
            // exact minimization key below — so the first eligible
            // device in the walk is the scan's argmin.
            return fleet
                .by_busy_order()
                .find(|&i| fleet.eligible(i, now, work.peak_sram));
        }
        (0..fleet.len())
            .filter(|&i| fleet.eligible(i, now, work.peak_sram))
            .min_by_key(|&i| (fleet.devices[i].busy_until, i))
    }
}

/// One pick's cost table for the deadline/energy policies: batch price
/// by device *kind* — `(registry name, effective clock)`. Sound because
/// the cycle/energy models behind a registry name are immutable; only
/// `clock_hz` mutates at runtime (DVFS), and it is part of the key. A
/// tiny linear map: fleets hold a handful of distinct kinds.
#[derive(Default)]
struct KindCosts {
    entries: Vec<((&'static str, u64), (u64, f64))>,
}

impl KindCosts {
    /// `(timeline cycles, joules)` of `work` on device `i`, computed
    /// once per kind. Pure functions of (models, clock, histogram), so
    /// the memoized values are bit-identical to recomputation.
    fn price(&mut self, fleet: &Fleet, i: usize, work: &BatchWork) -> (u64, f64) {
        let cfg = &fleet.devices[i].cfg;
        let key = (cfg.name, cfg.clock_hz);
        if let Some(&(_, v)) = self.entries.iter().find(|(k, _)| *k == key) {
            return v;
        }
        let v = (cfg.timeline_cost(work.counter), cfg.batch_joules(work.counter));
        self.entries.push((key, v));
        v
    }
}

/// Predicted (deadline misses, finish cycle) of `work` on device `i`:
/// the batch priced with that device's own cycle model + clock, started
/// at the later of `now` and the device's drain. The shared primary key
/// of [`SloAware`] and [`EnergyAware`] — one formula, so the two
/// policies can never drift on what "meets the deadlines" means.
fn predicted(fleet: &Fleet, i: usize, now: u64, work: &BatchWork) -> (usize, u64) {
    let d = &fleet.devices[i];
    let finish = now.max(d.busy_until) + d.cfg.timeline_cost(work.counter);
    let misses = work.deadlines.iter().filter(|&&dl| finish > dl).count();
    (misses, finish)
}

/// Deadline-aware placement: predict each eligible device's finish time
/// for this batch with that device's cycle model + clock, count the
/// member deadlines the prediction would miss, and take the device with
/// the fewest predicted misses (ties: earliest predicted finish, then
/// lowest id). Devices without deadline pressure degrade to fastest-
/// finish placement, which keeps batch-class traffic off the critical
/// path of interactive tenants.
#[derive(Debug, Default)]
pub struct SloAware;

impl Scheduler for SloAware {
    fn name(&self) -> &'static str {
        "slo-aware"
    }

    fn pick(&mut self, now: u64, work: &BatchWork, fleet: &Fleet) -> Option<usize> {
        if fleet.indexed {
            let mut memo = KindCosts::default();
            return (0..fleet.len())
                .filter(|&i| fleet.eligible(i, now, work.peak_sram))
                .min_by_key(|&i| {
                    let (cost, _) = memo.price(fleet, i, work);
                    let finish = now.max(fleet.devices[i].busy_until) + cost;
                    let misses = work.deadlines.iter().filter(|&&dl| finish > dl).count();
                    (misses, finish, i)
                });
        }
        (0..fleet.len())
            .filter(|&i| fleet.eligible(i, now, work.peak_sram))
            .min_by_key(|&i| {
                let (misses, finish) = predicted(fleet, i, now, work);
                (misses, finish, i)
            })
    }
}

/// Energy-aware placement: never accept a predicted deadline miss to
/// save energy (the miss count is the primary key, exactly as in
/// [`SloAware`]), but among devices that meet every member deadline,
/// take the one that executes the batch for the fewest predicted joules
/// — dynamic energy of the histogram plus static power over the batch's
/// runtime, both priced with the candidate device's own
/// [`Target`](crate::target::Target) models. Ties (same energy, e.g.
/// same-class devices) break to earliest predicted finish, then lowest
/// id.
#[derive(Debug, Default)]
pub struct EnergyAware;

impl Scheduler for EnergyAware {
    fn name(&self) -> &'static str {
        "energy-aware"
    }

    fn pick(&mut self, now: u64, work: &BatchWork, fleet: &Fleet) -> Option<usize> {
        if fleet.indexed {
            let mut memo = KindCosts::default();
            return (0..fleet.len())
                .filter(|&i| fleet.eligible(i, now, work.peak_sram))
                .map(|i| {
                    let (cost, joules) = memo.price(fleet, i, work);
                    let finish = now.max(fleet.devices[i].busy_until) + cost;
                    let misses = work.deadlines.iter().filter(|&&dl| finish > dl).count();
                    (misses, joules, finish, i)
                })
                .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(_, _, _, i)| i);
        }
        (0..fleet.len())
            .filter(|&i| fleet.eligible(i, now, work.peak_sram))
            .map(|i| {
                let (misses, finish) = predicted(fleet, i, now, work);
                let joules = fleet.devices[i].cfg.batch_joules(work.counter);
                (misses, joules, finish, i)
            })
            .min_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(_, _, _, i)| i)
    }
}

/// Scheduler selector: the configuration-level name of a policy
/// ([`ServeCfg`](super::ServeCfg) holds one; [`build`](SchedulerKind::build)
/// instantiates fresh policy state per replay).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    RoundRobin,
    LeastLoaded,
    SloAware,
    EnergyAware,
}

impl SchedulerKind {
    pub const ALL: [SchedulerKind; 4] = [
        SchedulerKind::RoundRobin,
        SchedulerKind::LeastLoaded,
        SchedulerKind::SloAware,
        SchedulerKind::EnergyAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::RoundRobin => "round-robin",
            SchedulerKind::LeastLoaded => "least-loaded",
            SchedulerKind::SloAware => "slo-aware",
            SchedulerKind::EnergyAware => "energy-aware",
        }
    }

    /// Parse a CLI spelling (`rr`, `least`, `slo`, `energy`, or the
    /// full names).
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(SchedulerKind::RoundRobin),
            "least" | "least-loaded" | "leastloaded" => Some(SchedulerKind::LeastLoaded),
            "slo" | "slo-aware" | "sloaware" => Some(SchedulerKind::SloAware),
            "energy" | "energy-aware" | "energyaware" => Some(SchedulerKind::EnergyAware),
            _ => None,
        }
    }

    /// Fresh policy state for one replay.
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::RoundRobin => Box::new(RoundRobin::new()),
            SchedulerKind::LeastLoaded => Box::new(LeastLoaded),
            SchedulerKind::SloAware => Box::new(SloAware),
            SchedulerKind::EnergyAware => Box::new(EnergyAware),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::{Counter, InstrClass};
    use crate::serve::fleet::DeviceCfg;

    fn ctr(alu: u64) -> Counter {
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, alu);
        c
    }

    fn work<'a>(ready: u64, c: &'a Counter, deadlines: &'a [u64]) -> BatchWork<'a> {
        BatchWork {
            ready,
            counter: c,
            peak_sram: 1024,
            images: 1,
            deadlines,
        }
    }

    #[test]
    fn round_robin_spreads_batches() {
        let mut fleet = Fleet::homogeneous(3, DeviceCfg::stm32f746(), 4);
        let mut rr = RoundRobin::new();
        let c = ctr(10);
        for _ in 0..6 {
            rr.place(&work(0, &c, &[]), &mut fleet).unwrap();
        }
        for d in &fleet.devices {
            assert_eq!(d.batches, 2, "device {} load", d.id);
        }
    }

    #[test]
    fn round_robin_skips_ineligible_and_backpressures() {
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 2);
        let mut rr = RoundRobin::new();
        let c = ctr(100);
        let cost = DeviceCfg::stm32f746().timeline_cost(&c);
        rr.place(&work(0, &c, &[]), &mut fleet).unwrap();
        rr.place(&work(0, &c, &[]), &mut fleet).unwrap();
        // Depth cap reached at t=0; the third batch must wait until the
        // first finishes before it may even enqueue.
        let third = rr.place(&work(0, &c, &[]), &mut fleet).unwrap();
        assert_eq!(third.start, 2 * cost, "starts after the backlog drains");
        assert_eq!(third.finish, 3 * cost);
    }

    #[test]
    fn sram_gate_rejects_oversized_models() {
        let mut small = DeviceCfg::stm32f746();
        small.sram_bytes = 10 * 1024;
        let mut fleet = Fleet::homogeneous(2, small, 4);
        let c = ctr(10);
        let mut rr = RoundRobin::new();
        let oversized = BatchWork {
            peak_sram: 64 * 1024,
            ..work(0, &c, &[])
        };
        assert!(rr.place(&oversized, &mut fleet).is_none());
        assert!(rr.place(&work(0, &c, &[]), &mut fleet).is_some());
    }

    #[test]
    fn least_loaded_prefers_idle_devices() {
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        let mut ll = LeastLoaded;
        let heavy = ctr(1_000_000);
        let light = ctr(10);
        // Load device 0 heavily.
        let first = ll.place(&work(0, &heavy, &[]), &mut fleet).unwrap();
        assert_eq!(first.device, 0, "ties break to the lowest id");
        // The next three light batches all belong on the idle device 1
        // until its backlog passes device 0's.
        let second = ll.place(&work(0, &light, &[]), &mut fleet).unwrap();
        assert_eq!(second.device, 1);
        let third = ll.place(&work(0, &light, &[]), &mut fleet).unwrap();
        assert_eq!(third.device, 1, "device 1 still drains earlier");
    }

    #[test]
    fn slo_aware_routes_tight_deadlines_to_the_device_that_meets_them() {
        // One M7 + one M4 on long-multiply-heavy work: the M4 prices
        // MULL at 4 cycles and runs a slower clock, so the same batch
        // costs far more shared-timeline cycles there.
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        let mut fleet = Fleet::new(vec![m7, m4], 8);
        let mut c = Counter::new();
        c.charge(InstrClass::MulLong, 1_000_000);
        let c7 = m7.timeline_cost(&c);
        let c4 = m4.timeline_cost(&c);
        assert!(c4 > 2 * c7, "M4 must cost over 2x on this histogram");
        let mut slo = SloAware;
        // First batch: both idle, zero misses everywhere, earliest
        // finish picks the M7.
        let no_deadline = [u64::MAX];
        let first = slo.place(&work(0, &c, &no_deadline), &mut fleet).unwrap();
        assert_eq!(first.device, 0);
        // Second batch arrives immediately with a deadline only the
        // (busy) M7 can still meet: queueing behind the first batch
        // finishes at 2*c7 <= dl, while the idle M4 would finish at
        // c4 > dl.
        let dl = [c4 - 1];
        let second = slo.place(&work(0, &c, &dl), &mut fleet).unwrap();
        assert_eq!(second.device, 0, "deadline-tight batch routes to the M7");
        // No-deadline work degrades to earliest predicted finish.
        let third = slo.place(&work(0, &c, &no_deadline), &mut fleet).unwrap();
        let expect = if 3 * c7 <= c4 { 0 } else { 1 };
        assert_eq!(third.device, expect);
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(SchedulerKind::parse("rr"), Some(SchedulerKind::RoundRobin));
        assert_eq!(SchedulerKind::parse("least"), Some(SchedulerKind::LeastLoaded));
        assert_eq!(SchedulerKind::parse("SLO"), Some(SchedulerKind::SloAware));
        assert_eq!(SchedulerKind::parse("energy"), Some(SchedulerKind::EnergyAware));
        assert_eq!(SchedulerKind::parse("fifo"), None);
        for kind in SchedulerKind::ALL {
            assert_eq!(kind.build().name(), kind.name());
        }
    }

    #[test]
    fn energy_aware_routes_deadline_free_work_to_the_efficient_device() {
        // [M7, M4], both idle, no deadlines: SloAware takes the faster
        // M7; EnergyAware takes the cheaper-in-joules M4 — and keeps
        // taking it while its queue still meets the (absent) deadlines.
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        let c = ctr(1000);
        assert!(m4.batch_joules(&c) < m7.batch_joules(&c));
        let mut fleet = Fleet::new(vec![m7, m4], 8);
        let mut ea = EnergyAware;
        let first = ea.place(&work(0, &c, &[]), &mut fleet).unwrap();
        assert_eq!(first.device, 1, "idle fleet: energy picks the M4");
        let second = ea.place(&work(0, &c, &[]), &mut fleet).unwrap();
        assert_eq!(second.device, 1, "energy is state-independent; M4 again");

        let mut slo_fleet = Fleet::new(vec![m7, m4], 8);
        let mut slo = SloAware;
        let slo_first = slo.place(&work(0, &c, &[]), &mut slo_fleet).unwrap();
        assert_eq!(slo_first.device, 0, "slo-aware picks the faster M7");
    }

    #[test]
    fn energy_aware_never_trades_a_deadline_for_joules() {
        // A deadline only the M7 can meet: the energy policy must route
        // to the M7 even though the M4 would be cheaper.
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        let c = ctr(1_000_000);
        let c7 = m7.timeline_cost(&c);
        let c4 = m4.timeline_cost(&c);
        assert!(c4 > c7);
        let mut fleet = Fleet::new(vec![m7, m4], 8);
        let mut ea = EnergyAware;
        let dl = [c7]; // exactly the M7's idle finish; the M4 misses it
        let d = ea.place(&work(0, &c, &dl), &mut fleet).unwrap();
        assert_eq!(d.device, 0, "deadline pressure overrides energy");
        // A relaxed deadline both devices meet goes back to the M4.
        let loose = [10 * c4];
        let d = ea.place(&work(0, &c, &loose), &mut fleet).unwrap();
        assert_eq!(d.device, 1);
    }

    #[test]
    fn indexed_picks_match_the_linear_scan_for_every_policy() {
        // Lockstep replay: an indexed fleet and a scan fleet receive the
        // exact same work sequence; every Dispatch must be identical.
        // Heterogeneous devices (2x M7 + 2x M4, one M4 throttled) keep
        // the KindCosts memo honest — three distinct (name, clock) keys.
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        let mut heavy = Counter::new();
        heavy.charge(InstrClass::MulLong, 500_000);
        heavy.charge(InstrClass::Alu, 200_000);
        let light = ctr(40_000);
        let dl_tight = [m7.timeline_cost(&heavy)];
        let dl_loose = [10 * m4.timeline_cost(&heavy)];
        let dl_mixed = [m7.timeline_cost(&light), 10 * m4.timeline_cost(&heavy)];
        let steps: Vec<(u64, &Counter, &[u64])> = vec![
            (0, &heavy, &[]),
            (0, &light, &dl_loose),
            (10, &heavy, &dl_tight),
            (10, &light, &[]),
            (500, &heavy, &dl_mixed),
            (500, &light, &dl_tight),
            (20_000, &heavy, &dl_loose),
            (20_000, &light, &dl_mixed),
            (1_000_000, &heavy, &[]),
            (1_000_000, &light, &dl_tight),
        ];
        for kind in [
            SchedulerKind::LeastLoaded,
            SchedulerKind::SloAware,
            SchedulerKind::EnergyAware,
        ] {
            let mut fast = Fleet::new(vec![m7, m7, m4, m4], 8);
            let mut slow = Fleet::new(vec![m7, m7, m4, m4], 8);
            fast.device_throttle(3, m4.clock_hz / 2);
            slow.device_throttle(3, m4.clock_hz / 2);
            assert!(fast.indexed, "indexed bookkeeping is the default");
            slow.indexed = false;
            let mut fast_pol = kind.build();
            let mut slow_pol = kind.build();
            for (step, &(ready, c, deadlines)) in steps.iter().enumerate() {
                let w = BatchWork {
                    ready,
                    counter: c,
                    peak_sram: 1024,
                    images: 2,
                    deadlines,
                };
                let a = fast_pol.place(&w, &mut fast);
                let b = slow_pol.place(&w, &mut slow);
                match (a, b) {
                    (Some(a), Some(b)) => {
                        assert_eq!(a.device, b.device, "{} step {step}", kind.name());
                        assert_eq!(a.start, b.start, "{} step {step}", kind.name());
                        assert_eq!(a.finish, b.finish, "{} step {step}", kind.name());
                    }
                    (a, b) => panic!(
                        "{} step {step}: indexed={} scan={}",
                        kind.name(),
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        }
    }
}
