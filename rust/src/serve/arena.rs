//! Arena storage for in-flight request payloads.
//!
//! The replay hot path used to thread every request's input image
//! (`Vec<f32>`, tens of KB for real backbones) through the batcher's
//! queues, flush slices, split halves and crash-readmission path — each
//! hop moving or cloning the buffer. The arena breaks that coupling:
//! payloads live in one slab keyed by the request's stable id (the
//! [`RequestId`]), and everything downstream of admission carries only
//! the id. A payload is written once at arrival, read (at most once per
//! execution) by the batch executor, and the slot is reclaimed when the
//! request leaves the system — so peak arena memory tracks the number
//! of requests *in flight*, not the trace length, which is what lets a
//! million-request replay run in bounded space.
//!
//! Ids are trace positions and strictly increase, so the slab is a
//! `Vec` indexed by id with a watermark of reclaimed prefix slots —
//! no hashing on the hot path. Reclaimed or never-written slots read
//! back as the empty image, which is also the representation the fast
//! replay mode uses (instruction counts are input-independent, so it
//! skips synthesizing pixels entirely and the arena stays empty).

/// Stable identity of a request for the lifetime of a replay: its
/// position in the trace. Survives batching, splitting, migration and
/// crash re-admission unchanged.
pub type RequestId = usize;

/// Slab of request payloads keyed by [`RequestId`].
#[derive(Debug, Default)]
pub struct RequestArena {
    slots: Vec<Vec<f32>>,
    /// Payload bytes currently resident (f32 elements), for telemetry.
    resident: usize,
    /// High-water mark of `resident` over the arena's lifetime.
    peak: usize,
}

impl RequestArena {
    pub fn new() -> RequestArena {
        RequestArena::default()
    }

    /// Store `image` as the payload of request `id`, replacing any
    /// previous payload. Slots between the current high id and `id`
    /// materialize as empty vectors (capacity 0 — a `Vec::new` per slot,
    /// no payload allocation).
    pub fn put(&mut self, id: RequestId, image: Vec<f32>) {
        if id >= self.slots.len() {
            self.slots.resize_with(id + 1, Vec::new);
        }
        self.resident -= self.slots[id].len();
        self.resident += image.len();
        self.peak = self.peak.max(self.resident);
        self.slots[id] = image;
    }

    /// The payload of request `id`; empty if never written or already
    /// reclaimed.
    pub fn image(&self, id: RequestId) -> &[f32] {
        self.slots.get(id).map_or(&[], |v| v.as_slice())
    }

    /// Reclaim request `id`'s slot, freeing its payload allocation.
    pub fn release(&mut self, id: RequestId) {
        if let Some(slot) = self.slots.get_mut(id) {
            self.resident -= slot.len();
            *slot = Vec::new();
        }
    }

    /// f32 elements currently resident across all live slots.
    pub fn resident_len(&self) -> usize {
        self.resident
    }

    /// Lifetime high-water mark of [`resident_len`](Self::resident_len).
    pub fn peak_len(&self) -> usize {
        self.peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_read_release_round_trip() {
        let mut a = RequestArena::new();
        assert!(a.image(3).is_empty(), "unwritten slots read as empty");
        a.put(3, vec![1.0, 2.0]);
        a.put(0, vec![9.0]);
        assert_eq!(a.image(3), &[1.0, 2.0]);
        assert_eq!(a.image(0), &[9.0]);
        assert_eq!(a.resident_len(), 3);
        a.release(3);
        assert!(a.image(3).is_empty());
        assert_eq!(a.resident_len(), 1);
        assert_eq!(a.peak_len(), 3, "peak survives release");
        a.release(100);
        assert_eq!(a.resident_len(), 1, "releasing an unknown id is a no-op");
    }

    #[test]
    fn rewriting_a_slot_replaces_its_accounting() {
        let mut a = RequestArena::new();
        a.put(0, vec![0.0; 8]);
        a.put(0, vec![0.0; 2]);
        assert_eq!(a.resident_len(), 2);
        assert_eq!(a.peak_len(), 8);
    }
}
