//! Deterministic synthetic request traces, with latency objectives and
//! file round-tripping.
//!
//! A trace is a sequence of (arrival cycle, model, SLO class, input seed)
//! tuples: arrivals follow a Poisson process (exponential inter-arrival
//! times at a configurable mean), the model of each request is drawn from
//! a weighted — optionally Zipf-skewed — tenant mix, and every request
//! carries a fork of the trace PRNG so its input image is reproducible
//! independently of processing order. Each request also carries an
//! [`SloClass`] that fixes its priority and absolute deadline; class
//! draws use a PRNG stream separate from the arrival stream, so enabling
//! deadlines never perturbs arrival times.
//!
//! Traces round-trip through JSON ([`trace_to_json`] / [`trace_from_json`],
//! [`save_trace`] / [`load_trace`]), so `serve --trace-file x.json`
//! replays a recorded trace deterministically on any fleet/scheduler
//! combination.
//!
//! # Fleet churn
//!
//! A trace can additionally carry a deterministic [`FleetEvent`] stream
//! — device joins, leaves, crashes, DVFS throttles, restores and drains
//! — synthesized by [`synth_fleet_events`] at a configurable per-request
//! rate ([`TraceCfg::churn`], the CLI's `--churn`). Fleet events draw
//! from their own PRNG stream, so `churn = 0` traces stay byte-identical
//! to pre-churn ones, and they round-trip through the same JSON file as
//! the requests ([`save_full_trace`] / [`load_full_trace`]; plain
//! [`load_trace`] still reads such files, ignoring the events).
//!
//! # Streaming ingestion
//!
//! The envelope format materializes every request before the replay
//! starts — fine at thousands of requests, prohibitive at millions.
//! [`TraceSource`] is the streaming alternative: an iterator of
//! `Result<TraceRequest>` backed either by an in-memory slice (synthetic
//! traces, already-loaded envelopes) or by a JSON-lines reader
//! ([`save_trace_jsonl`] writes that format: one request object per
//! line, no envelope) that holds a single line in memory at a time.
//! [`TraceSource::from_reader`] auto-detects which of the two formats it
//! was handed, so `--trace-file` accepts both; malformed or truncated
//! JSON-lines input fails with the offending line number. Streaming
//! sources carry requests only — fleet-event streams still ride the
//! envelope ([`load_full_trace`]).

use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::Path;

use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::Result;

/// Latency objective class of one request. Priorities order the classes
/// (higher = more urgent); deadlines are relative to arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    /// Tight interactive objective (20 ms).
    Interactive,
    /// Standard online objective (100 ms).
    Standard,
    /// Best-effort batch work: no deadline.
    Batch,
}

impl SloClass {
    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];

    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Option<SloClass> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Some(SloClass::Interactive),
            "standard" => Some(SloClass::Standard),
            "batch" => Some(SloClass::Batch),
            _ => None,
        }
    }

    /// Scheduling priority (higher = more urgent).
    pub fn priority(&self) -> u8 {
        match self {
            SloClass::Interactive => 2,
            SloClass::Standard => 1,
            SloClass::Batch => 0,
        }
    }

    /// Deadline relative to arrival, in 216 MHz reference cycles
    /// (`u64::MAX` = none).
    pub fn relative_deadline_cycles(&self) -> u64 {
        match self {
            // 20 ms and 100 ms at the 216 MHz reference clock.
            SloClass::Interactive => 4_320_000,
            SloClass::Standard => 21_600_000,
            SloClass::Batch => u64::MAX,
        }
    }

    /// Absolute deadline for a request arriving at `arrival`.
    pub fn deadline_at(&self, arrival: u64) -> u64 {
        arrival.saturating_add(self.relative_deadline_cycles())
    }
}

/// One request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: usize,
    /// Arrival time in virtual cycles (non-decreasing along the trace).
    pub arrival: u64,
    /// Index into the workload table of the replay.
    pub key_idx: usize,
    /// Seed for this request's synthetic input image.
    pub seed: u64,
    /// Latency objective class.
    pub class: SloClass,
    /// Absolute deadline in timeline cycles (`u64::MAX` = none).
    pub deadline: u64,
}

impl TraceRequest {
    /// A best-effort request (no deadline) — the pre-SLO trace shape.
    pub fn best_effort(id: usize, arrival: u64, key_idx: usize, seed: u64) -> TraceRequest {
        TraceRequest {
            id,
            arrival,
            key_idx,
            seed,
            class: SloClass::Batch,
            deadline: u64::MAX,
        }
    }

    /// Scheduling priority of this request's class.
    pub fn priority(&self) -> u8 {
        self.class.priority()
    }
}

/// What happens to one fleet device at one instant of a replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetEventKind {
    /// A previously departed (or standby) device comes up and starts
    /// accepting placements.
    Join,
    /// Planned departure: no new placements; the batch already started
    /// finishes, pending unstarted batches are re-admitted.
    Leave,
    /// Unplanned departure: the in-flight batch is lost. Deadline-
    /// carrying members re-enter through admission; best-effort members
    /// are lost forever.
    Crash,
    /// DVFS brown-out: the device keeps serving, but every subsequent
    /// batch is priced (cycles and joules) at the new clock.
    Throttle {
        /// New effective clock in Hz.
        clock_hz: u64,
    },
    /// Undo a [`Throttle`](FleetEventKind::Throttle) and/or
    /// [`Drain`](FleetEventKind::Drain): full base clock, accepting
    /// placements again.
    Restore,
    /// Graceful decommission: no new placements, in-flight work
    /// finishes, pending batches migrate away via work stealing.
    Drain,
}

impl FleetEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            FleetEventKind::Join => "join",
            FleetEventKind::Leave => "leave",
            FleetEventKind::Crash => "crash",
            FleetEventKind::Throttle { .. } => "throttle",
            FleetEventKind::Restore => "restore",
            FleetEventKind::Drain => "drain",
        }
    }
}

/// One fleet-lifecycle event in a trace: at virtual cycle `at`, device
/// `device` undergoes `kind`. Events are sorted by `at` and interpreted
/// by the replay loop between request arrivals.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FleetEvent {
    pub at: u64,
    /// Fleet index of the affected device.
    pub device: usize,
    pub kind: FleetEventKind,
}

/// Trace-generation parameters.
#[derive(Debug, Clone)]
pub struct TraceCfg {
    pub requests: usize,
    /// Mean inter-arrival gap in cycles (Poisson process). At 216 MHz,
    /// 2_160_000 cycles ≈ one request every 10 ms ≈ 100 req/s offered.
    pub mean_gap_cycles: u64,
    /// Relative traffic weight per workload (index-aligned; empty =
    /// uniform unless `tenant_skew` is set).
    pub weights: Vec<f64>,
    /// Zipf-style tenant skew: when `weights` is empty and this is > 0,
    /// tenant `i` receives weight `1 / (i+1)^tenant_skew` — a few heavy
    /// tenants and a long tail, the realistic multi-tenant shape.
    pub tenant_skew: f64,
    /// Relative draw weight of each [`SloClass`] in
    /// [`SloClass::ALL`] order (interactive, standard, batch). Empty =
    /// every request is best-effort `Batch` (no deadlines), which keeps
    /// legacy traces byte-identical.
    pub slo_weights: Vec<f64>,
    /// Overload-burst synthesis: every `burst_period` requests, the
    /// `burst_size` requests *after* the period leader collapse their
    /// inter-arrival gaps to zero, arriving simultaneously with it — a
    /// `burst_size + 1`-deep spike that stresses admission control.
    /// `0` disables bursts and keeps legacy traces byte-identical.
    pub burst_period: usize,
    /// Requests piled onto each burst leader (see `burst_period`).
    pub burst_size: usize,
    /// Fleet-churn rate: the probability, per request arrival, that one
    /// fleet-lifecycle event fires at that arrival instant
    /// ([`synth_fleet_events`]). `0.0` (the default) generates no
    /// events and — because churn draws from its own PRNG stream —
    /// leaves the request trace byte-identical to a churn-free config.
    pub churn: f64,
    pub seed: u64,
}

impl TraceCfg {
    pub fn new(requests: usize, mean_gap_cycles: u64, seed: u64) -> TraceCfg {
        TraceCfg {
            requests,
            mean_gap_cycles,
            weights: Vec::new(),
            tenant_skew: 0.0,
            slo_weights: Vec::new(),
            burst_period: 0,
            burst_size: 0,
            churn: 0.0,
            seed,
        }
    }

    /// Builder: Zipf tenant skew.
    pub fn with_skew(mut self, skew: f64) -> TraceCfg {
        self.tenant_skew = skew;
        self
    }

    /// Builder: deadline-class mix (interactive, standard, batch).
    pub fn with_slo(mut self, weights: [f64; 3]) -> TraceCfg {
        self.slo_weights = weights.to_vec();
        self
    }

    /// Builder: overload bursts — every `period` requests, `size`
    /// requests arrive simultaneously with the period leader.
    pub fn with_burst(mut self, period: usize, size: usize) -> TraceCfg {
        assert!(period > 0, "burst period must be positive");
        assert!(
            size >= 1 && size < period,
            "burst size must be in 1..period"
        );
        self.burst_period = period;
        self.burst_size = size;
        self
    }

    /// Builder: fleet-churn rate (fleet events per request arrival).
    pub fn with_churn(mut self, rate: f64) -> TraceCfg {
        assert!((0.0..=1.0).contains(&rate), "churn rate must be in 0..=1");
        self.churn = rate;
        self
    }
}

/// Weighted index draw: `pick` uniform in `[0, sum)` walks the weights.
fn weighted_pick(weights: &[f64], u: f64) -> usize {
    let wsum: f64 = weights.iter().sum();
    let mut pick = u * wsum;
    let mut idx = weights.len() - 1;
    for (i, w) in weights.iter().enumerate() {
        if pick < *w {
            idx = i;
            break;
        }
        pick -= w;
    }
    idx
}

/// Generate a synthetic trace over `num_keys` workloads.
pub fn synth_trace(cfg: &TraceCfg, num_keys: usize) -> Vec<TraceRequest> {
    assert!(num_keys >= 1, "trace needs at least one workload");
    let weights: Vec<f64> = if !cfg.weights.is_empty() {
        assert_eq!(cfg.weights.len(), num_keys, "one weight per workload");
        cfg.weights.clone()
    } else if cfg.tenant_skew > 0.0 {
        (0..num_keys)
            .map(|i| 1.0 / ((i + 1) as f64).powf(cfg.tenant_skew))
            .collect()
    } else {
        vec![1.0; num_keys]
    };
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0, "weights must not all be zero");
    if !cfg.slo_weights.is_empty() {
        assert_eq!(cfg.slo_weights.len(), SloClass::ALL.len(), "one weight per SLO class");
        assert!(cfg.slo_weights.iter().sum::<f64>() > 0.0, "SLO weights must not all be zero");
    }

    let mut rng = Rng::new(cfg.seed);
    // Separate stream for class draws: enabling deadlines must not
    // perturb the arrival/seed stream of an existing trace config.
    let mut class_rng = Rng::new(cfg.seed ^ 0x510_C1A5_5E5_u64);
    let mut t = 0u64;
    (0..cfg.requests)
        .map(|id| {
            // Exponential inter-arrival (clamped away from ln(0)). The
            // draw always happens — burst mode only overrides the gap,
            // so the tenant/seed streams stay aligned with the
            // non-burst trace.
            let u = (rng.f32() as f64).max(1e-7);
            let gap = (-u.ln() * cfg.mean_gap_cycles as f64) as u64;
            let in_burst = cfg.burst_period > 0
                && id % cfg.burst_period != 0
                && id % cfg.burst_period <= cfg.burst_size;
            t = t.saturating_add(if in_burst { 0 } else { gap });
            let key_idx = weighted_pick(&weights, rng.f32() as f64);
            let class = if cfg.slo_weights.is_empty() {
                SloClass::Batch
            } else {
                SloClass::ALL[weighted_pick(&cfg.slo_weights, class_rng.f32() as f64)]
            };
            TraceRequest {
                id,
                arrival: t,
                key_idx,
                seed: rng.next_u64(),
                class,
                deadline: class.deadline_at(t),
            }
        })
        .collect()
}

/// PRNG-stream offset for fleet-event draws: churn must never perturb
/// the arrival/tenant/seed stream or the class stream of an existing
/// trace config, mirroring how `class_rng` is split off above.
const CHURN_STREAM: u64 = 0xF1EE7_CA05;

/// Synthesize a deterministic fleet-lifecycle event stream for a trace:
/// at each request arrival, with probability [`TraceCfg::churn`], one
/// device event fires. The generator tracks simulated device state so
/// the stream stays coherent (downed devices rejoin rather than crash
/// twice, draining devices restore) and never takes the fleet below one
/// live — up and not draining — device; a disruptive pick that would do
/// so degrades to a DVFS throttle instead.
pub fn synth_fleet_events(
    cfg: &TraceCfg,
    trace: &[TraceRequest],
    fleet_size: usize,
) -> Vec<FleetEvent> {
    assert!(fleet_size >= 1, "fleet events need at least one device");
    if cfg.churn <= 0.0 {
        return Vec::new();
    }
    #[derive(Clone, Copy)]
    struct SimState {
        up: bool,
        draining: bool,
        throttled: bool,
    }
    let live = |st: &[SimState]| st.iter().filter(|s| s.up && !s.draining).count();
    // Brown-out operating points, in reference-clock Hz: deep enough to
    // visibly stretch batch latency on either device class.
    let throttle_points: [u64; 3] = [108_000_000, 84_000_000, 54_000_000];

    let mut rng = Rng::new(cfg.seed ^ CHURN_STREAM);
    let mut st = vec![
        SimState { up: true, draining: false, throttled: false };
        fleet_size
    ];
    let mut events = Vec::new();
    for r in trace {
        if (rng.f32() as f64) >= cfg.churn {
            continue;
        }
        let device = rng.below(fleet_size as u64) as usize;
        let kind = if !st[device].up {
            FleetEventKind::Join
        } else if st[device].draining {
            FleetEventKind::Restore
        } else {
            let pick = rng.below(6);
            let disruptive = live(&st) > 1;
            match pick {
                0 if disruptive => FleetEventKind::Leave,
                1 if disruptive => FleetEventKind::Crash,
                2 if disruptive => FleetEventKind::Drain,
                5 if st[device].throttled => FleetEventKind::Restore,
                _ => FleetEventKind::Throttle {
                    clock_hz: throttle_points[rng.below(3) as usize],
                },
            }
        };
        match kind {
            FleetEventKind::Join => {
                st[device] = SimState { up: true, draining: false, throttled: false };
            }
            FleetEventKind::Leave | FleetEventKind::Crash => {
                st[device] = SimState { up: false, draining: false, throttled: false };
            }
            FleetEventKind::Throttle { .. } => st[device].throttled = true,
            FleetEventKind::Restore => {
                st[device].draining = false;
                st[device].throttled = false;
            }
            FleetEventKind::Drain => st[device].draining = true,
        }
        events.push(FleetEvent { at: r.arrival, device, kind });
    }
    events
}

fn u64_field(v: &Json, key: &str) -> Result<u64> {
    let f = v
        .get(key)
        .ok_or_else(|| anyhow::anyhow!("trace request missing `{key}`"))?;
    match f {
        Json::Num(n) => Ok(*n as u64),
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|e| anyhow::anyhow!("trace `{key}` = `{s}`: {e}")),
        _ => anyhow::bail!("trace `{key}` must be a number or numeric string"),
    }
}

/// Serialize one request as the object shape shared by the envelope's
/// `requests` array and the JSON-lines stream. `arrival` fits a JSON
/// double for any realistic horizon; full-range `u64` fields (`seed`,
/// `deadline`) are written as decimal strings so they round-trip
/// losslessly.
pub fn request_to_json(r: &TraceRequest) -> Json {
    let mut o = BTreeMap::new();
    o.insert("id".into(), Json::Num(r.id as f64));
    o.insert("arrival".into(), Json::Num(r.arrival as f64));
    o.insert("key_idx".into(), Json::Num(r.key_idx as f64));
    o.insert("seed".into(), Json::Str(r.seed.to_string()));
    o.insert("class".into(), Json::Str(r.class.name().into()));
    o.insert("deadline".into(), Json::Str(r.deadline.to_string()));
    Json::Obj(o)
}

/// Parse one request object — an element of the envelope's `requests`
/// array, or one JSON-lines record.
pub fn request_from_json(v: &Json) -> Result<TraceRequest> {
    let class_name = v
        .get("class")
        .and_then(|c| c.as_str())
        .unwrap_or("batch");
    let class = SloClass::parse(class_name)
        .ok_or_else(|| anyhow::anyhow!("unknown SLO class `{class_name}`"))?;
    Ok(TraceRequest {
        id: u64_field(v, "id")? as usize,
        arrival: u64_field(v, "arrival")?,
        key_idx: u64_field(v, "key_idx")? as usize,
        seed: u64_field(v, "seed")?,
        class,
        deadline: u64_field(v, "deadline")?,
    })
}

/// Serialize a trace to JSON (the versioned envelope format).
pub fn trace_to_json(trace: &[TraceRequest]) -> Json {
    let requests: Vec<Json> = trace.iter().map(request_to_json).collect();
    let mut o = BTreeMap::new();
    o.insert("version".into(), Json::Num(1.0));
    o.insert("requests".into(), Json::Arr(requests));
    Json::Obj(o)
}

/// Parse a trace from its JSON form.
pub fn trace_from_json(js: &Json) -> Result<Vec<TraceRequest>> {
    let requests = js
        .get("requests")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace file has no `requests` array"))?;
    requests.iter().map(request_from_json).collect()
}

/// Serialize a fleet-event stream. `at` fits a JSON double for any
/// realistic horizon (like `arrival`); the throttle clock is a decimal
/// string like the other full-range `u64` fields.
pub fn fleet_events_to_json(events: &[FleetEvent]) -> Json {
    Json::Arr(
        events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("at".into(), Json::Num(e.at as f64));
                o.insert("device".into(), Json::Num(e.device as f64));
                o.insert("kind".into(), Json::Str(e.kind.name().into()));
                if let FleetEventKind::Throttle { clock_hz } = e.kind {
                    o.insert("clock_hz".into(), Json::Str(clock_hz.to_string()));
                }
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Parse the `fleet_events` array of a trace file. A file without one
/// (every pre-churn trace) yields an empty stream.
pub fn fleet_events_from_json(js: &Json) -> Result<Vec<FleetEvent>> {
    let arr = match js.get("fleet_events").and_then(|v| v.as_arr()) {
        Some(arr) => arr,
        None => return Ok(Vec::new()),
    };
    arr.iter()
        .map(|v| {
            let kind_name = v
                .get("kind")
                .and_then(|k| k.as_str())
                .ok_or_else(|| anyhow::anyhow!("fleet event missing `kind`"))?;
            let kind = match kind_name {
                "join" => FleetEventKind::Join,
                "leave" => FleetEventKind::Leave,
                "crash" => FleetEventKind::Crash,
                "throttle" => FleetEventKind::Throttle {
                    clock_hz: u64_field(v, "clock_hz")?,
                },
                "restore" => FleetEventKind::Restore,
                "drain" => FleetEventKind::Drain,
                other => anyhow::bail!("unknown fleet event kind `{other}`"),
            };
            Ok(FleetEvent {
                at: u64_field(v, "at")?,
                device: u64_field(v, "device")? as usize,
                kind,
            })
        })
        .collect()
}

/// Serialize a trace together with its fleet-event stream. An empty
/// stream writes the exact same JSON as [`trace_to_json`], so files
/// recorded without churn stay byte-identical.
pub fn full_trace_to_json(trace: &[TraceRequest], events: &[FleetEvent]) -> Json {
    let mut js = trace_to_json(trace);
    if !events.is_empty() {
        if let Json::Obj(o) = &mut js {
            o.insert("fleet_events".into(), fleet_events_to_json(events));
        }
    }
    js
}

/// Write a trace to `path` as JSON.
pub fn save_trace<P: AsRef<Path>>(path: P, trace: &[TraceRequest]) -> Result<()> {
    std::fs::write(path.as_ref(), trace_to_json(trace).to_string_compact())?;
    Ok(())
}

/// Load a trace previously written by [`save_trace`] (or hand-recorded
/// in the same schema).
pub fn load_trace<P: AsRef<Path>>(path: P) -> Result<Vec<TraceRequest>> {
    let src = std::fs::read_to_string(path.as_ref())?;
    let js = Json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
    trace_from_json(&js)
}

/// Write a trace plus its fleet-event stream to `path` as one JSON file.
pub fn save_full_trace<P: AsRef<Path>>(
    path: P,
    trace: &[TraceRequest],
    events: &[FleetEvent],
) -> Result<()> {
    std::fs::write(
        path.as_ref(),
        full_trace_to_json(trace, events).to_string_compact(),
    )?;
    Ok(())
}

/// Load a trace and its fleet-event stream. Files recorded before fleet
/// events existed (or with churn off) load with an empty stream.
pub fn load_full_trace<P: AsRef<Path>>(path: P) -> Result<(Vec<TraceRequest>, Vec<FleetEvent>)> {
    let src = std::fs::read_to_string(path.as_ref())?;
    let js = Json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
    Ok((trace_from_json(&js)?, fleet_events_from_json(&js)?))
}

/// Write a trace as JSON-lines: one [`request_to_json`] object per
/// line, no envelope. [`TraceSource`] reads the format back one line at
/// a time, so a replay over the file never materializes the full trace.
pub fn save_trace_jsonl<P: AsRef<Path>>(path: P, trace: &[TraceRequest]) -> Result<()> {
    use std::io::Write;
    let file = std::fs::File::create(path.as_ref())?;
    let mut w = std::io::BufWriter::new(file);
    for r in trace {
        w.write_all(request_to_json(r).to_string_compact().as_bytes())?;
        w.write_all(b"\n")?;
    }
    w.flush()?;
    Ok(())
}

/// A streaming source of trace requests: iterate to draw requests in
/// trace order, one at a time.
///
/// Three backings share the interface: a borrowed slice (synthetic
/// traces, already-loaded envelopes), an owned vector, and a buffered
/// JSON-lines reader that keeps a single line in memory — the backing
/// that lets a million-request replay run in bounded space. Reader
/// errors carry the 1-based line number of the offending line; after
/// the first error the source is poisoned and yields nothing further
/// (a corrupt stream has no trustworthy remainder).
pub struct TraceSource<'a> {
    inner: SourceInner<'a>,
}

enum SourceInner<'a> {
    Slice(std::slice::Iter<'a, TraceRequest>),
    Owned(std::vec::IntoIter<TraceRequest>),
    Lines {
        reader: Box<dyn BufRead + 'a>,
        /// 1-based number of the last line read from `reader`.
        line: usize,
        /// First request, already parsed by the format sniffer.
        pending: Option<TraceRequest>,
        /// Set after the first error; the stream is poisoned.
        failed: bool,
    },
}

impl<'a> TraceSource<'a> {
    /// Stream a trace that is already in memory, without copying it.
    pub fn from_slice(trace: &'a [TraceRequest]) -> TraceSource<'a> {
        TraceSource { inner: SourceInner::Slice(trace.iter()) }
    }

    /// Stream an owned, already-materialized trace.
    pub fn from_vec(trace: Vec<TraceRequest>) -> TraceSource<'static> {
        TraceSource { inner: SourceInner::Owned(trace.into_iter()) }
    }

    /// Stream requests from `reader`, auto-detecting the format from
    /// its first non-empty line:
    ///
    /// - a JSON object carrying a `requests` key is a one-line envelope
    ///   (what [`save_trace`] writes) — parsed whole, then iterated;
    /// - any other complete JSON value is the first JSON-lines record —
    ///   subsequent lines stream one at a time;
    /// - a line that is not complete JSON on its own is assumed to open
    ///   a pretty-printed envelope — the rest of the input is read and
    ///   parsed as one document.
    ///
    /// Fleet events never travel through a streaming source; envelope
    /// files that carry them load via [`load_full_trace`].
    pub fn from_reader(mut reader: impl BufRead + 'a) -> Result<TraceSource<'a>> {
        let mut first = String::new();
        let mut line = 0usize;
        loop {
            first.clear();
            line += 1;
            if reader.read_line(&mut first)? == 0 {
                // Empty input: a zero-request trace.
                return Ok(TraceSource::from_vec(Vec::new()));
            }
            if !first.trim().is_empty() {
                break;
            }
        }
        match Json::parse(first.trim()) {
            Ok(js) if js.get("requests").is_some() => {
                // Single-line envelope; the file holds nothing else.
                Ok(TraceSource::from_vec(trace_from_json(&js)?))
            }
            Ok(js) => {
                let req = request_from_json(&js)
                    .map_err(|e| anyhow::anyhow!("trace line {line}: {e}"))?;
                Ok(TraceSource {
                    inner: SourceInner::Lines {
                        reader: Box::new(reader),
                        line,
                        pending: Some(req),
                        failed: false,
                    },
                })
            }
            Err(first_err) => {
                // Not complete JSON by itself: the opening line of a
                // pretty-printed envelope, or garbage.
                let mut rest = String::new();
                reader.read_to_string(&mut rest)?;
                let js = Json::parse(&format!("{first}{rest}")).map_err(|_| {
                    anyhow::anyhow!(
                        "trace line {line}: neither a JSON-lines request \
                         nor the start of a trace envelope ({first_err})"
                    )
                })?;
                Ok(TraceSource::from_vec(trace_from_json(&js)?))
            }
        }
    }

    /// Open `path` as a streaming trace source (format auto-detected,
    /// see [`from_reader`](TraceSource::from_reader)).
    pub fn open<P: AsRef<Path>>(path: P) -> Result<TraceSource<'static>> {
        let file = std::fs::File::open(path.as_ref())
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))?;
        TraceSource::from_reader(std::io::BufReader::new(file))
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.as_ref().display()))
    }
}

impl Iterator for TraceSource<'_> {
    type Item = Result<TraceRequest>;

    fn next(&mut self) -> Option<Result<TraceRequest>> {
        match &mut self.inner {
            SourceInner::Slice(it) => it.next().cloned().map(Ok),
            SourceInner::Owned(it) => it.next().map(Ok),
            SourceInner::Lines { reader, line, pending, failed } => {
                if *failed {
                    return None;
                }
                if let Some(r) = pending.take() {
                    return Some(Ok(r));
                }
                let mut buf = String::new();
                loop {
                    buf.clear();
                    *line += 1;
                    let ln = *line;
                    match reader.read_line(&mut buf) {
                        Ok(0) => return None,
                        Ok(_) => {}
                        Err(e) => {
                            *failed = true;
                            return Some(Err(anyhow::anyhow!("trace line {ln}: {e}")));
                        }
                    }
                    let text = buf.trim();
                    if text.is_empty() {
                        continue;
                    }
                    let parsed = Json::parse(text)
                        .map_err(|e| anyhow::anyhow!("trace line {ln}: {e}"))
                        .and_then(|js| {
                            request_from_json(&js)
                                .map_err(|e| anyhow::anyhow!("trace line {ln}: {e}"))
                        });
                    if parsed.is_err() {
                        *failed = true;
                    }
                    return Some(parsed);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = TraceCfg::new(50, 100_000, 42);
        let a = synth_trace(&cfg, 2);
        let b = synth_trace(&cfg, 2);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals must be sorted");
        }
        // No SLO mix configured: everything is best-effort.
        assert!(a.iter().all(|r| r.class == SloClass::Batch && r.deadline == u64::MAX));
    }

    #[test]
    fn mean_gap_tracks_config() {
        let cfg = TraceCfg::new(2000, 1_000_000, 7);
        let tr = synth_trace(&cfg, 1);
        let span = tr.last().unwrap().arrival as f64;
        let mean_gap = span / tr.len() as f64;
        // Exponential mean should land near the configured gap.
        assert!(
            (0.8..1.2).contains(&(mean_gap / 1_000_000.0)),
            "mean gap {mean_gap}"
        );
    }

    #[test]
    fn weighted_mix_respected() {
        let mut cfg = TraceCfg::new(3000, 1000, 9);
        cfg.weights = vec![3.0, 1.0];
        let tr = synth_trace(&cfg, 2);
        let heavy = tr.iter().filter(|r| r.key_idx == 0).count() as f64;
        let frac = heavy / tr.len() as f64;
        assert!((0.68..0.82).contains(&frac), "mix fraction {frac}");
    }

    #[test]
    fn request_seeds_differ() {
        let tr = synth_trace(&TraceCfg::new(20, 1000, 3), 1);
        let mut seeds: Vec<u64> = tr.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 20, "every request gets its own input seed");
    }

    #[test]
    fn tenant_skew_concentrates_traffic() {
        let cfg = TraceCfg::new(4000, 1000, 5).with_skew(1.2);
        let tr = synth_trace(&cfg, 4);
        let counts: Vec<usize> = (0..4)
            .map(|k| tr.iter().filter(|r| r.key_idx == k).count())
            .collect();
        assert!(counts[0] > counts[1] && counts[1] > counts[3], "skew {counts:?}");
        assert!(counts[0] as f64 / tr.len() as f64 > 0.35, "head tenant share");
    }

    #[test]
    fn slo_mix_draws_every_class_without_perturbing_arrivals() {
        let base = TraceCfg::new(600, 50_000, 11);
        let plain = synth_trace(&base, 2);
        let slo = synth_trace(&base.clone().with_slo([2.0, 1.0, 1.0]), 2);
        for (p, s) in plain.iter().zip(&slo) {
            assert_eq!(p.arrival, s.arrival, "class draws must not shift arrivals");
            assert_eq!(p.key_idx, s.key_idx);
            assert_eq!(p.seed, s.seed);
        }
        for class in SloClass::ALL {
            assert!(
                slo.iter().filter(|r| r.class == class).count() > 0,
                "class {} never drawn",
                class.name()
            );
        }
        // Deadlines are consistent with class + arrival.
        for r in &slo {
            assert_eq!(r.deadline, r.class.deadline_at(r.arrival));
        }
        let interactive = slo.iter().find(|r| r.class == SloClass::Interactive).unwrap();
        assert_eq!(interactive.deadline, interactive.arrival + 4_320_000);
        assert_eq!(interactive.priority(), 2);
    }

    #[test]
    fn burst_knob_creates_simultaneous_spikes_without_perturbing_the_rest() {
        let base = TraceCfg::new(40, 50_000, 21);
        let plain = synth_trace(&base, 2);
        let burst = synth_trace(&base.clone().with_burst(10, 4), 2);
        // Same tenant/seed streams: only arrival times change.
        for (p, b) in plain.iter().zip(&burst) {
            assert_eq!(p.key_idx, b.key_idx);
            assert_eq!(p.seed, b.seed);
        }
        // Every burst leader is joined by `burst_size` simultaneous
        // arrivals.
        for leader in (0..40).step_by(10) {
            for member in leader + 1..=leader + 4 {
                assert_eq!(
                    burst[member].arrival, burst[leader].arrival,
                    "request {member} must arrive with its burst leader {leader}"
                );
            }
            if leader + 5 < 40 {
                assert!(
                    burst[leader + 5].arrival >= burst[leader].arrival,
                    "post-burst arrivals resume the Poisson process"
                );
            }
        }
        // period 0 (the default) is byte-identical to the legacy shape.
        let again = synth_trace(&base, 2);
        assert_eq!(plain, again);
    }

    #[test]
    fn trace_round_trips_through_json() {
        let cfg = TraceCfg::new(40, 75_000, 13).with_skew(0.8).with_slo([1.0, 1.0, 1.0]);
        let tr = synth_trace(&cfg, 3);
        let js = trace_to_json(&tr);
        let back = trace_from_json(&js).unwrap();
        assert_eq!(tr, back, "JSON round-trip must be lossless");
        // And through a file (including full-range u64 seeds).
        let path = std::env::temp_dir().join("mcu_mixq_trace_roundtrip.json");
        save_trace(&path, &tr).unwrap();
        let loaded = load_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(tr, loaded);
    }

    #[test]
    fn churn_stream_never_perturbs_requests_and_is_deterministic() {
        let base = TraceCfg::new(400, 50_000, 31).with_slo([1.0, 1.0, 1.0]);
        let plain = synth_trace(&base, 2);
        let churned_cfg = base.clone().with_churn(0.25);
        let churned = synth_trace(&churned_cfg, 2);
        // Fleet churn draws from its own stream: the request trace is
        // identical whether or not events are generated.
        assert_eq!(plain, churned);
        let ev_a = synth_fleet_events(&churned_cfg, &churned, 4);
        let ev_b = synth_fleet_events(&churned_cfg, &churned, 4);
        assert_eq!(ev_a, ev_b, "event stream must be deterministic");
        assert!(!ev_a.is_empty(), "25% churn over 400 requests fires");
        // churn = 0 generates nothing.
        assert!(synth_fleet_events(&base, &plain, 4).is_empty());
    }

    #[test]
    fn churn_events_are_sorted_coherent_and_keep_one_live_device() {
        let cfg = TraceCfg::new(1200, 50_000, 77).with_churn(0.5);
        let trace = synth_trace(&cfg, 2);
        for fleet_size in [1usize, 2, 4] {
            let events = synth_fleet_events(&cfg, &trace, fleet_size);
            #[derive(Clone, Copy)]
            struct St {
                up: bool,
                draining: bool,
            }
            let mut st = vec![St { up: true, draining: false }; fleet_size];
            let mut at = 0u64;
            for e in &events {
                assert!(e.at >= at, "events must be time-sorted");
                at = e.at;
                assert!(e.device < fleet_size);
                match e.kind {
                    FleetEventKind::Join => {
                        assert!(!st[e.device].up, "join only revives a downed device");
                        st[e.device] = St { up: true, draining: false };
                    }
                    FleetEventKind::Leave | FleetEventKind::Crash => {
                        assert!(st[e.device].up, "cannot lose a downed device twice");
                        st[e.device] = St { up: false, draining: false };
                    }
                    FleetEventKind::Throttle { clock_hz } => {
                        assert!(st[e.device].up && clock_hz >= 1_000_000);
                    }
                    FleetEventKind::Restore => st[e.device].draining = false,
                    FleetEventKind::Drain => {
                        assert!(st[e.device].up);
                        st[e.device].draining = true;
                    }
                }
                let live = st.iter().filter(|s| s.up && !s.draining).count();
                assert!(live >= 1, "churn must never take the fleet below one live device");
            }
        }
    }

    #[test]
    fn full_trace_round_trips_and_stays_backward_compatible() {
        let cfg = TraceCfg::new(120, 60_000, 19).with_slo([1.0, 1.0, 1.0]).with_churn(0.3);
        let trace = synth_trace(&cfg, 2);
        let events = synth_fleet_events(&cfg, &trace, 3);
        assert!(!events.is_empty());
        assert!(
            events.iter().any(|e| matches!(e.kind, FleetEventKind::Throttle { .. })),
            "30% churn should include a throttle"
        );
        let js = full_trace_to_json(&trace, &events);
        assert_eq!(trace_from_json(&js).unwrap(), trace);
        assert_eq!(fleet_events_from_json(&js).unwrap(), events);

        let path = std::env::temp_dir().join("mcu_mixq_full_trace_roundtrip.json");
        save_full_trace(&path, &trace, &events).unwrap();
        let (tr2, ev2) = load_full_trace(&path).unwrap();
        // Plain load_trace still reads a file that carries fleet events.
        let tr3 = load_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(tr2, trace);
        assert_eq!(ev2, events);
        assert_eq!(tr3, trace);

        // No events → byte-identical to the legacy schema, and legacy
        // files load with an empty stream.
        assert_eq!(
            full_trace_to_json(&trace, &[]).to_string_compact(),
            trace_to_json(&trace).to_string_compact()
        );
        assert!(fleet_events_from_json(&trace_to_json(&trace)).unwrap().is_empty());

        // Garbage kinds are rejected.
        let bad = Json::parse(
            r#"{"requests":[],"fleet_events":[{"at":1,"device":0,"kind":"implode"}]}"#,
        )
        .unwrap();
        assert!(fleet_events_from_json(&bad).is_err());
    }

    #[test]
    fn trace_from_json_rejects_garbage() {
        assert!(trace_from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = Json::parse(r#"{"requests":[{"id":0,"arrival":5,"key_idx":0,"seed":"1","class":"warp","deadline":"9"}]}"#).unwrap();
        assert!(trace_from_json(&bad).is_err());
    }

    #[test]
    fn jsonl_round_trips_through_the_streaming_source() {
        let cfg = TraceCfg::new(60, 75_000, 23).with_skew(0.8).with_slo([1.0, 1.0, 1.0]);
        let tr = synth_trace(&cfg, 3);
        let path = std::env::temp_dir().join("mcu_mixq_trace_jsonl_roundtrip.jsonl");
        save_trace_jsonl(&path, &tr).unwrap();
        let back: Vec<TraceRequest> = TraceSource::open(&path)
            .unwrap()
            .collect::<Result<_>>()
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(tr, back, "JSON-lines round-trip must be lossless");
        // The slice and owned backings yield the same stream.
        let from_slice: Vec<TraceRequest> = TraceSource::from_slice(&tr)
            .collect::<Result<_>>()
            .unwrap();
        assert_eq!(tr, from_slice);
        let from_vec: Vec<TraceRequest> = TraceSource::from_vec(tr.clone())
            .collect::<Result<_>>()
            .unwrap();
        assert_eq!(tr, from_vec);
    }

    #[test]
    fn streaming_source_auto_detects_legacy_envelopes() {
        let cfg = TraceCfg::new(25, 60_000, 29).with_slo([1.0, 1.0, 1.0]);
        let tr = synth_trace(&cfg, 2);
        // Compact single-line envelope: exactly what save_trace writes.
        let path = std::env::temp_dir().join("mcu_mixq_trace_envelope_stream.json");
        save_trace(&path, &tr).unwrap();
        let back: Vec<TraceRequest> = TraceSource::open(&path)
            .unwrap()
            .collect::<Result<_>>()
            .unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(tr, back, "single-line envelope auto-detected");
        // Pretty-printed (multi-line) envelope: the first line alone is
        // not complete JSON, so the sniffer reads the whole document.
        let rows: Vec<String> = tr
            .iter()
            .map(|r| format!("    {}", request_to_json(r).to_string_compact()))
            .collect();
        let pretty = format!(
            "{{\n  \"version\": 1,\n  \"requests\": [\n{}\n  ]\n}}\n",
            rows.join(",\n")
        );
        let back2: Vec<TraceRequest> = TraceSource::from_reader(std::io::Cursor::new(pretty))
            .unwrap()
            .collect::<Result<_>>()
            .unwrap();
        assert_eq!(tr, back2, "pretty-printed envelope auto-detected");
        // Empty input is a zero-request trace, not an error.
        assert_eq!(
            TraceSource::from_reader(std::io::Cursor::new("\n\n")).unwrap().count(),
            0
        );
    }

    #[test]
    fn corrupt_jsonl_lines_name_their_line_number() {
        let tr = synth_trace(&TraceCfg::new(3, 50_000, 37), 1);
        // Line 1 valid, line 2 blank, line 3 truncated mid-object.
        let text = format!(
            "{}\n\n{{\"id\":1,\"arrival\":12",
            request_to_json(&tr[0]).to_string_compact()
        );
        let mut src = TraceSource::from_reader(std::io::Cursor::new(text)).unwrap();
        assert_eq!(src.next().unwrap().unwrap(), tr[0]);
        let err = src.next().unwrap().unwrap_err().to_string();
        assert!(err.contains("trace line 3"), "error names the bad line: {err}");
        assert!(src.next().is_none(), "a corrupt stream is poisoned after the error");

        // A structurally valid record with an unknown class also names
        // its line (here the blank leading line shifts it to line 2).
        let text = format!(
            "\n{}\n",
            r#"{"id":0,"arrival":5,"key_idx":0,"seed":"1","class":"warp","deadline":"9"}"#
        );
        let err = TraceSource::from_reader(std::io::Cursor::new(text))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("trace line 2"), "{err}");
        assert!(err.contains("warp"), "{err}");

        // Garbage that is neither JSONL nor an envelope fails up front.
        let err = TraceSource::from_reader(std::io::Cursor::new("not json at all"))
            .map(|_| ())
            .unwrap_err()
            .to_string();
        assert!(err.contains("trace line 1"), "{err}");
    }
}
