//! Deterministic synthetic request traces.
//!
//! A trace is a sequence of (arrival cycle, model, input seed) triples:
//! arrivals follow a Poisson process (exponential inter-arrival times at
//! a configurable mean), the model of each request is drawn from a
//! weighted mix, and every request carries a fork of the trace PRNG so
//! its input image is reproducible independently of processing order.

use crate::util::prng::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: usize,
    /// Arrival time in virtual cycles (non-decreasing along the trace).
    pub arrival: u64,
    /// Index into the workload table of the replay.
    pub key_idx: usize,
    /// Seed for this request's synthetic input image.
    pub seed: u64,
}

/// Trace-generation parameters.
#[derive(Debug, Clone)]
pub struct TraceCfg {
    pub requests: usize,
    /// Mean inter-arrival gap in cycles (Poisson process). At 216 MHz,
    /// 2_160_000 cycles ≈ one request every 10 ms ≈ 100 req/s offered.
    pub mean_gap_cycles: u64,
    /// Relative traffic weight per workload (index-aligned; empty =
    /// uniform).
    pub weights: Vec<f64>,
    pub seed: u64,
}

impl TraceCfg {
    pub fn new(requests: usize, mean_gap_cycles: u64, seed: u64) -> TraceCfg {
        TraceCfg {
            requests,
            mean_gap_cycles,
            weights: Vec::new(),
            seed,
        }
    }
}

/// Generate a synthetic trace over `num_keys` workloads.
pub fn synth_trace(cfg: &TraceCfg, num_keys: usize) -> Vec<TraceRequest> {
    assert!(num_keys >= 1, "trace needs at least one workload");
    let weights: Vec<f64> = if cfg.weights.is_empty() {
        vec![1.0; num_keys]
    } else {
        assert_eq!(cfg.weights.len(), num_keys, "one weight per workload");
        cfg.weights.clone()
    };
    let wsum: f64 = weights.iter().sum();
    assert!(wsum > 0.0, "weights must not all be zero");

    let mut rng = Rng::new(cfg.seed);
    let mut t = 0u64;
    (0..cfg.requests)
        .map(|id| {
            // Exponential inter-arrival (clamped away from ln(0)).
            let u = (rng.f32() as f64).max(1e-7);
            let gap = (-u.ln() * cfg.mean_gap_cycles as f64) as u64;
            t = t.saturating_add(gap);
            // Weighted model pick.
            let mut pick = rng.f32() as f64 * wsum;
            let mut key_idx = num_keys - 1;
            for (i, w) in weights.iter().enumerate() {
                if pick < *w {
                    key_idx = i;
                    break;
                }
                pick -= w;
            }
            TraceRequest {
                id,
                arrival: t,
                key_idx,
                seed: rng.next_u64(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let cfg = TraceCfg::new(50, 100_000, 42);
        let a = synth_trace(&cfg, 2);
        let b = synth_trace(&cfg, 2);
        assert_eq!(a.len(), 50);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.key_idx, y.key_idx);
            assert_eq!(x.seed, y.seed);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival, "arrivals must be sorted");
        }
    }

    #[test]
    fn mean_gap_tracks_config() {
        let cfg = TraceCfg::new(2000, 1_000_000, 7);
        let tr = synth_trace(&cfg, 1);
        let span = tr.last().unwrap().arrival as f64;
        let mean_gap = span / tr.len() as f64;
        // Exponential mean should land near the configured gap.
        assert!(
            (0.8..1.2).contains(&(mean_gap / 1_000_000.0)),
            "mean gap {mean_gap}"
        );
    }

    #[test]
    fn weighted_mix_respected() {
        let mut cfg = TraceCfg::new(3000, 1000, 9);
        cfg.weights = vec![3.0, 1.0];
        let tr = synth_trace(&cfg, 2);
        let heavy = tr.iter().filter(|r| r.key_idx == 0).count() as f64;
        let frac = heavy / tr.len() as f64;
        assert!((0.68..0.82).contains(&frac), "mix fraction {frac}");
    }

    #[test]
    fn request_seeds_differ() {
        let tr = synth_trace(&TraceCfg::new(20, 1000, 3), 1);
        let mut seeds: Vec<u64> = tr.iter().map(|r| r.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 20, "every request gets its own input seed");
    }
}
