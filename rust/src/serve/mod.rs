//! Multi-model MCU-fleet inference serving.
//!
//! The engine's compile/run split ([`crate::engine::CompiledModel`])
//! makes sustained traffic expressible: compile each served model once,
//! then replay a request trace against a pool of simulated Cortex-M7
//! devices entirely in virtual time. The pipeline is
//!
//! ```text
//! trace ─► admission (SRAM / bounded queue) ─► batcher (per-model
//!   dynamic batching) ─► fleet (round-robin over serial devices,
//!     queue-depth backpressure) ─► stats (p50/p95/p99, throughput)
//! ```
//!
//! * [`registry`] — multi-tenant model registry with an LRU
//!   compile-once artifact cache;
//! * [`fleet`] — the device pool: per-device SRAM budget, cycle
//!   [`Counter`](crate::mcu::Counter) and virtual-time timeline;
//! * [`batcher`] — bounded request queue + dynamic batching window;
//! * [`stats`] — latency/throughput/cache reporting (tables + JSON);
//! * [`trace`] — deterministic synthetic request traces.
//!
//! Everything is deterministic: a (workloads, trace, config) triple
//! always produces the same report, so serving numbers are comparable
//! across PRs the same way the fig5–fig8 benches are.

pub mod batcher;
pub mod fleet;
pub mod registry;
pub mod stats;
pub mod trace;

pub use batcher::{Batcher, BatcherCfg, PendingRequest, ReadyBatch, BATCH_OVERHEAD_CYCLES};
pub use fleet::{Device, DeviceCfg, Dispatch, Fleet};
pub use registry::{ModelKey, Registry, RegistryStats};
pub use stats::{DeviceStats, LatencySummary, ModelStats, ServeReport};
pub use trace::{synth_trace, TraceCfg, TraceRequest};

use std::sync::Arc;
use std::time::Instant;

use crate::datasets::{self, Task};
use crate::engine::{self, CompiledModel};
use crate::mcu::Counter;
use crate::models::{self, ModelDesc};
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::util::prng::Rng;
use crate::Result;

/// One served tenant: the model identity plus the trained parameters it
/// deploys with.
pub struct Workload {
    pub key: ModelKey,
    pub model: ModelDesc,
    pub params: Vec<f32>,
}

impl Workload {
    pub fn new(model: ModelDesc, method: Method, cfg: BitConfig, params: Vec<f32>) -> Workload {
        Workload {
            key: ModelKey::new(&model.name, method, cfg),
            model,
            params,
        }
    }

    /// A workload over a zoo backbone with seeded synthetic parameters
    /// and a uniform bit configuration — lets the serving path run
    /// without AOT artifacts or a PJRT runtime.
    pub fn synth(backbone: &str, method: Method, bits: u8, seed: u64) -> Result<Workload> {
        let model = models::by_name(backbone)
            .ok_or_else(|| anyhow::anyhow!("unknown backbone `{backbone}`"))?;
        anyhow::ensure!(
            method.supports(bits, bits),
            "{} does not support w{bits}a{bits}",
            method.name()
        );
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
        let cfg = BitConfig::uniform(model.num_layers(), bits);
        Ok(Workload::new(model, method, cfg, params))
    }
}

/// Serving-stack configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Fleet size.
    pub devices: usize,
    /// Per-device hardware parameters.
    pub device: DeviceCfg,
    /// Unfinished batches one device may hold before backpressure.
    pub max_queue_depth: usize,
    pub batcher: BatcherCfg,
    /// Registry LRU capacity (compiled artifacts held at once).
    pub cache_capacity: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            devices: 4,
            device: DeviceCfg::stm32f746(),
            max_queue_depth: 4,
            batcher: BatcherCfg::default(),
            cache_capacity: 8,
        }
    }
}

/// Per-model accumulator while replaying.
#[derive(Default, Clone)]
struct ModelAcc {
    requests: u64,
    batches: u64,
    cycles: u64,
}

/// Dispatch a set of flushed batches in ready-time order (ties broken
/// by key index, then queue order). `pop_due` yields batches grouped by
/// key; without the sort a later-ready batch could jump the device
/// queue ahead of an earlier-ready one and skew the latency tail.
fn exec_batches(
    mut batches: Vec<ReadyBatch>,
    pinned: &[Option<Arc<CompiledModel>>],
    fleet: &mut Fleet,
    latencies: &mut Vec<u64>,
    accs: &mut [ModelAcc],
    makespan: &mut u64,
) -> Result<()> {
    batches.sort_by_key(|b| (b.ready, b.key_idx));
    for batch in batches {
        let art = pinned[batch.key_idx]
            .clone()
            .expect("queued request implies a compiled artifact");
        exec_batch(
            &batch,
            &art,
            fleet,
            latencies,
            &mut accs[batch.key_idx],
            makespan,
        )?;
    }
    Ok(())
}

/// Execute one flushed batch: run every image on the compiled artifact,
/// dispatch the total cost to the fleet, and charge each member request
/// its virtual-time latency.
fn exec_batch(
    batch: &ReadyBatch,
    art: &CompiledModel,
    fleet: &mut Fleet,
    latencies: &mut Vec<u64>,
    acc: &mut ModelAcc,
    makespan: &mut u64,
) -> Result<()> {
    let mut run_cycles = 0u64;
    let mut ctr = Counter::new();
    for r in &batch.requests {
        let res = art.run(&r.image)?;
        run_cycles += res.cycles;
        ctr.merge(&res.counter);
    }
    let cost = BATCH_OVERHEAD_CYCLES + run_cycles;
    let disp = fleet
        .dispatch(
            batch.ready,
            cost,
            art.peak_sram(),
            batch.requests.len() as u64,
            &ctr,
        )
        .ok_or_else(|| {
            anyhow::anyhow!("no device fits {}B arena (admission should reject)", art.peak_sram())
        })?;
    for r in &batch.requests {
        latencies.push(disp.finish.saturating_sub(r.arrival));
    }
    acc.requests += batch.requests.len() as u64;
    acc.batches += 1;
    acc.cycles += cost;
    *makespan = (*makespan).max(disp.finish);
    Ok(())
}

/// Replay `trace` over `workloads` with the serving stack in `cfg`,
/// producing the full [`ServeReport`].
pub fn run_trace(
    workloads: &[Workload],
    trace: &[TraceRequest],
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    anyhow::ensure!(!workloads.is_empty(), "serving needs at least one workload");
    let wall0 = Instant::now();
    let compiles0 = engine::compile_count();

    let mut registry = Registry::new(cfg.cache_capacity);
    let mut fleet = Fleet::new(cfg.devices, cfg.device, cfg.max_queue_depth);
    let mut batcher = Batcher::new(cfg.batcher.clone(), workloads.len());

    // Artifacts pinned for execution even if the LRU evicts them between
    // requests (the registry still tracks the recompilations).
    let mut pinned: Vec<Option<Arc<CompiledModel>>> = vec![None; workloads.len()];
    let mut latencies: Vec<u64> = Vec::new();
    let mut accs: Vec<ModelAcc> = vec![ModelAcc::default(); workloads.len()];
    let mut rejected_sram = 0u64;
    let mut makespan = 0u64;

    // Replay in arrival order (stable on id for equal arrivals).
    let mut order: Vec<&TraceRequest> = trace.iter().collect();
    order.sort_by_key(|r| (r.arrival, r.id));

    for req in order {
        anyhow::ensure!(
            req.key_idx < workloads.len(),
            "trace request {} references workload {} of {}",
            req.id,
            req.key_idx,
            workloads.len()
        );
        // Flush whatever became due before this arrival.
        exec_batches(
            batcher.pop_due(req.arrival),
            &pinned,
            &mut fleet,
            &mut latencies,
            &mut accs,
            &mut makespan,
        )?;

        // Compile-on-first-use through the registry (hits are counted
        // per request, which is what makes compile-once observable).
        let w = &workloads[req.key_idx];
        let art = registry.get_or_compile(&w.key, || {
            CompiledModel::compile(&w.model, &w.params, &w.key.cfg, w.key.method)
        })?;
        pinned[req.key_idx] = Some(art.clone());

        // Admission control: SRAM, then the bounded queue.
        if !fleet.fits_anywhere(art.peak_sram()) {
            rejected_sram += 1;
            continue;
        }
        let image = datasets::generate(
            Task::for_backbone(&w.model.name),
            1,
            w.model.input_hw,
            req.seed,
        )
        .images;
        batcher.offer(PendingRequest {
            id: req.id,
            key_idx: req.key_idx,
            arrival: req.arrival,
            image,
        });
        // A batch this arrival filled is ready right now — flush it
        // rather than letting it sit out the waiting window.
        exec_batches(
            batcher.pop_due(req.arrival),
            &pinned,
            &mut fleet,
            &mut latencies,
            &mut accs,
            &mut makespan,
        )?;
    }

    // End of trace: drain the remaining partial batches.
    exec_batches(
        batcher.drain_all(),
        &pinned,
        &mut fleet,
        &mut latencies,
        &mut accs,
        &mut makespan,
    )?;

    let completed = latencies.len();
    let virtual_s = makespan as f64 / crate::STM32F746_CLOCK_HZ as f64;
    let throughput_rps = if virtual_s > 0.0 {
        completed as f64 / virtual_s
    } else {
        0.0
    };
    let hits = registry.per_model_hits();
    let per_model = workloads
        .iter()
        .enumerate()
        .zip(&accs)
        .map(|((i, w), acc)| {
            let label = w.key.label();
            let cache_hits = hits
                .iter()
                .find(|(l, _)| *l == label)
                .map(|(_, h)| *h)
                .unwrap_or(0);
            let (peak_sram, flash_bytes, macs_per_instr) = pinned[i]
                .as_ref()
                .map(|a| {
                    (
                        a.peak_sram(),
                        a.flash_bytes(),
                        a.codegen.mean_macs_per_instr(),
                    )
                })
                .unwrap_or((0, 0, 0.0));
            ModelStats {
                label,
                requests: acc.requests,
                batches: acc.batches,
                cycles: acc.cycles,
                cache_hits,
                peak_sram,
                flash_bytes,
                macs_per_instr,
            }
        })
        .collect();
    let per_device = fleet
        .devices
        .iter()
        .map(|d| DeviceStats {
            id: d.id,
            batches: d.batches,
            images: d.images,
            busy_cycles: d.busy_cycles,
            utilization: d.utilization(makespan),
        })
        .collect();

    Ok(ServeReport {
        requests: trace.len(),
        completed,
        rejected_queue: batcher.shed,
        rejected_sram,
        makespan_cycles: makespan,
        throughput_rps,
        latency: LatencySummary::from_cycles(&latencies),
        per_model,
        per_device,
        cache: registry.stats().clone(),
        engine_compiles: engine::compile_count() - compiles0,
        wall_s: wall0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mobilenet_pair() -> Vec<Workload> {
        vec![
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap(),
            Workload::synth("mobilenet_tiny", Method::TinyEngine, 8, 22).unwrap(),
        ]
    }

    fn small_cfg() -> ServeCfg {
        ServeCfg {
            devices: 2,
            max_queue_depth: 2,
            ..ServeCfg::default()
        }
    }

    #[test]
    fn mixed_trace_completes_and_compiles_once() {
        let workloads = mobilenet_pair();
        let trace = synth_trace(&TraceCfg::new(24, 500_000, 5), workloads.len());
        let rep = run_trace(&workloads, &trace, &small_cfg()).unwrap();

        assert_eq!(rep.requests, 24);
        assert_eq!(
            rep.completed as u64 + rep.rejected_queue + rep.rejected_sram,
            24,
            "every request accounted for"
        );
        assert!(rep.completed > 0);
        // One registry lookup per request; compile-once per distinct model.
        assert_eq!(rep.cache.hits + rep.cache.misses, 24);
        assert_eq!(rep.cache.compiles, rep.cache.misses);
        assert!(rep.cache.compiles <= workloads.len() as u64);
        // Latency and throughput sanity.
        assert!(rep.latency.p50_ms > 0.0);
        assert!(rep.latency.p50_ms <= rep.latency.p95_ms);
        assert!(rep.latency.p95_ms <= rep.latency.p99_ms);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.makespan_cycles > 0);
        // Per-model accounting covers every completed request.
        let sum: u64 = rep.per_model.iter().map(|m| m.requests).sum();
        assert_eq!(sum, rep.completed as u64);
        // Fleet accounting agrees.
        let images: u64 = rep.per_device.iter().map(|d| d.images).sum();
        assert_eq!(images, rep.completed as u64);
    }

    #[test]
    fn batching_amortizes_invocation_overhead() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 3).unwrap()];
        let mk_trace = |gap: u64| -> Vec<TraceRequest> {
            (0..8)
                .map(|id| TraceRequest {
                    id,
                    arrival: id as u64 * gap,
                    key_idx: 0,
                    seed: 1000 + id as u64, // same inputs in both traces
                })
                .collect()
        };
        let cfg = ServeCfg {
            devices: 1,
            ..ServeCfg::default()
        };
        // Burst: all 8 arrive within the batching window -> one batch.
        let burst = run_trace(&workloads, &mk_trace(1), &cfg).unwrap();
        // Spread: 10 ms apart -> every request rides alone.
        let spread = run_trace(&workloads, &mk_trace(2_160_000), &cfg).unwrap();

        assert_eq!(burst.completed, 8);
        assert_eq!(spread.completed, 8);
        assert_eq!(burst.per_model[0].batches, 1);
        assert_eq!(spread.per_model[0].batches, 8);
        assert!(burst.per_model[0].mean_batch() > spread.per_model[0].mean_batch());
        // Identical inference work; the difference is exactly the seven
        // saved per-invocation overheads.
        assert_eq!(
            spread.per_model[0].cycles - burst.per_model[0].cycles,
            7 * BATCH_OVERHEAD_CYCLES
        );
    }

    #[test]
    fn bounded_queue_sheds_under_burst() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let trace: Vec<TraceRequest> = (0..10)
            .map(|id| TraceRequest {
                id,
                arrival: 0,
                key_idx: 0,
                seed: id as u64,
            })
            .collect();
        let cfg = ServeCfg {
            devices: 1,
            batcher: BatcherCfg {
                max_batch: 4,
                max_wait_cycles: 432_000,
                max_queue: 2,
            },
            ..ServeCfg::default()
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        // Queue holds 2; everything else in the simultaneous burst sheds
        // (the window never expires at t=0 and 2 < max_batch).
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.rejected_queue, 8);
        assert_eq!(rep.requests, 10);
    }

    #[test]
    fn replay_is_deterministic() {
        let workloads = mobilenet_pair();
        let trace = synth_trace(&TraceCfg::new(16, 300_000, 9), workloads.len());
        let a = run_trace(&workloads, &trace, &small_cfg()).unwrap();
        let b = run_trace(&workloads, &trace, &small_cfg()).unwrap();
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.latency.p99_ms, b.latency.p99_ms);
        assert_eq!(a.latency.mean_ms, b.latency.mean_ms);
        assert_eq!(a.cache.hits, b.cache.hits);
        let ca: Vec<u64> = a.per_model.iter().map(|m| m.cycles).collect();
        let cb: Vec<u64> = b.per_model.iter().map(|m| m.cycles).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn sram_admission_rejects_oversized_tenant() {
        // A fleet of tiny devices cannot host the model at all.
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 6).unwrap()];
        let trace = synth_trace(&TraceCfg::new(5, 100_000, 2), 1);
        let cfg = ServeCfg {
            devices: 2,
            device: DeviceCfg {
                sram_bytes: 16, // nothing fits
                clock_hz: crate::STM32F746_CLOCK_HZ,
            },
            ..ServeCfg::default()
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.rejected_sram, 5);
    }
}
