//! Multi-model MCU-fleet inference serving over heterogeneous devices.
//!
//! The engine's compile/run split ([`crate::engine::CompiledModel`])
//! makes sustained traffic expressible: compile each served model once,
//! then replay a request trace against a pool of simulated MCUs entirely
//! in virtual time. Since the scheduler refactor the pipeline is a
//! policy framework rather than a fixed pool:
//!
//! Since the event-driven refactor the replay is clocked by a single
//! binary-heap event loop ([`events::EventHeap`]) rather than per-step
//! linear scans:
//!
//! ```text
//! trace source ([`trace::TraceSource`]: borrowed slice | owned vec |
//!        streaming JSON-lines reader — a 10M-request file is never
//!        materialized; priority/deadline classes, overload bursts,
//!        replayable from JSON)
//!   │                 fleet events (seeded churn stream: Join | Leave
//!   │                        | Crash | Throttle | Restore | Drain)
//!   ▼                 ▼
//! ┌───────────────────────────────────────────────────────────────┐
//! │ event heap (min-heap on virtual cycles: FleetLifecycle ranks  │
//! │   before the Arrival sharing its cycle; exactly one arrival — │
//! │   the next undrawn request — is staged at a time)             │
//! └───────────────────────────────────────────────────────────────┘
//!   │ Arrival                          │ FleetLifecycle
//!   ▼                                  ▼
//! admission (SRAM gate + bounded    fleet lifecycle (join/leave/
//!     queue; FIFO or class-aware      crash/throttle/restore/drain;
//!     shedding; payload parked in     a crash's deadline-carrying
//!     the [`arena`] slab — the        members re-enter through
//!     queues carry only ids)          admission, deadline-free
//!   ─► batcher (per-model dynamic     members are lost and counted;
//!        batching; its *own* event    drains migrate pending batches
//!        heap indexes window          via the steal machinery)
//!        expiries — `pop_due` pops
//!        due keys instead of scanning all; preemption flushes
//!        window-doomed requests ahead of the window and splits
//!        mixed batches into critical + deferrable halves)
//!     ─► scheduler (pluggable policy: round-robin | least-loaded |
//!          slo-aware | energy-aware; the indexed fleet answers
//!          least-loaded picks from a busy-ordered set and prices
//!          SLO/energy picks through a per-kind cost memo)
//!       ─► fleet (heterogeneous devices, each described by one
//!            [`Target`](crate::target::Target); shared 216 MHz
//!            reference timeline; queue-depth backpressure; a
//!            finish-ordered wake index answers `next_wake` in
//!            O(log n); steal mode keeps committed-but-not-started
//!            batches migratable)
//!         ─► stats (p50/p95/p99, virtual-time throughput, deadline +
//!              shed-SLO misses per class, migrations, crash losses,
//!              joules per device — plus host-side `wall_ms` and
//!              `replay_requests_per_sec` simulator speed)
//! ```
//!
//! Batch-window expiries and batch finishes deliberately do *not*
//! enter the outer heap: decision points stay pinned at the exact
//! arrival boundaries the pre-refactor linear loop used, so every
//! report is reproduced bit-for-bit (`--legacy-loop` keeps the scan
//! loop alive as the equivalence oracle). The heap, the batcher's
//! due-index and the fleet's wake index change only *how fast* the
//! next due event is found, never *which* event is next.
//!
//! By default the replay also runs in "fast" mode: instruction counts
//! are shape-driven, not data-driven, so one probe inference per model
//! key prices every batch member exactly and no per-request pixels are
//! synthesized (the arena stays empty). `--legacy-loop` restores the
//! per-image inference path; the `round_robin_on_all_m7_matches_legacy_
//! pipeline_bit_for_bit` and equivalence tests pin the two modes to
//! identical reports.
//!
//! * [`events`] — the simulation event heap: one ordered queue of
//!   virtual-time events (arrivals, lifecycle, window expiry, batch
//!   finish) with lazy deletion;
//! * [`arena`] — slab storage for in-flight request payloads, keyed by
//!   stable request id so the hot path stops cloning image buffers;
//! * [`registry`] — multi-tenant model registry with an LRU
//!   compile-once artifact cache and cross-tenant weight sharing
//!   (identical-params tenants collapse onto one artifact);
//! * [`fleet`] — the device pool mechanics: each device is a
//!   [`Target`](crate::target::Target) (SRAM budget, clock,
//!   [`CycleModel`](crate::mcu::CycleModel),
//!   [`EnergyModel`](crate::target::EnergyModel)) plus a cycle
//!   [`Counter`](crate::mcu::Counter), a virtual-time timeline and the
//!   work-stealing pending queues;
//! * [`sched`] — the [`Scheduler`] trait and the four built-in
//!   placement policies;
//! * [`batcher`] — bounded request queue + dynamic batching window,
//!   class-aware admission and deadline-driven preemption;
//! * [`stats`] — latency/throughput/SLO/cache reporting (tables + JSON);
//! * [`trace`] — deterministic synthetic request traces with deadline
//!   classes and overload bursts, (de)serializable for recorded-trace
//!   replay; [`TraceSource`] streams JSON-lines files one request at a
//!   time (legacy envelope files auto-detected).
//!
//! With a [`Recorder`](crate::obs::Recorder) attached
//! ([`run_trace_observed`]), every decision point above emits a typed
//! lifecycle event on the virtual timeline: `Arrive` at trace replay,
//! `Admit` / `Shed` / `Evict` at admission, `SramReject` at the SRAM
//! gate, `FlushWindow` / `FlushFull` / `FlushPreempt` at the batcher,
//! `Place` / `Start` / `Finish` around scheduling and execution, and
//! `Migrate` at the fleet's steal pass — and an optional
//! [`MetricsRegistry`](crate::obs::MetricsRegistry) samples queue depth,
//! in-flight batches and per-device utilization on a virtual-time
//! cadence. Recording is strictly passive: the no-op recorder costs
//! nothing, and an attached recorder never changes a single report bit
//! (pinned by the `recorder_attachment_is_passive` test).
//!
//! Everything is deterministic: a (workloads, trace, config) triple
//! always produces the same report, so serving numbers are comparable
//! across PRs the same way the fig5–fig8 benches are. Each replay owns
//! its conv scratch ([`crate::ops::slbc::ConvScratch`]), so concurrent
//! fleet simulations never share mutable pipeline state.

pub mod arena;
pub mod batcher;
pub mod events;
pub mod fleet;
pub mod registry;
pub mod sched;
pub mod stats;
pub mod trace;

pub use arena::{RequestArena, RequestId};
pub use batcher::{
    class_index, AdmissionKind, Batcher, BatcherCfg, PendingRequest, ReadyBatch,
    BATCH_OVERHEAD_CYCLES,
};
pub use events::{EventHeap, SimEvent, SimEventKind};
pub use fleet::{
    BatchWork, Device, DeviceCfg, DeviceClass, Dispatch, Fleet, PendingBatch, Resolution,
};
pub use registry::{hash_params, KeyLint, ModelKey, Registry, RegistryStats};
pub use sched::{EnergyAware, LeastLoaded, RoundRobin, Scheduler, SchedulerKind, SloAware};
pub use stats::{DeviceStats, LatencySummary, ModelStats, ServeReport};
pub use trace::{
    load_full_trace, load_trace, save_full_trace, save_trace, save_trace_jsonl,
    synth_fleet_events, synth_trace, trace_from_json, trace_to_json, FleetEvent, FleetEventKind,
    SloClass, TraceCfg, TraceRequest, TraceSource,
};

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::datasets::{self, Task};
use crate::engine::{self, CompiledModel};
use crate::mcu::Counter;
use crate::obs::{Event, EventKind, MetricsRegistry, NoopRecorder, Recorder};
use crate::models::{self, ModelDesc};
use crate::ops::slbc::ConvScratch;
use crate::ops::Method;
use crate::quant::BitConfig;
use crate::util::prng::Rng;
use crate::Result;

/// One served tenant: the model identity plus the trained parameters it
/// deploys with. Tenants with identical `(backbone, method, bits)` and
/// identical parameters hash to the same [`ModelKey`] and share one
/// compiled artifact in the registry.
pub struct Workload {
    pub key: ModelKey,
    pub model: ModelDesc,
    pub params: Vec<f32>,
}

impl Workload {
    pub fn new(model: ModelDesc, method: Method, cfg: BitConfig, params: Vec<f32>) -> Workload {
        Workload {
            key: ModelKey::with_params(&model.name, method, cfg, &params),
            model,
            params,
        }
    }

    /// A workload over a zoo backbone with seeded synthetic parameters
    /// and a uniform bit configuration — lets the serving path run
    /// without AOT artifacts or a PJRT runtime.
    pub fn synth(backbone: &str, method: Method, bits: u8, seed: u64) -> Result<Workload> {
        let model = models::by_name(backbone)
            .ok_or_else(|| anyhow::anyhow!("unknown backbone `{backbone}`"))?;
        anyhow::ensure!(
            method.supports(bits, bits),
            "{} does not support w{bits}a{bits}",
            method.name()
        );
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
        let cfg = BitConfig::uniform(model.num_layers(), bits);
        Ok(Workload::new(model, method, cfg, params))
    }

    /// [`synth`](Workload::synth) with a per-layer mixed-precision
    /// [`BitConfig`] instead of a uniform width — how a native-searched
    /// configuration (`nas::search`, saved via `quant::save_config`)
    /// enters the fleet as a first-class [`ModelKey`]: the key hashes the
    /// full per-layer config, so distinct searched configs of the same
    /// backbone compile and cache independently.
    pub fn with_config(
        backbone: &str,
        method: Method,
        cfg: BitConfig,
        seed: u64,
    ) -> Result<Workload> {
        let model = models::by_name(backbone)
            .ok_or_else(|| anyhow::anyhow!("unknown backbone `{backbone}`"))?;
        anyhow::ensure!(
            cfg.num_layers() == model.num_layers(),
            "config has {} layers, {} has {}",
            cfg.num_layers(),
            backbone,
            model.num_layers()
        );
        for (i, (&w, &a)) in cfg.wbits.iter().zip(&cfg.abits).enumerate() {
            let consumed = if i == 0 { 8 } else { a };
            anyhow::ensure!(
                method.supports(w, consumed),
                "{} does not support w{w}a{consumed} (layer {i})",
                method.name()
            );
        }
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..model.param_count).map(|_| rng.normal() * 0.1).collect();
        Ok(Workload::new(model, method, cfg, params))
    }
}

/// Serving-stack configuration.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Per-device hardware profiles — one entry per fleet device, mixed
    /// classes welcome.
    pub fleet: Vec<DeviceCfg>,
    /// Batch-placement policy.
    pub scheduler: SchedulerKind,
    /// Unfinished batches one device may hold before backpressure.
    pub max_queue_depth: usize,
    pub batcher: BatcherCfg,
    /// Registry LRU capacity (compiled artifacts held at once).
    pub cache_capacity: usize,
    /// Work-stealing rebalance: committed-but-not-started batches stay
    /// migratable, and drained devices steal from backlogged neighbors
    /// at each dispatch step.
    pub steal: bool,
    /// Crash recovery: re-admit a cancelled batch's deadline-carrying
    /// members through the admission path (`true`, the default) instead
    /// of naively dropping every crashed member as lost (`false` — the
    /// baseline the churn bench compares against).
    pub readmit: bool,
    /// Reactive autoscaler: grow/shrink the fleet from a standby pool
    /// against the windowed predicted interactive-miss rate and a
    /// joules budget. `None` = fixed fleet.
    pub autoscale: Option<AutoscaleCfg>,
    /// Run the pre-event-loop replay core: per-image inference (instead
    /// of the per-key probe counter), linear `next_wake`/flush scans
    /// (instead of the wake/due indices). Kept as the equivalence oracle
    /// and the benchmark baseline; every report bit is identical either
    /// way.
    pub legacy_loop: bool,
}

/// Reactive autoscaler policy (see [`ServeCfg::autoscale`]): standby
/// devices start down; when the windowed predicted interactive-miss
/// rate crosses `grow_rate` (and the fleet is still under its joules
/// budget) the next standby joins, and when it falls below
/// `shrink_rate` the most recently grown device drains back out.
#[derive(Debug, Clone)]
pub struct AutoscaleCfg {
    /// Standby pool, appended to the fleet starting down.
    pub standby: Vec<DeviceCfg>,
    /// Recent interactive outcomes (predicted misses at placement plus
    /// interactive sheds) the miss-rate window holds.
    pub miss_window: usize,
    /// Grow when the windowed miss rate exceeds this.
    pub grow_rate: f64,
    /// Shrink when the windowed miss rate falls below this.
    pub shrink_rate: f64,
    /// No growth once cumulative fleet joules exceed this budget.
    pub joules_budget: f64,
    /// Minimum arrivals between scaling actions (anti-flapping).
    pub cooldown: usize,
}

impl Default for AutoscaleCfg {
    fn default() -> Self {
        AutoscaleCfg {
            standby: vec![DeviceCfg::stm32f746()],
            miss_window: 32,
            grow_rate: 0.25,
            shrink_rate: 0.02,
            joules_budget: f64::INFINITY,
            cooldown: 16,
        }
    }
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); 4],
            scheduler: SchedulerKind::RoundRobin,
            max_queue_depth: 4,
            batcher: BatcherCfg::default(),
            cache_capacity: 8,
            steal: false,
            readmit: true,
            autoscale: None,
            legacy_loop: false,
        }
    }
}

impl ServeCfg {
    /// Convenience: the default stack over `n` M7-class devices.
    pub fn homogeneous(n: usize) -> ServeCfg {
        ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); n],
            ..ServeCfg::default()
        }
    }
}

/// Per-model accumulator while replaying.
#[derive(Default, Clone)]
struct ModelAcc {
    requests: u64,
    batches: u64,
    cycles: u64,
    deadline_misses: u64,
}

/// One request whose batch is still migratable (steal mode): its
/// latency and deadline outcome resolve only after the fleet finalizes
/// — or whose batch a fleet event cancels, sending it back through
/// admission (re-admission) or into the lost count.
struct DeferredReq {
    ticket: usize,
    id: usize,
    arrival: u64,
    deadline: u64,
    class_idx: usize,
    key_idx: usize,
}

/// Where one ticket's deferred accounting lives: its slot in the batch
/// list plus its members' slots in the request list. Lets a fleet-event
/// cancellation touch exactly the cancelled entries (tombstoning their
/// slots) instead of scanning every deferral made so far — on a
/// million-request churned replay that scan was the last O(trace)
/// pass per event.
struct DeferredSlots {
    batch: usize,
    reqs: Vec<usize>,
}

/// Everything `exec_batch` mutates, bundled so the replay loop stays
/// readable.
struct ReplayState<'a> {
    sched: &'a mut dyn Scheduler,
    fleet: &'a mut Fleet,
    scratch: &'a mut ConvScratch,
    /// In-flight request payloads (legacy mode; empty in fast mode).
    arena: &'a mut RequestArena,
    /// Fast mode: batch counters come from the per-key probe instead of
    /// per-image inference (instruction counts are input-independent).
    fast: bool,
    /// Per-key probe counters, installed at each key's first admission.
    key_counters: Vec<Option<Counter>>,
    /// Lifecycle-event sink (the no-op recorder on the plain path).
    rec: &'a mut dyn Recorder,
    latencies: Vec<u64>,
    /// Per-SLO-class completed-request latencies (0 = interactive).
    latencies_by_class: [Vec<u64>; 3],
    accs: Vec<ModelAcc>,
    deadline_misses: u64,
    miss_by_class: [u64; 3],
    /// Completed-but-late requests whose inference alone would have met
    /// the deadline: the miss was queueing/batching delay.
    miss_queue_wait: u64,
    /// Completed-but-late requests that could not have met the deadline
    /// even starting at arrival: the miss was compute-bound.
    miss_compute: u64,
    makespan: u64,
    /// Steal mode: per-request outcomes awaiting fleet resolution, in
    /// deferral order. `None` = cancelled by a fleet event (tombstone —
    /// removal would either scramble the order or cost a full shift).
    deferred_reqs: Vec<Option<DeferredReq>>,
    /// Steal mode: per-batch (ticket, key) pairs awaiting resolution,
    /// tombstoned like `deferred_reqs`.
    deferred_batches: Vec<Option<(usize, usize)>>,
    /// Ticket -> its slots in the two deferred lists, so cancellation
    /// is O(cancelled members), not O(deferrals so far).
    deferred_index: HashMap<usize, DeferredSlots>,
    /// Fleet events present (or autoscale on): a transient no-live-host
    /// placement failure loses the batch instead of erroring.
    churn: bool,
    /// Crash-cancelled members re-admitted through admission, per class.
    readmitted_by_class: [u64; 3],
    /// Requests lost forever to crashes (deadline-free members, or
    /// batches no live device could host). Every one counts as a miss.
    lost: u64,
    lost_by_class: [u64; 3],
    /// Recent interactive outcomes (true = predicted miss) feeding the
    /// autoscaler; capacity 0 disables collection.
    slo_signal: std::collections::VecDeque<bool>,
    slo_signal_cap: usize,
    /// Running miss count over `slo_signal`, maintained incrementally —
    /// the autoscaler used to recount the whole window every arrival.
    slo_misses: usize,
}

impl ReplayState<'_> {
    /// Record one interactive outcome in the autoscaler window. The
    /// running miss count updates as entries enter and age out, so the
    /// windowed rate read is O(1) instead of a window rescan — and
    /// exactly equal to it.
    fn push_slo_signal(&mut self, miss: bool) {
        if self.slo_signal_cap == 0 {
            return;
        }
        if self.slo_signal.len() == self.slo_signal_cap
            && self.slo_signal.pop_front() == Some(true)
        {
            self.slo_misses -= 1;
        }
        self.slo_signal.push_back(miss);
        if miss {
            self.slo_misses += 1;
        }
    }
}

/// Dispatch a set of flushed batches in ready-time order (same-ready
/// ties broken by batch priority — most urgent member first — then key
/// index, then queue order). `pop_due` yields batches grouped by key;
/// without the sort a later-ready batch could jump the device queue
/// ahead of an earlier-ready one and skew the latency tail. Priority
/// only reorders genuinely concurrent batches, so best-effort traces
/// (uniform priority) keep the original ordering exactly.
fn exec_batches(
    mut batches: Vec<ReadyBatch>,
    pinned: &[Option<Arc<CompiledModel>>],
    st: &mut ReplayState,
) -> Result<()> {
    batches.sort_by_key(|b| (b.ready, std::cmp::Reverse(b.priority()), b.key_idx));
    for batch in batches {
        let art = pinned[batch.key_idx]
            .clone()
            .expect("queued request implies a compiled artifact");
        exec_batch(&batch, &art, st)?;
    }
    Ok(())
}

/// Execute one flushed batch: run every image on the compiled artifact
/// (collecting the instruction histogram), let the scheduler place the
/// batch on a device — which prices it with its *own* cycle model — and
/// charge each member request its virtual-time latency and deadline
/// outcome. In steal mode the placement is a migratable ticket: latency
/// and deadline accounting defer until the fleet finalizes.
fn exec_batch(batch: &ReadyBatch, art: &CompiledModel, st: &mut ReplayState) -> Result<()> {
    let mut ctr = Counter::new();
    if st.fast {
        // Instruction counts are shape-driven, not data-driven: the
        // per-key probe counter (installed at the key's first
        // admission) prices each member exactly as its own inference
        // would. No pixels are read; the arena stays empty.
        let probe = st.key_counters[batch.key_idx]
            .as_ref()
            .expect("admission installs the probe counter before any flush");
        for _ in &batch.requests {
            ctr.merge(probe);
        }
    } else {
        for r in &batch.requests {
            let res = art.run_with_scratch(st.arena.image(r.id), &mut *st.scratch)?;
            ctr.merge(&res.counter);
            // The payload is never read again: execution is the
            // request's last touch, wherever the batch lands.
            st.arena.release(r.id);
        }
    }
    let deadlines: Vec<u64> = batch.requests.iter().map(|r| r.deadline).collect();
    let work = BatchWork {
        ready: batch.ready,
        counter: &ctr,
        peak_sram: art.peak_sram(),
        images: batch.requests.len() as u64,
        deadlines: &deadlines,
    };
    let Some(disp) = st.sched.place(&work, &mut *st.fleet) else {
        if st.churn {
            // The fleet that admitted this batch has churned out from
            // under it: no live device hosts the arena any more. The
            // members are lost — counted, never silently vanished.
            for r in &batch.requests {
                let class_idx = class_index(r.priority);
                st.lost += 1;
                st.lost_by_class[class_idx] += 1;
                if st.rec.enabled() {
                    st.rec.record(Event {
                        cycles: batch.ready,
                        id: r.id,
                        key_idx: batch.key_idx,
                        class: class_idx as u8,
                        kind: EventKind::Lost { device: 0 },
                    });
                }
            }
            return Ok(());
        }
        anyhow::bail!(
            "no device fits {}B arena (admission should reject)",
            art.peak_sram()
        );
    };
    if st.rec.enabled() {
        // Each member request gets its own Place event so the lifecycle
        // chain Arrive → Admit → Place → Start → Finish is per-request.
        let policy = st.sched.name();
        let predicted_joules = st.fleet.devices[disp.device].cfg.batch_joules(&ctr);
        for r in &batch.requests {
            st.rec.record(Event {
                cycles: batch.ready,
                id: r.id,
                key_idx: batch.key_idx,
                class: class_index(r.priority) as u8,
                kind: EventKind::Place {
                    policy,
                    device: disp.device,
                    ticket: disp.ticket,
                    predicted_cycles: disp.device_cycles,
                    predicted_joules,
                },
            });
        }
    }
    // Autoscaler signal: the projected finish vs. deadline of every
    // interactive member is the "predicted miss" the policy reacts to.
    if st.slo_signal_cap > 0 {
        for r in &batch.requests {
            if class_index(r.priority) == 0 {
                let miss = disp.finish > r.deadline;
                st.push_slo_signal(miss);
            }
        }
    }
    let acc = &mut st.accs[batch.key_idx];
    acc.requests += batch.requests.len() as u64;
    acc.batches += 1;
    if let Some(ticket) = disp.ticket {
        // Migratable: final device, finish time and pricing arrive with
        // the fleet's resolution.
        let mut slots = DeferredSlots {
            batch: st.deferred_batches.len(),
            reqs: Vec::with_capacity(batch.requests.len()),
        };
        for r in &batch.requests {
            slots.reqs.push(st.deferred_reqs.len());
            st.deferred_reqs.push(Some(DeferredReq {
                ticket,
                id: r.id,
                arrival: r.arrival,
                deadline: r.deadline,
                class_idx: class_index(r.priority),
                key_idx: batch.key_idx,
            }));
        }
        st.deferred_batches.push(Some((ticket, batch.key_idx)));
        st.deferred_index.insert(ticket, slots);
        return Ok(());
    }
    for r in &batch.requests {
        let latency = disp.finish.saturating_sub(r.arrival);
        let class_idx = class_index(r.priority);
        st.latencies.push(latency);
        st.latencies_by_class[class_idx].push(latency);
        let miss = disp.finish > r.deadline;
        if miss {
            acc.deadline_misses += 1;
            st.deadline_misses += 1;
            st.miss_by_class[class_idx] += 1;
            // Attribution: had the batch started the moment the request
            // arrived, would pure execution time still have missed?
            if r.arrival + (disp.finish - disp.start) > r.deadline {
                st.miss_compute += 1;
            } else {
                st.miss_queue_wait += 1;
            }
        }
        if st.rec.enabled() {
            st.rec.record(Event {
                cycles: disp.start,
                id: r.id,
                key_idx: batch.key_idx,
                class: class_idx as u8,
                kind: EventKind::Start {
                    device: disp.device,
                },
            });
            st.rec.record(Event {
                cycles: disp.finish,
                id: r.id,
                key_idx: batch.key_idx,
                class: class_idx as u8,
                kind: EventKind::Finish {
                    device: disp.device,
                    start: disp.start,
                    latency_cycles: latency,
                    miss,
                },
            });
        }
    }
    acc.cycles += disp.device_cycles;
    st.makespan = st.makespan.max(disp.finish);
    Ok(())
}

/// Resolve every deferred (steal-mode) batch after the fleet finalizes:
/// charge latencies, deadline outcomes and the final device's pricing.
fn resolve_deferred(st: &mut ReplayState) {
    st.fleet.finalize();
    st.deferred_index.clear();
    for (ticket, key_idx) in std::mem::take(&mut st.deferred_batches).into_iter().flatten() {
        let res = st
            .fleet
            .resolution(ticket)
            .expect("finalized fleet resolves every ticket");
        st.accs[key_idx].cycles += res.device_cycles;
        st.makespan = st.makespan.max(res.finish);
    }
    for dr in std::mem::take(&mut st.deferred_reqs).into_iter().flatten() {
        let res = st
            .fleet
            .resolution(dr.ticket)
            .expect("finalized fleet resolves every ticket");
        let latency = res.finish.saturating_sub(dr.arrival);
        st.latencies.push(latency);
        st.latencies_by_class[dr.class_idx].push(latency);
        let miss = res.finish > dr.deadline;
        if miss {
            st.accs[dr.key_idx].deadline_misses += 1;
            st.deadline_misses += 1;
            st.miss_by_class[dr.class_idx] += 1;
            if dr.arrival + (res.finish - res.start) > dr.deadline {
                st.miss_compute += 1;
            } else {
                st.miss_queue_wait += 1;
            }
        }
        if st.rec.enabled() {
            st.rec.record(Event {
                cycles: res.start,
                id: dr.id,
                key_idx: dr.key_idx,
                class: dr.class_idx as u8,
                kind: EventKind::Start { device: res.device },
            });
            st.rec.record(Event {
                cycles: res.finish,
                id: dr.id,
                key_idx: dr.key_idx,
                class: dr.class_idx as u8,
                kind: EventKind::Finish {
                    device: res.device,
                    start: res.start,
                    latency_cycles: latency,
                    miss,
                },
            });
        }
    }
}

/// Move the batcher's and fleet's internal observability logs into the
/// recorder. The batcher log is gated (empty unless recording); the
/// fleet's migration log always accumulates, so it is drained — and
/// discarded — even with recording off to stay empty.
fn drain_obs_logs(batcher: &mut Batcher, st: &mut ReplayState) {
    let migrations = st.fleet.drain_migrations();
    if !st.rec.enabled() {
        return;
    }
    for ev in batcher.drain_events() {
        st.rec.record(ev);
    }
    for (now, from, to, ticket) in migrations {
        st.rec.record(Event {
            cycles: now,
            id: ticket,
            key_idx: Event::NO_KEY,
            class: 0,
            kind: EventKind::Migrate { from, to },
        });
    }
}

/// Replay `trace` over `workloads` with the serving stack in `cfg`,
/// producing the full [`ServeReport`]. Equivalent to
/// [`run_trace_observed`] with the no-op recorder and no metrics.
pub fn run_trace(
    workloads: &[Workload],
    trace: &[TraceRequest],
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    run_trace_full_observed(workloads, trace, &[], cfg, &mut NoopRecorder, None)
}

/// [`run_trace`] with observability attached: lifecycle events flow into
/// `rec` and (optionally) queue/fleet time series into `metrics` on its
/// virtual-time cadence. Recording is passive — the returned report is
/// bit-identical to the unobserved replay.
pub fn run_trace_observed(
    workloads: &[Workload],
    trace: &[TraceRequest],
    cfg: &ServeCfg,
    rec: &mut dyn Recorder,
    metrics: Option<&mut MetricsRegistry>,
) -> Result<ServeReport> {
    run_trace_full_observed(workloads, trace, &[], cfg, rec, metrics)
}

/// [`run_trace`] with a fault-injection stream: `fleet_events` replay on
/// the same virtual timeline as the requests, churning devices in and
/// out mid-trace. With an empty stream (and no autoscaler) this is
/// exactly [`run_trace`].
pub fn run_trace_full(
    workloads: &[Workload],
    trace: &[TraceRequest],
    fleet_events: &[FleetEvent],
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    run_trace_full_observed(workloads, trace, fleet_events, cfg, &mut NoopRecorder, None)
}

/// Apply one fleet event to the running replay: flip the device's
/// lifecycle state, emit the matching observability event, and route
/// every cancelled in-flight batch through
/// [`cancel_tickets`] — re-admission or loss, never silent vanishing.
#[allow(clippy::too_many_arguments)]
fn apply_fleet_event(
    ev: &FleetEvent,
    workloads: &[Workload],
    seed_by_id: &HashMap<usize, u64>,
    readmit: bool,
    batcher: &mut Batcher,
    st: &mut ReplayState,
    crashes: &mut u64,
) {
    if ev.device >= st.fleet.devices.len() {
        return; // stream generated for a larger fleet; ignore
    }
    let mut lifecycle = |st: &mut ReplayState, kind: EventKind| {
        if st.rec.enabled() {
            st.rec.record(Event {
                cycles: ev.at,
                id: ev.device,
                key_idx: Event::NO_KEY,
                class: 0,
                kind,
            });
        }
    };
    match ev.kind {
        FleetEventKind::Join => {
            st.fleet.device_join(ev.device, ev.at);
            lifecycle(&mut *st, EventKind::DeviceUp { device: ev.device });
        }
        FleetEventKind::Leave => {
            let cancelled = st.fleet.device_leave(ev.device, ev.at);
            lifecycle(&mut *st, EventKind::DeviceDown { device: ev.device, crashed: false });
            cancel_tickets(&cancelled, ev.device, ev.at, workloads, seed_by_id, readmit, batcher, st);
        }
        FleetEventKind::Crash => {
            let cancelled = st.fleet.device_crash(ev.device, ev.at);
            *crashes += 1;
            lifecycle(&mut *st, EventKind::DeviceDown { device: ev.device, crashed: true });
            cancel_tickets(&cancelled, ev.device, ev.at, workloads, seed_by_id, readmit, batcher, st);
        }
        FleetEventKind::Throttle { clock_hz } => {
            st.fleet.device_throttle(ev.device, clock_hz);
            lifecycle(&mut *st, EventKind::Throttle { device: ev.device, clock_hz });
        }
        FleetEventKind::Restore => {
            st.fleet.device_restore(ev.device);
            lifecycle(&mut *st, EventKind::DeviceUp { device: ev.device });
        }
        FleetEventKind::Drain => {
            let cancelled = st.fleet.device_drain(ev.device, ev.at);
            lifecycle(&mut *st, EventKind::Drain { device: ev.device });
            cancel_tickets(&cancelled, ev.device, ev.at, workloads, seed_by_id, readmit, batcher, st);
        }
    }
}

/// Unwind the deferred accounting of cancelled tickets and route every
/// member request onward: deadline-carrying members re-enter through
/// class-aware admission (so a shed re-admission lands in the usual
/// shed counters), deadline-free members — and everything when
/// re-admission is off — are lost, each loss an unconditional SLO miss.
#[allow(clippy::too_many_arguments)]
fn cancel_tickets(
    tickets: &[usize],
    device: usize,
    now: u64,
    workloads: &[Workload],
    seed_by_id: &HashMap<usize, u64>,
    readmit: bool,
    batcher: &mut Batcher,
    st: &mut ReplayState,
) {
    if tickets.is_empty() {
        return;
    }
    // The deferred index names exactly the slots each dead ticket owns,
    // so cancellation touches only the cancelled entries — the full
    // list scan this replaces was O(deferrals so far) per fleet event.
    let mut victims = Vec::new();
    for t in tickets {
        let Some(slots) = st.deferred_index.remove(t) else {
            continue;
        };
        if let Some((_, key_idx)) = st.deferred_batches[slots.batch].take() {
            st.accs[key_idx].batches -= 1;
        }
        for ri in slots.reqs {
            if let Some(dr) = st.deferred_reqs[ri].take() {
                victims.push(dr);
            }
        }
    }
    // Keep the re-admission sequence deterministic regardless of the
    // ticket order the fleet reported: restore request-id order.
    victims.sort_by_key(|dr| dr.id);
    for dr in victims {
        // The batch never completed: its members are back in flight, so
        // the per-model request count unwinds (a re-admitted member is
        // recounted when its new batch places).
        st.accs[dr.key_idx].requests -= 1;
        if readmit && dr.deadline != u64::MAX {
            if !st.fast {
                // Legacy mode regenerates the member's payload from its
                // trace seed (it was released when the batch executed);
                // fast mode never reads pixels, so nothing to restore.
                let w = &workloads[dr.key_idx];
                let seed = seed_by_id.get(&dr.id).copied().unwrap_or(dr.id as u64);
                let image = datasets::generate(
                    Task::for_backbone(&w.model.name),
                    1,
                    w.model.input_hw,
                    seed,
                )
                .images;
                st.arena.put(dr.id, image);
            }
            if st.rec.enabled() {
                st.rec.record(Event {
                    cycles: now,
                    id: dr.id,
                    key_idx: dr.key_idx,
                    class: dr.class_idx as u8,
                    kind: EventKind::Readmit { device },
                });
            }
            st.readmitted_by_class[dr.class_idx] += 1;
            batcher.offer(PendingRequest {
                id: dr.id,
                key_idx: dr.key_idx,
                arrival: dr.arrival,
                priority: (2 - dr.class_idx) as u8,
                deadline: dr.deadline,
            });
        } else {
            st.lost += 1;
            st.lost_by_class[dr.class_idx] += 1;
            if st.rec.enabled() {
                st.rec.record(Event {
                    cycles: now,
                    id: dr.id,
                    key_idx: dr.key_idx,
                    class: dr.class_idx as u8,
                    kind: EventKind::Lost { device },
                });
            }
        }
    }
    // A re-admission offer can shed (or evict a victim): those slots
    // will never execute, so their payloads reclaim immediately.
    for id in batcher.drain_reclaimed() {
        st.arena.release(id);
    }
}

/// Draws requests from a [`TraceSource`] one at a time, keeping exactly
/// one pending arrival staged in the event heap — the piece that lets a
/// streamed trace replay in bounded memory. Enforces the `(arrival, id)`
/// ordering contract a streamed source must satisfy (the slice entry
/// points guarantee it by sorting up front).
struct ArrivalFeed<'a> {
    source: TraceSource<'a>,
    /// The drawn-but-unprocessed request matching the staged heap entry.
    staged: Option<TraceRequest>,
    /// Requests drawn so far (the report's `requests` count).
    drawn: usize,
    /// Arrival cycle of the first drawn request (throughput epoch).
    first_arrival: u64,
    /// `(arrival, id)` of the last draw — the ordering guard.
    last: Option<(u64, usize)>,
    /// Record draw seeds for crash re-admission (legacy churn mode only:
    /// fast mode never regenerates payloads).
    track_seeds: bool,
}

impl ArrivalFeed<'_> {
    /// Draw the next request (if any), stage it as an `Arrival` heap
    /// entry, and remember whatever re-admission will need.
    fn stage_next(
        &mut self,
        heap: &mut EventHeap,
        seed_by_id: &mut HashMap<usize, u64>,
    ) -> Result<()> {
        let Some(next) = self.source.next() else {
            return Ok(());
        };
        let req = next?;
        if let Some((at, id)) = self.last {
            anyhow::ensure!(
                (req.arrival, req.id) >= (at, id),
                "trace source must be (arrival, id)-ordered: request {} at cycle {} \
                 follows request {} at cycle {}",
                req.id,
                req.arrival,
                id,
                at,
            );
        } else {
            self.first_arrival = req.arrival;
        }
        self.last = Some((req.arrival, req.id));
        self.drawn += 1;
        if self.track_seeds {
            seed_by_id.insert(req.id, req.seed);
        }
        heap.push(req.arrival, SimEventKind::Arrival(req.id));
        self.staged = Some(req);
        Ok(())
    }
}

/// The full-fidelity entry point: requests, fault-injection events,
/// observability, and (optionally) the reactive autoscaler, all on one
/// virtual timeline.
///
/// The slice-based entry points sort a copy of the trace by
/// `(arrival, id)` and replay it through [`run_trace_source_observed`];
/// hand the replay a streaming [`TraceSource`] directly to avoid ever
/// materializing a large trace.
pub fn run_trace_full_observed(
    workloads: &[Workload],
    trace: &[TraceRequest],
    fleet_events: &[FleetEvent],
    cfg: &ServeCfg,
    rec: &mut dyn Recorder,
    metrics: Option<&mut MetricsRegistry>,
) -> Result<ServeReport> {
    // Replay in arrival order (stable on id for equal arrivals).
    let mut order: Vec<TraceRequest> = trace.to_vec();
    order.sort_by_key(|r| (r.arrival, r.id));
    run_trace_source_observed(
        workloads,
        TraceSource::from_vec(order),
        fleet_events,
        cfg,
        rec,
        metrics,
    )
}

/// Replay a streaming [`TraceSource`] with the default stack: no fleet
/// events, no observability. The source must yield requests in
/// `(arrival, id)` order — what [`save_trace_jsonl`] writes and
/// [`synth_trace`] generates; an out-of-order draw is an error.
pub fn run_trace_source(
    workloads: &[Workload],
    source: TraceSource<'_>,
    cfg: &ServeCfg,
) -> Result<ServeReport> {
    run_trace_source_observed(workloads, source, &[], cfg, &mut NoopRecorder, None)
}

/// The streaming full-fidelity entry point: requests are drawn from
/// `source` one at a time (a JSON-lines trace file never materializes),
/// staged one arrival ahead in the event heap, and merged with the
/// fleet-event stream on one virtual timeline.
pub fn run_trace_source_observed(
    workloads: &[Workload],
    source: TraceSource<'_>,
    fleet_events: &[FleetEvent],
    cfg: &ServeCfg,
    rec: &mut dyn Recorder,
    mut metrics: Option<&mut MetricsRegistry>,
) -> Result<ServeReport> {
    anyhow::ensure!(!workloads.is_empty(), "serving needs at least one workload");
    let wall0 = Instant::now();
    let compiles0 = engine::compile_count();

    // Churn (or autoscaling) forces deferred-commit mode: batches must
    // stay migratable tickets so crashes can revoke them and drains can
    // move them. With no events and no autoscaler the flag is inert and
    // the eager path is untouched (the bit-for-bit pin).
    let churn_mode = !fleet_events.is_empty() || cfg.autoscale.is_some();
    let mut registry = Registry::new(cfg.cache_capacity);
    let mut fleet = Fleet::new(cfg.fleet.clone(), cfg.max_queue_depth);
    fleet.steal = cfg.steal || churn_mode;
    let standby_lo = fleet.devices.len();
    if let Some(asc) = &cfg.autoscale {
        for dc in &asc.standby {
            fleet.push_standby(*dc);
        }
    }
    // Fast mode (the default): shape-driven probe counters, the wake/
    // due/pick indices, an empty arena. `legacy_loop` flips all of it
    // back to the pre-event-loop core — the equivalence oracle.
    let fast = !cfg.legacy_loop;
    fleet.indexed = fast;
    // Crash re-admission regenerates the member's image from its trace
    // seed (images are not retained once a batch commits). Only the
    // legacy path reads payloads, so seeds are tracked — incrementally,
    // as requests are drawn — only for legacy churn replays.
    let mut seed_by_id: HashMap<usize, u64> = HashMap::new();
    let mut batcher = Batcher::new(cfg.batcher.clone(), workloads.len());
    batcher.set_record(rec.enabled());
    batcher.set_indexed(fast);
    let mut sched = cfg.scheduler.build();
    // Per-worker conv scratch: this replay's pipeline state is private,
    // so concurrent fleet simulations never contend on a shared
    // thread-local (ROADMAP PR-2 follow-up).
    let mut scratch = ConvScratch::new();
    let mut arena = RequestArena::new();
    let mut st = ReplayState {
        sched: sched.as_mut(),
        fleet: &mut fleet,
        scratch: &mut scratch,
        arena: &mut arena,
        fast,
        key_counters: vec![None; workloads.len()],
        rec,
        latencies: Vec::new(),
        latencies_by_class: [Vec::new(), Vec::new(), Vec::new()],
        accs: vec![ModelAcc::default(); workloads.len()],
        deadline_misses: 0,
        miss_by_class: [0; 3],
        miss_queue_wait: 0,
        miss_compute: 0,
        makespan: 0,
        deferred_reqs: Vec::new(),
        deferred_batches: Vec::new(),
        deferred_index: HashMap::new(),
        churn: churn_mode,
        readmitted_by_class: [0; 3],
        lost: 0,
        lost_by_class: [0; 3],
        slo_signal: std::collections::VecDeque::new(),
        slo_signal_cap: cfg.autoscale.as_ref().map(|a| a.miss_window).unwrap_or(0),
        slo_misses: 0,
    };
    // Fleet events replay in timeline order, ties broken by device so a
    // shuffled stream and a sorted one behave identically.
    let mut events: Vec<&FleetEvent> = fleet_events.iter().collect();
    events.sort_by_key(|e| (e.at, e.device));
    let mut crashes = 0u64;
    let mut autoscale_ups = 0u64;
    let mut autoscale_downs = 0u64;
    let mut cooldown_left = 0usize;
    let mut prev_interactive_shed = 0u64;

    // Artifacts pinned for execution even if the LRU evicts them between
    // requests (the registry still tracks the recompilations).
    let mut pinned: Vec<Option<Arc<CompiledModel>>> = vec![None; workloads.len()];
    let mut rejected_sram = 0u64;
    let mut sram_deadline_by_class = [0u64; 3];
    // Cache hits attributed per tenant (identical-params tenants share a
    // registry entry, so the registry's own per-label counts would blur
    // them together).
    let mut tenant_hits: Vec<u64> = vec![0; workloads.len()];
    // Preemption wants a per-model cost yardstick before the first
    // inference runs: installed once per key from the analytic Eq. 12
    // predictor, priced optimistically (fastest fleet device).
    let mut est_installed: Vec<bool> = vec![false; workloads.len()];

    // The outer event loop. Every fleet-lifecycle event enters the heap
    // up front (push order = sorted (at, device) order, preserved by the
    // heap's sequence numbers); arrivals are staged one at a time from
    // the source. At equal cycles a lifecycle event ranks before the
    // arrival — exactly the legacy cursor interleave ("every event with
    // `at <= arrival` lands first"), and events past the last arrival
    // drain from the same heap instead of a tail sweep.
    let mut heap = EventHeap::new();
    for (i, ev) in events.iter().enumerate() {
        heap.push(ev.at, SimEventKind::FleetLifecycle(i));
    }
    let mut feed = ArrivalFeed {
        source,
        staged: None,
        drawn: 0,
        first_arrival: 0,
        last: None,
        track_seeds: churn_mode && cfg.legacy_loop,
    };
    feed.stage_next(&mut heap, &mut seed_by_id)?;

    while let Some(sim) = heap.pop() {
        match sim.kind {
            SimEventKind::FleetLifecycle(i) => {
                // Fault injection: ranks before the arrival sharing its
                // cycle, so the arrival sees the churned fleet.
                apply_fleet_event(
                    events[i],
                    workloads,
                    &seed_by_id,
                    cfg.readmit,
                    &mut batcher,
                    &mut st,
                    &mut crashes,
                );
                continue;
            }
            SimEventKind::Arrival(_) => {}
            SimEventKind::WindowExpiry(_) | SimEventKind::BatchFinish(_) => unreachable!(
                "window/finish events live in the batcher's due-index and \
                 the fleet's wake index, never the outer heap"
            ),
        }
        let req = feed
            .staged
            .take()
            .expect("a staged request backs every Arrival entry");
        // Stage the successor before processing: its heap entry cannot
        // pop until this body returns, and staging up front keeps every
        // early-out (`continue` on an admission reject) from stalling
        // the draw. Fleet events past the last arrival drain from the
        // same heap on later iterations — no tail sweep.
        feed.stage_next(&mut heap, &mut seed_by_id)?;
        anyhow::ensure!(
            req.key_idx < workloads.len(),
            "trace request {} references workload {} of {}",
            req.id,
            req.key_idx,
            workloads.len()
        );
        if st.rec.enabled() {
            st.rec.record(Event {
                cycles: req.arrival,
                id: req.id,
                key_idx: req.key_idx,
                class: class_index(req.priority()) as u8,
                kind: EventKind::Arrive {
                    deadline: req.deadline,
                },
            });
        }
        // Flush whatever became due before this arrival.
        let mut due = batcher.pop_due(req.arrival);
        if cfg.batcher.preempt {
            due = batcher.split_critical(due);
        }
        exec_batches(due, &pinned, &mut st)?;
        drain_obs_logs(&mut batcher, &mut st);
        if let Some(m) = metrics.as_deref_mut() {
            m.inc("requests", 1);
            if m.should_sample(req.arrival) {
                let now = req.arrival;
                m.push_series("queue_depth", now, batcher.queued() as f64);
                let inflight: usize =
                    st.fleet.devices.iter().map(|d| d.queue_depth(now)).sum();
                m.push_series("inflight_batches", now, inflight as f64);
                let horizon = now.saturating_sub(feed.first_arrival);
                for d in &st.fleet.devices {
                    m.push_series(&format!("util_dev{}", d.id), now, d.utilization(horizon));
                }
            }
        }

        // Compile-on-first-use through the registry (hits are counted
        // per request, which is what makes compile-once — and, across
        // identical-params tenants, weight sharing — observable).
        let w = &workloads[req.key_idx];
        let hits_before = registry.stats().hits;
        let art = registry.get_or_compile_for(req.key_idx, &w.key, || {
            CompiledModel::compile(&w.model, &w.params, &w.key.cfg, w.key.method)
        })?;
        if registry.stats().hits > hits_before {
            tenant_hits[req.key_idx] += 1;
        }
        pinned[req.key_idx] = Some(art.clone());
        if cfg.batcher.preempt && !est_installed[req.key_idx] {
            let p = crate::perf::predict_model(&w.model, w.key.method, &w.key.cfg);
            let base = cfg
                .fleet
                .iter()
                .map(|d| d.to_timeline(BATCH_OVERHEAD_CYCLES))
                .min()
                .unwrap_or(BATCH_OVERHEAD_CYCLES);
            let per_image = cfg
                .fleet
                .iter()
                .map(|d| d.to_timeline(p.counter.cycles(&d.cycle_model)))
                .min()
                .unwrap_or(0);
            batcher.set_est_cost(req.key_idx, base, per_image);
            est_installed[req.key_idx] = true;
        }

        // Admission control: SRAM, then the bounded queue. A rejected
        // request's deadline is a lost SLO, not a vanished request.
        if !st.fleet.fits_anywhere(art.peak_sram()) {
            rejected_sram += 1;
            if req.deadline != u64::MAX {
                sram_deadline_by_class[class_index(req.priority())] += 1;
            }
            if st.rec.enabled() {
                st.rec.record(Event {
                    cycles: req.arrival,
                    id: req.id,
                    key_idx: req.key_idx,
                    class: class_index(req.priority()) as u8,
                    kind: EventKind::SramReject {
                        had_deadline: req.deadline != u64::MAX,
                    },
                });
            }
            if let Some(m) = metrics.as_deref_mut() {
                m.inc("sram_rejects", 1);
            }
            continue;
        }
        if st.fast {
            // One probe inference per model key, at its first admission:
            // instruction counts are shape-driven, not data-driven, so
            // the probe's counter prices every later batch member
            // exactly (the bit-for-bit equivalence tests rest on this).
            if st.key_counters[req.key_idx].is_none() {
                let probe = datasets::generate(
                    Task::for_backbone(&w.model.name),
                    1,
                    w.model.input_hw,
                    req.seed,
                )
                .images;
                let res = art.run_with_scratch(&probe, &mut *st.scratch)?;
                st.key_counters[req.key_idx] = Some(res.counter);
            }
        } else {
            // Legacy mode synthesizes every request's pixels and parks
            // them in the arena; the batch executor is the single reader.
            let image = datasets::generate(
                Task::for_backbone(&w.model.name),
                1,
                w.model.input_hw,
                req.seed,
            )
            .images;
            st.arena.put(req.id, image);
        }
        batcher.offer(PendingRequest {
            id: req.id,
            key_idx: req.key_idx,
            arrival: req.arrival,
            priority: req.priority(),
            deadline: req.deadline,
        });
        // The offer may have shed this request or evicted a victim —
        // either way those payloads will never be read.
        for id in batcher.drain_reclaimed() {
            st.arena.release(id);
        }
        // A batch this arrival filled is ready right now — flush it
        // rather than letting it sit out the waiting window.
        let mut due = batcher.pop_due(req.arrival);
        if cfg.batcher.preempt {
            due = batcher.split_critical(due);
        }
        exec_batches(due, &pinned, &mut st)?;
        drain_obs_logs(&mut batcher, &mut st);

        // Reactive autoscaler: grow (join a standby) when the recent
        // interactive predicted-miss rate runs hot and the joules budget
        // allows; drain the newest standby back out when it runs cold.
        if let Some(asc) = &cfg.autoscale {
            // Interactive sheds are misses the placement signal never
            // sees — feed them in as (certain) misses.
            let ished = batcher.shed_by_class[0];
            for _ in prev_interactive_shed..ished {
                st.push_slo_signal(true);
            }
            prev_interactive_shed = ished;
            if cooldown_left > 0 {
                cooldown_left -= 1;
            } else if st.slo_signal_cap > 0 && st.slo_signal.len() * 2 >= st.slo_signal_cap {
                // Both reads used to rescan per arrival (the whole
                // signal window; every device's joules). The running
                // miss count and the fleet's energy cache answer the
                // same questions in O(1).
                let misses = st.slo_misses;
                let rate = misses as f64 / st.slo_signal.len() as f64;
                if rate > asc.grow_rate {
                    let spent: f64 = st.fleet.total_joules();
                    let idle = (standby_lo..st.fleet.devices.len())
                        .find(|&i| !st.fleet.devices[i].is_live());
                    if spent < asc.joules_budget {
                        if let Some(i) = idle {
                            st.fleet.device_join(i, req.arrival);
                            autoscale_ups += 1;
                            cooldown_left = asc.cooldown;
                            if st.rec.enabled() {
                                st.rec.record(Event {
                                    cycles: req.arrival,
                                    id: i,
                                    key_idx: Event::NO_KEY,
                                    class: 0,
                                    kind: EventKind::DeviceUp { device: i },
                                });
                            }
                        }
                    }
                } else if rate < asc.shrink_rate {
                    let live = (standby_lo..st.fleet.devices.len())
                        .rev()
                        .find(|&i| st.fleet.devices[i].is_live());
                    if let Some(i) = live {
                        let cancelled = st.fleet.device_drain(i, req.arrival);
                        autoscale_downs += 1;
                        cooldown_left = asc.cooldown;
                        if st.rec.enabled() {
                            st.rec.record(Event {
                                cycles: req.arrival,
                                id: i,
                                key_idx: Event::NO_KEY,
                                class: 0,
                                kind: EventKind::Drain { device: i },
                            });
                        }
                        cancel_tickets(
                            &cancelled,
                            i,
                            req.arrival,
                            workloads,
                            &seed_by_id,
                            cfg.readmit,
                            &mut batcher,
                            &mut st,
                        );
                        drain_obs_logs(&mut batcher, &mut st);
                    }
                }
            }
        }
    }

    // End of trace: the remaining partial batches drain.
    let mut rest = batcher.drain_all();
    if cfg.batcher.preempt {
        rest = batcher.split_critical(rest);
    }
    exec_batches(rest, &pinned, &mut st)?;
    // Deferred mode (steal or churn): pending batches resolve now;
    // latencies, deadline outcomes and final-device pricing land with
    // the resolutions.
    if st.fleet.steal {
        resolve_deferred(&mut st);
    }
    drain_obs_logs(&mut batcher, &mut st);

    let ReplayState {
        latencies,
        latencies_by_class,
        accs,
        deadline_misses,
        miss_by_class,
        miss_queue_wait,
        miss_compute,
        makespan,
        readmitted_by_class,
        lost,
        lost_by_class,
        ..
    } = st;
    let first_arrival = feed.first_arrival;
    let completed = latencies.len();
    let span_cycles = makespan.saturating_sub(first_arrival);
    let virtual_s = span_cycles as f64 / crate::STM32F746_CLOCK_HZ as f64;
    let throughput_rps = if virtual_s > 0.0 {
        completed as f64 / virtual_s
    } else {
        0.0
    };
    let per_model = workloads
        .iter()
        .enumerate()
        .zip(&accs)
        .map(|((i, w), acc)| {
            let label = w.key.label();
            let cache_hits = tenant_hits[i];
            let (peak_sram, flash_bytes, macs_per_instr) = pinned[i]
                .as_ref()
                .map(|a| {
                    (
                        a.peak_sram(),
                        a.flash_bytes(),
                        a.codegen.mean_macs_per_instr(),
                    )
                })
                .unwrap_or((0, 0, 0.0));
            ModelStats {
                label,
                requests: acc.requests,
                batches: acc.batches,
                cycles: acc.cycles,
                deadline_misses: acc.deadline_misses,
                cache_hits,
                peak_sram,
                flash_bytes,
                macs_per_instr,
            }
        })
        .collect();
    let per_device: Vec<DeviceStats> = fleet
        .devices
        .iter()
        .map(|d| DeviceStats {
            id: d.id,
            class: d.cfg.class.name().to_string(),
            batches: d.batches,
            images: d.images,
            busy_cycles: d.busy_cycles,
            // Same epoch as throughput: a recorded trace whose arrivals
            // start late must not deflate utilization either.
            utilization: d.utilization(span_cycles),
            migrations: d.migrations,
            joules: d.joules(),
        })
        .collect();
    let total_joules: f64 = per_device.iter().map(|d| d.joules).sum();
    if let Some(m) = metrics.as_deref_mut() {
        m.inc("completed", completed as u64);
        for &l in &latencies {
            m.observe("latency_cycles", l);
        }
        m.gauge("throughput_rps", throughput_rps);
        m.gauge("total_joules", total_joules);
    }

    let wall_s = wall0.elapsed().as_secs_f64();
    Ok(ServeReport {
        scheduler: cfg.scheduler.name().to_string(),
        admission: cfg.batcher.admission.name().to_string(),
        requests: feed.drawn,
        completed,
        rejected_queue: batcher.shed,
        shed_by_class: batcher.shed_by_class,
        shed_deadline_by_class: batcher.shed_deadline_by_class,
        rejected_sram,
        sram_deadline_by_class,
        deadline_misses,
        miss_by_class,
        miss_queue_wait,
        miss_compute,
        preempt_flushes: batcher.preempt_flushes,
        batch_splits: batcher.splits,
        migrations: fleet.migrations(),
        readmitted_by_class,
        lost,
        lost_by_class,
        crashes,
        autoscale_ups,
        autoscale_downs,
        first_arrival_cycles: first_arrival,
        makespan_cycles: makespan,
        throughput_rps,
        total_joules,
        latency: LatencySummary::from_cycles(&latencies),
        latency_by_class: [
            LatencySummary::from_cycles(&latencies_by_class[0]),
            LatencySummary::from_cycles(&latencies_by_class[1]),
            LatencySummary::from_cycles(&latencies_by_class[2]),
        ],
        per_model,
        per_device,
        cache: registry.stats().clone(),
        engine_compiles: engine::compile_count() - compiles0,
        wall_s,
        wall_ms: wall_s * 1e3,
        replay_requests_per_sec: if wall_s > 0.0 {
            feed.drawn as f64 / wall_s
        } else {
            0.0
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::CycleModel;

    fn mobilenet_pair() -> Vec<Workload> {
        vec![
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap(),
            Workload::synth("mobilenet_tiny", Method::TinyEngine, 8, 22).unwrap(),
        ]
    }

    fn small_cfg() -> ServeCfg {
        ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); 2],
            max_queue_depth: 2,
            ..ServeCfg::default()
        }
    }

    /// Compact report JSON with the host-timing fields zeroed — the
    /// bit-for-bit comparison key (wall time differs run to run; every
    /// virtual-time bit must not).
    fn dewalled(mut rep: ServeReport) -> String {
        rep.wall_s = 0.0;
        rep.wall_ms = 0.0;
        rep.replay_requests_per_sec = 0.0;
        rep.to_json().to_string_compact()
    }

    #[test]
    fn mixed_trace_completes_and_compiles_once() {
        let workloads = mobilenet_pair();
        let trace = synth_trace(&TraceCfg::new(24, 500_000, 5), workloads.len());
        let rep = run_trace(&workloads, &trace, &small_cfg()).unwrap();

        assert_eq!(rep.requests, 24);
        assert_eq!(
            rep.completed as u64 + rep.rejected_queue + rep.rejected_sram,
            24,
            "every request accounted for"
        );
        assert!(rep.completed > 0);
        // One registry lookup per request; compile-once per distinct model.
        assert_eq!(rep.cache.hits + rep.cache.misses, 24);
        assert_eq!(rep.cache.compiles, rep.cache.misses);
        assert!(rep.cache.compiles <= workloads.len() as u64);
        // No SLO classes in this trace: no deadline pressure.
        assert_eq!(rep.deadline_misses, 0);
        assert_eq!(rep.scheduler, "round-robin");
        // Latency and throughput sanity.
        assert!(rep.latency.p50_ms > 0.0);
        assert!(rep.latency.p50_ms <= rep.latency.p95_ms);
        assert!(rep.latency.p95_ms <= rep.latency.p99_ms);
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.makespan_cycles > 0);
        // Per-model accounting covers every completed request, and
        // per-tenant cache hits sum to the registry total.
        let sum: u64 = rep.per_model.iter().map(|m| m.requests).sum();
        assert_eq!(sum, rep.completed as u64);
        let hit_sum: u64 = rep.per_model.iter().map(|m| m.cache_hits).sum();
        assert_eq!(hit_sum, rep.cache.hits);
        // Fleet accounting agrees.
        let images: u64 = rep.per_device.iter().map(|d| d.images).sum();
        assert_eq!(images, rep.completed as u64);
        assert!(rep.per_device.iter().all(|d| d.class == "m7"));
        // Energy accounting: completed work costs joules, and the fleet
        // total is the per-device sum.
        assert!(rep.total_joules > 0.0);
        assert!(rep.joules_per_inference() > 0.0);
        let dev_sum: f64 = rep.per_device.iter().map(|d| d.joules).sum();
        assert!((rep.total_joules - dev_sum).abs() < 1e-12);
    }

    #[test]
    fn batching_amortizes_invocation_overhead() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 3).unwrap()];
        let mk_trace = |gap: u64| -> Vec<TraceRequest> {
            (0..8)
                // same inputs in both traces
                .map(|id| TraceRequest::best_effort(id, id as u64 * gap, 0, 1000 + id as u64))
                .collect()
        };
        let cfg = ServeCfg::homogeneous(1);
        // Burst: all 8 arrive within the batching window -> one batch.
        let burst = run_trace(&workloads, &mk_trace(1), &cfg).unwrap();
        // Spread: 10 ms apart -> every request rides alone.
        let spread = run_trace(&workloads, &mk_trace(2_160_000), &cfg).unwrap();

        assert_eq!(burst.completed, 8);
        assert_eq!(spread.completed, 8);
        assert_eq!(burst.per_model[0].batches, 1);
        assert_eq!(spread.per_model[0].batches, 8);
        assert!(burst.per_model[0].mean_batch() > spread.per_model[0].mean_batch());
        // Identical inference work; the difference is exactly the seven
        // saved per-invocation overheads.
        assert_eq!(
            spread.per_model[0].cycles - burst.per_model[0].cycles,
            7 * BATCH_OVERHEAD_CYCLES
        );
    }

    #[test]
    fn bounded_queue_sheds_under_burst() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let trace: Vec<TraceRequest> = (0..10)
            .map(|id| TraceRequest::best_effort(id, 0, 0, id as u64))
            .collect();
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746()],
            batcher: BatcherCfg {
                max_batch: 4,
                max_wait_cycles: 432_000,
                max_queue: 2,
                ..BatcherCfg::default()
            },
            ..ServeCfg::default()
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        // Queue holds 2; everything else in the simultaneous burst sheds
        // (the window never expires at t=0 and 2 < max_batch).
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.rejected_queue, 8);
        assert_eq!(rep.requests, 10);
    }

    #[test]
    fn replay_is_deterministic() {
        let workloads = mobilenet_pair();
        let trace = synth_trace(&TraceCfg::new(16, 300_000, 9), workloads.len());
        let a = run_trace(&workloads, &trace, &small_cfg()).unwrap();
        let b = run_trace(&workloads, &trace, &small_cfg()).unwrap();
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.latency.p99_ms, b.latency.p99_ms);
        assert_eq!(a.latency.mean_ms, b.latency.mean_ms);
        assert_eq!(a.cache.hits, b.cache.hits);
        let ca: Vec<u64> = a.per_model.iter().map(|m| m.cycles).collect();
        let cb: Vec<u64> = b.per_model.iter().map(|m| m.cycles).collect();
        assert_eq!(ca, cb);
    }

    #[test]
    fn sram_admission_rejects_oversized_tenant() {
        // A fleet of tiny devices cannot host the model at all.
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 6).unwrap()];
        let trace = synth_trace(&TraceCfg::new(5, 100_000, 2), 1);
        let tiny = DeviceCfg {
            sram_bytes: 16, // nothing fits
            ..DeviceCfg::stm32f746()
        };
        let cfg = ServeCfg {
            fleet: vec![tiny; 2],
            ..ServeCfg::default()
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        assert_eq!(rep.completed, 0);
        assert_eq!(rep.rejected_sram, 5);
        // Best-effort trace: no deadlines were lost to the SRAM gate.
        assert_eq!(rep.sram_deadline_misses(), 0);
        assert_eq!(rep.total_misses(), 0);

        // Deadline-classed traffic against the same gate: every lost
        // deadline must surface as an SLO miss (the SRAM-side twin of
        // the shed-accounting bugfix).
        let classed = synth_trace(&TraceCfg::new(5, 100_000, 2).with_slo([1.0, 0.0, 0.0]), 1);
        let rep = run_trace(&workloads, &classed, &cfg).unwrap();
        assert_eq!(rep.rejected_sram, 5);
        assert_eq!(rep.sram_deadline_by_class, [5, 0, 0]);
        assert_eq!(rep.sram_deadline_misses(), 5);
        assert_eq!(rep.class_misses(0), 5);
        assert_eq!(rep.total_misses(), 5, "the SRAM gate cannot hide lost deadlines");
    }

    // ------------------------------------------------------------------
    // Regression pin: the pre-scheduler homogeneous pipeline, transcribed
    // from the seed (global M7 cycle model, inline round-robin dispatch).
    // `RoundRobin` over an all-M7 fleet must reproduce it bit-for-bit.
    // ------------------------------------------------------------------

    struct LegacyDev {
        busy_until: u64,
        inflight: Vec<u64>,
        busy: u64,
        batches: u64,
        images: u64,
    }

    fn legacy_dispatch(
        devs: &mut [LegacyDev],
        rr_next: &mut usize,
        depth: usize,
        ready: u64,
        cost: u64,
        images: u64,
    ) -> u64 {
        let n = devs.len();
        let mut now = ready;
        loop {
            for off in 0..n {
                let idx = (*rr_next + off) % n;
                let d = &mut devs[idx];
                if d.inflight.iter().filter(|&&f| f > now).count() >= depth {
                    continue;
                }
                *rr_next = (idx + 1) % n;
                let start = now.max(d.busy_until);
                let finish = start + cost;
                d.busy_until = finish;
                d.inflight.retain(|&f| f > now);
                d.inflight.push(finish);
                d.busy += cost;
                d.batches += 1;
                d.images += images;
                return finish;
            }
            now = devs
                .iter()
                .flat_map(|d| d.inflight.iter().copied())
                .filter(|&f| f > now)
                .min()
                .expect("saturated fleet has in-flight work");
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn legacy_exec(
        mut batches: Vec<ReadyBatch>,
        pinned: &[Option<Arc<CompiledModel>>],
        images: &HashMap<usize, Vec<f32>>,
        devs: &mut [LegacyDev],
        rr_next: &mut usize,
        depth: usize,
        latencies: &mut Vec<u64>,
        makespan: &mut u64,
    ) {
        batches.sort_by_key(|b| (b.ready, b.key_idx));
        for batch in batches {
            let art = pinned[batch.key_idx].clone().unwrap();
            let mut run_cycles = 0u64;
            for r in &batch.requests {
                run_cycles += art.run(&images[&r.id]).unwrap().cycles;
            }
            let cost = BATCH_OVERHEAD_CYCLES + run_cycles;
            let finish = legacy_dispatch(
                devs,
                rr_next,
                depth,
                batch.ready,
                cost,
                batch.requests.len() as u64,
            );
            for r in &batch.requests {
                latencies.push(finish.saturating_sub(r.arrival));
            }
            *makespan = (*makespan).max(finish);
        }
    }

    /// Returns (makespan, latencies, per-device (batches, images, busy),
    /// shed).
    fn legacy_round_robin_replay(
        workloads: &[Workload],
        trace: &[TraceRequest],
        cfg: &ServeCfg,
    ) -> (u64, Vec<u64>, Vec<(u64, u64, u64)>, u64) {
        let mut registry = Registry::new(cfg.cache_capacity);
        let mut batcher = Batcher::new(cfg.batcher.clone(), workloads.len());
        let mut devs: Vec<LegacyDev> = (0..cfg.fleet.len())
            .map(|_| LegacyDev {
                busy_until: 0,
                inflight: Vec::new(),
                busy: 0,
                batches: 0,
                images: 0,
            })
            .collect();
        let mut rr_next = 0usize;
        let depth = cfg.max_queue_depth;
        let mut pinned: Vec<Option<Arc<CompiledModel>>> = vec![None; workloads.len()];
        // The pre-arena pipeline carried each image inside its pending
        // request; here a side table keyed by id plays that role.
        let mut images: HashMap<usize, Vec<f32>> = HashMap::new();
        let mut latencies = Vec::new();
        let mut makespan = 0u64;

        let mut order: Vec<&TraceRequest> = trace.iter().collect();
        order.sort_by_key(|r| (r.arrival, r.id));
        for req in order {
            legacy_exec(
                batcher.pop_due(req.arrival),
                &pinned,
                &images,
                &mut devs,
                &mut rr_next,
                depth,
                &mut latencies,
                &mut makespan,
            );
            let w = &workloads[req.key_idx];
            let art = registry
                .get_or_compile(&w.key, || {
                    CompiledModel::compile(&w.model, &w.params, &w.key.cfg, w.key.method)
                })
                .unwrap();
            pinned[req.key_idx] = Some(art.clone());
            assert!(art.peak_sram() <= crate::STM32F746_SRAM_BYTES);
            let image = datasets::generate(
                Task::for_backbone(&w.model.name),
                1,
                w.model.input_hw,
                req.seed,
            )
            .images;
            images.insert(req.id, image);
            batcher.offer(PendingRequest {
                id: req.id,
                key_idx: req.key_idx,
                arrival: req.arrival,
                priority: req.priority(),
                deadline: req.deadline,
            });
            legacy_exec(
                batcher.pop_due(req.arrival),
                &pinned,
                &images,
                &mut devs,
                &mut rr_next,
                depth,
                &mut latencies,
                &mut makespan,
            );
        }
        legacy_exec(
            batcher.drain_all(),
            &pinned,
            &images,
            &mut devs,
            &mut rr_next,
            depth,
            &mut latencies,
            &mut makespan,
        );
        let per_dev = devs.iter().map(|d| (d.batches, d.images, d.busy)).collect();
        (makespan, latencies, per_dev, batcher.shed)
    }

    #[test]
    fn round_robin_on_all_m7_matches_legacy_pipeline_bit_for_bit() {
        let workloads = mobilenet_pair();
        let trace = synth_trace(&TraceCfg::new(48, 400_000, 17), workloads.len());
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); 3],
            max_queue_depth: 2,
            ..ServeCfg::default()
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        let (makespan, latencies, per_dev, shed) =
            legacy_round_robin_replay(&workloads, &trace, &cfg);

        assert_eq!(rep.makespan_cycles, makespan);
        assert_eq!(rep.rejected_queue, shed);
        assert_eq!(rep.completed, latencies.len());
        let want = LatencySummary::from_cycles(&latencies);
        assert_eq!(rep.latency.p50_ms, want.p50_ms);
        assert_eq!(rep.latency.p95_ms, want.p95_ms);
        assert_eq!(rep.latency.p99_ms, want.p99_ms);
        assert_eq!(rep.latency.mean_ms, want.mean_ms);
        assert_eq!(rep.latency.max_ms, want.max_ms);
        for (d, (batches, images, busy)) in rep.per_device.iter().zip(&per_dev) {
            assert_eq!(d.batches, *batches, "device {} batches", d.id);
            assert_eq!(d.images, *images, "device {} images", d.id);
            assert_eq!(d.busy_cycles, *busy, "device {} busy cycles", d.id);
        }
    }

    #[test]
    fn heterogeneous_fleet_is_slower_than_all_m7() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap()];
        let trace = synth_trace(&TraceCfg::new(32, 500_000, 8), 1);
        // A deep queue cap keeps every device always eligible, so the
        // round-robin assignment sequence is identical across the two
        // fleets and the comparison isolates per-device pricing.
        let homo = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); 2],
            max_queue_depth: 64,
            ..ServeCfg::default()
        };
        let hetero = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
            max_queue_depth: 64,
            ..ServeCfg::default()
        };
        let a = run_trace(&workloads, &trace, &homo).unwrap();
        let b = run_trace(&workloads, &trace, &hetero).unwrap();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.per_device[1].batches, b.per_device[1].batches);
        assert!(b.per_device[1].busy_cycles > a.per_device[1].busy_cycles,
            "the M4 slot pays more timeline cycles for the same batches");
        assert!(b.makespan_cycles >= a.makespan_cycles);
        assert!(b.latency.mean_ms >= a.latency.mean_ms);
        assert_eq!(b.per_device[0].class, "m7");
        assert_eq!(b.per_device[1].class, "m4");
        // The model must actually fit the smaller part for this test to
        // exercise heterogeneous dispatch.
        assert!(b.per_device[1].images > 0);
    }

    #[test]
    fn slo_aware_strictly_beats_round_robin_on_hetero_deadlines() {
        // Constructed two-request scenario over [M7, M4]: round-robin
        // blindly alternates onto the M4 and misses the interactive
        // deadline; the SLO-aware policy predicts the miss with the M4's
        // own cycle model and keeps the request on the (busy) M7, which
        // still meets it.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap()];
        let art =
            CompiledModel::compile(&ws[0].model, &ws[0].params, &ws[0].key.cfg, ws[0].key.method)
                .unwrap();
        assert!(
            art.peak_sram() <= crate::STM32F446_SRAM_BYTES,
            "model must fit the M4 for the scenario to bite"
        );
        let img = datasets::generate(
            Task::for_backbone(&ws[0].model.name),
            1,
            ws[0].model.input_hw,
            777,
        )
        .images;
        let res = art.run(&img).unwrap();
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        let c7 = m7.timeline_cost(&res.counter);
        let c4 = m4.timeline_cost(&res.counter);
        assert!(c4 > c7, "the M4 must be strictly slower on the timeline");

        let trace = vec![
            TraceRequest {
                id: 0,
                arrival: 0,
                key_idx: 0,
                seed: 777,
                class: SloClass::Batch,
                deadline: u64::MAX,
            },
            TraceRequest {
                id: 1,
                arrival: c7,
                key_idx: 0,
                seed: 777,
                class: SloClass::Interactive,
                deadline: 2 * c7,
            },
        ];
        let mk = |scheduler: SchedulerKind| ServeCfg {
            fleet: vec![m7, m4],
            scheduler,
            max_queue_depth: 8,
            batcher: BatcherCfg {
                max_batch: 1,
                max_wait_cycles: 0,
                max_queue: 64,
                ..BatcherCfg::default()
            },
            ..ServeCfg::default()
        };
        let rr = run_trace(&ws, &trace, &mk(SchedulerKind::RoundRobin)).unwrap();
        let slo = run_trace(&ws, &trace, &mk(SchedulerKind::SloAware)).unwrap();
        assert_eq!(rr.completed, 2);
        assert_eq!(slo.completed, 2);
        assert_eq!(rr.deadline_misses, 1, "round-robin sends the tight request to the M4");
        assert_eq!(slo.deadline_misses, 0, "slo-aware keeps it on the M7");
        assert_eq!(slo.per_model[0].deadline_misses, 0);
        assert_eq!(rr.per_model[0].deadline_misses, 1);
    }

    #[test]
    fn energy_aware_cuts_fleet_joules_without_new_misses() {
        // Two best-effort requests over [M7, M4]: SLO-aware placement
        // chases the earliest finish (the M7 at least once), while
        // energy-aware placement routes deadline-free work to the
        // cheaper-in-joules M4 — strictly reducing fleet energy with
        // zero deadline impact (nothing here carries one).
        let ws = vec![Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap()];
        let trace = vec![
            TraceRequest::best_effort(0, 0, 0, 777),
            TraceRequest::best_effort(1, 0, 0, 778),
        ];
        let mk = |scheduler: SchedulerKind| ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
            scheduler,
            max_queue_depth: 8,
            batcher: BatcherCfg {
                max_batch: 1,
                max_wait_cycles: 0,
                max_queue: 64,
                ..BatcherCfg::default()
            },
            ..ServeCfg::default()
        };
        let slo = run_trace(&ws, &trace, &mk(SchedulerKind::SloAware)).unwrap();
        let energy = run_trace(&ws, &trace, &mk(SchedulerKind::EnergyAware)).unwrap();
        assert_eq!(slo.completed, 2);
        assert_eq!(energy.completed, 2);
        assert_eq!(energy.scheduler, "energy-aware");
        // SLO-aware sends the first (idle-fleet) batch to the faster
        // M7; energy-aware concentrates both on the efficient M4.
        assert!(slo.per_device[0].images >= 1, "slo-aware uses the M7");
        assert_eq!(energy.per_device[1].images, 2, "energy-aware uses the M4");
        assert_eq!(energy.per_device[0].images, 0);
        assert!(
            energy.total_joules < slo.total_joules,
            "energy {} J vs slo {} J",
            energy.total_joules,
            slo.total_joules
        );
        // No deadline was traded away for the savings.
        assert_eq!(slo.total_misses(), 0);
        assert_eq!(energy.total_misses(), 0);
        // The saving shows up per inference too.
        assert!(energy.joules_per_inference() < slo.joules_per_inference());
    }

    #[test]
    fn higher_priority_batch_dispatches_first_on_ready_ties() {
        // Two tenants' partial batches expire at the same virtual cycle
        // on a single device; the interactive one must run first even
        // though its tenant index sorts later. Its deadline is exactly
        // first-place finish, so a key-ordered dispatch would miss it.
        let ws = vec![
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 33).unwrap(),
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 33).unwrap(),
        ];
        let art =
            CompiledModel::compile(&ws[0].model, &ws[0].params, &ws[0].key.cfg, ws[0].key.method)
                .unwrap();
        let img = datasets::generate(
            Task::for_backbone(&ws[0].model.name),
            1,
            ws[0].model.input_hw,
            777,
        )
        .images;
        let res = art.run(&img).unwrap();
        let cost = DeviceCfg::stm32f746().timeline_cost(&res.counter);
        let wait = 432_000u64;

        let trace = vec![
            TraceRequest {
                id: 0,
                arrival: 0,
                key_idx: 0,
                seed: 777,
                class: SloClass::Batch,
                deadline: u64::MAX,
            },
            TraceRequest {
                id: 1,
                arrival: 0,
                key_idx: 1,
                seed: 777,
                class: SloClass::Interactive,
                deadline: wait + cost,
            },
        ];
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746()],
            batcher: BatcherCfg {
                max_batch: 8,
                max_wait_cycles: wait,
                max_queue: 64,
                ..BatcherCfg::default()
            },
            ..ServeCfg::default()
        };
        let rep = run_trace(&ws, &trace, &cfg).unwrap();
        assert_eq!(rep.completed, 2);
        assert_eq!(rep.makespan_cycles, wait + 2 * cost);
        assert_eq!(
            rep.deadline_misses, 0,
            "the interactive batch must win the same-ready tie"
        );
    }

    #[test]
    fn least_loaded_balances_like_round_robin_on_uniform_load() {
        let workloads = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 12).unwrap()];
        let trace = synth_trace(&TraceCfg::new(20, 100_000, 6), 1);
        let cfg = ServeCfg {
            scheduler: SchedulerKind::LeastLoaded,
            ..ServeCfg::homogeneous(2)
        };
        let rep = run_trace(&workloads, &trace, &cfg).unwrap();
        assert_eq!(rep.scheduler, "least-loaded");
        assert_eq!(rep.completed as u64 + rep.rejected_queue, 20);
        // Both devices share the work (least-loaded alternates as each
        // dispatch makes the chosen device the busier one).
        assert!(rep.per_device.iter().all(|d| d.batches > 0));
    }

    #[test]
    fn identical_param_tenants_share_one_artifact_in_replay() {
        // Two tenants, same backbone/method/bits AND same synth seed:
        // identical parameters, one shared compiled artifact.
        let ws = vec![
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 33).unwrap(),
            Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 33).unwrap(),
        ];
        assert_eq!(ws[0].key, ws[1].key, "identical tenants must key identically");
        let trace: Vec<TraceRequest> = (0..8)
            .map(|id| TraceRequest::best_effort(id, id as u64 * 1_000_000, id % 2, 50 + id as u64))
            .collect();
        let rep = run_trace(&ws, &trace, &ServeCfg::homogeneous(2)).unwrap();
        assert_eq!(rep.cache.compiles, 1, "one compilation serves both tenants");
        assert_eq!(rep.cache.misses, 1);
        assert_eq!(rep.cache.hits, 7);
        // Tenant 0's first lookup compiled the entry; tenant 1's four
        // requests all hit it cross-tenant.
        assert_eq!(rep.cache.shared_hits, 4);
        assert_eq!(rep.completed, 8);
        // Hits are attributed per tenant even though the two tenants
        // share one registry entry (and one label).
        assert_eq!(rep.per_model[0].cache_hits, 3);
        assert_eq!(rep.per_model[1].cache_hits, 4);
    }

    #[test]
    fn recorded_trace_replays_identically_from_file() {
        let workloads = mobilenet_pair();
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
            scheduler: SchedulerKind::SloAware,
            ..ServeCfg::default()
        };
        let trace = synth_trace(
            &TraceCfg::new(24, 350_000, 19).with_skew(1.0).with_slo([1.0, 1.0, 1.0]),
            workloads.len(),
        );
        let path = std::env::temp_dir().join("mcu_mixq_serve_trace_replay.json");
        save_trace(&path, &trace).unwrap();
        let loaded = load_trace(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(trace, loaded);

        let a = run_trace(&workloads, &trace, &cfg).unwrap();
        let b = run_trace(&workloads, &loaded, &cfg).unwrap();
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.deadline_misses, b.deadline_misses);
        assert_eq!(a.latency.p99_ms, b.latency.p99_ms);
    }

    #[test]
    fn concurrent_replays_with_private_scratch_stay_deterministic() {
        // Each replay owns its ConvScratch, so simulations running on
        // different threads (or interleaved on a pool) must agree with a
        // sequential run exactly.
        fn replay() -> (u64, f64, usize) {
            let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 7).unwrap()];
            let trace = synth_trace(&TraceCfg::new(10, 150_000, 4), 1);
            let rep = run_trace(&ws, &trace, &ServeCfg::homogeneous(2)).unwrap();
            (rep.makespan_cycles, rep.latency.p99_ms, rep.completed)
        }
        let base = replay();
        let handles: Vec<_> = (0..2).map(|_| std::thread::spawn(replay)).collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), base);
        }
    }

    #[test]
    fn device_cycle_models_are_per_class() {
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        assert_eq!(m7.cycle_model, CycleModel::cortex_m7());
        assert_eq!(m4.cycle_model, CycleModel::cortex_m4());
        assert!(m4.sram_bytes < m7.sram_bytes);
        assert!(m4.clock_hz < m7.clock_hz);
    }

    // ------------------------------------------------------------------
    // Overload resilience: class-aware admission, preemption, stealing
    // ------------------------------------------------------------------

    /// An overload burst of 6 batch-class + 4 interactive requests, all
    /// at t=0, against a queue bounded at 4.
    fn overload_trace() -> Vec<TraceRequest> {
        let mut trace: Vec<TraceRequest> = (0..6)
            .map(|id| TraceRequest::best_effort(id, 0, 0, 100 + id as u64))
            .collect();
        for id in 6..10 {
            trace.push(TraceRequest {
                id,
                arrival: 0,
                key_idx: 0,
                seed: 100 + id as u64,
                class: SloClass::Interactive,
                deadline: 1 << 40, // generous: any completion meets it
            });
        }
        trace
    }

    fn overload_cfg(admission: AdmissionKind) -> ServeCfg {
        ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(); 2],
            batcher: BatcherCfg {
                max_batch: 16,
                max_wait_cycles: 1000,
                max_queue: 4,
                admission,
                preempt: false,
            },
            ..ServeCfg::default()
        }
    }

    #[test]
    fn class_admission_sheds_batch_class_first_under_overload() {
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let trace = overload_trace();
        let fifo = run_trace(&ws, &trace, &overload_cfg(AdmissionKind::Fifo)).unwrap();
        let class = run_trace(&ws, &trace, &overload_cfg(AdmissionKind::ClassAware)).unwrap();

        // FIFO sheds arrival order: the late-arriving interactive burst
        // loses its deadlines while the earlier batch-class work rides.
        assert_eq!(fifo.admission, "fifo");
        assert_eq!(fifo.completed, 4);
        assert_eq!(fifo.rejected_queue, 6);
        assert_eq!(fifo.shed_by_class, [4, 0, 2]);
        assert_eq!(fifo.shed_deadline_by_class, [4, 0, 0]);
        assert_eq!(fifo.class_misses(0), 4, "four interactive deadlines lost to shedding");

        // Class-aware admission evicts batch-class work instead: every
        // interactive request survives and meets its deadline.
        assert_eq!(class.admission, "class");
        assert_eq!(class.completed, 4);
        assert_eq!(class.rejected_queue, 6);
        assert_eq!(class.shed_by_class, [0, 0, 6]);
        assert_eq!(class.shed_deadline_by_class, [0, 0, 0]);
        assert_eq!(class.class_misses(0), 0);
        assert!(
            class.class_misses(0) < fifo.class_misses(0),
            "class-aware admission strictly cuts interactive misses"
        );

        // Both disciplines conserve requests.
        for rep in [&fifo, &class] {
            assert_eq!(
                rep.completed as u64 + rep.rejected_queue + rep.rejected_sram,
                trace.len() as u64
            );
            assert_eq!(rep.shed_by_class.iter().sum::<u64>(), rep.rejected_queue);
        }
    }

    #[test]
    fn shed_deadline_requests_surface_as_slo_misses() {
        // Regression (ISSUE 4): `rejected_queue = batcher.shed` used to
        // be the only trace a shed deadline left — overload *improved*
        // the reported miss rate. Deadline-carrying sheds now count.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let rep = run_trace(&ws, &overload_trace(), &overload_cfg(AdmissionKind::Fifo)).unwrap();
        assert_eq!(rep.deadline_misses, 0, "every *completed* request met its deadline");
        assert_eq!(rep.shed_deadline_misses(), 4);
        assert_eq!(rep.total_misses(), 4, "overload can no longer hide misses");
    }

    #[test]
    fn throughput_is_measured_from_the_first_arrival() {
        // Regression (ISSUE 4): a recorded trace whose arrivals start
        // late used to deflate throughput (makespan measured from cycle
        // 0). A pure time shift must not change throughput or latency.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let mk = |shift: u64| -> Vec<TraceRequest> {
            (0..6)
                .map(|id| {
                    TraceRequest::best_effort(id, shift + id as u64 * 100_000, 0, 300 + id as u64)
                })
                .collect()
        };
        let cfg = ServeCfg::homogeneous(2);
        let base = run_trace(&ws, &mk(0), &cfg).unwrap();
        let late = run_trace(&ws, &mk(5_000_000_000), &cfg).unwrap();
        assert_eq!(late.first_arrival_cycles, 5_000_000_000);
        assert_eq!(base.span_cycles(), late.span_cycles());
        assert_eq!(base.throughput_rps, late.throughput_rps);
        assert!(late.throughput_rps > 0.0);
        assert_eq!(base.latency.mean_ms, late.latency.mean_ms);
        assert_eq!(base.latency.p99_ms, late.latency.p99_ms);
        // Device utilization shares the first-arrival epoch, so it is
        // shift-invariant too.
        for (a, b) in base.per_device.iter().zip(&late.per_device) {
            assert_eq!(a.utilization, b.utilization, "device {} utilization", a.id);
        }
        assert_eq!(
            late.makespan_cycles,
            base.makespan_cycles + 5_000_000_000,
            "the timeline itself shifts; only the span is invariant"
        );
    }

    #[test]
    fn preemptive_flush_beats_deadline_for_lone_interactive_request() {
        // One interactive request whose deadline dies before its waiting
        // window would expire: without preemption it flushes at the
        // window and misses; with preemption it flushes on arrival and
        // meets the deadline.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap()];
        let art =
            CompiledModel::compile(&ws[0].model, &ws[0].params, &ws[0].key.cfg, ws[0].key.method)
                .unwrap();
        let img = datasets::generate(
            Task::for_backbone(&ws[0].model.name),
            1,
            ws[0].model.input_hw,
            777,
        )
        .images;
        let cost = DeviceCfg::stm32f746().timeline_cost(&art.run(&img).unwrap().counter);
        let wait = 2 * cost;
        let trace = vec![TraceRequest {
            id: 0,
            arrival: 0,
            key_idx: 0,
            seed: 777,
            class: SloClass::Interactive,
            deadline: wait, // window expiry alone already spends it all
        }];
        let mk = |preempt: bool| ServeCfg {
            fleet: vec![DeviceCfg::stm32f746()],
            batcher: BatcherCfg {
                max_batch: 8,
                max_wait_cycles: wait,
                max_queue: 64,
                admission: AdmissionKind::Fifo,
                preempt,
            },
            ..ServeCfg::default()
        };
        let lazy = run_trace(&ws, &trace, &mk(false)).unwrap();
        assert_eq!(lazy.completed, 1);
        assert_eq!(lazy.deadline_misses, 1, "waiting out the window misses");
        assert_eq!(lazy.miss_by_class, [1, 0, 0]);
        assert_eq!(lazy.preempt_flushes, 0);

        let eager = run_trace(&ws, &trace, &mk(true)).unwrap();
        assert_eq!(eager.completed, 1);
        assert_eq!(eager.deadline_misses, 0, "the preemptive flush meets the deadline");
        assert_eq!(eager.preempt_flushes, 1);
        assert_eq!(eager.makespan_cycles, cost, "dispatched at arrival, not at the window");
    }

    #[test]
    fn steal_mode_conserves_results_and_stays_deterministic() {
        // Work stealing may re-place batches but must not change *what*
        // was computed: same completions, same per-model request
        // counts, same fleet-wide image totals — and the replay stays
        // bit-reproducible.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::RpSlbc, 4, 21).unwrap()];
        let trace = synth_trace(
            &TraceCfg::new(24, 100_000, 5)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(8, 4),
            1,
        );
        let mk = |steal: bool| ServeCfg {
            fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
            scheduler: SchedulerKind::LeastLoaded,
            steal,
            ..ServeCfg::default()
        };
        let plain = run_trace(&ws, &trace, &mk(false)).unwrap();
        let stealing = run_trace(&ws, &trace, &mk(true)).unwrap();
        assert_eq!(plain.completed, stealing.completed);
        assert_eq!(plain.rejected_queue, stealing.rejected_queue);
        assert_eq!(plain.per_model[0].requests, stealing.per_model[0].requests);
        assert_eq!(plain.per_model[0].batches, stealing.per_model[0].batches);
        let images = |r: &ServeReport| r.per_device.iter().map(|d| d.images).sum::<u64>();
        assert_eq!(images(&plain), images(&stealing));
        assert_eq!(plain.migrations, 0, "stealing off migrates nothing");

        let again = run_trace(&ws, &trace, &mk(true)).unwrap();
        assert_eq!(stealing.makespan_cycles, again.makespan_cycles);
        assert_eq!(stealing.migrations, again.migrations);
        assert_eq!(stealing.latency.p99_ms, again.latency.p99_ms);
        assert_eq!(stealing.deadline_misses, again.deadline_misses);
        for (a, b) in stealing.per_device.iter().zip(&again.per_device) {
            assert_eq!(a.batches, b.batches);
            assert_eq!(a.migrations, b.migrations);
        }
    }

    // ------------------------------------------------------------------
    // Observability: event streams, metrics, and passivity
    // ------------------------------------------------------------------

    #[test]
    fn event_stream_rederives_report_accounting() {
        use crate::obs::{derive_class_misses, RingRecorder};
        let ws = mobilenet_pair();
        let trace = synth_trace(
            &TraceCfg::new(24, 100_000, 5)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(8, 4),
            ws.len(),
        );
        for steal in [false, true] {
            let cfg = ServeCfg {
                scheduler: SchedulerKind::LeastLoaded,
                steal,
                ..small_cfg()
            };
            let mut rec = RingRecorder::new(1 << 16);
            let rep = run_trace_observed(&ws, &trace, &cfg, &mut rec, None).unwrap();
            assert_eq!(rec.dropped, 0, "ring must hold the whole stream");
            let events = rec.into_events();

            // Every trace request arrives exactly once.
            let arrives = events
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Arrive { .. }))
                .count();
            assert_eq!(arrives, trace.len(), "steal={steal}");

            // Every completion is a Finish with a matching Start and
            // Place for the same request id.
            let finishes: Vec<&Event> = events
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Finish { .. }))
                .collect();
            assert_eq!(finishes.len(), rep.completed, "steal={steal}");
            for f in &finishes {
                assert!(
                    events
                        .iter()
                        .any(|e| e.id == f.id && matches!(e.kind, EventKind::Start { .. })),
                    "Finish #{} without Start (steal={steal})",
                    f.id
                );
                assert!(
                    events
                        .iter()
                        .any(|e| e.id == f.id && matches!(e.kind, EventKind::Place { .. })),
                    "Finish #{} without Place (steal={steal})",
                    f.id
                );
            }

            // The ISSUE's acceptance invariant: per-class misses derived
            // from events alone equal the report's accounting exactly.
            let derived = derive_class_misses(&events);
            assert_eq!(
                derived,
                [rep.class_misses(0), rep.class_misses(1), rep.class_misses(2)],
                "steal={steal}"
            );
            assert_eq!(derived.iter().sum::<u64>(), rep.total_misses());

            // Migrations in the stream match the fleet's count, and the
            // queue-wait/compute split partitions the completed misses.
            let migs = events
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Migrate { .. }))
                .count() as u64;
            assert_eq!(migs, rep.migrations, "steal={steal}");
            assert_eq!(rep.miss_queue_wait + rep.miss_compute, rep.deadline_misses);
        }
    }

    #[test]
    fn recorder_attachment_is_passive() {
        use crate::obs::{MetricsRegistry, RingRecorder};
        let ws = mobilenet_pair();
        let trace = synth_trace(
            &TraceCfg::new(24, 350_000, 19).with_slo([1.0, 1.0, 1.0]),
            ws.len(),
        );
        // The RoundRobin/all-M7 legacy pin runs without a recorder; this
        // pins the other direction — attaching a recorder and metrics
        // must not move a single report bit (wall_s excepted).
        let cfg = small_cfg();
        let plain = run_trace(&ws, &trace, &cfg).unwrap();
        let mut rec = RingRecorder::new(4096);
        let mut metrics = MetricsRegistry::new(216_000);
        let observed =
            run_trace_observed(&ws, &trace, &cfg, &mut rec, Some(&mut metrics)).unwrap();
        assert_eq!(dewalled(plain), dewalled(observed));
        assert!(!rec.is_empty());
        assert_eq!(metrics.counter("requests"), trace.len() as u64);
        assert!(metrics.series("queue_depth").is_some());
        assert!(metrics.series("util_dev0").is_some());
        assert!(metrics.histogram("latency_cycles").is_some());
    }

    #[test]
    fn per_class_latency_and_miss_attribution_are_consistent() {
        let ws = mobilenet_pair();
        let trace = synth_trace(
            &TraceCfg::new(24, 100_000, 5)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(8, 4),
            ws.len(),
        );
        let rep = run_trace(&ws, &trace, &small_cfg()).unwrap();
        // Per-class completion counts sum to the overall count.
        let class_total: u64 = (0..3).map(|i| rep.latency_by_class[i].count).sum();
        assert_eq!(class_total, rep.completed as u64);
        // Each class's extremes bound the global ones.
        for s in &rep.latency_by_class {
            if s.count > 0 {
                assert!(s.max_ms <= rep.latency.max_ms);
                assert!(s.p50_ms >= 0.0);
            }
        }
        assert_eq!(rep.miss_queue_wait + rep.miss_compute, rep.deadline_misses);
    }

    #[test]
    fn every_policy_combination_conserves_requests() {
        // Property-style sweep: scheduler x admission x steal (with
        // preemption on) must account for every trace request exactly
        // once — completed, queue-shed, or SRAM-rejected.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let trace = synth_trace(
            &TraceCfg::new(14, 80_000, 9)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(7, 5),
            1,
        );
        for sched in SchedulerKind::ALL {
            for admission in AdmissionKind::ALL {
                for steal in [false, true] {
                    let cfg = ServeCfg {
                        fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
                        scheduler: sched,
                        batcher: BatcherCfg {
                            max_batch: 4,
                            max_wait_cycles: 432_000,
                            max_queue: 6,
                            admission,
                            preempt: true,
                        },
                        steal,
                        ..ServeCfg::default()
                    };
                    let rep = run_trace(&ws, &trace, &cfg).unwrap();
                    let label = format!(
                        "sched {} admission {} steal {}",
                        sched.name(),
                        admission.name(),
                        steal
                    );
                    assert_eq!(
                        rep.completed as u64 + rep.rejected_queue + rep.rejected_sram,
                        trace.len() as u64,
                        "conservation violated under {label}"
                    );
                    assert_eq!(
                        rep.shed_by_class.iter().sum::<u64>(),
                        rep.rejected_queue,
                        "per-class shed accounting out of sync under {label}"
                    );
                    let images: u64 = rep.per_device.iter().map(|d| d.images).sum();
                    assert_eq!(images, rep.completed as u64, "fleet images mismatch under {label}");
                    let reqs: u64 = rep.per_model.iter().map(|m| m.requests).sum();
                    assert_eq!(reqs, rep.completed as u64, "per-model mismatch under {label}");
                }
            }
        }
    }

    #[test]
    fn empty_fleet_event_stream_is_the_plain_replay() {
        // The API contract behind the bit-for-bit pin: no events and no
        // autoscaler means churn mode never engages, so run_trace_full
        // IS run_trace — same report, zero churn accounting.
        let ws = mobilenet_pair();
        let trace = synth_trace(
            &TraceCfg::new(20, 250_000, 7).with_slo([1.0, 1.0, 1.0]),
            ws.len(),
        );
        let cfg = small_cfg();
        let a = run_trace(&ws, &trace, &cfg).unwrap();
        let b = run_trace_full(&ws, &trace, &[], &cfg).unwrap();
        assert_eq!(dewalled(a.clone()), dewalled(b));
        assert_eq!(a.crashes, 0);
        assert_eq!(a.lost, 0);
        assert_eq!(a.readmissions(), 0);
        assert_eq!(a.autoscale_ups + a.autoscale_downs, 0);
    }

    #[test]
    fn churned_replay_conserves_requests_and_balances_events() {
        // Satellite 4's property test: over random churn traces, every
        // request lands in exactly one terminal bucket —
        //   completed + queue-shed + SRAM-rejected + lost == admitted —
        // every crash re-admission appears exactly once in the event
        // stream, and per-class misses derived from events alone still
        // equal the report's accounting.
        use crate::obs::{derive_class_misses, RingRecorder};
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let mut churn_effects = 0u64;
        for seed in [1u64, 2, 3] {
            let tc = TraceCfg::new(28, 120_000, seed)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(7, 4)
                .with_churn(0.5);
            let trace = synth_trace(&tc, 1);
            let fleet = vec![
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f446(),
            ];
            let events = synth_fleet_events(&tc, &trace, fleet.len());
            assert!(!events.is_empty(), "seed {seed} produced no churn");
            let cfg = ServeCfg {
                fleet,
                batcher: BatcherCfg {
                    max_batch: 4,
                    max_wait_cycles: 432_000,
                    max_queue: 6,
                    admission: AdmissionKind::ClassAware,
                    preempt: true,
                },
                ..ServeCfg::default()
            };
            let mut rec = RingRecorder::new(1 << 16);
            let rep =
                run_trace_full_observed(&ws, &trace, &events, &cfg, &mut rec, None).unwrap();
            assert_eq!(rec.dropped, 0);
            let evs = rec.into_events();

            // Conservation: no request vanishes, no request is double-
            // counted, under arbitrary churn.
            assert_eq!(
                rep.completed as u64 + rep.rejected_queue + rep.rejected_sram + rep.lost,
                trace.len() as u64,
                "conservation violated at seed {seed}"
            );
            let images: u64 = rep.per_device.iter().map(|d| d.images).sum();
            assert_eq!(images, rep.completed as u64, "seed {seed}");
            let reqs: u64 = rep.per_model.iter().map(|m| m.requests).sum();
            assert_eq!(reqs, rep.completed as u64, "seed {seed}");

            // Event/report balance: one Readmit per re-admission, one
            // Lost per lost request.
            let readmits: Vec<&Event> = evs
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Readmit { .. }))
                .collect();
            assert_eq!(readmits.len() as u64, rep.readmissions(), "seed {seed}");
            let losts = evs
                .iter()
                .filter(|e| matches!(e.kind, EventKind::Lost { .. }))
                .count() as u64;
            assert_eq!(losts, rep.lost, "seed {seed}");

            // Each re-admission is unique (no double re-admission of one
            // cancellation) and refers to a request that actually
            // arrived.
            let mut seen = std::collections::HashSet::new();
            for r in &readmits {
                assert!(
                    seen.insert((r.id, r.cycles)),
                    "duplicate re-admission of #{} at {} (seed {seed})",
                    r.id,
                    r.cycles
                );
                assert!(
                    evs.iter()
                        .any(|e| e.id == r.id && matches!(e.kind, EventKind::Arrive { .. })),
                    "re-admitted #{} never arrived (seed {seed})",
                    r.id
                );
            }

            // Crashes in the stream match the report.
            let downs = evs
                .iter()
                .filter(|e| matches!(e.kind, EventKind::DeviceDown { crashed: true, .. }))
                .count() as u64;
            assert_eq!(downs, rep.crashes, "seed {seed}");

            // The rejection-and-loss-inclusive miss accounting still
            // rederives from the event stream alone.
            let derived = derive_class_misses(&evs);
            assert_eq!(
                derived,
                [rep.class_misses(0), rep.class_misses(1), rep.class_misses(2)],
                "seed {seed}"
            );

            // Determinism: same trace + events, same report.
            let again = run_trace_full(&ws, &trace, &events, &cfg).unwrap();
            assert_eq!(
                dewalled(rep.clone()),
                dewalled(again),
                "churned replay not deterministic at seed {seed}"
            );
            churn_effects += rep.readmissions() + rep.lost + rep.crashes;
        }
        assert!(
            churn_effects > 0,
            "three churned seeds produced zero observable churn"
        );
    }

    #[test]
    fn autoscaler_grows_under_interactive_pressure_within_joules_budget() {
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        // All-interactive bursts against a single M7: the predicted-miss
        // window runs hot almost immediately.
        let trace = synth_trace(
            &TraceCfg::new(32, 40_000, 11)
                .with_slo([1.0, 0.0, 0.0])
                .with_burst(8, 6),
            1,
        );
        let asc = AutoscaleCfg {
            standby: vec![DeviceCfg::stm32f746()],
            miss_window: 8,
            grow_rate: 0.25,
            shrink_rate: 0.0,
            joules_budget: f64::INFINITY,
            cooldown: 4,
        };
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746()],
            batcher: BatcherCfg {
                max_batch: 4,
                max_wait_cycles: 432_000,
                max_queue: 4,
                admission: AdmissionKind::ClassAware,
                preempt: true,
            },
            autoscale: Some(asc.clone()),
            ..ServeCfg::default()
        };
        let rep = run_trace_full(&ws, &trace, &[], &cfg).unwrap();
        assert!(
            rep.autoscale_ups >= 1,
            "hot window never grew the fleet: {}",
            rep.render()
        );
        // The standby device is part of the report once joined.
        assert_eq!(rep.per_device.len(), 2);
        assert_eq!(
            rep.completed as u64 + rep.rejected_queue + rep.rejected_sram + rep.lost,
            trace.len() as u64
        );

        // A zero joules budget forbids growth entirely.
        let cfg0 = ServeCfg {
            autoscale: Some(AutoscaleCfg {
                joules_budget: 0.0,
                ..asc
            }),
            ..cfg.clone()
        };
        let rep0 = run_trace_full(&ws, &trace, &[], &cfg0).unwrap();
        assert_eq!(rep0.autoscale_ups, 0, "grew past a zero joules budget");
        // Growth helped: the scaled fleet misses no more interactive
        // deadlines than the budget-frozen one.
        assert!(
            rep.class_misses(0) <= rep0.class_misses(0),
            "scaling up worsened interactive misses: {} vs {}",
            rep.class_misses(0),
            rep0.class_misses(0)
        );
    }

    #[test]
    fn event_loop_replay_is_bit_identical_to_the_legacy_scan_loop() {
        // The tentpole equivalence property: the event-heap replay core
        // (probe counters, wake/due/pick indices — the default) and the
        // pre-refactor linear-scan core (`legacy_loop`: per-image
        // inference, full scans) must agree on every report bit, across
        // the four CI bench shapes, three seeds each.
        let ws = mobilenet_pair();
        let tight_batcher = BatcherCfg {
            max_batch: 4,
            max_wait_cycles: 432_000,
            max_queue: 6,
            admission: AdmissionKind::ClassAware,
            preempt: true,
        };
        for seed in [5u64, 6, 7] {
            let mut scenarios: Vec<(String, ServeCfg, Vec<TraceRequest>, Vec<FleetEvent>)> =
                Vec::new();

            // Canonical: mixed SLO classes, RoundRobin, all-M7 fleet.
            let tc = TraceCfg::new(40, 150_000, seed).with_slo([0.3, 0.4, 0.3]);
            scenarios.push((
                format!("canonical/{seed}"),
                ServeCfg {
                    fleet: vec![DeviceCfg::stm32f746(); 3],
                    ..ServeCfg::default()
                },
                synth_trace(&tc, ws.len()),
                Vec::new(),
            ));

            // Overload: bursts, class-aware shedding, preemption, steal,
            // SloAware placement on a mixed fleet.
            let tc = TraceCfg::new(40, 60_000, seed)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(8, 5);
            scenarios.push((
                format!("overload/{seed}"),
                ServeCfg {
                    fleet: vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446()],
                    scheduler: SchedulerKind::SloAware,
                    batcher: tight_batcher.clone(),
                    steal: true,
                    ..ServeCfg::default()
                },
                synth_trace(&tc, ws.len()),
                Vec::new(),
            ));

            // Energy: EnergyAware pricing over a heterogeneous fleet.
            let tc = TraceCfg::new(40, 200_000, seed).with_slo([0.5, 0.5, 0.0]);
            scenarios.push((
                format!("energy/{seed}"),
                ServeCfg {
                    fleet: vec![
                        DeviceCfg::stm32f746(),
                        DeviceCfg::stm32f446(),
                        DeviceCfg::stm32f446(),
                    ],
                    scheduler: SchedulerKind::EnergyAware,
                    ..ServeCfg::default()
                },
                synth_trace(&tc, ws.len()),
                Vec::new(),
            ));

            // Churn: a fault-injection stream rides the trace, so crash
            // re-admission, loss and drain-migration all exercise.
            let tc = TraceCfg::new(40, 120_000, seed)
                .with_slo([1.0, 1.0, 1.0])
                .with_burst(7, 4)
                .with_churn(0.5);
            let trace = synth_trace(&tc, ws.len());
            let fleet = vec![
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f746(),
                DeviceCfg::stm32f446(),
            ];
            let events = synth_fleet_events(&tc, &trace, fleet.len());
            scenarios.push((
                format!("churn/{seed}"),
                ServeCfg {
                    fleet,
                    batcher: tight_batcher.clone(),
                    ..ServeCfg::default()
                },
                trace,
                events,
            ));

            for (label, cfg, trace, events) in scenarios {
                let fast = run_trace_full(&ws, &trace, &events, &cfg).unwrap();
                assert_eq!(fast.requests, trace.len(), "{label}");
                let legacy_cfg = ServeCfg {
                    legacy_loop: true,
                    ..cfg
                };
                let legacy = run_trace_full(&ws, &trace, &events, &legacy_cfg).unwrap();
                assert_eq!(
                    dewalled(fast),
                    dewalled(legacy),
                    "{label}: event-loop replay diverged from the scan loop"
                );
            }
        }
    }

    #[test]
    fn autoscaler_decisions_survive_the_event_loop_refactor() {
        // The incremental window bookkeeping (running miss count, cached
        // fleet joules) must reproduce the rescanning autoscaler's
        // grow/shrink sequence exactly — pinned on a scenario that
        // actually grows.
        let ws = vec![Workload::synth("mobilenet_tiny", Method::Slbc, 4, 4).unwrap()];
        let trace = synth_trace(
            &TraceCfg::new(32, 40_000, 11)
                .with_slo([1.0, 0.0, 0.0])
                .with_burst(8, 6),
            1,
        );
        let cfg = ServeCfg {
            fleet: vec![DeviceCfg::stm32f746()],
            batcher: BatcherCfg {
                max_batch: 4,
                max_wait_cycles: 432_000,
                max_queue: 4,
                admission: AdmissionKind::ClassAware,
                preempt: true,
            },
            autoscale: Some(AutoscaleCfg {
                standby: vec![DeviceCfg::stm32f746()],
                miss_window: 8,
                grow_rate: 0.25,
                shrink_rate: 0.02,
                joules_budget: f64::INFINITY,
                cooldown: 4,
            }),
            ..ServeCfg::default()
        };
        let fast = run_trace_full(&ws, &trace, &[], &cfg).unwrap();
        let legacy = run_trace_full(
            &ws,
            &trace,
            &[],
            &ServeCfg {
                legacy_loop: true,
                ..cfg
            },
        )
        .unwrap();
        assert!(fast.autoscale_ups >= 1, "scenario must exercise growth");
        assert_eq!(fast.autoscale_ups, legacy.autoscale_ups, "grow decisions moved");
        assert_eq!(fast.autoscale_downs, legacy.autoscale_downs, "shrink decisions moved");
        assert_eq!(dewalled(fast), dewalled(legacy));
    }

    #[test]
    fn streamed_jsonl_replay_matches_the_slice_replay() {
        // End-to-end streaming: a JSON-lines trace file replayed through
        // `TraceSource::open` (one request in memory at a time) produces
        // the same report as the in-memory slice replay.
        let ws = mobilenet_pair();
        let trace = synth_trace(
            &TraceCfg::new(24, 200_000, 13).with_slo([0.5, 0.5, 0.0]),
            ws.len(),
        );
        let cfg = small_cfg();
        let baseline = run_trace(&ws, &trace, &cfg).unwrap();

        let path = std::env::temp_dir().join(format!(
            "mcu_mixq_streamed_replay_{}.jsonl",
            std::process::id()
        ));
        save_trace_jsonl(&path, &trace).unwrap();
        let streamed = run_trace_source(&ws, TraceSource::open(&path).unwrap(), &cfg).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(streamed.requests, trace.len());
        assert_eq!(dewalled(baseline), dewalled(streamed));

        // An out-of-order source is rejected, never silently misreplayed.
        let mut shuffled = trace.clone();
        let last = shuffled.len() - 1;
        shuffled.swap(0, last);
        let err = run_trace_source(&ws, TraceSource::from_vec(shuffled), &cfg).unwrap_err();
        assert!(
            err.to_string().contains("ordered"),
            "unexpected ordering error: {err}"
        );
    }
}
