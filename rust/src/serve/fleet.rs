//! A heterogeneous pool of simulated MCU devices executing batches in
//! virtual time.
//!
//! Every device is a serial executor with its own SRAM budget, clock,
//! per-class [`CycleModel`], cumulative instruction [`Counter`] and a
//! virtual-time timeline (`busy_until`). The timeline is denominated in
//! **reference cycles** of the paper platform's 216 MHz Cortex-M7 clock:
//! a batch that costs `c` cycles *on its device's cycle model* occupies
//! `c · 216 MHz / device clock` reference cycles of the shared timeline,
//! so latencies from M4- and M7-class devices are directly comparable
//! (and an all-M7 fleet reproduces the homogeneous timeline bit-for-bit).
//!
//! Placement policy lives outside the fleet: a
//! [`Scheduler`](super::sched::Scheduler) picks the device, the fleet
//! [`commit`](Fleet::commit)s the batch and keeps the accounting. The
//! fleet still owns backpressure mechanics ([`Fleet::next_wake`]): when
//! every eligible device is at the queue-depth cap, virtual time advances
//! to the earliest in-flight completion and placement retries — delayed,
//! never reordered.

use super::batcher::BATCH_OVERHEAD_CYCLES;
use crate::mcu::{Counter, CycleModel};

/// Device class label (reporting + fleet-spec parsing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    /// Cortex-M7 class (STM32F746 profile).
    M7,
    /// Cortex-M4 class (STM32F446 profile).
    M4,
}

impl DeviceClass {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceClass::M7 => "m7",
            DeviceClass::M4 => "m4",
        }
    }
}

/// Hardware parameters of one simulated device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCfg {
    pub class: DeviceClass,
    pub sram_bytes: usize,
    pub clock_hz: u64,
    /// Per-class instruction costs of this device — batch costs are
    /// priced with the *target* device's table, not a global one.
    pub cycle_model: CycleModel,
}

impl Default for DeviceCfg {
    fn default() -> Self {
        DeviceCfg::stm32f746()
    }
}

impl DeviceCfg {
    /// The paper's evaluation platform (Cortex-M7, 320 KB SRAM, 216 MHz).
    pub fn stm32f746() -> DeviceCfg {
        DeviceCfg {
            class: DeviceClass::M7,
            sram_bytes: crate::STM32F746_SRAM_BYTES,
            clock_hz: crate::STM32F746_CLOCK_HZ,
            cycle_model: CycleModel::cortex_m7(),
        }
    }

    /// An STM32F446-class companion part (Cortex-M4, 128 KB SRAM,
    /// 180 MHz, 4-cycle long multiplies) — the "just enough data width"
    /// end of a heterogeneous extreme-edge fleet.
    pub fn stm32f446() -> DeviceCfg {
        DeviceCfg {
            class: DeviceClass::M4,
            sram_bytes: crate::STM32F446_SRAM_BYTES,
            clock_hz: crate::STM32F446_CLOCK_HZ,
            cycle_model: CycleModel::cortex_m4(),
        }
    }

    /// Parse a single fleet-spec class token (`m7`, `m4`, or the full
    /// part names).
    pub fn parse_class(s: &str) -> Option<DeviceCfg> {
        match s.trim().to_ascii_lowercase().as_str() {
            "m7" | "stm32f746" => Some(DeviceCfg::stm32f746()),
            "m4" | "stm32f446" => Some(DeviceCfg::stm32f446()),
            _ => None,
        }
    }

    /// Cycles one batch costs *on this device*: the per-invocation
    /// overhead plus the instruction histogram priced by this device's
    /// cycle table.
    pub fn batch_cycles(&self, ctr: &Counter) -> u64 {
        BATCH_OVERHEAD_CYCLES + ctr.cycles(&self.cycle_model)
    }

    /// Convert device cycles to shared-timeline reference cycles
    /// (216 MHz), rounding up so slower clocks never under-account. The
    /// reference-clock device maps identically, which is what keeps an
    /// all-M7 fleet bit-compatible with the homogeneous timeline.
    pub fn to_timeline(&self, device_cycles: u64) -> u64 {
        if self.clock_hz == crate::STM32F746_CLOCK_HZ {
            return device_cycles;
        }
        let num = device_cycles as u128 * crate::STM32F746_CLOCK_HZ as u128;
        num.div_ceil(self.clock_hz as u128) as u64
    }

    /// Shared-timeline cost of one batch on this device.
    pub fn timeline_cost(&self, ctr: &Counter) -> u64 {
        self.to_timeline(self.batch_cycles(ctr))
    }
}

/// One flushed batch from the scheduler's point of view: everything a
/// placement policy may consult, with the execution work already
/// summarized as an instruction histogram (so each candidate device can
/// price it with its own cycle model).
#[derive(Debug, Clone, Copy)]
pub struct BatchWork<'a> {
    /// Virtual cycle the batch became ready.
    pub ready: u64,
    /// Merged instruction histogram of every member inference.
    pub counter: &'a Counter,
    /// Activation-arena peak of the batch's model (bytes).
    pub peak_sram: usize,
    /// Member count (images).
    pub images: u64,
    /// Absolute member deadlines (timeline cycles; `u64::MAX` = none).
    pub deadlines: &'a [u64],
}

/// One simulated device and its accounting.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub cfg: DeviceCfg,
    /// Virtual timeline cycle at which the device has drained everything
    /// dispatched to it so far.
    pub busy_until: u64,
    /// Finish times of dispatched batches (pruned lazily).
    inflight: Vec<u64>,
    /// Cumulative instruction histogram of everything run here.
    pub counter: Counter,
    /// Total busy timeline cycles (sum of dispatched batch costs).
    pub busy_cycles: u64,
    pub batches: u64,
    pub images: u64,
}

impl Device {
    fn new(id: usize, cfg: DeviceCfg) -> Device {
        Device {
            id,
            cfg,
            busy_until: 0,
            inflight: Vec::new(),
            counter: Counter::new(),
            busy_cycles: 0,
            batches: 0,
            images: 0,
        }
    }

    /// Unfinished batches at virtual time `now`.
    pub fn queue_depth(&self, now: u64) -> usize {
        self.inflight.iter().filter(|&&f| f > now).count()
    }

    /// Fraction of `[0, horizon]` this device spent executing.
    pub fn utilization(&self, horizon_cycles: u64) -> f64 {
        if horizon_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / horizon_cycles as f64
        }
    }

    /// Earliest in-flight finish strictly after `now` (for backpressure).
    fn next_free(&self, now: u64) -> Option<u64> {
        self.inflight.iter().copied().filter(|&f| f > now).min()
    }
}

/// Where and when a batch landed.
#[derive(Debug, Clone, Copy)]
pub struct Dispatch {
    pub device: usize,
    /// Virtual timeline cycle execution began (>= ready time).
    pub start: u64,
    /// Virtual timeline cycle the batch completed.
    pub finish: u64,
    /// Cost in the target device's own cycles.
    pub device_cycles: u64,
    /// Cost in shared-timeline reference cycles.
    pub timeline_cycles: u64,
}

/// The heterogeneous device pool (mechanics only — policy is a
/// [`Scheduler`](super::sched::Scheduler)).
pub struct Fleet {
    pub devices: Vec<Device>,
    pub max_queue_depth: usize,
}

impl Fleet {
    pub fn new(cfgs: Vec<DeviceCfg>, max_queue_depth: usize) -> Fleet {
        assert!(!cfgs.is_empty(), "fleet needs at least one device");
        assert!(max_queue_depth >= 1, "queue depth cap must be >= 1");
        Fleet {
            devices: cfgs
                .into_iter()
                .enumerate()
                .map(|(i, cfg)| Device::new(i, cfg))
                .collect(),
            max_queue_depth,
        }
    }

    /// A fleet of `n` identical devices.
    pub fn homogeneous(n: usize, cfg: DeviceCfg, max_queue_depth: usize) -> Fleet {
        Fleet::new(vec![cfg; n], max_queue_depth)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Can any device hold a model with this arena peak? (Admission
    /// control consults this at request arrival.)
    pub fn fits_anywhere(&self, peak_sram: usize) -> bool {
        self.devices.iter().any(|d| peak_sram <= d.cfg.sram_bytes)
    }

    /// Is device `idx` placeable at `now`: enough SRAM and below the
    /// queue-depth cap. The eligibility contract every scheduler's
    /// `pick` must respect.
    pub fn eligible(&self, idx: usize, now: u64, peak_sram: usize) -> bool {
        let d = &self.devices[idx];
        peak_sram <= d.cfg.sram_bytes && d.queue_depth(now) < self.max_queue_depth
    }

    /// Earliest in-flight completion strictly after `now` among devices
    /// whose SRAM could host the model — where backpressure resumes when
    /// every eligible device is saturated.
    pub fn next_wake(&self, now: u64, peak_sram: usize) -> Option<u64> {
        self.devices
            .iter()
            .filter(|d| peak_sram <= d.cfg.sram_bytes)
            .filter_map(|d| d.next_free(now))
            .min()
    }

    /// Commit `work` to device `idx` at virtual time `now` (chosen by a
    /// scheduler), updating the device timeline and accounting. `now`
    /// must satisfy [`eligible`](Fleet::eligible).
    pub fn commit(&mut self, idx: usize, now: u64, work: &BatchWork) -> Dispatch {
        let d = &mut self.devices[idx];
        debug_assert!(work.peak_sram <= d.cfg.sram_bytes, "scheduler placed an oversized model");
        let device_cycles = d.cfg.batch_cycles(work.counter);
        let timeline_cycles = d.cfg.to_timeline(device_cycles);
        let start = now.max(d.busy_until);
        let finish = start + timeline_cycles;
        d.busy_until = finish;
        d.inflight.retain(|&f| f > now);
        d.inflight.push(finish);
        d.counter.merge(work.counter);
        d.busy_cycles += timeline_cycles;
        d.batches += 1;
        d.images += work.images;
        Dispatch {
            device: idx,
            start,
            finish,
            device_cycles,
            timeline_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::InstrClass;

    fn cheap_counter() -> Counter {
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 10);
        c
    }

    fn work<'a>(ready: u64, ctr: &'a Counter, deadlines: &'a [u64]) -> BatchWork<'a> {
        BatchWork {
            ready,
            counter: ctr,
            peak_sram: 1024,
            images: 1,
            deadlines,
        }
    }

    #[test]
    fn m7_timeline_is_identity() {
        let cfg = DeviceCfg::stm32f746();
        assert_eq!(cfg.to_timeline(12_345), 12_345);
        let ctr = cheap_counter();
        assert_eq!(cfg.batch_cycles(&ctr), BATCH_OVERHEAD_CYCLES + 10);
        assert_eq!(cfg.timeline_cost(&ctr), BATCH_OVERHEAD_CYCLES + 10);
    }

    #[test]
    fn m4_is_strictly_slower_on_the_shared_timeline() {
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        // Same ALU-only histogram: identical device cycles, but the
        // slower clock stretches the timeline cost.
        let ctr = cheap_counter();
        assert_eq!(m4.batch_cycles(&ctr), m7.batch_cycles(&ctr));
        assert!(m4.timeline_cost(&ctr) > m7.timeline_cost(&ctr));
        // Long multiplies additionally cost more device cycles on M4.
        let mut heavy = Counter::new();
        heavy.charge(InstrClass::MulLong, 100);
        assert!(m4.batch_cycles(&heavy) > m7.batch_cycles(&heavy));
    }

    #[test]
    fn timeline_conversion_rounds_up() {
        let m4 = DeviceCfg::stm32f446();
        // 1 device cycle at 180 MHz is 1.2 reference cycles -> 2.
        assert_eq!(m4.to_timeline(1), 2);
        // 5 device cycles is exactly 6 reference cycles.
        assert_eq!(m4.to_timeline(5), 6);
        assert_eq!(m4.to_timeline(0), 0);
    }

    #[test]
    fn parse_class_accepts_aliases() {
        assert_eq!(DeviceCfg::parse_class("m7").unwrap().class, DeviceClass::M7);
        assert_eq!(DeviceCfg::parse_class("STM32F446").unwrap().class, DeviceClass::M4);
        assert!(DeviceCfg::parse_class("m33").is_none());
    }

    #[test]
    fn serial_device_queues_in_virtual_time() {
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(0, 0, &work(0, &ctr, &[]));
        assert_eq!(a.finish, cost);
        assert_eq!(b.start, cost, "second batch waits for the first");
        assert_eq!(b.finish, 2 * cost);
        assert_eq!(fleet.devices[0].queue_depth(cost / 2), 2);
        assert_eq!(fleet.devices[0].queue_depth(cost + 1), 1);
        assert_eq!(fleet.devices[0].queue_depth(2 * cost), 0);
    }

    #[test]
    fn eligibility_gates_sram_and_depth() {
        let mut small = DeviceCfg::stm32f746();
        small.sram_bytes = 10 * 1024;
        let mut fleet = Fleet::new(vec![small, DeviceCfg::stm32f746()], 1);
        // Device 0 lacks SRAM for a 64 KB arena; device 1 fits.
        assert!(!fleet.eligible(0, 0, 64 * 1024));
        assert!(fleet.eligible(1, 0, 64 * 1024));
        assert!(fleet.fits_anywhere(64 * 1024));
        assert!(!fleet.fits_anywhere(512 * 1024));
        // Fill device 1 to the depth cap; it becomes ineligible until
        // its batch completes.
        let ctr = cheap_counter();
        let d = fleet.commit(1, 0, &work(0, &ctr, &[]));
        assert!(!fleet.eligible(1, 0, 64 * 1024));
        assert_eq!(fleet.next_wake(0, 64 * 1024), Some(d.finish));
        assert!(fleet.eligible(1, d.finish, 64 * 1024));
    }

    #[test]
    fn accounting_accumulates() {
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 4);
        let ctr = cheap_counter();
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(1, 0, &work(0, &ctr, &[]));
        let total_busy: u64 = fleet.devices.iter().map(|d| d.busy_cycles).sum();
        let total_images: u64 = fleet.devices.iter().map(|d| d.images).sum();
        assert_eq!(total_busy, a.timeline_cycles + b.timeline_cycles);
        assert_eq!(total_images, 2);
        assert!(fleet.devices[0].utilization(1_000_000) > 0.0);
        assert_eq!(fleet.devices[0].counter.alu, 10);
        assert_eq!(a.device_cycles, BATCH_OVERHEAD_CYCLES + 10);
    }
}
