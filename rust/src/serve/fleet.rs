//! A pool of simulated MCU devices executing batches in virtual time.
//!
//! Every device is a serial Cortex-M7-class executor with its own SRAM
//! budget, cumulative instruction [`Counter`] and a virtual-time timeline
//! (`busy_until`, in cycles). The fleet schedules round-robin across
//! devices, skipping devices whose model doesn't fit in SRAM, and applies
//! backpressure when every eligible device already holds
//! `max_queue_depth` unfinished batches: the dispatch is delayed (in
//! virtual time) until a slot frees, never reordered.

use crate::mcu::Counter;

/// Hardware parameters of one simulated device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCfg {
    pub sram_bytes: usize,
    pub clock_hz: u64,
}

impl Default for DeviceCfg {
    fn default() -> Self {
        DeviceCfg::stm32f746()
    }
}

impl DeviceCfg {
    /// The paper's evaluation platform (320 KB SRAM, 216 MHz).
    pub fn stm32f746() -> DeviceCfg {
        DeviceCfg {
            sram_bytes: crate::STM32F746_SRAM_BYTES,
            clock_hz: crate::STM32F746_CLOCK_HZ,
        }
    }
}

/// One simulated device and its accounting.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub cfg: DeviceCfg,
    /// Virtual cycle at which the device has drained everything
    /// dispatched to it so far.
    pub busy_until: u64,
    /// Finish times of dispatched batches (pruned lazily).
    inflight: Vec<u64>,
    /// Cumulative instruction histogram of everything run here.
    pub counter: Counter,
    /// Total busy cycles (sum of dispatched batch costs).
    pub busy_cycles: u64,
    pub batches: u64,
    pub images: u64,
}

impl Device {
    fn new(id: usize, cfg: DeviceCfg) -> Device {
        Device {
            id,
            cfg,
            busy_until: 0,
            inflight: Vec::new(),
            counter: Counter::new(),
            busy_cycles: 0,
            batches: 0,
            images: 0,
        }
    }

    /// Unfinished batches at virtual time `now`.
    pub fn queue_depth(&self, now: u64) -> usize {
        self.inflight.iter().filter(|&&f| f > now).count()
    }

    /// Fraction of `[0, horizon]` this device spent executing.
    pub fn utilization(&self, horizon_cycles: u64) -> f64 {
        if horizon_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / horizon_cycles as f64
        }
    }

    /// Earliest in-flight finish strictly after `now` (for backpressure).
    fn next_free(&self, now: u64) -> Option<u64> {
        self.inflight.iter().copied().filter(|&f| f > now).min()
    }
}

/// Where and when a batch landed.
#[derive(Debug, Clone, Copy)]
pub struct Dispatch {
    pub device: usize,
    /// Virtual cycle execution began (>= ready time).
    pub start: u64,
    /// Virtual cycle the batch completed.
    pub finish: u64,
}

/// The device pool plus the round-robin cursor.
pub struct Fleet {
    pub devices: Vec<Device>,
    rr_next: usize,
    pub max_queue_depth: usize,
}

impl Fleet {
    pub fn new(n: usize, cfg: DeviceCfg, max_queue_depth: usize) -> Fleet {
        assert!(n >= 1, "fleet needs at least one device");
        assert!(max_queue_depth >= 1, "queue depth cap must be >= 1");
        Fleet {
            devices: (0..n).map(|i| Device::new(i, cfg)).collect(),
            rr_next: 0,
            max_queue_depth,
        }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Can any device hold a model with this arena peak? (Admission
    /// control consults this at request arrival.)
    pub fn fits_anywhere(&self, peak_sram: usize) -> bool {
        self.devices.iter().any(|d| peak_sram <= d.cfg.sram_bytes)
    }

    /// Dispatch a batch that becomes ready at `ready` and costs
    /// `cost_cycles`, round-robin over devices with enough SRAM. When all
    /// eligible devices are at the queue-depth cap, virtual time advances
    /// to the earliest in-flight completion and scheduling retries —
    /// backpressure, not reordering.
    ///
    /// Returns `None` only when no device's SRAM fits the model (callers
    /// should have rejected such requests at admission).
    pub fn dispatch(
        &mut self,
        ready: u64,
        cost_cycles: u64,
        peak_sram: usize,
        images: u64,
        counter: &Counter,
    ) -> Option<Dispatch> {
        if !self.fits_anywhere(peak_sram) {
            return None;
        }
        let n = self.devices.len();
        let mut now = ready;
        loop {
            for off in 0..n {
                let idx = (self.rr_next + off) % n;
                let d = &mut self.devices[idx];
                if peak_sram > d.cfg.sram_bytes {
                    continue;
                }
                if d.queue_depth(now) >= self.max_queue_depth {
                    continue;
                }
                self.rr_next = (idx + 1) % n;
                let start = now.max(d.busy_until);
                let finish = start + cost_cycles;
                d.busy_until = finish;
                d.inflight.retain(|&f| f > now);
                d.inflight.push(finish);
                d.counter.merge(counter);
                d.busy_cycles += cost_cycles;
                d.batches += 1;
                d.images += images;
                return Some(Dispatch {
                    device: idx,
                    start,
                    finish,
                });
            }
            // Everyone eligible is saturated: wait for the earliest
            // completion among devices that could host this model.
            let wake = self
                .devices
                .iter()
                .filter(|d| peak_sram <= d.cfg.sram_bytes)
                .filter_map(|d| d.next_free(now))
                .min()?;
            now = wake;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cheap_counter() -> Counter {
        let mut c = Counter::new();
        c.charge(crate::mcu::InstrClass::Alu, 10);
        c
    }

    #[test]
    fn round_robin_spreads_batches() {
        let mut fleet = Fleet::new(3, DeviceCfg::stm32f746(), 4);
        for _ in 0..6 {
            fleet.dispatch(0, 1000, 1024, 1, &cheap_counter()).unwrap();
        }
        for d in &fleet.devices {
            assert_eq!(d.batches, 2, "device {} load", d.id);
        }
    }

    #[test]
    fn serial_device_queues_in_virtual_time() {
        let mut fleet = Fleet::new(1, DeviceCfg::stm32f746(), 8);
        let a = fleet.dispatch(0, 500, 1024, 1, &cheap_counter()).unwrap();
        let b = fleet.dispatch(0, 500, 1024, 1, &cheap_counter()).unwrap();
        assert_eq!(a.finish, 500);
        assert_eq!(b.start, 500, "second batch waits for the first");
        assert_eq!(b.finish, 1000);
        assert_eq!(fleet.devices[0].queue_depth(250), 2);
        assert_eq!(fleet.devices[0].queue_depth(750), 1);
        assert_eq!(fleet.devices[0].queue_depth(1000), 0);
    }

    #[test]
    fn backpressure_delays_when_depth_capped() {
        let mut fleet = Fleet::new(1, DeviceCfg::stm32f746(), 2);
        fleet.dispatch(0, 100, 1024, 1, &cheap_counter()).unwrap();
        fleet.dispatch(0, 100, 1024, 1, &cheap_counter()).unwrap();
        // Depth cap reached at t=0; the third batch must wait until the
        // first finishes (t=100) before it may even enqueue.
        let c = fleet.dispatch(0, 100, 1024, 1, &cheap_counter()).unwrap();
        assert_eq!(c.start, 200, "starts after the backlog drains");
        assert_eq!(c.finish, 300);
    }

    #[test]
    fn sram_gate_rejects_oversized_models() {
        let small = DeviceCfg {
            sram_bytes: 10 * 1024,
            clock_hz: crate::STM32F746_CLOCK_HZ,
        };
        let mut fleet = Fleet::new(2, small, 4);
        assert!(!fleet.fits_anywhere(64 * 1024));
        assert!(fleet
            .dispatch(0, 100, 64 * 1024, 1, &cheap_counter())
            .is_none());
        assert!(fleet.dispatch(0, 100, 8 * 1024, 1, &cheap_counter()).is_some());
    }

    #[test]
    fn accounting_accumulates() {
        let mut fleet = Fleet::new(2, DeviceCfg::stm32f746(), 4);
        fleet.dispatch(0, 300, 1024, 3, &cheap_counter()).unwrap();
        fleet.dispatch(0, 200, 1024, 2, &cheap_counter()).unwrap();
        let total_busy: u64 = fleet.devices.iter().map(|d| d.busy_cycles).sum();
        let total_images: u64 = fleet.devices.iter().map(|d| d.images).sum();
        assert_eq!(total_busy, 500);
        assert_eq!(total_images, 5);
        assert!(fleet.devices[0].utilization(1000) > 0.0);
        assert_eq!(fleet.devices[0].counter.alu, 10);
    }
}
