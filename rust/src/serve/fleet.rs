//! A heterogeneous pool of simulated MCU devices executing batches in
//! virtual time.
//!
//! Every device is a serial executor described by a [`Target`] (SRAM
//! budget, clock, per-class [`CycleModel`](crate::mcu::CycleModel) and
//! [`EnergyModel`](crate::target::EnergyModel)), with a cumulative
//! instruction [`Counter`] and a
//! virtual-time timeline (`busy_until`). The timeline is denominated in
//! **reference cycles** of the paper platform's 216 MHz Cortex-M7 clock:
//! a batch that costs `c` cycles *on its device's cycle model* occupies
//! `c · 216 MHz / device clock` reference cycles of the shared timeline,
//! so latencies from M4- and M7-class devices are directly comparable
//! (and an all-M7 fleet reproduces the homogeneous timeline bit-for-bit).
//!
//! Placement policy lives outside the fleet: a
//! [`Scheduler`](super::sched::Scheduler) picks the device, the fleet
//! [`commit`](Fleet::commit)s the batch and keeps the accounting. The
//! fleet still owns backpressure mechanics ([`Fleet::next_wake`]): when
//! every eligible device is at the queue-depth cap, virtual time advances
//! to the earliest in-flight completion and placement retries — delayed,
//! never reordered.
//!
//! # Work stealing
//!
//! With [`steal`](Fleet::steal) enabled, a commit is *deferred*: the
//! batch becomes a migratable [`PendingBatch`] on the target device's
//! queue instead of an immutable timeline entry, and its final placement
//! is a [`Resolution`] looked up after the replay. At every dispatch
//! step [`advance`](Fleet::advance) resolves batches whose start time
//! has passed (a started batch is pinned to its device), then
//! [`rebalance`](Fleet::rebalance) lets each drained, idle device steal
//! the latest-deadline pending batch from the most-backlogged
//! SRAM-compatible victim — but only when the thief would strictly
//! finish it earlier, so migration never worsens a batch. Migrations are
//! counted per thief device and surfaced in
//! [`DeviceStats`](super::stats::DeviceStats). With stealing off the
//! eager path is byte-identical to the pre-steal fleet (the RoundRobin /
//! all-M7 regression pin).
//!
//! # Fleet lifecycle (fault injection)
//!
//! Devices are no longer permanently live: each carries `up` /
//! `draining` flags and a restorable base clock, driven by the
//! [`FleetEvent`](super::trace::FleetEvent) stream the replay loop
//! interprets between arrivals:
//!
//! * [`device_join`](Fleet::device_join) — a down device (re)enters the
//!   pool at its registry clock and becomes placeable;
//! * [`device_leave`](Fleet::device_leave) — planned departure: the
//!   started batch finishes, committed-but-unstarted batches are
//!   cancelled and handed back for re-admission;
//! * [`device_crash`](Fleet::device_crash) — unplanned death: pending
//!   *and* started-but-unfinished batches are cancelled, their
//!   resolutions revoked, and the unexecuted timeline tail plus lost
//!   results rolled back (cycles and energy burned before the crash stay
//!   spent — crashed work is wasted, not free);
//! * [`device_throttle`](Fleet::device_throttle) — DVFS brown-out: the
//!   effective clock drops, repricing every batch the device *starts
//!   from now on* (started batches keep their resolved price);
//! * [`device_restore`](Fleet::device_restore) — clock back to the
//!   registry base, drain lifted;
//! * [`device_drain`](Fleet::device_drain) — no new placements; in-flight
//!   work finishes and pending batches migrate immediately to the best
//!   live host through the steal machinery (batches no live device can
//!   hold are cancelled for re-admission).
//!
//! Only live (`up && !draining`) devices are
//! [`eligible`](Fleet::eligible), count for
//! [`fits_anywhere`](Fleet::fits_anywhere), or anchor
//! [`next_wake`](Fleet::next_wake). Lifecycle interpretation requires
//! deferred-commit (steal) mode — the replay loop forces it whenever a
//! trace carries fleet events — and with no events every gate is
//! trivially open, which preserves the bit-for-bit pin.
//!
//! # Event-indexed bookkeeping
//!
//! Two hot queries used to rescan every device per replay step; both
//! are now answered from incremental indices that the mutation paths
//! keep exact, so the indexed answers are *provably identical* to the
//! scans (the `--legacy-loop` replay still runs the scans as the
//! baseline):
//!
//! * [`next_wake`](Fleet::next_wake) reads a `BTreeMap<(finish, device),
//!   count>` multiset mirroring every device's in-flight finish times —
//!   maintained by the single choke point that rewrites a device's
//!   `inflight` vector — and walks it in ascending order from `now`,
//!   taking the first entry whose device passes the live/SRAM filter.
//! * [`advance`](Fleet::advance) keeps a conservative *horizon*: the
//!   earliest cycle at which any pending batch could start or any
//!   started batch could finish. Calls strictly below the horizon are
//!   proven no-ops and return immediately; every queue / `free_at` /
//!   lifecycle mutation invalidates the cache.
//!
//! The fleet-wide energy total the autoscaler reads every arrival
//! ([`total_joules`](Fleet::total_joules)) is cached the same way:
//! recomputed — by the exact device-order summation the scan used —
//! only after a commit, resolution or crash dirties a counter.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::batcher::BATCH_OVERHEAD_CYCLES;
use crate::mcu::Counter;
use crate::target::Target;

pub use crate::target::DeviceClass;

/// Hardware parameters of one simulated device — an alias of the
/// unified [`Target`] type: the registry ([`Target::lookup`],
/// [`Target::parse_fleet`]) is the single source of device constants,
/// and the fleet prices batches with `target.cycle_model` /
/// `target.energy_model` directly.
pub type DeviceCfg = Target;

/// Serving-layer pricing on top of [`Target`]: batch overhead, the
/// shared reference timeline, and per-batch energy.
impl Target {
    /// Parse a single fleet-spec class token (`m7`, `m4`, or the full
    /// part names) — a delegation to the [`Target`] registry.
    pub fn parse_class(s: &str) -> Option<DeviceCfg> {
        Target::lookup(s).copied()
    }

    /// Cycles one batch costs *on this device*: the per-invocation
    /// overhead plus the instruction histogram priced by this device's
    /// cycle table.
    pub fn batch_cycles(&self, ctr: &Counter) -> u64 {
        BATCH_OVERHEAD_CYCLES + ctr.cycles(&self.cycle_model)
    }

    /// Convert device cycles to shared-timeline reference cycles
    /// (216 MHz), rounding up so slower clocks never under-account. The
    /// reference-clock device maps identically, which is what keeps an
    /// all-M7 fleet bit-compatible with the homogeneous timeline.
    pub fn to_timeline(&self, device_cycles: u64) -> u64 {
        if self.clock_hz == crate::STM32F746_CLOCK_HZ {
            return device_cycles;
        }
        let num = device_cycles as u128 * crate::STM32F746_CLOCK_HZ as u128;
        num.div_ceil(self.clock_hz as u128) as u64
    }

    /// Shared-timeline cost of one batch on this device.
    pub fn timeline_cost(&self, ctr: &Counter) -> u64 {
        self.to_timeline(self.batch_cycles(ctr))
    }

    /// Predicted energy of one batch on this device: dynamic energy of
    /// the histogram plus static power over the batch's execution time
    /// (inference + invocation overhead) at this device's clock.
    pub fn batch_joules(&self, ctr: &Counter) -> f64 {
        self.energy_model.dynamic_joules(ctr)
            + self.energy_model.static_watts() * self.seconds(self.batch_cycles(ctr))
    }
}

/// One flushed batch from the scheduler's point of view: everything a
/// placement policy may consult, with the execution work already
/// summarized as an instruction histogram (so each candidate device can
/// price it with its own cycle model).
#[derive(Debug, Clone, Copy)]
pub struct BatchWork<'a> {
    /// Virtual cycle the batch became ready.
    pub ready: u64,
    /// Merged instruction histogram of every member inference.
    pub counter: &'a Counter,
    /// Activation-arena peak of the batch's model (bytes).
    pub peak_sram: usize,
    /// Member count (images).
    pub images: u64,
    /// Absolute member deadlines (timeline cycles; `u64::MAX` = none).
    pub deadlines: &'a [u64],
}

/// A committed-but-not-started batch (steal mode): a migratable queue
/// entry carrying everything needed to price and start it later, on
/// whichever device ends up running it.
#[derive(Debug, Clone)]
pub struct PendingBatch {
    /// Resolution handle returned to the committer.
    pub ticket: usize,
    /// Earliest cycle the batch may start (its commit — or steal —
    /// time, whichever is later).
    pub ready: u64,
    /// Owned instruction histogram (priced by the final device).
    pub counter: Counter,
    pub peak_sram: usize,
    pub images: u64,
    /// Most urgent member deadline (`u64::MAX` = none). The steal pass
    /// migrates the *latest*-deadline batch first — the safest cargo.
    pub min_deadline: u64,
}

/// Final placement of one deferred batch (steal mode).
#[derive(Debug, Clone, Copy)]
pub struct Resolution {
    pub device: usize,
    pub start: u64,
    pub finish: u64,
    /// Cost in the executing device's own cycles.
    pub device_cycles: u64,
    /// Cost in shared-timeline reference cycles.
    pub timeline_cycles: u64,
    /// Member count — kept so a crash can roll the lost results back.
    pub images: u64,
}

/// One simulated device and its accounting.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub cfg: DeviceCfg,
    /// Virtual timeline cycle at which the device has drained everything
    /// dispatched to it so far (projected over the pending queue in
    /// steal mode).
    pub busy_until: u64,
    /// Finish times of dispatched batches (pruned lazily; projected for
    /// pending batches in steal mode).
    inflight: Vec<u64>,
    /// Cumulative instruction histogram of everything run here.
    pub counter: Counter,
    /// Total busy timeline cycles (sum of dispatched batch costs).
    pub busy_cycles: u64,
    pub batches: u64,
    pub images: u64,
    /// Pending batches this device stole from backlogged neighbors.
    pub migrations: u64,
    /// Accepting work? `false` after `Leave`/`Crash` (and for standby
    /// autoscaler devices) until a `Join` brings it back.
    pub up: bool,
    /// Draining: no new placements; in-flight work finishes.
    pub draining: bool,
    /// Registry clock, restored by `Restore`/`Join` after throttling.
    base_clock_hz: u64,
    /// Resolved timeline: when every *started* batch is done (steal
    /// mode; the eager path never reads it).
    free_at: u64,
    /// Committed-but-not-started batches (steal mode only).
    queue: VecDeque<PendingBatch>,
    /// `(ticket, finish)` of started-but-possibly-unfinished batches
    /// (steal mode; pruned as virtual time advances, revoked by crash).
    resolved_open: Vec<(usize, u64)>,
}

impl Device {
    fn new(id: usize, cfg: DeviceCfg) -> Device {
        Device {
            id,
            base_clock_hz: cfg.clock_hz,
            cfg,
            busy_until: 0,
            inflight: Vec::new(),
            counter: Counter::new(),
            busy_cycles: 0,
            batches: 0,
            images: 0,
            migrations: 0,
            up: true,
            draining: false,
            free_at: 0,
            queue: VecDeque::new(),
            resolved_open: Vec::new(),
        }
    }

    /// Placeable from a lifecycle standpoint: up and not draining.
    pub fn is_live(&self) -> bool {
        self.up && !self.draining
    }

    /// Unfinished batches at virtual time `now` (running + pending).
    pub fn queue_depth(&self, now: u64) -> usize {
        self.inflight.iter().filter(|&&f| f > now).count()
    }

    /// Committed-but-not-started batches (steal mode).
    pub fn pending_len(&self) -> usize {
        self.queue.len()
    }

    /// Fraction of `[0, horizon]` this device spent executing.
    pub fn utilization(&self, horizon_cycles: u64) -> f64 {
        if horizon_cycles == 0 {
            0.0
        } else {
            self.busy_cycles as f64 / horizon_cycles as f64
        }
    }

    /// Total energy this device spent executing: dynamic energy of the
    /// cumulative instruction histogram plus static power over its busy
    /// time. Busy time is exact in the shared reference timeline
    /// (reference cycles / 216 MHz = seconds, whatever the device's own
    /// clock), so the static term needs no per-device conversion.
    pub fn joules(&self) -> f64 {
        self.cfg.energy_model.dynamic_joules(&self.counter)
            + self.cfg.energy_model.static_watts()
                * (self.busy_cycles as f64 / crate::STM32F746_CLOCK_HZ as f64)
    }

    /// Earliest in-flight finish strictly after `now` (for backpressure).
    fn next_free(&self, now: u64) -> Option<u64> {
        self.inflight.iter().copied().filter(|&f| f > now).min()
    }

    /// Timeline cost of one pending batch on this device.
    fn pending_cost(&self, pb: &PendingBatch) -> u64 {
        self.cfg.timeline_cost(&pb.counter)
    }

    /// The single source of truth for the pending-queue timeline walk
    /// (steal mode): projected finish times in queue order, each batch
    /// starting at `max(its ready, predecessor finish)` from the
    /// resolved backlog. `advance` resolves fronts with the same start
    /// rule, so projections and resolutions cannot diverge.
    fn projected_finishes(&self) -> Vec<u64> {
        let mut t = self.free_at;
        self.queue
            .iter()
            .map(|pb| {
                let start = pb.ready.max(t);
                t = start + self.pending_cost(pb);
                t
            })
            .collect()
    }
}

/// Where and when a batch landed.
#[derive(Debug, Clone, Copy)]
pub struct Dispatch {
    pub device: usize,
    /// Virtual timeline cycle execution began (>= ready time). Projected
    /// when `ticket` is set.
    pub start: u64,
    /// Virtual timeline cycle the batch completed. Projected when
    /// `ticket` is set.
    pub finish: u64,
    /// Cost in the target device's own cycles.
    pub device_cycles: u64,
    /// Cost in shared-timeline reference cycles.
    pub timeline_cycles: u64,
    /// Steal mode: the batch is pending and may migrate; its final
    /// placement is [`Fleet::resolution`]`(ticket)` after
    /// [`Fleet::finalize`]. `None` = eager commit, fields are final.
    pub ticket: Option<usize>,
}

/// The heterogeneous device pool (mechanics only — policy is a
/// [`Scheduler`](super::sched::Scheduler)).
pub struct Fleet {
    pub devices: Vec<Device>,
    pub max_queue_depth: usize,
    /// Deferred-commit mode: batches stay migratable until started.
    pub steal: bool,
    /// Final placements by ticket (steal mode).
    resolutions: Vec<Option<Resolution>>,
    /// Observability log of steals: `(now, from, to, ticket)` per
    /// migration, appended by [`rebalance`](Fleet::rebalance) and
    /// drained by the replay loop ([`drain_migrations`](Fleet::drain_migrations)).
    /// Purely passive — no placement decision reads it. Bounded at
    /// [`migration_log_cap`](Fleet::migration_log_cap) entries, oldest
    /// dropped first (mirroring `RingRecorder`), so million-request
    /// replays with an undrained log cannot grow it without limit.
    migration_log: VecDeque<(u64, usize, usize, usize)>,
    /// Capacity of the migration ring.
    pub migration_log_cap: usize,
    /// Migration-log entries evicted because the ring was full.
    pub migration_log_dropped: u64,
    /// Use the incremental wake index for [`next_wake`](Fleet::next_wake)
    /// (default). `false` re-enables the per-device linear scan — the
    /// `--legacy-loop` baseline. Both answers are identical; the index
    /// is maintained either way, so the flag can toggle at any time.
    pub indexed: bool,
    /// Exact multiset mirror of every device's `inflight` vector:
    /// `(finish cycle, device) -> multiplicity`. Maintained solely by
    /// [`set_inflight`](Fleet::set_inflight).
    wake_index: BTreeMap<(u64, usize), u32>,
    /// One `(busy_until, device)` entry per device, in the exact
    /// `(busy_until, id)` order `LeastLoaded` minimizes over. Maintained
    /// solely by [`set_busy_until`](Fleet::set_busy_until).
    by_busy: BTreeSet<(u64, usize)>,
    /// Conservative no-op horizon for [`advance`](Fleet::advance):
    /// `Some(h)` proves `advance(now)` changes nothing for `now < h`.
    /// `None` = a queue/`free_at`/lifecycle input changed, recompute.
    advance_horizon: Option<u64>,
    /// Cached [`total_joules`](Fleet::total_joules), valid when
    /// `!energy_dirty`.
    energy_cache: f64,
    energy_dirty: bool,
}

/// Default capacity of the fleet's migration ring.
pub const MIGRATION_LOG_CAP: usize = 1 << 16;

impl Fleet {
    pub fn new(cfgs: Vec<DeviceCfg>, max_queue_depth: usize) -> Fleet {
        assert!(!cfgs.is_empty(), "fleet needs at least one device");
        assert!(max_queue_depth >= 1, "queue depth cap must be >= 1");
        let devices: Vec<Device> = cfgs
            .into_iter()
            .enumerate()
            .map(|(i, cfg)| Device::new(i, cfg))
            .collect();
        let by_busy = devices.iter().map(|d| (d.busy_until, d.id)).collect();
        Fleet {
            devices,
            max_queue_depth,
            steal: false,
            resolutions: Vec::new(),
            migration_log: VecDeque::new(),
            migration_log_cap: MIGRATION_LOG_CAP,
            migration_log_dropped: 0,
            indexed: true,
            wake_index: BTreeMap::new(),
            by_busy,
            advance_horizon: None,
            energy_cache: 0.0,
            energy_dirty: true,
        }
    }

    /// A fleet of `n` identical devices.
    pub fn homogeneous(n: usize, cfg: DeviceCfg, max_queue_depth: usize) -> Fleet {
        Fleet::new(vec![cfg; n], max_queue_depth)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Can any *live* device hold a model with this arena peak?
    /// (Admission control consults this at request arrival; a down or
    /// draining device cannot extend admission capability.)
    pub fn fits_anywhere(&self, peak_sram: usize) -> bool {
        self.devices
            .iter()
            .any(|d| d.is_live() && peak_sram <= d.cfg.sram_bytes)
    }

    /// Is device `idx` placeable at `now`: live, enough SRAM and below
    /// the queue-depth cap. The eligibility contract every scheduler's
    /// `pick` must respect.
    pub fn eligible(&self, idx: usize, now: u64, peak_sram: usize) -> bool {
        let d = &self.devices[idx];
        d.is_live()
            && peak_sram <= d.cfg.sram_bytes
            && d.queue_depth(now) < self.max_queue_depth
    }

    /// Earliest in-flight completion strictly after `now` among live
    /// devices whose SRAM could host the model — where backpressure
    /// resumes when every eligible device is saturated. (A down or
    /// draining device's completions can never make it eligible, so they
    /// are no wake anchor.) Answered from the wake index unless
    /// [`indexed`](Fleet::indexed) is off; both paths are identical.
    pub fn next_wake(&self, now: u64, peak_sram: usize) -> Option<u64> {
        if self.indexed {
            self.next_wake_indexed(now, peak_sram)
        } else {
            self.next_wake_scan(now, peak_sram)
        }
    }

    /// The pre-index `next_wake`: a linear pass over every device's
    /// in-flight vector. Kept as the `--legacy-loop` baseline and the
    /// equivalence oracle for the wake index.
    pub fn next_wake_scan(&self, now: u64, peak_sram: usize) -> Option<u64> {
        self.devices
            .iter()
            .filter(|d| d.is_live() && peak_sram <= d.cfg.sram_bytes)
            .filter_map(|d| d.next_free(now))
            .min()
    }

    /// `next_wake` off the wake index: ascending `(finish, device)`
    /// walk starting strictly after `now`, first entry whose device is
    /// a valid anchor. The index mirrors `inflight` exactly (stale
    /// finishes at or before `now` are excluded by the range bound, not
    /// by deletion), so the first passing entry carries the same
    /// minimal finish the scan would compute.
    fn next_wake_indexed(&self, now: u64, peak_sram: usize) -> Option<u64> {
        use std::ops::Bound;
        self.wake_index
            .range((Bound::Excluded((now, usize::MAX)), Bound::Unbounded))
            .find(|&(&(_, dev), _)| {
                let d = &self.devices[dev];
                d.is_live() && peak_sram <= d.cfg.sram_bytes
            })
            .map(|(&(finish, _), _)| finish)
    }

    /// The single choke point that moves a device's `busy_until`,
    /// keeping the `by_busy` order an exact mirror.
    fn set_busy_until(&mut self, idx: usize, v: u64) {
        let old = self.devices[idx].busy_until;
        if old != v {
            self.by_busy.remove(&(old, idx));
            self.by_busy.insert((v, idx));
            self.devices[idx].busy_until = v;
        }
    }

    /// Device ids in ascending `(busy_until, id)` order — the exact key
    /// `LeastLoaded` minimizes, so the first eligible id in this walk
    /// *is* its pick.
    pub fn by_busy_order(&self) -> impl Iterator<Item = usize> + '_ {
        self.by_busy.iter().map(|&(_, i)| i)
    }

    /// The single choke point that rewrites a device's in-flight finish
    /// set, keeping the wake index an exact multiset mirror.
    fn set_inflight(&mut self, idx: usize, inflight: Vec<u64>) {
        for &f in &self.devices[idx].inflight {
            if let Some(c) = self.wake_index.get_mut(&(f, idx)) {
                *c -= 1;
                if *c == 0 {
                    self.wake_index.remove(&(f, idx));
                }
            } else {
                debug_assert!(false, "wake index lost an inflight entry");
            }
        }
        for &f in &inflight {
            *self.wake_index.entry((f, idx)).or_insert(0) += 1;
        }
        self.devices[idx].inflight = inflight;
    }

    /// Commit `work` to device `idx` at virtual time `now` (chosen by a
    /// scheduler), updating the device timeline and accounting. `now`
    /// must satisfy [`eligible`](Fleet::eligible). In steal mode the
    /// commit is deferred: the batch joins the device's migratable
    /// pending queue and the returned [`Dispatch`] carries a `ticket`
    /// plus *projected* times.
    pub fn commit(&mut self, idx: usize, now: u64, work: &BatchWork) -> Dispatch {
        if self.steal {
            return self.commit_deferred(idx, now, work);
        }
        let d = &mut self.devices[idx];
        debug_assert!(work.peak_sram <= d.cfg.sram_bytes, "scheduler placed an oversized model");
        let device_cycles = d.cfg.batch_cycles(work.counter);
        let timeline_cycles = d.cfg.to_timeline(device_cycles);
        let start = now.max(d.busy_until);
        let finish = start + timeline_cycles;
        d.counter.merge(work.counter);
        d.busy_cycles += timeline_cycles;
        d.batches += 1;
        d.images += work.images;
        let mut inflight: Vec<u64> = d.inflight.iter().copied().filter(|&f| f > now).collect();
        inflight.push(finish);
        self.set_busy_until(idx, finish);
        self.set_inflight(idx, inflight);
        self.energy_dirty = true;
        Dispatch {
            device: idx,
            start,
            finish,
            device_cycles,
            timeline_cycles,
            ticket: None,
        }
    }

    fn commit_deferred(&mut self, idx: usize, now: u64, work: &BatchWork) -> Dispatch {
        let ticket = self.resolutions.len();
        self.resolutions.push(None);
        {
            let d = &mut self.devices[idx];
            debug_assert!(
                work.peak_sram <= d.cfg.sram_bytes,
                "scheduler placed an oversized model"
            );
            d.queue.push_back(PendingBatch {
                ticket,
                ready: now,
                counter: work.counter.clone(),
                peak_sram: work.peak_sram,
                images: work.images,
                min_deadline: work.deadlines.iter().copied().min().unwrap_or(u64::MAX),
            });
        }
        self.recompute_projection(idx);
        let d = &self.devices[idx];
        let device_cycles = d.cfg.batch_cycles(work.counter);
        let timeline_cycles = d.cfg.to_timeline(device_cycles);
        Dispatch {
            device: idx,
            start: d.busy_until - timeline_cycles,
            finish: d.busy_until,
            device_cycles,
            timeline_cycles,
            ticket: Some(ticket),
        }
    }

    /// Rebuild a device's projected timeline (`busy_until`, `inflight`)
    /// from its resolved backlog plus pending queue (steal mode). Also
    /// invalidates the advance horizon: every caller just mutated a
    /// horizon input (queue, `free_at`, ready times, or liveness).
    fn recompute_projection(&mut self, idx: usize) {
        self.advance_horizon = None;
        let finishes = self.devices[idx].projected_finishes();
        let d = &self.devices[idx];
        let busy_until = finishes.last().copied().unwrap_or(d.free_at);
        let mut inflight: Vec<u64> = d.resolved_open.iter().map(|&(_, f)| f).collect();
        inflight.extend(&finishes);
        self.set_busy_until(idx, busy_until);
        self.set_inflight(idx, inflight);
    }

    /// [`recompute_projection`](Fleet::recompute_projection) guarded for
    /// lifecycle methods, which may also run on an eager-mode fleet
    /// (where `busy_until` is authoritative and must not be rebuilt).
    fn reproject(&mut self, idx: usize) {
        if self.steal {
            self.recompute_projection(idx);
        }
    }

    /// Resolve every pending batch whose start time has passed by `now`:
    /// a started batch is pinned to its device, priced with that
    /// device's cycle model, and accounted. No-op outside steal mode.
    ///
    /// Calls strictly below the cached horizon return immediately: no
    /// pending front can start and no open resolution can finish at or
    /// before such a `now`, so the pop loop, the `resolved_open` prune
    /// and the (idempotent) reprojection would all change nothing.
    pub fn advance(&mut self, now: u64) {
        if !self.steal {
            return;
        }
        if self.advance_horizon.is_some_and(|h| now < h) {
            return;
        }
        for i in 0..self.devices.len() {
            loop {
                let (ticket, res) = {
                    let d = &mut self.devices[i];
                    let Some(front) = d.queue.front() else { break };
                    let start = front.ready.max(d.free_at);
                    if start > now {
                        break;
                    }
                    let pb = d.queue.pop_front().expect("front exists");
                    let device_cycles = d.cfg.batch_cycles(&pb.counter);
                    let timeline_cycles = d.cfg.to_timeline(device_cycles);
                    let finish = start + timeline_cycles;
                    d.free_at = finish;
                    d.counter.merge(&pb.counter);
                    d.busy_cycles += timeline_cycles;
                    d.batches += 1;
                    d.images += pb.images;
                    d.resolved_open.push((pb.ticket, finish));
                    (
                        pb.ticket,
                        Resolution {
                            device: i,
                            start,
                            finish,
                            device_cycles,
                            timeline_cycles,
                            images: pb.images,
                        },
                    )
                };
                self.resolutions[ticket] = Some(res);
                self.energy_dirty = true;
            }
            self.devices[i].resolved_open.retain(|&(_, f)| f > now);
            self.recompute_projection(i);
        }
        self.advance_horizon = Some(self.compute_advance_horizon());
    }

    /// Earliest cycle at which `advance` could have any effect: the
    /// minimum over all devices of the front pending batch's start time
    /// (`ready.max(free_at)`) and every open resolution's finish.
    fn compute_advance_horizon(&self) -> u64 {
        let mut h = u64::MAX;
        for d in &self.devices {
            if let Some(front) = d.queue.front() {
                h = h.min(front.ready.max(d.free_at));
            }
            for &(_, f) in &d.resolved_open {
                h = h.min(f);
            }
        }
        h
    }

    /// Projected in-situ finish of the pending batch at `pos` in device
    /// `idx`'s queue (steal mode) — same walk the projections use.
    fn projected_finish(&self, idx: usize, pos: usize) -> u64 {
        let d = &self.devices[idx];
        d.projected_finishes().get(pos).copied().unwrap_or(d.free_at)
    }

    /// One work-stealing pass at virtual time `now` (call after
    /// [`advance`](Fleet::advance)): each drained, idle device — in id
    /// order — may steal one pending batch. The victim is the
    /// most-backlogged device holding a batch that fits the thief's
    /// SRAM (deepest pending queue, then latest projected drain, then
    /// lowest id); the cargo is the victim's latest-deadline such batch;
    /// and the steal only happens when the thief would strictly finish
    /// it earlier than it would finish in place. Returns the number of
    /// migrations performed. No-op outside steal mode.
    pub fn rebalance(&mut self, now: u64) -> u64 {
        if !self.steal {
            return 0;
        }
        let n = self.devices.len();
        let mut stolen = 0u64;
        for thief in 0..n {
            let idle = self.devices[thief].is_live()
                && self.devices[thief].queue.is_empty()
                && self.devices[thief].free_at <= now;
            if !idle {
                continue;
            }
            let thief_sram = self.devices[thief].cfg.sram_bytes;
            let mut victims: Vec<usize> = (0..n)
                .filter(|&v| v != thief && !self.devices[v].queue.is_empty())
                .collect();
            victims.sort_by_key(|&v| {
                (
                    std::cmp::Reverse(self.devices[v].queue.len()),
                    std::cmp::Reverse(self.devices[v].busy_until),
                    v,
                )
            });
            for v in victims {
                // Latest-deadline pending batch that fits the thief
                // (ties take the one deepest in the queue: it would
                // start last in place).
                let mut cand: Option<(usize, u64)> = None;
                for (pos, pb) in self.devices[v].queue.iter().enumerate() {
                    if pb.peak_sram > thief_sram {
                        continue;
                    }
                    match cand {
                        Some((_, best)) if pb.min_deadline < best => {}
                        _ => cand = Some((pos, pb.min_deadline)),
                    }
                }
                let Some((pos, _)) = cand else { continue };
                let in_situ_finish = self.projected_finish(v, pos);
                let pb_ready = self.devices[v].queue[pos].ready;
                let tcfg = self.devices[thief].cfg;
                let thief_start = now.max(pb_ready).max(self.devices[thief].free_at);
                let thief_finish =
                    thief_start + tcfg.timeline_cost(&self.devices[v].queue[pos].counter);
                if thief_finish >= in_situ_finish {
                    continue;
                }
                let mut pb = self.devices[v]
                    .queue
                    .remove(pos)
                    .expect("candidate position valid");
                // A steal decided at `now` cannot start retroactively.
                pb.ready = pb.ready.max(now);
                self.log_migration(now, v, thief, pb.ticket);
                self.devices[thief].queue.push_back(pb);
                self.devices[thief].migrations += 1;
                self.recompute_projection(v);
                self.recompute_projection(thief);
                stolen += 1;
                break;
            }
        }
        stolen
    }

    /// Resolve every still-pending batch (end of replay, steal mode).
    pub fn finalize(&mut self) {
        self.advance(u64::MAX);
    }

    // ------------------------------------------------------------------
    // Fleet lifecycle (fault injection)
    // ------------------------------------------------------------------

    /// Append a standby device (down until a `Join`): the autoscaler's
    /// growth pool. Returns the new device's index.
    pub fn push_standby(&mut self, cfg: DeviceCfg) -> usize {
        let id = self.devices.len();
        let mut d = Device::new(id, cfg);
        d.up = false;
        self.by_busy.insert((d.busy_until, id));
        self.devices.push(d);
        id
    }

    /// Live (up, not draining) devices.
    pub fn live_count(&self) -> usize {
        self.devices.iter().filter(|d| d.is_live()).count()
    }

    /// A (possibly new) device joins at `now`: placeable again, at its
    /// registry base clock, and unable to start work before the join.
    pub fn device_join(&mut self, idx: usize, now: u64) {
        let d = &mut self.devices[idx];
        d.up = true;
        d.draining = false;
        d.cfg.clock_hz = d.base_clock_hz;
        d.free_at = d.free_at.max(now);
        let busy_until = d.busy_until.max(now);
        self.set_busy_until(idx, busy_until);
        self.advance_horizon = None;
        self.reproject(idx);
    }

    /// Planned departure at `now`: the device stops accepting work, its
    /// started batch finishes, and every committed-but-unstarted batch
    /// is cancelled. Returns the cancelled tickets — the replay layer
    /// re-admits their deadline-carrying members.
    pub fn device_leave(&mut self, idx: usize, now: u64) -> Vec<usize> {
        self.advance(now);
        let d = &mut self.devices[idx];
        d.up = false;
        d.draining = false;
        let cancelled: Vec<usize> = d.queue.drain(..).map(|pb| pb.ticket).collect();
        self.reproject(idx);
        cancelled
    }

    /// Unplanned death at `now`: like a leave, but the in-flight batch
    /// dies too — its resolution is revoked, the unexecuted timeline
    /// tail and the lost results are rolled back, while the cycles and
    /// energy burned before the crash stay spent (crashed work is
    /// wasted, not free). Returns every cancelled ticket, pending and
    /// started alike.
    pub fn device_crash(&mut self, idx: usize, now: u64) -> Vec<usize> {
        self.advance(now);
        let mut cancelled: Vec<usize> =
            self.devices[idx].queue.drain(..).map(|pb| pb.ticket).collect();
        // After `advance(now)` every open entry finishes strictly after
        // `now` and started at or before it.
        let open = std::mem::take(&mut self.devices[idx].resolved_open);
        for (ticket, _) in open {
            let res = self.resolutions[ticket]
                .take()
                .expect("started batch was resolved");
            let d = &mut self.devices[idx];
            d.busy_cycles -= res.finish - now;
            d.batches -= 1;
            d.images -= res.images;
            cancelled.push(ticket);
        }
        let d = &mut self.devices[idx];
        d.up = false;
        d.draining = false;
        d.free_at = d.free_at.min(now);
        self.energy_dirty = true;
        self.reproject(idx);
        cancelled
    }

    /// DVFS throttle: the device's effective clock drops to `clock_hz`,
    /// repricing every batch it starts from now on (started batches keep
    /// the price they resolved at). The registry base clock is
    /// remembered for [`device_restore`](Fleet::device_restore).
    pub fn device_throttle(&mut self, idx: usize, clock_hz: u64) {
        self.devices[idx].cfg.clock_hz = clock_hz.max(1);
        self.reproject(idx);
    }

    /// Lift a throttle and/or a drain: clock back to the registry base,
    /// new placements allowed again. (Does not revive a down device —
    /// that is a `Join`.)
    pub fn device_restore(&mut self, idx: usize) {
        let d = &mut self.devices[idx];
        d.cfg.clock_hz = d.base_clock_hz;
        d.draining = false;
        self.reproject(idx);
    }

    /// Begin draining at `now`: no new placements, in-flight work
    /// finishes, and every pending batch migrates immediately to the
    /// live host that finishes it earliest (the steal machinery's move,
    /// logged and counted as a migration). Batches no live device can
    /// hold are cancelled and returned for re-admission.
    pub fn device_drain(&mut self, idx: usize, now: u64) -> Vec<usize> {
        self.advance(now);
        self.devices[idx].draining = true;
        let pending: Vec<PendingBatch> = self.devices[idx].queue.drain(..).collect();
        self.reproject(idx);
        let mut cancelled = Vec::new();
        for mut pb in pending {
            let host = (0..self.devices.len())
                .filter(|&i| {
                    i != idx
                        && self.devices[i].is_live()
                        && pb.peak_sram <= self.devices[i].cfg.sram_bytes
                })
                .min_by_key(|&i| {
                    let d = &self.devices[i];
                    let start = pb.ready.max(now).max(d.busy_until.max(d.free_at));
                    (start + d.cfg.timeline_cost(&pb.counter), i)
                });
            match host {
                Some(h) => {
                    pb.ready = pb.ready.max(now);
                    let ticket = pb.ticket;
                    self.log_migration(now, idx, h, ticket);
                    self.devices[h].queue.push_back(pb);
                    self.devices[h].migrations += 1;
                    self.reproject(h);
                }
                None => cancelled.push(pb.ticket),
            }
        }
        cancelled
    }

    /// Ring-push one migration record, evicting the oldest at capacity.
    fn log_migration(&mut self, now: u64, from: usize, to: usize, ticket: usize) {
        if self.migration_log.len() >= self.migration_log_cap {
            self.migration_log.pop_front();
            self.migration_log_dropped += 1;
        }
        self.migration_log.push_back((now, from, to, ticket));
    }

    /// Final placement of a deferred batch; `None` until the batch has
    /// been resolved by [`advance`](Fleet::advance) /
    /// [`finalize`](Fleet::finalize).
    pub fn resolution(&self, ticket: usize) -> Option<Resolution> {
        self.resolutions.get(ticket).copied().flatten()
    }

    /// Total migrations across the fleet.
    pub fn migrations(&self) -> u64 {
        self.devices.iter().map(|d| d.migrations).sum()
    }

    /// Fleet-wide energy spent so far — the autoscaler's budget signal,
    /// read every arrival. Cached between counter mutations (commits,
    /// resolutions, crash rollbacks); the recomputation is the exact
    /// device-order summation the per-arrival scan performed, so the
    /// cached value is bit-identical to it.
    pub fn total_joules(&mut self) -> f64 {
        if self.energy_dirty {
            self.energy_cache = self.devices.iter().map(|d| d.joules()).sum();
            self.energy_dirty = false;
        }
        self.energy_cache
    }

    /// Take the steal log accumulated since the last drain:
    /// `(now, from, to, ticket)` per migration, in decision order
    /// (oldest entries past [`migration_log_cap`](Fleet::migration_log_cap)
    /// were dropped, counted in
    /// [`migration_log_dropped`](Fleet::migration_log_dropped)).
    pub fn drain_migrations(&mut self) -> Vec<(u64, usize, usize, usize)> {
        self.migration_log.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcu::InstrClass;

    fn cheap_counter() -> Counter {
        let mut c = Counter::new();
        c.charge(InstrClass::Alu, 10);
        c
    }

    fn work<'a>(ready: u64, ctr: &'a Counter, deadlines: &'a [u64]) -> BatchWork<'a> {
        BatchWork {
            ready,
            counter: ctr,
            peak_sram: 1024,
            images: 1,
            deadlines,
        }
    }

    #[test]
    fn m7_timeline_is_identity() {
        let cfg = DeviceCfg::stm32f746();
        assert_eq!(cfg.to_timeline(12_345), 12_345);
        let ctr = cheap_counter();
        assert_eq!(cfg.batch_cycles(&ctr), BATCH_OVERHEAD_CYCLES + 10);
        assert_eq!(cfg.timeline_cost(&ctr), BATCH_OVERHEAD_CYCLES + 10);
    }

    #[test]
    fn m4_is_strictly_slower_on_the_shared_timeline() {
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        // Same ALU-only histogram: identical device cycles, but the
        // slower clock stretches the timeline cost.
        let ctr = cheap_counter();
        assert_eq!(m4.batch_cycles(&ctr), m7.batch_cycles(&ctr));
        assert!(m4.timeline_cost(&ctr) > m7.timeline_cost(&ctr));
        // Long multiplies additionally cost more device cycles on M4.
        let mut heavy = Counter::new();
        heavy.charge(InstrClass::MulLong, 100);
        assert!(m4.batch_cycles(&heavy) > m7.batch_cycles(&heavy));
    }

    #[test]
    fn timeline_conversion_rounds_up() {
        let m4 = DeviceCfg::stm32f446();
        // 1 device cycle at 180 MHz is 1.2 reference cycles -> 2.
        assert_eq!(m4.to_timeline(1), 2);
        // 5 device cycles is exactly 6 reference cycles.
        assert_eq!(m4.to_timeline(5), 6);
        assert_eq!(m4.to_timeline(0), 0);
    }

    #[test]
    fn m4_batch_is_cheaper_in_joules_despite_costing_more_timeline() {
        let ctr = cheap_counter();
        let m7 = DeviceCfg::stm32f746();
        let m4 = DeviceCfg::stm32f446();
        assert!(m4.timeline_cost(&ctr) > m7.timeline_cost(&ctr));
        assert!(
            m4.batch_joules(&ctr) < m7.batch_joules(&ctr),
            "m4 {} J vs m7 {} J",
            m4.batch_joules(&ctr),
            m7.batch_joules(&ctr)
        );
    }

    #[test]
    fn device_energy_accounts_dynamic_plus_static() {
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        let ctr = cheap_counter();
        assert_eq!(fleet.devices[0].joules(), 0.0, "idle device spends nothing");
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        let j = fleet.devices[0].joules();
        assert!(j > 0.0);
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        assert!(fleet.devices[0].joules() > j, "energy is cumulative");
    }

    #[test]
    fn parse_class_accepts_aliases() {
        assert_eq!(DeviceCfg::parse_class("m7").unwrap().class, DeviceClass::M7);
        assert_eq!(DeviceCfg::parse_class("STM32F446").unwrap().class, DeviceClass::M4);
        assert!(DeviceCfg::parse_class("m33").is_none());
    }

    #[test]
    fn serial_device_queues_in_virtual_time() {
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(0, 0, &work(0, &ctr, &[]));
        assert_eq!(a.finish, cost);
        assert_eq!(b.start, cost, "second batch waits for the first");
        assert_eq!(b.finish, 2 * cost);
        assert_eq!(fleet.devices[0].queue_depth(cost / 2), 2);
        assert_eq!(fleet.devices[0].queue_depth(cost + 1), 1);
        assert_eq!(fleet.devices[0].queue_depth(2 * cost), 0);
    }

    #[test]
    fn eligibility_gates_sram_and_depth() {
        let mut small = DeviceCfg::stm32f746();
        small.sram_bytes = 10 * 1024;
        let mut fleet = Fleet::new(vec![small, DeviceCfg::stm32f746()], 1);
        // Device 0 lacks SRAM for a 64 KB arena; device 1 fits.
        assert!(!fleet.eligible(0, 0, 64 * 1024));
        assert!(fleet.eligible(1, 0, 64 * 1024));
        assert!(fleet.fits_anywhere(64 * 1024));
        assert!(!fleet.fits_anywhere(512 * 1024));
        // Fill device 1 to the depth cap; it becomes ineligible until
        // its batch completes.
        let ctr = cheap_counter();
        let d = fleet.commit(1, 0, &work(0, &ctr, &[]));
        assert!(!fleet.eligible(1, 0, 64 * 1024));
        assert_eq!(fleet.next_wake(0, 64 * 1024), Some(d.finish));
        assert!(fleet.eligible(1, d.finish, 64 * 1024));
    }

    #[test]
    fn accounting_accumulates() {
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 4);
        let ctr = cheap_counter();
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(1, 0, &work(0, &ctr, &[]));
        let total_busy: u64 = fleet.devices.iter().map(|d| d.busy_cycles).sum();
        let total_images: u64 = fleet.devices.iter().map(|d| d.images).sum();
        assert_eq!(total_busy, a.timeline_cycles + b.timeline_cycles);
        assert_eq!(total_images, 2);
        assert!(fleet.devices[0].utilization(1_000_000) > 0.0);
        assert_eq!(fleet.devices[0].counter.alu, 10);
        assert_eq!(a.device_cycles, BATCH_OVERHEAD_CYCLES + 10);
    }

    // ------------------------------------------------------------------
    // Work-stealing (deferred commit) mode
    // ------------------------------------------------------------------

    #[test]
    fn deferred_single_device_matches_eager_timeline() {
        // With no steal opportunity (one device), the deferred timeline
        // must resolve to exactly the eager one.
        let ctr = cheap_counter();
        let mut eager = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        let e1 = eager.commit(0, 0, &work(0, &ctr, &[]));
        let e2 = eager.commit(0, 0, &work(0, &ctr, &[]));

        let mut def = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        def.steal = true;
        let d1 = def.commit(0, 0, &work(0, &ctr, &[]));
        let d2 = def.commit(0, 0, &work(0, &ctr, &[]));
        assert_eq!(def.devices[0].pending_len(), 2);
        assert_eq!(def.devices[0].batches, 0, "accounting defers until start");
        def.finalize();
        let r1 = def.resolution(d1.ticket.unwrap()).unwrap();
        let r2 = def.resolution(d2.ticket.unwrap()).unwrap();
        assert_eq!((r1.start, r1.finish), (e1.start, e1.finish));
        assert_eq!((r2.start, r2.finish), (e2.start, e2.finish));
        assert_eq!(r1.device_cycles, e1.device_cycles);
        // Projected dispatch fields matched the final resolution here.
        assert_eq!(d2.finish, r2.finish);
        assert_eq!(def.devices[0].batches, eager.devices[0].batches);
        assert_eq!(def.devices[0].busy_cycles, eager.devices[0].busy_cycles);
        assert_eq!(def.devices[0].counter, eager.devices[0].counter);
    }

    #[test]
    fn idle_device_steals_pending_batch_and_conserves_counters() {
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        // Both batches pile onto device 0; device 1 never gets work.
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(0, 0, &work(0, &ctr, &[]));
        // A dispatch step mid-first-batch: batch A has started (pinned),
        // batch B is still pending — device 1 is idle and steals it.
        let now = 1;
        fleet.advance(now);
        assert_eq!(fleet.devices[0].pending_len(), 1, "A started, B pending");
        let stolen = fleet.rebalance(now);
        assert_eq!(stolen, 1);
        assert_eq!(fleet.devices[1].migrations, 1);
        assert_eq!(fleet.migrations(), 1);
        // The steal log records the migration exactly once.
        let log = fleet.drain_migrations();
        assert_eq!(log, vec![(now, 0, 1, b.ticket.unwrap())]);
        assert!(fleet.drain_migrations().is_empty(), "drain empties the log");
        fleet.finalize();
        let ra = fleet.resolution(a.ticket.unwrap()).unwrap();
        let rb = fleet.resolution(b.ticket.unwrap()).unwrap();
        assert_eq!(ra.device, 0);
        assert_eq!(rb.device, 1, "B migrated to the idle device");
        assert_eq!(rb.start, now, "a steal decided at `now` cannot start earlier");
        assert_eq!(rb.finish, now + cost);
        assert!(rb.finish < 2 * cost, "migration strictly beat the in-situ finish");
        // The batch's work is bit-identical wherever it ran: each device
        // holds exactly one batch's histogram, and the totals conserve.
        assert_eq!(fleet.devices[0].counter, ctr);
        assert_eq!(fleet.devices[1].counter, ctr);
        assert_eq!(fleet.devices[0].batches + fleet.devices[1].batches, 2);
        assert_eq!(fleet.devices[0].images + fleet.devices[1].images, 2);
        assert_eq!(rb.device_cycles, ra.device_cycles, "same histogram, same class, same price");
    }

    #[test]
    fn steal_respects_thief_sram() {
        let ctr = cheap_counter();
        let mut small = DeviceCfg::stm32f746();
        small.sram_bytes = 512; // cannot host the 1024 B arena
        let mut fleet = Fleet::new(vec![DeviceCfg::stm32f746(), small], 8);
        fleet.steal = true;
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.advance(1);
        assert_eq!(fleet.rebalance(1), 0, "the small device cannot steal an oversized batch");
        assert_eq!(fleet.migrations(), 0);
    }

    #[test]
    fn steal_prefers_the_latest_deadline_batch() {
        let ctr = cheap_counter();
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        let running = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let tight = fleet.commit(0, 0, &work(0, &ctr, &[1_000_000]));
        let loose = fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.advance(1);
        assert_eq!(fleet.rebalance(1), 1);
        fleet.finalize();
        assert_eq!(fleet.resolution(running.ticket.unwrap()).unwrap().device, 0);
        assert_eq!(
            fleet.resolution(loose.ticket.unwrap()).unwrap().device,
            1,
            "the no-deadline batch is the safest cargo"
        );
        assert_eq!(
            fleet.resolution(tight.ticket.unwrap()).unwrap().device,
            0,
            "the deadline-critical batch stays put (and now starts earlier)"
        );
    }

    #[test]
    fn no_steal_when_in_situ_finish_is_not_beaten() {
        // The victim's pending batch would finish in place at the same
        // cycle the (equal-speed) thief could — no churn.
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        // At now = cost the first batch just finished; the second starts
        // immediately in place, so a steal cannot strictly improve it.
        fleet.advance(cost);
        assert_eq!(fleet.devices[0].pending_len(), 0, "both batches started back-to-back");
        assert_eq!(fleet.rebalance(cost), 0);
    }

    // ------------------------------------------------------------------
    // Fleet lifecycle (fault injection)
    // ------------------------------------------------------------------

    #[test]
    fn lifecycle_gates_eligibility_admission_and_wake() {
        let ctr = cheap_counter();
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        assert!(fleet.eligible(0, 0, 1024));
        assert!(fleet.fits_anywhere(1024));
        assert_eq!(fleet.live_count(), 1);

        let cancelled = fleet.device_leave(0, 0);
        assert!(cancelled.is_empty(), "nothing was pending");
        assert!(!fleet.eligible(0, 0, 1024));
        assert!(!fleet.fits_anywhere(1024), "a down device cannot admit");
        assert_eq!(fleet.live_count(), 0);

        fleet.device_join(0, 500);
        assert!(fleet.eligible(0, 500, 1024));
        assert!(fleet.fits_anywhere(1024));
        // A rejoined device cannot start work before its join time.
        let d = fleet.commit(0, 500, &work(0, &ctr, &[]));
        fleet.finalize();
        let res = fleet.resolution(d.ticket.unwrap()).unwrap();
        assert!(res.start >= 500);

        // Draining blocks placement and the wake anchor but stays up.
        fleet.devices[0].draining = true;
        assert!(!fleet.eligible(0, 500, 1024));
        assert!(!fleet.fits_anywhere(1024));
        assert_eq!(fleet.next_wake(0, 1024), None, "draining devices anchor no wake");
        fleet.device_restore(0);
        assert!(fleet.eligible(0, res.finish, 1024));
    }

    #[test]
    fn crash_revokes_started_batch_and_rolls_back_unexecuted_work() {
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(0, 0, &work(0, &ctr, &[]));
        // Mid-first-batch: A started (resolved), B still pending.
        let now = cost / 2;
        fleet.advance(now);
        assert!(fleet.resolution(a.ticket.unwrap()).is_some());

        let mut cancelled = fleet.device_crash(0, now);
        cancelled.sort();
        assert_eq!(
            cancelled,
            vec![a.ticket.unwrap(), b.ticket.unwrap()],
            "crash cancels pending AND started-but-unfinished batches"
        );
        assert!(
            fleet.resolution(a.ticket.unwrap()).is_none(),
            "the in-flight resolution is revoked"
        );
        assert!(!fleet.devices[0].up);
        // Results rolled back; the half-executed timeline stays spent.
        assert_eq!(fleet.devices[0].batches, 0);
        assert_eq!(fleet.devices[0].images, 0);
        assert_eq!(fleet.devices[0].busy_cycles, cost - (cost - now));
        assert_eq!(fleet.devices[0].counter, ctr, "burned instructions stay charged");
        // Finalize resolves nothing new and the fleet stays consistent.
        fleet.finalize();
        assert!(fleet.resolution(b.ticket.unwrap()).is_none());
    }

    #[test]
    fn drain_migrates_pending_to_live_host_or_cancels() {
        let ctr = cheap_counter();
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        let b = fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.advance(1);
        let cancelled = fleet.device_drain(0, 1);
        assert!(cancelled.is_empty(), "device 1 hosts the pending batch");
        assert!(fleet.devices[0].draining);
        assert_eq!(fleet.devices[1].migrations, 1);
        assert_eq!(
            fleet.drain_migrations(),
            vec![(1, 0, 1, b.ticket.unwrap())],
            "the drain migration is logged like a steal"
        );
        fleet.finalize();
        assert_eq!(fleet.resolution(a.ticket.unwrap()).unwrap().device, 0);
        assert_eq!(
            fleet.resolution(b.ticket.unwrap()).unwrap().device,
            1,
            "pending work moved off the draining device"
        );

        // No live host that fits: the pending batch is cancelled.
        let mut small = DeviceCfg::stm32f746();
        small.sram_bytes = 512;
        let mut fleet = Fleet::new(vec![DeviceCfg::stm32f746(), small], 8);
        fleet.steal = true;
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        let pend = fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.advance(1);
        let cancelled = fleet.device_drain(0, 1);
        assert_eq!(cancelled, vec![pend.ticket.unwrap()]);
    }

    #[test]
    fn throttle_reprices_subsequent_batches_and_restore_recovers() {
        let ctr = cheap_counter();
        let m7 = DeviceCfg::stm32f746();
        let full_cost = m7.timeline_cost(&ctr);
        let mut fleet = Fleet::homogeneous(1, m7, 8);
        fleet.steal = true;
        // Throttle to half the reference clock before anything starts:
        // the same device cycles cost twice the timeline.
        fleet.device_throttle(0, crate::STM32F746_CLOCK_HZ / 2);
        let a = fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.finalize();
        let res = fleet.resolution(a.ticket.unwrap()).unwrap();
        assert_eq!(res.device_cycles, m7.batch_cycles(&ctr), "device cycles unchanged");
        assert_eq!(res.timeline_cycles, 2 * full_cost, "timeline doubles at half clock");

        fleet.device_restore(0);
        assert_eq!(fleet.devices[0].cfg.clock_hz, crate::STM32F746_CLOCK_HZ);
        let b = fleet.commit(0, res.finish, &work(res.finish, &ctr, &[]));
        fleet.finalize();
        let rb = fleet.resolution(b.ticket.unwrap()).unwrap();
        assert_eq!(rb.timeline_cycles, full_cost, "restored clock, restored price");
    }

    #[test]
    fn migration_log_is_a_bounded_ring_with_drop_counter() {
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        fleet.migration_log_cap = 2;
        fleet.log_migration(10, 0, 1, 100);
        fleet.log_migration(20, 0, 1, 101);
        fleet.log_migration(30, 1, 0, 102);
        assert_eq!(fleet.migration_log_dropped, 1, "the oldest entry was evicted");
        assert_eq!(
            fleet.drain_migrations(),
            vec![(20, 0, 1, 101), (30, 1, 0, 102)],
            "the ring keeps the newest entries in order"
        );
        assert!(fleet.drain_migrations().is_empty());
        assert_eq!(fleet.migration_log_dropped, 1, "draining does not reset the counter");
    }

    // ------------------------------------------------------------------
    // Event-indexed bookkeeping (wake index, advance horizon, energy)
    // ------------------------------------------------------------------

    #[test]
    fn indexed_next_wake_matches_the_scan_in_eager_mode() {
        let ctr = cheap_counter();
        let mut fleet = Fleet::new(
            vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446(), DeviceCfg::stm32f746()],
            8,
        );
        let mut probes = vec![0u64];
        for i in 0..12u64 {
            let d = fleet.commit((i % 3) as usize, i * 1_000, &work(i * 1_000, &ctr, &[]));
            probes.extend([d.finish.saturating_sub(1), d.finish, d.finish + 1]);
        }
        fleet.devices[1].draining = true;
        for &now in &probes {
            for sram in [1024usize, 200 * 1024, 4 << 20] {
                assert_eq!(
                    fleet.next_wake(now, sram),
                    fleet.next_wake_scan(now, sram),
                    "now={now} sram={sram}"
                );
            }
        }
        // The busy-order index is exactly the (busy_until, id) sort.
        let mut expect: Vec<usize> = (0..fleet.len()).collect();
        expect.sort_by_key(|&i| (fleet.devices[i].busy_until, i));
        assert_eq!(fleet.by_busy_order().collect::<Vec<_>>(), expect);
        // The legacy flag routes the public entry point to the scan.
        fleet.indexed = false;
        assert_eq!(fleet.next_wake(0, 1024), fleet.next_wake_scan(0, 1024));
    }

    #[test]
    fn wake_index_survives_churn_and_matches_the_scan() {
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let mut fleet = Fleet::new(
            vec![DeviceCfg::stm32f746(), DeviceCfg::stm32f446(), DeviceCfg::stm32f746()],
            8,
        );
        fleet.steal = true;
        let probes: Vec<u64> = (0..12).map(|i| i * cost / 3).collect();
        let check = |fleet: &Fleet, stage: &str| {
            for &now in &probes {
                for sram in [1024usize, 200 * 1024] {
                    assert_eq!(
                        fleet.next_wake(now, sram),
                        fleet.next_wake_scan(now, sram),
                        "{stage}: now={now} sram={sram}"
                    );
                }
            }
        };
        for i in 0..6u64 {
            fleet.commit((i % 3) as usize, i * 10, &work(i * 10, &ctr, &[]));
        }
        check(&fleet, "after commits");
        fleet.advance(cost / 2);
        fleet.rebalance(cost / 2);
        check(&fleet, "after advance+rebalance");
        fleet.device_crash(1, cost / 2);
        check(&fleet, "after crash");
        fleet.device_drain(2, cost);
        check(&fleet, "after drain");
        fleet.device_join(1, 2 * cost);
        fleet.device_throttle(0, 54_000_000);
        check(&fleet, "after join+throttle");
        fleet.finalize();
        check(&fleet, "after finalize");
    }

    #[test]
    fn sparse_and_dense_advance_schedules_resolve_identically() {
        // The horizon early-exit must make extra advance() calls free:
        // a replay that advances at every probe and one that advances
        // only at the end pin every batch to the same resolution.
        let ctr = cheap_counter();
        let cost = DeviceCfg::stm32f746().timeline_cost(&ctr);
        let build = || {
            let mut f = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
            f.steal = true;
            let mut tickets = Vec::new();
            for i in 0..5u64 {
                let d = f.commit((i % 2) as usize, i * cost / 4, &work(i * cost / 4, &ctr, &[]));
                tickets.push(d.ticket.unwrap());
            }
            (f, tickets)
        };
        let (mut dense, tickets) = build();
        let (mut sparse, tickets2) = build();
        assert_eq!(tickets, tickets2);
        for step in 0..40u64 {
            dense.advance(step * cost / 5);
        }
        dense.finalize();
        sparse.finalize();
        for &t in &tickets {
            let a = dense.resolution(t).unwrap();
            let b = sparse.resolution(t).unwrap();
            assert_eq!(
                (a.device, a.start, a.finish, a.timeline_cycles),
                (b.device, b.start, b.finish, b.timeline_cycles),
                "ticket {t}"
            );
        }
        for (da, db) in dense.devices.iter().zip(&sparse.devices) {
            assert_eq!(da.batches, db.batches);
            assert_eq!(da.busy_cycles, db.busy_cycles);
            assert_eq!(da.busy_until, db.busy_until);
        }
    }

    #[test]
    fn cached_energy_total_is_bit_identical_to_the_scan() {
        let ctr = cheap_counter();
        let mut fleet = Fleet::homogeneous(2, DeviceCfg::stm32f746(), 8);
        assert_eq!(fleet.total_joules(), 0.0, "idle fleet spends nothing");
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        let manual: f64 = fleet.devices.iter().map(|d| d.joules()).sum();
        assert_eq!(fleet.total_joules(), manual, "recompute is the exact scan");
        assert_eq!(fleet.total_joules(), manual, "cached read is stable");
        fleet.commit(1, 0, &work(0, &ctr, &[]));
        let manual2: f64 = fleet.devices.iter().map(|d| d.joules()).sum();
        assert_eq!(fleet.total_joules(), manual2);
        assert!(manual2 > manual, "energy accumulates");

        // Steal mode: commits spend nothing until resolved; a crash
        // rollback re-dirties the cache.
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        fleet.steal = true;
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        fleet.commit(0, 0, &work(0, &ctr, &[]));
        assert_eq!(fleet.total_joules(), 0.0, "deferred commits defer energy");
        fleet.advance(1);
        let after_start: f64 = fleet.devices.iter().map(|d| d.joules()).sum();
        assert_eq!(fleet.total_joules(), after_start);
        assert!(after_start > 0.0, "the started batch is charged");
        fleet.device_crash(0, 2);
        let after_crash: f64 = fleet.devices.iter().map(|d| d.joules()).sum();
        assert_eq!(fleet.total_joules(), after_crash);
        assert!(after_crash < after_start, "the unexecuted tail rolls back");
    }

    #[test]
    fn standby_devices_join_with_fresh_accounting() {
        let mut fleet = Fleet::homogeneous(1, DeviceCfg::stm32f746(), 8);
        let idx = fleet.push_standby(DeviceCfg::stm32f446());
        assert_eq!(idx, 1);
        assert_eq!(fleet.len(), 2);
        assert!(!fleet.devices[idx].up, "standby devices start down");
        assert!(!fleet.eligible(idx, 0, 1024));
        assert_eq!(fleet.live_count(), 1);
        fleet.device_join(idx, 1_000);
        assert!(fleet.eligible(idx, 1_000, 1024));
        assert_eq!(fleet.live_count(), 2);
        assert_eq!(fleet.devices[idx].batches, 0);
    }
}
