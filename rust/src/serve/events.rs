//! The simulation event heap: one ordered queue of virtual-time events
//! driving the replay loop.
//!
//! Every source of "something happens at cycle T" in the serving
//! simulator — request arrivals, fleet-lifecycle events (churn,
//! autoscaling), batch-window expiries inside the [`Batcher`]
//! (super::Batcher) and batch finishes inside the [`Fleet`]
//! (super::Fleet) — is represented as a [`SimEvent`] and ordered by one
//! rule: ascending virtual time, then a kind rank that reproduces the
//! legacy dispatch order (fleet-lifecycle events apply *before* the
//! arrival sharing their cycle), then a stable sequence number so
//! same-cycle events of the same kind keep their source order (burst
//! arrivals, pre-sorted churn streams).
//!
//! The heap is an *index*, not a re-scheduler: decision points (batch
//! flush commits, placements, autoscaler reactions) stay pinned at the
//! exact virtual times the linear-scan replay used, so every report is
//! reproduced bit-for-bit. What changes is the cost of finding the next
//! due event: O(log n) heap operations instead of a linear pass over
//! every device and queue per step. Entries are lazily deleted — a
//! stale entry (its queue already flushed, its batch already resolved)
//! pops, fails its due-check against live state, and is dropped or
//! replaced with a tightened re-estimate. Conservative (early) entries
//! are therefore always safe; *late* entries never happen because every
//! state mutation that can pull an event earlier pushes a fresh entry.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// What a [`SimEvent`] fires. The payload is an index into the owning
/// structure's tables: trace position, fleet-event position, batcher
/// key, or device slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEventKind {
    /// A fleet-lifecycle event (join/leave/crash/throttle/restore/drain)
    /// at `fleet_events[idx]`. Ranks *before* an arrival at the same
    /// cycle — the legacy loop applied every lifecycle event with
    /// `at <= arrival` before processing the arrival.
    FleetLifecycle(usize),
    /// Request arrival: the `idx`-th request drawn from the trace
    /// source. The replay keeps at most one arrival in the heap (the
    /// next undrawn one), so requests are processed in trace order even
    /// for pathological unsorted inputs — exactly like the sequential
    /// scan it replaces.
    Arrival(usize),
    /// A batching window may expire for batcher key `idx`. Owned by the
    /// batcher's due-index; conservative entries re-arm on pop.
    WindowExpiry(usize),
    /// An in-flight batch on device `idx` reaches its finish cycle.
    /// Owned by the fleet's wake index.
    BatchFinish(usize),
}

impl SimEventKind {
    /// Tie rank at equal virtual time. Mirrors the legacy interleave:
    /// lifecycle events apply first, then arrivals; expiry/finish checks
    /// happen at those same boundaries.
    fn rank(&self) -> u8 {
        match self {
            SimEventKind::FleetLifecycle(_) => 0,
            SimEventKind::Arrival(_) => 1,
            SimEventKind::WindowExpiry(_) => 2,
            SimEventKind::BatchFinish(_) => 3,
        }
    }

    fn payload(&self) -> usize {
        match self {
            SimEventKind::FleetLifecycle(i)
            | SimEventKind::Arrival(i)
            | SimEventKind::WindowExpiry(i)
            | SimEventKind::BatchFinish(i) => *i,
        }
    }
}

/// One scheduled simulation event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimEvent {
    /// Virtual cycle the event fires.
    pub at: u64,
    pub kind: SimEventKind,
    /// Stable sequence number breaking (at, kind) ties in source order.
    pub seq: u64,
}

impl SimEvent {
    fn key(&self) -> (u64, u8, u64, usize) {
        (self.at, self.kind.rank(), self.seq, self.kind.payload())
    }
}

impl Ord for SimEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

impl PartialOrd for SimEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// A min-heap of [`SimEvent`]s with lazy deletion.
///
/// `BinaryHeap` is a max-heap; entries are wrapped in [`Reverse`] so
/// [`pop`](EventHeap::pop) yields the earliest event. Sequence numbers
/// are handed out by [`push`](EventHeap::push) in call order, so two
/// same-cycle same-kind events pop in the order they were scheduled.
#[derive(Debug, Default)]
pub struct EventHeap {
    heap: BinaryHeap<Reverse<SimEvent>>,
    next_seq: u64,
}

impl EventHeap {
    pub fn new() -> EventHeap {
        EventHeap {
            heap: BinaryHeap::new(),
            next_seq: 0,
        }
    }

    /// Schedule `kind` at cycle `at`. Returns the assigned sequence
    /// number (monotone per heap).
    pub fn push(&mut self, at: u64, kind: SimEventKind) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(SimEvent { at, kind, seq }));
        seq
    }

    /// Earliest scheduled event, if any.
    pub fn peek(&self) -> Option<&SimEvent> {
        self.heap.peek().map(|Reverse(e)| e)
    }

    /// Cycle of the earliest scheduled event.
    pub fn next_at(&self) -> Option<u64> {
        self.peek().map(|e| e.at)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<SimEvent> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    /// Pop the earliest event iff it fires at or before `now` — the
    /// lazy-deletion workhorse: callers drain due entries, re-validate
    /// each against live state, and re-arm survivors.
    pub fn pop_due(&mut self, now: u64) -> Option<SimEvent> {
        if self.peek().is_some_and(|e| e.at <= now) {
            self.pop()
        } else {
            None
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Drop every entry (end of replay, or a structural reset that
    /// invalidates all scheduled estimates).
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_then_rank_then_seq_order() {
        let mut h = EventHeap::new();
        h.push(50, SimEventKind::Arrival(0));
        h.push(10, SimEventKind::Arrival(1));
        h.push(10, SimEventKind::FleetLifecycle(0));
        h.push(10, SimEventKind::WindowExpiry(3));
        let a = h.pop().unwrap();
        assert_eq!(
            (a.at, a.kind),
            (10, SimEventKind::FleetLifecycle(0)),
            "lifecycle ranks before an arrival at the same cycle"
        );
        assert_eq!(h.pop().unwrap().kind, SimEventKind::Arrival(1));
        assert_eq!(h.pop().unwrap().kind, SimEventKind::WindowExpiry(3));
        assert_eq!(h.pop().unwrap().at, 50);
        assert!(h.pop().is_none());
    }

    #[test]
    fn same_key_events_keep_push_order() {
        let mut h = EventHeap::new();
        for i in 0..5 {
            h.push(7, SimEventKind::FleetLifecycle(i));
        }
        let order: Vec<usize> = std::iter::from_fn(|| h.pop())
            .map(|e| e.kind.payload())
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4], "seq preserves source order");
    }

    #[test]
    fn pop_due_respects_the_deadline() {
        let mut h = EventHeap::new();
        h.push(100, SimEventKind::WindowExpiry(0));
        h.push(200, SimEventKind::WindowExpiry(1));
        assert!(h.pop_due(99).is_none(), "nothing due yet");
        assert_eq!(h.pop_due(100).unwrap().at, 100);
        assert!(h.pop_due(150).is_none(), "next entry still in the future");
        assert_eq!(h.next_at(), Some(200));
        h.clear();
        assert!(h.is_empty());
        assert_eq!(h.len(), 0);
    }
}
